#!/usr/bin/env python3
"""Fail CI on broken intra-repo markdown links.

    python tools/check_links.py README.md docs/*.md

Scans every ``[text](target)`` and bare reference-style ``[text]: target``
link in the given markdown files.  External targets (http/https/mailto)
and pure in-page anchors (``#section``) are ignored; everything else is
resolved relative to the linking file (fragments stripped) and must exist
in the repository.  Exits 1 listing every broken link, 0 when clean --
stdlib only, so the CI docs job can run it before installing anything.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline [text](target) -- target ends at the first unescaped ')';
# reference definitions "[name]: target" at line start
_INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def targets(text: str):
    yield from _INLINE.findall(text)
    yield from _REFDEF.findall(text)


def check_file(md: Path) -> list:
    broken = []
    for raw in targets(md.read_text(encoding="utf-8")):
        if raw.startswith(_EXTERNAL) or raw.startswith("#"):
            continue
        path = raw.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            broken.append((str(md), raw))
    return broken


def main(argv) -> int:
    files = [Path(a) for a in argv]
    if not files:
        print("usage: check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    missing_inputs = [f for f in files if not f.exists()]
    if missing_inputs:
        for f in missing_inputs:
            print(f"input file not found: {f}", file=sys.stderr)
        return 2
    broken = [b for f in files for b in check_file(f)]
    for src, target in broken:
        print(f"BROKEN {src}: {target}")
    checked = len(files)
    if broken:
        print(f"{len(broken)} broken link(s) across {checked} file(s)")
        return 1
    print(f"all intra-repo links resolve across {checked} file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
