"""Hierarchical heavy hitters in 60 lines.

    PYTHONPATH=src python examples/heavy_hitters.py

Builds a Zipf edge stream and a bigram token stream, stacks a prefix
hierarchy of composite-hash sketches over each, and recovers every key
above a frequency threshold by recursive descent -- comparing the batched
Pallas candidate kernel against the jnp reference and against exact ground
truth, then serves top-k through the SketchTopKEndpoint -- and finally
through the sharded service, whose output is bit-identical at any shard
count (the forced 8-device CPU mesh below stands in for real hardware).
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax
import numpy as np

from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.serving.engine import SketchTopKEndpoint
from repro.streams import ngram_hh_workload, zipf_hh_workload

key = jax.random.PRNGKey(0)

for wl, part, ranges in (
    (zipf_hh_workload(n_occurrences=100_000), [(0,), (1,)], (256, 256)),
    (ngram_hh_workload(vocab_size=512, n=2), [(0,), (1,)], (128, 128)),
):
    stream = wl.stream
    base = sk.mod_sketch_spec(stream.schema, part, ranges, 4)
    hspec = hh.HierarchySpec.from_spec(base)
    state = hh.build_hierarchy(hspec, key, stream.items, stream.freqs)
    cands = wl.candidates(base)

    got_ref, est_ref = hh.find_heavy_hitters(hspec, state, wl.threshold, cands)
    got_krn, est_krn = hh.find_heavy_hitters(hspec, state, wl.threshold, cands,
                                             use_kernel=True)
    assert np.array_equal(got_ref, got_krn), "kernel/reference disagree"

    exact = {tuple(r) for r in wl.exact_items.tolist()}
    got = {tuple(r) for r in got_ref.tolist()}
    print(f"{stream.name}: L={stream.total:,} threshold={wl.threshold} "
          f"exact={len(exact)} reported={len(got)} "
          f"false_neg={len(exact - got)} false_pos={len(got - exact)} "
          f"(tables: {hspec.table_cells:,} cells over {hspec.n_levels} levels)")

# serving endpoint: ingest in shards, merge, query top-k
wl = zipf_hh_workload(n_occurrences=100_000, seed=1)
spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (256, 256), 4)
shards = [SketchTopKEndpoint(spec, key) for _ in range(2)]
half = len(wl.stream.items) // 2
shards[0].ingest(wl.stream.items[:half], wl.stream.freqs[:half])
shards[1].ingest(wl.stream.items[half:], wl.stream.freqs[half:])
shards[0].merge_from(shards[1])
items, est = shards[0].topk(10)
true_top = wl.exact_freqs[:10] if len(wl.exact_freqs) >= 10 else wl.exact_freqs
print(f"endpoint top-10 estimates: {est.tolist()}")
print(f"exact top frequencies:     {true_top.tolist()}")

# conservative endpoint: tighter estimates, but single-shard (non-linear
# tables refuse merge_from -- excluded from the cell-wise merge/psum paths)
cons = SketchTopKEndpoint(spec, key, mode="conservative")
cons.ingest(wl.stream.items, wl.stream.freqs)
cons_items, est_cons = cons.topk(10)
# same hash params + same stream => per-key dominance (rank-wise comparison
# would be unsound once the two endpoints' candidate pools diverge)
lin_est = {tuple(k): e for k, e in zip(items.tolist(), est.tolist())}
overlap = [(c, lin_est[tuple(k)])
           for k, c in zip(cons_items.tolist(), est_cons.tolist())
           if tuple(k) in lin_est]
assert overlap and all(c <= l for c, l in overlap), \
    "conservative must be tighter per key"
print(f"conservative top-10:       {est_cons.tolist()} (<= linear per key)")

# sharded service: the same stream through a 1-shard and a 4-shard mesh
# (different block splits!) yields bit-identical level tables and top-k --
# the psum merge of linear tables is exact, so shard count cannot matter
from repro.serving.sharded_topk import ShardedTopKService
svc1 = ShardedTopKService(spec, key, jax.make_mesh((1,), ("data",)))
svc4 = ShardedTopKService(spec, key, jax.make_mesh((4,), ("data",)),
                          sync_every=2)
svc1.ingest(wl.stream.items, wl.stream.freqs)
third = len(wl.stream.items) // 3
for s, e in ((0, third), (third, 2 * third), (2 * third, None)):
    svc4.ingest(wl.stream.items[s:e], wl.stream.freqs[s:e])
for a, b in zip(svc1.state().states, svc4.state().states):
    assert np.array_equal(np.asarray(a.table), np.asarray(b.table))
s1_items, s1_est = svc1.topk(10)
s4_items, s4_est = svc4.topk(10)
assert np.array_equal(s1_items, s4_items) and np.array_equal(s1_est, s4_est)
print(f"sharded top-10 (1==4 shards): {s4_est.tolist()}")
