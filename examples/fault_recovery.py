"""Kill -9 a sketch server and get every bit back.

    PYTHONPATH=src python examples/fault_recovery.py

Walks the durability layer end to end (docs/architecture.md section 9):

  1. wrap a serving engine in DurableSketchEngine: every ingest block is
     WAL-appended before it touches the tables, and periodic snapshots
     (CRC-verified, versioned) bound how much log a recovery replays,
  2. crash it mid-stream through the fault-injection supervisor -- a hard
     kill, no drain, no goodbye snapshot -- then recover() and finish the
     stream: the result is bit-identical to a run that never crashed,
  3. corrupt the newest snapshot on disk before a second crash: the CRC
     check rejects it, recovery falls back to replaying the whole log,
     and the answers are STILL bit-identical,
  4. remesh a sharded service 2 -> 4 shards mid-stream (the elastic
     resize a real fleet does when capacity changes) and verify the
     tables and top-k are bit-identical at any shard count.
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import tempfile

import jax
import numpy as np

from repro.core import sketch as sk
from repro.serving.faults import FaultPlan, ServingSupervisor
from repro.serving.sharded_topk import ShardedTopKService
from repro.serving.sketch_engine import SketchTopKEndpoint
from repro.streams import zipf_hh_workload

wl = zipf_hh_workload(n_occurrences=60_000, n_edges=8_000, seed=5)
spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (128, 128), 4)
key = jax.random.PRNGKey(0)
items, freqs = wl.stream.items, wl.stream.freqs
BLOCK = 128
ops = [("block", items[s:s + BLOCK], freqs[s:s + BLOCK])
       for s in range(0, len(items), BLOCK)]
print(f"stream: {len(ops)} blocks, {wl.stream.total} total mass")

# the run that never crashes, as ground truth
ref = SketchTopKEndpoint(spec, key)
for _, it, fr in ops:
    ref.ingest(it, fr)
ref_ids, ref_est = ref.topk(10)

# --- 1+2: hard kill mid-stream, recover, finish -------------------------
with tempfile.TemporaryDirectory() as d:
    sup = ServingSupervisor(d, lambda: SketchTopKEndpoint(spec, key),
                            snapshot_every=8)
    eng, rep = sup.run(ops, FaultPlan(crash_after_ops=len(ops) // 2))
    ids, est = eng.topk(10)
    assert np.array_equal(ids, ref_ids) and np.array_equal(est, ref_est)
    r = rep.recoveries[-1]
    print(f"killed after {len(ops)//2} ops: restored snapshot "
          f"step={r.restored_step}, replayed {r.replayed_blocks} WAL "
          f"blocks -> top-10 bit-identical to the uninterrupted run")

# --- 3: the newest snapshot is corrupted on disk ------------------------
with tempfile.TemporaryDirectory() as d:
    sup = ServingSupervisor(d, lambda: SketchTopKEndpoint(spec, key),
                            snapshot_every=8)
    plan = FaultPlan(crash_after_ops=len(ops) // 2,
                     corrupt_newest_snapshot=True)
    eng, rep = sup.run(ops, plan)
    ids, est = eng.topk(10)
    assert np.array_equal(ids, ref_ids) and np.array_equal(est, ref_est)
    r = rep.recoveries[-1]
    print(f"corrupted snapshot(s) {r.corrupted_steps} rejected by CRC, "
          f"fell back and replayed {r.replayed_blocks} blocks -> still "
          f"bit-identical")

# --- 4: elastic 2 -> 4 shard remesh mid-stream --------------------------
svc = ShardedTopKService(spec, key, jax.make_mesh((2,), ("data",)),
                         sync_every=4)
half = len(ops) // 2
for _, it, fr in ops[:half]:
    svc.ingest(it, fr)
svc.remesh(jax.make_mesh((4,), ("data",)))
for _, it, fr in ops[half:]:
    svc.ingest(it, fr)
ids, est = svc.topk(10)
assert np.array_equal(ids, ref_ids) and np.array_equal(est, ref_est)
print(f"remeshed 2 -> 4 shards mid-stream -> top-10 bit-identical "
      f"(total={svc.total})")

print("OK")
