"""Quickstart: MOD-Sketch in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a Twitter-like edge stream, runs the paper's full pipeline
(sample -> Thm-3 ranges -> Thm-4/5 selection -> build -> query) and prints
the observed error of every method.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.selection import choose_sketch
from repro.streams import observed_error, zipf_graph_stream

stream = zipf_graph_stream(n_src=20_000, n_tgt=60_000, n_edges=400_000,
                           n_occurrences=2_000_000, s_src=0.7, s_tgt=0.7)
print(f"stream: {len(stream.items):,} distinct edges, L={stream.total:,}")

h, w = 4096, 5
rng = np.random.default_rng(0)
key = jax.random.PRNGKey(0)

# 1. uniform 2% sample (paper SIV: "2~4% of the stream")
s_items, s_freqs = stream.sample(0.02, rng)

# 2+3. optimal MOD ranges (Thm 3) + sigma-based selection (Thm 4/5)
result = choose_sketch(s_items, s_freqs, stream.schema, h, w, key)
a, b = result.mod_ranges
print(f"Thm-3 ranges: a={a}, b={b} (equal split would be {int(h**0.5)}^2); "
      f"selected: {result.choice} (sigma={result.sigma})")

# 4. build each sketch over the full stream and compare on both query
#    mixes -- the sigma-selector optimises the OVERALL error profile
#    (top-k heavy hitters tend to favour Count-Min, tail queries favour
#    composite hashing; see EXPERIMENTS.md SRepro, Fig 4 row)
qsets = {"top-500": stream.top_k_queries(500),
         "random-500": stream.random_k_queries(500, rng)}
for name, spec in {
    "count-min": sk.count_min_spec(stream.schema, h, w),
    "equal-sketch": sk.equal_sketch_spec(stream.schema, h, w),
    "mod-sketch": sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (a, b), w),
    "selected": result.spec,
}.items():
    state = sk.build_sketch(spec, key, stream.items, stream.freqs)
    errs = []
    for qname, (qi, qf) in qsets.items():
        est = np.asarray(sk.query_jit(spec, state, jnp.asarray(qi)))
        errs.append(f"{qname}={observed_error(est, qf):.3f}")
    print(f"{name:13s} {'  '.join(errs)}   ({spec.describe()})")
