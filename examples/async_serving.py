"""Async sketch serving: pipelined ingest + bounded-staleness queries.

    PYTHONPATH=src python examples/async_serving.py

Exercises the SketchServeEngine the way a serving deployment would
(docs/architecture.md section 8):

  1. the staleness contract: ingest moves the engine's mass watermark
     while queries serve from a snapshot; a query only refreshes when the
     mass ingested since the snapshot exceeds ``max_staleness``, and after
     any query the observed staleness is back within the bound,
  2. an ingest thread streams blocks while the main thread submits
     concurrent top-k / heavy-hitter requests and serves them with one
     batched flush per round (one packed descent launch per level per
     round, every answer mutually consistent on one snapshot),
  3. after the ingest thread joins, drain + sync gives staleness 0 and
     answers bit-identical to a synchronous SketchTopKEndpoint fed the
     same stream -- the pipeline and the snapshots are invisible at the
     barrier.
"""
import threading

import jax
import numpy as np

from repro.core import sketch as sk
from repro.serving.sketch_engine import SketchServeEngine, SketchTopKEndpoint
from repro.streams import zipf_hh_workload

wl = zipf_hh_workload(n_occurrences=120_000, n_edges=12_000, seed=7)
spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (128, 128), 4)
key = jax.random.PRNGKey(0)
items, freqs = wl.stream.items, wl.stream.freqs
BLOCK = 1024
blocks = [(items[s:s + BLOCK], freqs[s:s + BLOCK])
          for s in range(0, len(items), BLOCK)]
BOUND = wl.stream.total // 4

eng = SketchServeEngine(SketchTopKEndpoint(spec, key), max_staleness=BOUND)

# phase 1: the staleness contract, single-threaded so it is observable.
# Ingest moves the watermark; a query refreshes only past the bound.
half = len(blocks) // 2
max_seen = 0
for b, (bi, bf) in enumerate(blocks[:half]):
    eng.ingest(bi, bf)
    if (b + 1) % 2 == 0:
        before = eng.staleness
        max_seen = max(max_seen, before)
        eng.topk(5)
        assert eng.staleness <= BOUND, "query served beyond the bound"
        print(f"block {b + 1}: staleness {before:,} -> {eng.staleness:,} "
              f"(bound {BOUND:,})")
assert max_seen > 0, "pipelined ingest should have outrun the snapshot"

# phase 2: ingest thread + concurrent batched queries.  The engine's lock
# makes submit/flush safe against the ingest thread; each flush serves
# every queued request from ONE snapshot via the packed descent.
def feed():
    for bi, bf in blocks[half:]:
        eng.ingest(bi, bf)

t = threading.Thread(target=feed)
t.start()
rounds = 0
while t.is_alive() or rounds == 0:
    eng.submit_topk(10)
    eng.submit_topk(3)
    eng.submit_heavy_hitters(wl.threshold)
    top10, top3, hhs = eng.flush()
    # one snapshot per flush: the smaller request is a prefix of the larger
    assert np.array_equal(top3.items, top10.items[:3])
    rounds += 1
t.join()
print(f"served {rounds} batched rounds (3 requests each) during ingest")

# phase 3: barrier.  drain + sync folds the staged block and refreshes;
# the engine now answers exactly like a synchronous endpoint.
eng.drain()
eng.sync()
assert eng.staleness == 0
ref = SketchTopKEndpoint(spec, key)
ref.ingest(items, freqs)
e_items, e_est = eng.topk(10)
r_items, r_est = ref.topk(10)
assert np.array_equal(e_items, r_items) and np.array_equal(e_est, r_est)
got = {tuple(r) for r in eng.heavy_hitters(wl.threshold)[0].tolist()}
exact = {tuple(r) for r in wl.exact_items.tolist()}
assert exact <= got
print(f"after sync: topk(10) bit-identical to the synchronous endpoint; "
      f"heavy_hitters(>={wl.threshold}) reported={len(got)} "
      f"false_neg={len(exact - got)}")
