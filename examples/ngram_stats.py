"""LM-framework integration: MOD-Sketch n-gram statistics during training.

    PYTHONPATH=src python examples/ngram_stats.py

Trains a reduced gemma2 for a few dozen steps; the train step folds every
batch's bigrams into a MOD-Sketch *inside the jitted step* (zero extra data
passes).  Afterwards the sketch answers corpus-frequency queries, compared
against exact counts collected on the host.
"""
import collections

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import sketch as sk
from repro.training import train_loop as tl
from repro.training.optimizer import OptimizerConfig

cfg = get_reduced("gemma2-9b")
tcfg = tl.TrainConfig(optimizer=OptimizerConfig(lr=1e-3, total_steps=60))
steps, batch, seq = 40, 8, 64

state = tl.init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
step_fn = jax.jit(tl.make_train_step(cfg, tcfg))
data = tl.synthetic_batches(cfg, batch, seq)

exact = collections.Counter()
for s in range(steps):
    b = data(s)
    toks = b["tokens"]
    for row in toks:
        exact.update(zip(row[:-1].tolist(), row[1:].tolist()))
    state, metrics = step_fn(state, {"tokens": jnp.asarray(toks)})
print(f"trained {steps} steps, loss={float(metrics['loss']):.3f}")

spec = tl.make_sketch_spec(cfg)
sketch_state = sk.SketchState(params=state["sketch_params"],
                              table=state["sketch_table"])
top = exact.most_common(10)
grams = np.array([g for g, _ in top], dtype=np.uint32)
est = np.asarray(sk.query_jit(spec, sketch_state, jnp.asarray(grams)))
print(f"{'bigram':>16s} {'exact':>8s} {'sketch':>8s}")
for (g, c), e in zip(top, est):
    print(f"{str(g):>16s} {c:8d} {int(e):8d}")
over = np.mean([int(e) - c for (g, c), e in zip(top, est)])
print(f"mean overestimate on top-10: {over:.1f} "
      f"(sketch never underestimates; total mass {int(np.asarray(sketch_state.table).sum() // spec.width):,})")
