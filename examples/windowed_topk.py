"""Sliding-window and time-decayed top-k in 60 lines.

    PYTHONPATH=src python examples/windowed_topk.py

A drifting stream (the heavy set is re-permuted partway through) flows
through three windowed services -- tumbling, exponential-decay, landmark
-- driven by the DStream-style harness, which advances the epoch clock
from batch timestamps and scores every batch against exact windowed
ground truth.  After the drift, the windowed modes track the new heavy
set while landmark keeps voting for the old one; the closing check shows
the tumbling window is bit-exact against a hierarchy rebuilt from
scratch over the live epochs.  See docs/architecture.md for the design.
"""
import jax
import numpy as np

from repro.core import sketch as sk
from repro.core import window as win
from repro.serving.windowed_topk import WindowedTopKService
from repro.streams import DStreamHarness, drifting_batches

DOMAINS = (1 << 20, 1 << 20)
N_EPOCHS, N_BATCHES, BATCHES_PER_EPOCH = 3, 16, 2
spec = sk.mod_sketch_spec(sk.KeySchema(domains=DOMAINS), [(0,), (1,)],
                          (64, 64), 4)
key = jax.random.PRNGKey(0)


def batches():
    return drifting_batches(DOMAINS, N_BATCHES, rows_per_batch=4_000,
                            batches_per_epoch=BATCHES_PER_EPOCH,
                            drift_every=4, n_keys=1_000, seed=0)


services = {
    "tumbling": WindowedTopKService(spec, key, n_epochs=N_EPOCHS),
    "decay": WindowedTopKService(spec, key, n_epochs=N_EPOCHS,
                                 window_mode="decay", decay=0.5),
    "landmark": WindowedTopKService(spec, key, n_epochs=N_EPOCHS,
                                    window_mode="landmark"),
}
for name, svc in services.items():
    harness = DStreamHarness(svc, k=16, phi=0.01)
    for batch in batches():
        r = harness.step(batch)
    mid, last = harness.reports[N_BATCHES // 2], harness.reports[-1]
    print(f"{name:9s} epoch={last.epoch} window_mass={last.window_total:,.0f} "
          f"are(top16)={last.are_topk:.4f} recall={last.recall:.2f} "
          f"f2_rel_err={last.f2_rel_err:.4f}")
    assert last.recall == 1.0, "no-false-negative guarantee broken"

# the windowed merge is exact: rebuild a hierarchy from scratch over the
# live epochs' batches and compare tables bit for bit
svc = services["tumbling"]
per_epoch = {}
for batch in batches():
    per_epoch.setdefault(batch.t, []).append(batch)
live_epochs = sorted(per_epoch)[-N_EPOCHS:]
blocks = [(np.concatenate([b.items for b in per_epoch[e]]),
           np.concatenate([b.freqs for b in per_epoch[e]]))
          for e in live_epochs]
ref = win.reference_window_state(svc.wspec, key, blocks)
for got, want in zip(svc.state().states, ref.states):
    assert np.array_equal(np.asarray(got.table), np.asarray(want.table))
print(f"window == rebuild-from-scratch over last {N_EPOCHS} epochs: bit-exact")

items, est = svc.topk(5)
print("tumbling top-5:", [(tuple(k), int(e))
                          for k, e in zip(items.tolist(), est)])
