"""End-to-end driver: high-modularity stream summarization at scale.

    PYTHONPATH=src python examples/stream_pipeline.py [--occurrences N]

The paper's kind of system end to end: a modularity-8 IPv4-like trace is
processed in streaming blocks through the Pallas kernel path, with the
greedy Algorithm-1 configuration found from a 2% sample; frequency queries
are answered from the sketch and scored against exact ground truth.
"""
import argparse
import time

import jax
import numpy as np

from repro.core.greedy import greedy_config
from repro.core import sketch as sk
from repro.kernels.ops import KernelSketch
from repro.streams import ipv4_stream, observed_error, reinterpret_modularity

ap = argparse.ArgumentParser()
ap.add_argument("--occurrences", type=int, default=2_000_000)
ap.add_argument("--modularity", type=int, default=8, choices=(2, 4, 8))
ap.add_argument("--h", type=int, default=4096)
ap.add_argument("--w", type=int, default=5)
ap.add_argument("--mode", default="linear", choices=("linear", "conservative"),
                help="conservative = tighter estimates, single-shard only "
                     "(non-linear table, no merge); slower on the interpret "
                     "path, so pair with a smaller --occurrences")
args = ap.parse_args()

base = ipv4_stream(n_src_hosts=30_000, n_tgt_hosts=3_000, n_pairs=120_000,
                   n_occurrences=args.occurrences)
stream = base if args.modularity == 2 else reinterpret_modularity(
    base, args.modularity)
print(f"stream {stream.name}: modularity={stream.schema.modularity}, "
      f"{len(stream.items):,} distinct, L={stream.total:,}")

# --- configure from a 2% sample (Algorithm 1) ------------------------------
rng = np.random.default_rng(0)
t0 = time.perf_counter()
s_items, s_freqs = stream.sample(0.02, rng)
g = greedy_config(s_items, s_freqs, stream.schema, args.h, args.w,
                  jax.random.PRNGKey(0))
print(f"greedy config in {time.perf_counter()-t0:.1f}s "
      f"({g.n_candidates} candidates): {g.spec.describe()}")

# --- stream the full trace through the kernel path -------------------------
ks = KernelSketch(g.spec, jax.random.PRNGKey(1), block_b=1024, mode=args.mode)
t0 = time.perf_counter()
seen = 0
for s in range(0, len(stream.items), 1 << 14):
    blk_i = stream.items[s : s + (1 << 14)]
    blk_f = stream.freqs[s : s + (1 << 14)]
    ks.update(blk_i, blk_f)
    seen += int(blk_f.sum())
dt = time.perf_counter() - t0
print(f"ingested {seen:,} occurrences in {dt:.1f}s ({args.mode} update, "
      f"{seen/dt:.0f} weighted-items/s on the interpret path)")

# --- queries ----------------------------------------------------------------
for qname, (qi, qf) in (
    ("top-500", stream.top_k_queries(500)),
    ("random-500", stream.random_k_queries(500, rng)),
):
    est = ks.query(qi)
    print(f"{qname}: observed error = {observed_error(est, qf):.4f}")

# compare against the baselines on the same budget
for name, spec in {
    "count-min": sk.count_min_spec(stream.schema, args.h, args.w),
    "equal-sketch": sk.equal_sketch_spec(stream.schema, args.h, args.w),
}.items():
    st = sk.build_sketch(spec, jax.random.PRNGKey(1), stream.items,
                         stream.freqs)
    qi, qf = stream.top_k_queries(500)
    import jax.numpy as jnp
    est = np.asarray(sk.query_jit(spec, st, jnp.asarray(qi)))
    print(f"{name}: top-500 observed error = {observed_error(est, qf):.4f}")
