"""Sharded heavy-hitter serving, production shape.

    PYTHONPATH=src python examples/sharded_serving.py

Simulates the serving deployment on a forced 8-device CPU mesh (swap in a
real TPU mesh via repro.launch.mesh.make_production_mesh on hardware):

  1. a single-shard SketchTopKEndpoint handles early traffic,
  2. traffic grows, so the endpoint is promoted in place to a
     ShardedTopKService (to_sharded carries tables, hash params, candidate
     pools, and totals over),
  3. ingest workers feed uneven blocks; the psum sync runs every few
     blocks (lazy local tables between sync points -- no collective on the
     ingest hot path),
  4. top-k and threshold queries serve from the merged level tables, and a
     1-shard reference service run over the identical stream verifies the
     answers are bit-identical (shard-count invariance).
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax
import numpy as np

from repro.core import sketch as sk
from repro.serving.engine import SketchTopKEndpoint
from repro.serving.sharded_topk import ShardedTopKService
from repro.streams import zipf_hh_workload

wl = zipf_hh_workload(n_occurrences=150_000, n_edges=15_000, seed=4)
spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (256, 256), 4)
key = jax.random.PRNGKey(0)
items, freqs = wl.stream.items, wl.stream.freqs

# phase 1: single-shard endpoint takes the first quarter of the stream
q = len(items) // 4
ep = SketchTopKEndpoint(spec, key)
ep.ingest(items[:q], freqs[:q])
print(f"endpoint: ingested {ep.total:,} of {wl.stream.total:,} occurrences")

# phase 2: promote to an 8-shard service on the mesh; conservative
# endpoints would be refused here (non-linear tables cannot psum)
mesh = jax.make_mesh((8,), ("data",))
svc = ep.to_sharded(mesh, sync_every=4)
print(f"promoted to {svc.n_shards} shards over axes {svc.data_axes}")

# phase 3: ingest workers push uneven blocks; sync every 4 blocks
rng = np.random.default_rng(0)
cuts = np.sort(rng.choice(np.arange(q + 1, len(items)), 6, replace=False))
for s, e in zip(np.r_[q, cuts], np.r_[cuts, len(items)]):
    svc.ingest(items[s:e], freqs[s:e])
svc.sync()

# phase 4: serve queries from the merged tables
top_items, top_est = svc.topk(10)
hh_items, hh_est = svc.heavy_hitters(wl.threshold)
exact = {tuple(r) for r in wl.exact_items.tolist()}
got = {tuple(r) for r in hh_items.tolist()}
print(f"topk(10) estimates: {top_est.tolist()}")
print(f"heavy_hitters(>={wl.threshold}): reported={len(got)} "
      f"false_neg={len(exact - got)} false_pos={len(got - exact)}")
assert exact <= got

# verification: a 1-shard service over the identical stream agrees bit-
# for-bit -- linear tables + exact integer psum make sharding invisible
ref = ShardedTopKService(spec, key, jax.make_mesh((1,), ("data",)))
ref.ingest(items, freqs)
for a, b in zip(svc.state().states, ref.state().states):
    assert np.array_equal(np.asarray(a.table), np.asarray(b.table))
r_items, r_est = ref.topk(10)
assert np.array_equal(top_items, r_items) and np.array_equal(top_est, r_est)
print("1-shard reference agrees bit-exactly: shard count is invisible")
