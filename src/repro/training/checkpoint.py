"""Checkpoint save/restore: atomic, manifest-driven, async-capable.

Layout (one directory per step)::

    <dir>/step_000042/
        manifest.json          # step, tree paths, shapes, dtypes, process count
        proc00_shard000.npz    # this process's addressable leaf data

Writes go to ``step_xxx.tmp`` and are renamed into place only after fsync --
a crashed writer never corrupts the latest complete checkpoint, and restore
always picks the newest *complete* step (manifest present).
``AsyncCheckpointer`` moves serialization off the training thread
(device->host copy happens at submit time, so the step buffer donation
stays safe), surfaces worker failures on the next ``wait()``/``submit()``,
and retries transient save failures with backoff.  Multi-host: each
process writes its own addressable shards; restore re-assembles per process
(single-process covers the CPU container; the naming scheme is already
process-indexed).

Manifests are versioned (``format_version: 2``) and carry a CRC32 per
array, so a restore detects silent on-disk corruption
(:class:`CheckpointCorruptionError`) instead of loading garbage tables —
the serving recovery layer (serving/recovery.py) uses this to fall back to
the previous snapshot.  Version-1 manifests (pre-CRC) still restore.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

FORMAT_VERSION = 2


class CheckpointCorruptionError(RuntimeError):
    """A stored array failed its CRC32 check (or the archive is unreadable)."""


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def save(
    directory: str,
    step: int,
    trees: Dict[str, PyTree],
    keep_last: int = 3,
) -> str:
    """Write a checkpoint; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    proc = jax.process_index()
    manifest: Dict[str, Any] = {"step": step, "trees": {},
                                "format_version": FORMAT_VERSION,
                                "n_processes": jax.process_count(),
                                "time": time.time()}
    arrays: Dict[str, np.ndarray] = {}
    for name, tree in trees.items():
        leaves = _flatten_with_paths(tree)
        manifest["trees"][name] = [
            {"path": k, "shape": list(v.shape), "dtype": str(v.dtype),
             "crc32": _crc(v)}
            for k, v in leaves
        ]
        for k, v in leaves:
            arrays[f"{name}::{k}"] = v
    np.savez(os.path.join(tmp, f"proc{proc:02d}_shard000.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(directory, keep_last)
    return final


def _prune(directory: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                best = max(best or -1, int(d.split("_")[1]))
    return best


def _load_step_arrays(
    directory: str,
    step: Optional[int],
    verify: bool,
) -> Tuple[int, Dict[str, Any], Dict[str, np.ndarray]]:
    """Load (step, manifest, {"name::path": array}) with optional CRC check."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    proc = jax.process_index()
    npz_path = os.path.join(path, f"proc{proc:02d}_shard000.npz")
    try:
        with np.load(npz_path) as data:
            arrays = {k: data[k] for k in data.files}
    except (OSError, ValueError, zlib.error) as e:
        raise CheckpointCorruptionError(f"unreadable archive {npz_path}: {e}")
    if verify and manifest.get("format_version", 1) >= 2:
        for name, entries in manifest["trees"].items():
            for e in entries:
                key = f"{name}::{e['path']}"
                if key not in arrays:
                    raise CheckpointCorruptionError(
                        f"step {step}: array {key} missing from archive")
                got = _crc(arrays[key])
                if got != e["crc32"]:
                    raise CheckpointCorruptionError(
                        f"step {step}: CRC mismatch for {key} "
                        f"(stored {e['crc32']:#010x}, got {got:#010x})")
    return manifest["step"], manifest, arrays


def restore(
    directory: str,
    templates: Dict[str, PyTree],
    step: Optional[int] = None,
    verify: bool = True,
) -> Tuple[int, Dict[str, PyTree]]:
    """Restore trees shaped like ``templates`` from the newest (or given) step."""
    step, manifest, data = _load_step_arrays(directory, step, verify)
    out: Dict[str, PyTree] = {}
    for name, template in templates.items():
        leaves, treedef = jax.tree_util.tree_flatten(template)
        paths = [e["path"] for e in manifest["trees"][name]]
        if len(paths) != len(leaves):
            raise ValueError(f"tree {name}: checkpoint has {len(paths)} leaves, "
                             f"template has {len(leaves)}")
        vals = [data[f"{name}::{p}"] for p in paths]
        out[name] = jax.tree_util.tree_unflatten(treedef, vals)
    return step, out


def restore_trees(
    directory: str,
    step: Optional[int] = None,
    verify: bool = True,
) -> Tuple[int, Dict[str, Dict[str, np.ndarray]]]:
    """Template-free restore: ``(step, {tree_name: {leaf_path: array}})``.

    Serving recovery can't always build a shaped template before reading
    (e.g. the saved shard count decides how the backend is rebuilt), so
    this returns the raw flat mapping in manifest order instead.
    """
    step, manifest, data = _load_step_arrays(directory, step, verify)
    out: Dict[str, Dict[str, np.ndarray]] = {}
    for name, entries in manifest["trees"].items():
        out[name] = {e["path"]: data[f"{name}::{e['path']}"] for e in entries}
    return step, out


def list_steps(directory: str) -> List[int]:
    """All complete checkpoint steps under ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return sorted(steps)


class AsyncCheckpointer:
    """Background checkpoint writer (one in flight; host copy at submit).

    ``submit`` first waits on the in-flight write, so a failed prior write
    raises *there* rather than being dropped; ``wait`` re-raises the
    worker's exception.  Transient save failures (OSError and friends) are
    retried ``retries`` times with exponential backoff before giving up.
    """

    def __init__(self, directory: str, keep_last: int = 3,
                 retries: int = 2, backoff: float = 0.05):
        self.directory = directory
        self.keep_last = keep_last
        self.retries = int(retries)
        self.backoff = float(backoff)
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def _save_with_retry(self, step: int, trees: Dict[str, PyTree]) -> None:
        # calls the module-global `save` each attempt so tests can
        # monkeypatch in transient failures
        for attempt in range(self.retries + 1):
            try:
                save(self.directory, step, trees, self.keep_last)
                return
            except OSError:
                # only I/O errors are plausibly transient; a serialization
                # or type error would fail identically on every attempt,
                # so anything else propagates immediately
                if attempt == self.retries:
                    raise
                time.sleep(self.backoff * (2 ** attempt))

    def submit(self, step: int, trees: Dict[str, PyTree]) -> None:
        self.wait()  # raises if the previous write failed -- never dropped
        host_trees = {k: jax.tree.map(lambda x: np.asarray(x), t)
                      for k, t in trees.items()}

        def work():
            try:
                self._save_with_retry(step, host_trees)
            except Exception as e:  # surfaced on next wait()/submit()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


# Back-compat name (pre-recovery-layer callers).
AsyncWriter = AsyncCheckpointer
