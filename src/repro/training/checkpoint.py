"""Checkpoint save/restore: atomic, manifest-driven, async-capable.

Layout (one directory per step)::

    <dir>/step_000042/
        manifest.json          # step, tree paths, shapes, dtypes, process count
        proc00_shard000.npz    # this process's addressable leaf data

Writes go to ``step_xxx.tmp`` and are renamed into place only after fsync --
a crashed writer never corrupts the latest complete checkpoint, and restore
always picks the newest *complete* step (manifest present).  ``AsyncWriter``
moves serialization off the training thread (device->host copy happens at
submit time, so the step buffer donation stays safe).  Multi-host: each
process writes its own addressable shards; restore re-assembles per process
(single-process covers the CPU container; the naming scheme is already
process-indexed).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def save(
    directory: str,
    step: int,
    trees: Dict[str, PyTree],
    keep_last: int = 3,
) -> str:
    """Write a checkpoint; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    proc = jax.process_index()
    manifest: Dict[str, Any] = {"step": step, "trees": {},
                                "n_processes": jax.process_count(),
                                "time": time.time()}
    arrays: Dict[str, np.ndarray] = {}
    for name, tree in trees.items():
        leaves = _flatten_with_paths(tree)
        manifest["trees"][name] = [
            {"path": k, "shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in leaves
        ]
        for k, v in leaves:
            arrays[f"{name}::{k}"] = v
    np.savez(os.path.join(tmp, f"proc{proc:02d}_shard000.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(directory, keep_last)
    return final


def _prune(directory: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                best = max(best or -1, int(d.split("_")[1]))
    return best


def restore(
    directory: str,
    templates: Dict[str, PyTree],
    step: Optional[int] = None,
) -> Tuple[int, Dict[str, PyTree]]:
    """Restore trees shaped like ``templates`` from the newest (or given) step."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    proc = jax.process_index()
    data = np.load(os.path.join(path, f"proc{proc:02d}_shard000.npz"))

    out: Dict[str, PyTree] = {}
    for name, template in templates.items():
        leaves, treedef = jax.tree_util.tree_flatten(template)
        paths = [e["path"] for e in manifest["trees"][name]]
        if len(paths) != len(leaves):
            raise ValueError(f"tree {name}: checkpoint has {len(paths)} leaves, "
                             f"template has {len(leaves)}")
        vals = [data[f"{name}::{p}"] for p in paths]
        out[name] = jax.tree_util.tree_unflatten(treedef, vals)
    return manifest["step"], out


class AsyncWriter:
    """Background checkpoint writer (one in flight; host copy at submit)."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def submit(self, step: int, trees: Dict[str, PyTree]) -> None:
        self.wait()
        host_trees = {k: jax.tree.map(lambda x: np.asarray(x), t)
                      for k, t in trees.items()}

        def work():
            try:
                save(self.directory, step, host_trees, self.keep_last)
            except Exception as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
