"""Sketch-based gradient compression with error feedback (beyond paper).

SketchML/Sketched-SGD-style: instead of all-reducing N gradient values per
leaf, each worker folds its gradient into a signed Count-Sketch (w x h table,
core/countsketch.py) whose *index keys are modular*: a weight coordinate is
the ordered pair (row, col) of its matrix -- exactly the composite-key
setting of the paper, so the table indexing reuses the MOD composite-hash
machinery (ranges split per Thm 3 intuition: skew between fan-in and fan-out
marginals).  Tables are linear => the DP all-reduce of tables equals the
sketch of the all-reduced gradient.  Decompression dequeries every
coordinate and keeps the top-k heavy hitters; the compression error goes
into an error-feedback residual re-injected next step (EF-SGD).

Contract: effective for *heavy-tailed* gradients (the empirically typical
case, and the regime Sketched-SGD analyzes).  A dense isotropic gradient
carries N independent values and cannot be represented in w*h < N cells --
EF then only bounds, not shrinks, the residual.

Compression ratio per leaf = N / (w*h).  Leaves below ``min_size`` are sent
uncompressed (bias/norm vectors are tiny and precision-critical).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import countsketch as cs
from repro.core import sketch as sk
from repro.core.hashing import KeySchema

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    width: int = 3            # sketch rows (median estimator)
    ratio: float = 16.0       # target N / (w*h) compression
    min_size: int = 1 << 14   # leaves smaller than this pass through
    beta_rows_cols: float = 1.0  # MOD range split ratio between (row, col)


def _leaf_schema(shape: Tuple[int, ...]) -> KeySchema:
    """Coordinates of a >=2D leaf as a modularity-2 (row, col) key."""
    rows = int(jnp.prod(jnp.array(shape[:-1]))) if len(shape) > 1 else 1
    cols = int(shape[-1])
    return KeySchema(domains=(max(2, rows), max(2, cols)))


def _leaf_spec(cfg: CompressionConfig, shape: Tuple[int, ...]) -> sk.SketchSpec:
    n = int(jnp.prod(jnp.array(shape)))
    h = max(64, int(n / (cfg.ratio * cfg.width)))
    schema = _leaf_schema(shape)
    # MOD split of h between the (row, col) modules
    a = max(2, int(round((h * cfg.beta_rows_cols) ** 0.5)))
    b = max(2, int(round(h / a)))
    return sk.mod_sketch_spec(schema, [(0,), (1,)], (a, b), cfg.width)


def _coords(shape: Tuple[int, ...]) -> jax.Array:
    """uint32[N, 2] (row, col) coordinates for a leaf."""
    rows = int(jnp.prod(jnp.array(shape[:-1]))) if len(shape) > 1 else 1
    cols = int(shape[-1])
    r = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0).reshape(-1)
    c = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1).reshape(-1)
    return jnp.stack([r, c], axis=-1)


class CompressionState(NamedTuple):
    residual: PyTree          # error-feedback memory
    cs_states: PyTree         # per-leaf CountSketchState (params fixed)


def init_compression(cfg: CompressionConfig, params: PyTree,
                     key: jax.Array) -> CompressionState:
    leaves, treedef = jax.tree.flatten(params)
    residual = [jnp.zeros(p.shape, jnp.float32) if p.size >= cfg.min_size else None
                for p in leaves]
    states = []
    for i, p in enumerate(leaves):
        if p.size >= cfg.min_size:
            spec = _leaf_spec(cfg, p.shape)
            states.append(cs.init_state(spec, jax.random.fold_in(key, i)))
        else:
            states.append(None)
    return CompressionState(
        residual=jax.tree.unflatten(treedef, residual),
        cs_states=jax.tree.unflatten(treedef, states),
    )


def compress_decompress(
    cfg: CompressionConfig,
    grads: PyTree,
    state: CompressionState,
) -> Tuple[PyTree, CompressionState, Dict[str, jax.Array]]:
    """grad -> sketch -> estimate, with error feedback.

    Returns (decompressed grads, new state, metrics).  In the distributed
    runtime the table (not the gradient) is what crosses the DP axes; by
    linearity psum(table_i) == table(psum(grad_i)), so applying this per
    worker before the grad all-reduce is exact w.r.t. the compression model.
    """
    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = treedef.flatten_up_to(state.residual)
    s_leaves = treedef.flatten_up_to(state.cs_states)

    out_g, out_r, out_s = [], [], []
    sq_err = jnp.float32(0.0)
    sq_tot = jnp.float32(0.0)
    for g, r, st in zip(g_leaves, r_leaves, s_leaves):
        if st is None:
            out_g.append(g)
            out_r.append(r)
            out_s.append(st)
            continue
        spec = _leaf_spec(cfg, g.shape)
        corrected = g.astype(jnp.float32) + r
        items = _coords(g.shape)
        vals = corrected.reshape(-1)
        st_new = cs.update(spec, st._replace(table=jnp.zeros_like(st.table)),
                           items, vals)
        rows, est = cs.query_rows(spec, st_new, items)
        # Two-round protocol (Sketched-SGD practice): the sketch finds
        # WHERE the heavy coordinates are (top-k of the dequeried medians);
        # their VALUES travel in a second exact exchange of k (index, value)
        # pairs.  Raw median values at compression density carry false
        # heavy hitters whose wrong-value subtraction compounds in the EF
        # residual (measured: divergence); with exact second-round values a
        # false positive merely spends one of the k slots.  Comm cost per
        # leaf = w*h table (all-reduced) + 2k words.
        k = max(1, spec.table_size // 4)
        thresh = jax.lax.top_k(jnp.abs(est), k)[0][-1]
        selected = jnp.abs(est) >= thresh
        est = jnp.where(selected, vals, 0.0).reshape(g.shape)
        new_r = corrected - est
        sq_err = sq_err + jnp.sum(jnp.square(new_r))
        sq_tot = sq_tot + jnp.sum(jnp.square(corrected))
        out_g.append(est.astype(g.dtype))
        out_r.append(new_r)
        out_s.append(st_new)

    metrics = {"compress_rel_err": jnp.sqrt(sq_err / (sq_tot + 1e-12))}
    return (
        jax.tree.unflatten(treedef, out_g),
        CompressionState(residual=jax.tree.unflatten(treedef, out_r),
                         cs_states=jax.tree.unflatten(treedef, out_s)),
        metrics,
    )


def compression_ratio(cfg: CompressionConfig, params: PyTree) -> float:
    """Achieved bytes(grads) / bytes(tables) over compressed leaves."""
    n_grad = n_table = 0
    for p in jax.tree.leaves(params):
        if p.size >= cfg.min_size:
            spec = _leaf_spec(cfg, p.shape)
            n_grad += p.size
            n_table += spec.width * spec.table_size
    return n_grad / max(1, n_table)
