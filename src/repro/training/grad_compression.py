"""Sketch-based gradient compression with error feedback (beyond paper).

SketchML/Sketched-SGD-style: instead of all-reducing N gradient values per
leaf, each worker folds its gradient into a *hierarchical* signed
Count-Sketch (core/countsketch.py) whose index keys are modular: a weight
coordinate is the ordered pair (row, col) of its matrix -- exactly the
composite-key setting of the paper, so the table indexing reuses the MOD
composite-hash machinery (ranges split per Thm 3 intuition: skew between
fan-in and fan-out marginals).  Tables are linear => the DP all-reduce of
tables equals the sketch of the all-reduced gradient, so with
``axis_name`` set the tables (not the gradients) are what cross the DP
axis.

Decompression is a *descent*, not a dense dequery: level 0 of the
hierarchy estimates every ROW-prefix's signed mass, a beam of the
heaviest rows survives, and only the [beam, cols] candidate grid of the
finest level is dequeried before an exact top-k.  For k << rows this never
materializes the [w, N] estimate tensor the old path built.  The sketch
only finds WHERE the heavy coordinates are (Sketched-SGD two-round
practice); their VALUES travel in a second exact exchange of k (index,
value) pairs -- raw median values at compression density carry false heavy
hitters whose wrong-value subtraction compounds in the EF residual
(measured: divergence); with exact second-round values a false positive
merely spends one of the k slots.  The compression error goes into an
error-feedback residual re-injected next step (EF-SGD).

Contract: effective for *heavy-tailed* gradients (the empirically typical
case, and the regime Sketched-SGD analyzes).  A dense isotropic gradient
carries N independent values and cannot be represented in w*h < N cells --
EF then only bounds, not shrinks, the residual.

Comm bytes per leaf = f32 tables of every level (all-reduced) + 8k for the
second round; :func:`compression_ratio` reports exactly that against the
leaf's own dtype.  Leaves below ``min_size`` are sent uncompressed (bias /
norm vectors are tiny and precision-critical).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import countsketch as cs
from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.kernels.hier_query import hier_candidate_query_signed_ref

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    width: int = 3            # sketch rows (median estimator)
    ratio: float = 16.0       # target N / (w*h) cell compression
    min_size: int = 1 << 14   # leaves smaller than this pass through
    beta_rows_cols: float = 1.0  # MOD range split ratio between (row, col)
    k: Optional[int] = None   # heavy coords kept per leaf (None: h // 4)
    beam_factor: int = 2      # descent keeps min(rows, beam_factor * k) rows
    axis_name: Optional[str] = None  # DP axis: all-reduce TABLES, not grads


def _leaf_dims(shape: Tuple[int, ...]) -> Tuple[int, int]:
    """(rows, cols) of a leaf flattened to 2D: all-but-last x last axis."""
    rows = math.prod(shape[:-1]) if len(shape) > 1 else 1
    cols = int(shape[-1])
    return rows, cols


def _leaf_schema(shape: Tuple[int, ...]) -> KeySchema:
    """Coordinates of a leaf as a modularity-2 (row, col) key."""
    rows, cols = _leaf_dims(shape)
    return KeySchema(domains=(max(2, rows), max(2, cols)))


def _leaf_spec(cfg: CompressionConfig, shape: Tuple[int, ...]) -> sk.SketchSpec:
    """Per-leaf finest-level spec with ``prod(ranges) <= h`` GUARANTEED.

    Floor split (core.sketch.equal_ranges discipline): a is the floored
    beta-weighted square root, b the floor of the remaining budget, so the
    table never exceeds its byte allocation -- the old round()-based split
    overshot the budget by up to ~2x for small h (e.g. h=65 -> 8*8=64 ok
    but h=13 -> round(3.6)*round(3.6) = 16 > 13).  Ranges are additionally
    clamped to the module domains: buckets beyond a domain's size can never
    be hit and would silently dilute the real compression ratio.
    """
    rows, cols = _leaf_dims(shape)
    n = rows * cols
    h = max(64, int(n / (cfg.ratio * cfg.width)))
    a = int((h * cfg.beta_rows_cols) ** 0.5)
    a = max(2, min(a, h // 2, max(2, rows)))
    b = max(2, min(h // a, max(2, cols)))
    return sk.mod_sketch_spec(_leaf_schema(shape), [(0,), (1,)], (a, b),
                              cfg.width)


def _coords(shape: Tuple[int, ...]) -> jax.Array:
    """uint32[N, 2] (row, col) coordinates for a leaf."""
    rows, cols = _leaf_dims(shape)
    r = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0).reshape(-1)
    c = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1).reshape(-1)
    return jnp.stack([r, c], axis=-1)


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    """Static (hashable) per-leaf geometry, frozen at init so the compress
    path traces with the plan as pytree aux data -- no host-side spec
    rebuild per call."""
    hspec: hh.HierarchySpec
    shape: Tuple[int, ...]
    rows: int
    cols: int
    k: int                    # exact number of coordinates kept
    beam: int                 # rows surviving the level-0 descent


def _leaf_plan(cfg: CompressionConfig, shape: Tuple[int, ...]) -> LeafPlan:
    spec = _leaf_spec(cfg, shape)
    rows, cols = _leaf_dims(shape)
    k = spec.table_size // 4 if cfg.k is None else int(cfg.k)
    k = max(1, min(k, rows * cols))
    # k heavy coords occupy at most k distinct rows, so a beam of
    # beam_factor * k rows keeps every heavy row -- PROVIDED level 0 can
    # rank rows at all.  When the row range is narrower than the row
    # domain (ranges[0] < rows), several rows share every level-0 cell and
    # inherit each other's magnitude, so a beam would drop true heavy rows
    # near-uniformly (measured); the plan then falls back to beam == rows
    # (the full grid -- the pre-descent dense behavior, no false
    # negatives).  Row-resolving level-0 tables come from the budget/
    # beta_rows_cols split in :func:`_leaf_spec`.
    if spec.ranges[0] >= rows and k < rows:
        beam = max(1, min(rows, cfg.beam_factor * k))
    else:
        beam = rows
    return LeafPlan(hspec=hh.HierarchySpec.from_spec(spec),
                    shape=tuple(int(s) for s in shape),
                    rows=rows, cols=cols, k=k, beam=beam)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LeafCompressor:
    """One leaf's frozen plan + hash draw + precomputed coordinate keys.

    A pytree node: (params, coords) are children (traced through jit /
    carried in the train-state dict), the plan is static aux data, so
    ``compress_decompress`` is jittable with the state as an argument."""
    plan: LeafPlan
    params: cs.CountSketchParams
    coords: jax.Array         # uint32[N, 2]

    def tree_flatten(self):
        return (self.params, self.coords), self.plan

    @classmethod
    def tree_unflatten(cls, plan, children):
        params, coords = children
        return cls(plan, params, coords)


class CompressionState(NamedTuple):
    residual: PyTree          # error-feedback memory (None for passthrough)
    compressors: PyTree       # per-leaf LeafCompressor (None for passthrough)


def init_compression(cfg: CompressionConfig, params: PyTree,
                     key: jax.Array) -> CompressionState:
    leaves, treedef = jax.tree.flatten(params)
    residual, comps = [], []
    for i, p in enumerate(leaves):
        if p.size >= cfg.min_size:
            plan = _leaf_plan(cfg, p.shape)
            cparams = cs.init_params(plan.hspec.levels[-1],
                                     jax.random.fold_in(key, i))
            residual.append(jnp.zeros(p.shape, jnp.float32))
            comps.append(LeafCompressor(plan, cparams, _coords(p.shape)))
        else:
            residual.append(None)
            comps.append(None)
    return CompressionState(
        residual=jax.tree.unflatten(treedef, residual),
        compressors=jax.tree.unflatten(treedef, comps),
    )


def _descend_topk(plan: LeafPlan, params: cs.CountSketchParams,
                  tables: Tuple[jax.Array, ...]) -> jax.Array:
    """Exact-k heavy-coordinate selection by hierarchy descent: int32[k]
    flat (row * cols + col) indices.  Static shapes throughout (beam and k
    are plan constants), so this traces under jit.

    ``top_k`` returns k distinct positions, so exactly k coordinates come
    back -- the old ``|est| >= thresh`` mask over-selected on ties (every
    coordinate equal to the k-th magnitude survived, silently inflating
    the second-round payload past its k-slot budget).
    """
    hspec = plan.hspec
    hstate = cs.CountSketchHierarchy(params, tables)
    if plan.beam >= plan.rows:
        # Dense fallback (level 0 cannot rank rows, or k >= rows): the
        # grid covers every row, so skip the level-0 query entirely.
        top_rows = jnp.arange(plan.rows, dtype=jnp.uint32)
    else:
        row_ids = jnp.arange(plan.rows, dtype=jnp.uint32)[:, None]
        row_est = cs.hier_query(hspec, hstate, 0, row_ids)        # [rows]
        top_rows = jax.lax.top_k(jnp.abs(row_est), plan.beam)[1]
        top_rows = top_rows.astype(jnp.uint32)

    col_ids = jnp.arange(plan.cols, dtype=jnp.uint32)[:, None]
    pp, cp, sp, sc = cs.candidate_signed_partials(
        hspec, params, 1, top_rows[:, None], col_ids)
    per_row = hier_candidate_query_signed_ref(tables[1], pp, cp, sp, sc)
    grid = jnp.median(per_row, axis=0)                        # [beam, cols]

    flat = jax.lax.top_k(jnp.abs(grid).reshape(-1), plan.k)[1]    # [k]
    bi = flat // plan.cols
    ci = flat % plan.cols
    sel_rows = top_rows[bi].astype(jnp.int32)
    return sel_rows * plan.cols + ci.astype(jnp.int32)


def _compress_leaf(cfg: CompressionConfig, comp: LeafCompressor,
                   g: jax.Array, r: jax.Array):
    """One leaf's sketch -> (DP table reduce) -> descent -> exact values."""
    plan = comp.plan
    corrected = g.astype(jnp.float32) + r
    vals = corrected.reshape(-1)
    tables = tuple(jnp.zeros((s.width, s.table_size), jnp.float32)
                   for s in plan.hspec.levels)
    tables = cs.hier_fold_tables(plan.hspec, comp.params, tables,
                                 comp.coords, vals)
    if cfg.axis_name is not None:
        # linearity: pmean of shard tables == table of the mean gradient,
        # so every worker descends the SAME merged sketch and selects the
        # same k coordinates -- the all-reduce ships w * sum_L h_L cells
        # instead of N gradient values.
        tables = tuple(jax.lax.pmean(t, cfg.axis_name) for t in tables)
    coord_flat = _descend_topk(plan, comp.params, tables)
    sel = vals[coord_flat]
    if cfg.axis_name is not None:
        # second round: k exact local values -> mean (coordinates agree
        # across workers, so this is the exact mean-gradient value).
        sel = jax.lax.pmean(sel, cfg.axis_name)
    dense = jnp.zeros_like(vals).at[coord_flat].set(sel).reshape(g.shape)
    new_r = corrected - dense
    return dense, new_r


def compress_decompress(
    cfg: CompressionConfig,
    grads: PyTree,
    state: CompressionState,
) -> Tuple[PyTree, CompressionState, Dict[str, jax.Array]]:
    """grad -> sketch -> descent top-k -> exact values, with error feedback.

    Jittable: every leaf's spec/coords/descent geometry lives in the state
    (frozen at :func:`init_compression`), so tracing never rebuilds specs.
    With ``cfg.axis_name`` set (running under pmap/shard_map over that
    axis) this performs the FULL cross-worker gradient reduction: sketch
    tables and second-round values are pmean'd for compressed leaves and
    passthrough leaves are pmean'd directly, so the caller must not
    all-reduce the gradients again.
    """
    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = treedef.flatten_up_to(state.residual)
    c_leaves = treedef.flatten_up_to(state.compressors)

    out_g, out_r = [], []
    sq_err = jnp.float32(0.0)
    sq_tot = jnp.float32(0.0)
    for g, r, comp in zip(g_leaves, r_leaves, c_leaves):
        if comp is None:
            if cfg.axis_name is not None:
                g = jax.lax.pmean(g, cfg.axis_name)
            out_g.append(g)
            out_r.append(r)
            continue
        dense, new_r = _compress_leaf(cfg, comp, g, r)
        sq_err = sq_err + jnp.sum(jnp.square(new_r))
        sq_tot = sq_tot + jnp.sum(jnp.square(g.astype(jnp.float32) + r))
        out_g.append(dense.astype(g.dtype))
        out_r.append(new_r)

    metrics = {"compress_rel_err": jnp.sqrt(sq_err / (sq_tot + 1e-12))}
    return (
        jax.tree.unflatten(treedef, out_g),
        CompressionState(residual=jax.tree.unflatten(treedef, out_r),
                         compressors=state.compressors),
        metrics,
    )


def compression_ratio(cfg: CompressionConfig, params: PyTree) -> float:
    """Achieved comm-bytes ratio over compressed leaves.

    Numerator: the bytes a plain all-reduce would ship (leaf size x the
    leaf's own dtype width).  Denominator: what this module actually ships
    -- float32 tables of EVERY hierarchy level (the descent needs the
    coarse tables too, and coarse signs are not derivable from the finest
    table) plus the 8k-byte second round (k int32 indices + k float32
    values).  The old element-count ratio ignored dtypes and both
    overheads, overstating the win.
    """
    raw = comp = 0
    for p in jax.tree.leaves(params):
        if p.size >= cfg.min_size:
            plan = _leaf_plan(cfg, p.shape)
            raw += p.size * p.dtype.itemsize
            comp += 4 * sum(s.width * s.table_size
                            for s in plan.hspec.levels)
            comp += 8 * plan.k
    return raw / max(1, comp)
