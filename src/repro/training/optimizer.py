"""Optimizers built from scratch: AdamW and blockwise-int8 AdamW.

The int8 variant stores both moments quantized per 128-element block along
the last axis (absmax scaling, symmetric for m, asymmetric-positive for v),
cutting optimizer-state HBM from 8 to ~2.07 bytes/param -- what makes the
398B-param jamba train_step fit 16 GB/chip at 512 ways (DESIGN.md S5).
Scale tensors have the same rank as the param, so they inherit the param's
PartitionSpec unchanged.  Leaves smaller than one block stay fp32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"          # adamw | adamw8bit
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), norm


# --------------------------------------------------------------------------
# int8 blockwise moment quantization
# --------------------------------------------------------------------------

def _quantizable(x: jax.Array) -> bool:
    return x.ndim >= 1 and x.shape[-1] % _BLOCK == 0 and x.size >= _BLOCK


def _quantize_sym(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x[..., D] -> (int8[..., D], f32 scales[..., D/BLOCK])."""
    xb = x.reshape(x.shape[:-1] + (x.shape[-1] // _BLOCK, _BLOCK))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale


def _dequantize_sym(q: jax.Array, scale: jax.Array) -> jax.Array:
    qb = q.reshape(q.shape[:-1] + (q.shape[-1] // _BLOCK, _BLOCK))
    return (qb.astype(jnp.float32) * scale[..., None]).reshape(q.shape)


class Moment8(NamedTuple):
    q: jax.Array       # int8, param shape
    scale: jax.Array   # f32, param shape with last dim / BLOCK


# --------------------------------------------------------------------------
# state init / update
# --------------------------------------------------------------------------

def init_state(cfg: OptimizerConfig, params: PyTree) -> Dict[str, PyTree]:
    def zeros_like_moment(p):
        if cfg.name == "adamw8bit" and _quantizable(p):
            return Moment8(
                q=jnp.zeros(p.shape, jnp.int8),
                scale=jnp.zeros(p.shape[:-1] + (p.shape[-1] // _BLOCK,), jnp.float32),
            )
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(zeros_like_moment, params),
        "v": jax.tree.map(zeros_like_moment, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _load_moment(x, sqrt_domain: bool = False) -> jax.Array:
    if isinstance(x, Moment8):
        v = _dequantize_sym(x.q, x.scale)
        return jnp.square(v) if sqrt_domain else v
    return x


def _store_moment(val: jax.Array, like, sqrt_domain: bool = False):
    if isinstance(like, Moment8):
        # second moments span a huge dynamic range; quantizing sqrt(v)
        # halves the exponent range and keeps small denominators accurate
        q, s = _quantize_sym(jnp.sqrt(val) if sqrt_domain else val)
        return Moment8(q=q, scale=s)
    return val


def apply_updates(
    cfg: OptimizerConfig,
    params: PyTree,
    grads: PyTree,
    state: Dict[str, PyTree],
) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, jax.Array]]:
    """AdamW step (decoupled weight decay), moments maybe int8-blockwise."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])

    new_p, new_m, new_v = [], [], []
    for p, g, m0, v0 in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32)
        m = b1 * _load_moment(m0) + (1 - b1) * g
        v = b2 * _load_moment(v0, sqrt_domain=True) + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(_store_moment(m, m0))
        new_v.append(_store_moment(v, v0, sqrt_domain=True))

    params = jax.tree.unflatten(treedef, new_p)
    state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    return params, state, {"lr": lr, "grad_norm": gnorm}


def state_bytes(state: Dict[str, PyTree]) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))
