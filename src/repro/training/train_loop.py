"""Training step factory + loop: the paper's sketch runs *inside* the step.

``make_train_step`` builds the pure step function

    (params, opt_state, sketch_table, batch) ->
        (params, opt_state, sketch_table, metrics)

with the MOD-Sketch n-gram update fused into the lowered computation: the
batch's token bigrams (modularity-2 keys, streams/ngram.py) are folded into
the sketch table every step, so corpus statistics ride along with training
at zero extra passes -- the technique as a first-class framework feature.
Optional sketch-based gradient compression (grad_compression.py) plugs in
between backward and optimizer.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import sketch as sk
from repro.models import transformer as tfm
from repro.streams import ngram
from repro.training import optimizer as opt
from repro.training.grad_compression import (
    CompressionConfig,
    CompressionState,
    compress_decompress,
    init_compression,
)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: opt.OptimizerConfig = opt.OptimizerConfig()
    microbatches: int = 1
    lb_coef: float = 0.01
    sketch_enabled: bool = True
    sketch_seed: int = 0
    compression: CompressionConfig = CompressionConfig()


def make_sketch_spec(cfg: ModelConfig) -> sk.SketchSpec:
    """MOD-Sketch over token bigrams: (prev, next) with equal vocab domains.

    The range split uses the Thm-3 default beta=1 prior (token marginals are
    symmetric for bigrams a priori); training jobs that sample a corpus
    prefix can re-run range_opt and pass a custom spec.
    """
    schema = ngram.ngram_schema(cfg.vocab_size, cfg.sketch_ngrams)
    a = max(2, int(round(cfg.sketch_range ** 0.5)))
    b = max(2, int(round(cfg.sketch_range / a)))
    return sk.mod_sketch_spec(schema, [(i,) for i in range(cfg.sketch_ngrams)],
                              (a, b) if cfg.sketch_ngrams == 2
                              else sk.equal_ranges(cfg.sketch_range, cfg.sketch_ngrams),
                              cfg.sketch_width)


def init_train_state(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    key: jax.Array,
) -> Dict[str, PyTree]:
    params = tfm.init_params(cfg, key)
    state: Dict[str, PyTree] = {
        "params": params,
        "opt": opt.init_state(tcfg.optimizer, params),
    }
    if tcfg.sketch_enabled:
        spec = make_sketch_spec(cfg)
        st = sk.init_state(spec, jax.random.fold_in(key, 17))
        state["sketch_params"] = st.params
        state["sketch_table"] = st.table
    if tcfg.compression.enabled:
        state["compression"] = init_compression(
            tcfg.compression, params, jax.random.fold_in(key, 23))
    return state


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
) -> Callable[..., Tuple[Dict[str, PyTree], Dict[str, jax.Array]]]:
    """Pure train step over the state dict (jit/pjit by the caller)."""
    spec = make_sketch_spec(cfg) if tcfg.sketch_enabled else None

    def loss_for(params, tokens, embeds):
        return tfm.loss_fn(cfg, params, tokens, embeds=embeds,
                           lb_coef=tcfg.lb_coef)

    def step(state: Dict[str, PyTree], batch: Dict[str, jax.Array]):
        params = state["params"]
        tokens = batch["tokens"]
        embeds = batch.get("embeds")

        if tcfg.microbatches > 1:
            nm = tcfg.microbatches
            b = tokens.shape[0]
            assert b % nm == 0, f"batch {b} % microbatches {nm}"
            tk = tokens.reshape(nm, b // nm, *tokens.shape[1:])
            em = (embeds.reshape(nm, b // nm, *embeds.shape[1:])
                  if embeds is not None else None)

            def micro(carry, i):
                g_acc, loss_acc = carry
                e_i = em[i] if em is not None else None
                (loss, mets), g = jax.value_and_grad(loss_for, has_aux=True)(
                    params, tk[i], e_i)
                g_acc = jax.tree.map(lambda a, x: a + x.astype(a.dtype), g_acc, g)
                return (g_acc, loss_acc + loss), mets

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), mets = jax.lax.scan(micro, (g0, 0.0), jnp.arange(nm))
            grads = jax.tree.map(lambda g: g / nm, grads)
            loss = loss / nm
            metrics = {k: jnp.mean(v) for k, v in mets.items()}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_for, has_aux=True)(
                params, tokens, embeds)

        new_state = dict(state)
        if tcfg.compression.enabled:
            grads, comp_state, cmet = compress_decompress(
                tcfg.compression, grads, state["compression"])
            new_state["compression"] = comp_state
            metrics.update(cmet)

        new_params, new_opt, omet = opt.apply_updates(
            tcfg.optimizer, params, grads, state["opt"])
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        metrics.update(omet)
        metrics["loss"] = loss

        if tcfg.sketch_enabled:
            grams = ngram.ngram_items(tokens.astype(jnp.uint32), cfg.sketch_ngrams)
            st = sk.SketchState(params=state["sketch_params"],
                                table=state["sketch_table"])
            freqs = jnp.ones((grams.shape[0],), state["sketch_table"].dtype)
            st = sk.update(spec, st, grams, freqs)
            new_state["sketch_table"] = st.table

        return new_state, metrics

    return step


# --------------------------------------------------------------------------
# synthetic data pipeline (deterministic per step: exactly-once on replay)
# --------------------------------------------------------------------------

def synthetic_batches(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    seed: int = 0,
) -> Callable[[int], Dict[str, np.ndarray]]:
    """step -> batch; Zipf-ish marginals so the n-gram sketch sees skew."""
    def get(step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(seed * 1_000_003 + step)
        z = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
        tokens = (z % cfg.vocab_size).astype(np.int32)
        out = {"tokens": tokens}
        if cfg.frontend:
            out["embeds"] = rng.standard_normal(
                (batch, cfg.frontend_len, cfg.d_model)).astype(np.float32) * 0.02
        return out
    return get


def train(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    num_steps: int,
    batch: int,
    seq: int,
    key: jax.Array,
    ckpt_dir: Optional[str] = None,
    save_every: int = 50,
    log_every: int = 10,
) -> Tuple[Dict[str, PyTree], Dict[str, list]]:
    """Single-host training driver with checkpoint/restart fault tolerance."""
    from repro.training.fault_tolerance import Supervisor

    state = init_train_state(cfg, tcfg, key)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    data = synthetic_batches(cfg, batch, seq)
    history: Dict[str, list] = {"loss": [], "step_time_s": []}

    start = 0
    if ckpt_dir:
        from repro.training import checkpoint as ckpt
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            start, restored = ckpt.restore(ckpt_dir, {"state": state})
            state = restored["state"]

    def one_step(step: int, st):
        batch_np = data(step)
        b = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if "embeds" in b:
            b["embeds"] = b["embeds"].astype(cfg.activation_dtype)
        st, metrics = step_fn(st, b)
        if step % log_every == 0:
            history["loss"].append(float(metrics["loss"]))
        return st

    if ckpt_dir:
        sup = Supervisor(ckpt_dir, save_every=save_every)
        _, state = sup.run({"state": state},
                           lambda s, st: {"state": one_step(s, st["state"])},
                           start, num_steps)
        state = state["state"]
    else:
        for s in range(start, start + num_steps):
            t0 = time.perf_counter()
            state = one_step(s, state)
            history["step_time_s"].append(time.perf_counter() - t0)
    return state, history
