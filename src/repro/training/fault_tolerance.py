"""Fault tolerance: supervised restarts, straggler detection, elastic remesh.

At thousand-node scale the question is not *if* a host dies mid-run but how
cheaply the run continues.  Pieces:

  * :class:`Supervisor` -- wraps the step loop; any exception (device loss,
    preemption, injected test failure) triggers restore-from-latest-
    checkpoint and replay, up to ``max_restarts``.  Deterministic data
    order is keyed by step number, so replayed steps consume identical
    batches (exactly-once semantics w.r.t. optimizer state).
  * :class:`StragglerMonitor` -- EWMA of per-step (per-host, when available)
    wall times; flags hosts slower than ``threshold`` x the fleet median.
    On TPU pods the signal feeds scheduler-level drain/replace; here it
    also powers a test that injects a slow step and asserts detection.
  * :func:`elastic_remesh` -- rebuilds a smaller/larger mesh after failures
    and re-shards live state onto it via device_put (survivor-only
    continuation instead of full job restart).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.training import checkpoint as ckpt

PyTree = Any


@dataclasses.dataclass
class StragglerReport:
    step: int
    host_times: Dict[int, float]
    median: float
    stragglers: List[int]


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, ewma: float = 0.7):
        self.threshold = threshold
        self.ewma = ewma
        self._smoothed: Dict[int, float] = {}
        self.reports: List[StragglerReport] = []

    def record(self, step: int, host_times: Dict[int, float]) -> StragglerReport:
        for h, t in host_times.items():
            prev = self._smoothed.get(h, t)
            self._smoothed[h] = self.ewma * prev + (1 - self.ewma) * t
        med = float(np.median(list(self._smoothed.values())))
        stragglers = [h for h, t in self._smoothed.items()
                      if t > self.threshold * med]
        rep = StragglerReport(step=step, host_times=dict(host_times),
                              median=med, stragglers=stragglers)
        self.reports.append(rep)
        return rep


class Supervisor:
    """Run a step function with checkpoint/restart fault tolerance."""

    def __init__(
        self,
        ckpt_dir: str,
        save_every: int = 50,
        max_restarts: int = 3,
        keep_last: int = 3,
        async_save: bool = True,
        restart_backoff: float = 0.0,
    ):
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.writer = ckpt.AsyncCheckpointer(ckpt_dir, keep_last) if async_save else None
        self.keep_last = keep_last
        # exponential backoff between restarts: a crash-looping fleet must
        # not hammer the checkpoint store at full speed
        self.restart_backoff = float(restart_backoff)
        self.restarts = 0
        self.monitor = StragglerMonitor()

    def run(
        self,
        state: Dict[str, PyTree],
        step_fn: Callable[[int, Dict[str, PyTree]], Dict[str, PyTree]],
        start_step: int,
        num_steps: int,
        on_metrics: Optional[Callable[[int, Dict[str, Any]], None]] = None,
    ) -> Tuple[int, Dict[str, PyTree]]:
        """Advance ``num_steps`` steps with restart-on-failure."""
        step = start_step
        end = start_step + num_steps
        while step < end:
            try:
                t0 = time.perf_counter()
                state = step_fn(step, state)
                dt = time.perf_counter() - t0
                self.monitor.record(step, {jax.process_index(): dt})
                step += 1
                if step % self.save_every == 0:
                    self._save(step, state)
                if on_metrics:
                    on_metrics(step, {"step_time_s": dt})
            except KeyboardInterrupt:
                raise
            except Exception as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}") from e
                if self.restart_backoff > 0:
                    time.sleep(self.restart_backoff * 2 ** (self.restarts - 1))
                step, state = self._restore(state)
        self._save(step, state)
        if self.writer:
            self.writer.wait()
        return step, state

    # ------------------------------------------------------------------
    def _save(self, step: int, state: Dict[str, PyTree]) -> None:
        if self.writer:
            self.writer.submit(step, state)
        else:
            ckpt.save(self.ckpt_dir, step, state, self.keep_last)

    def _restore(self, templates: Dict[str, PyTree]) -> Tuple[int, Dict[str, PyTree]]:
        if self.writer:
            self.writer.wait()
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return 0, templates  # no checkpoint yet: restart from scratch
        return ckpt.restore(self.ckpt_dir, templates)


def elastic_remesh(
    state: PyTree,
    new_mesh: Mesh,
    spec_fn: Callable[[Any], PartitionSpec],
) -> PyTree:
    """Re-shard live state onto a rebuilt mesh (after losing/adding hosts).

    ``spec_fn(leaf)`` gives each array's PartitionSpec on the new mesh;
    arrays are device_put onto the corresponding NamedSharding.  Batch-axis
    shrink (fewer DP replicas) needs no logical change -- the same specs
    re-lay the data over the surviving devices.
    """
    return jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(new_mesh, spec_fn(x))),
        state,
    )
