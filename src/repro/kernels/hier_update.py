"""Pallas TPU kernel: fold one stream block into ALL hierarchy levels in a
single launch.

Hierarchy ingest used to run per level -- L hash passes and L kernel
launches per stream block (that path survives only as the parity
reference, core.hierarchy.update_reference).  Under the shared per-group
hash family (core/hierarchy.py) the level indices nest in the mixed radix,

    idx_L = idx_finest // (r_{L+1} * ... * r_{m-1}),

so one composite hash per (row, item) determines every level's cell.  This
kernel concatenates the levels into one padded table ``[w, sum_L h_L_pad]``
(each level padded to a tile multiple) and runs ONE pallas_call with grid
(w, total_tiles):

  * at each row's first tile the full composite index is hashed once into a
    VMEM scratch (uint32 limb CW arithmetic on the VPU, exactly
    kernels/hashes.row_indices);
  * every tile then derives ITS level's local index with one integer
    division by the tile's static level divisor and a subtraction of the
    tile's base column -- the per-tile metadata rides in a tiny
    ``[n_tiles, 2]`` int32 input indexed by the grid;
  * the scatter-add reuses the one-hot MXU limb-matmul machinery of
    kernels/sketch_update.py verbatim: frequencies split into two 12-bit
    limbs so integer tables accumulate exactly (per-arrival |f| < 2^24,
    wrapper-checked upstream), f32 tables use a single contraction.

Versus L per-level launches this amortizes the chunk/frequency loads and
the B x tile one-hot materialization across levels, hashes each item once
per row instead of once per (row, level), and dispatches once.  The
conservative update is excluded (its row-coupling min forces a sequential
B-loop; it gets the shared cascade at the index level via
core.hierarchy.update_conservative instead).

Bit-exactness: identical to per-level core.sketch.update on integer tables;
for f32 tables exact whenever every per-cell partial sum is exactly
representable (e.g. integer-valued weights < 2^24), tolerance-level
otherwise (MXU accumulation order differs from scatter order).

See docs/architecture.md ("Fused Pallas ingest") for where this kernel
sits in the ingest dataflow.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.hashes import (
    IndexPlan,
    make_plan,
    row_indices,
    row_sign_bits,
    signs_from_bits,
)

_LIMB_BITS = 12
_LIMB_MASK = (1 << _LIMB_BITS) - 1


class HierPlan(NamedTuple):
    """Static layout of the fused multi-level update (hashable, jit-static).

    ``plan`` is the FINEST level's IndexPlan (group-major chunk layout);
    every coarser level's index is plan's composite index divided by its
    ``level_divs`` entry.  Level l's table occupies columns
    ``[level_offsets[l], level_offsets[l] + level_sizes[l])`` of the
    concatenated table, zero-padded up to ``level_pads[l]`` (a tile_h
    multiple)."""
    plan: IndexPlan
    level_sizes: Tuple[int, ...]    # h_l (unpadded cells per row)
    level_pads: Tuple[int, ...]     # h_l padded to a tile_h multiple
    level_divs: Tuple[int, ...]     # idx_l = idx_finest // div_l
    tile_h: int

    @property
    def n_levels(self) -> int:
        return len(self.level_sizes)

    @property
    def padded_cols(self) -> int:
        return sum(self.level_pads)

    @property
    def level_offsets(self) -> Tuple[int, ...]:
        out, off = [], 0
        for p in self.level_pads:
            out.append(off)
            off += p
        return tuple(out)

    @property
    def n_tiles(self) -> int:
        return self.padded_cols // self.tile_h


def make_hier_plan(hspec, tile_h: int = 512) -> HierPlan:
    """Build the fused-update plan from a core.hierarchy.HierarchySpec."""
    fine = hspec.levels[-1]
    if fine.table_size >= 1 << 31:
        raise ValueError("finest table size must fit int32 cell indices")
    pads = tuple(-(-s.table_size // tile_h) * tile_h for s in hspec.levels)
    return HierPlan(
        plan=make_plan(fine),
        level_sizes=tuple(s.table_size for s in hspec.levels),
        level_pads=pads,
        level_divs=tuple(int(d) for d in hspec.level_divisors),
        tile_h=int(tile_h),
    )


def _tile_meta(hplan: HierPlan) -> np.ndarray:
    """int32[n_tiles, 2]: (level divisor, tile's base column within its
    level) per global tile -- the only per-tile state the kernel needs."""
    rows = []
    for l, pad in enumerate(hplan.level_pads):
        for t in range(pad // hplan.tile_h):
            rows.append((hplan.level_divs[l], t * hplan.tile_h))
    return np.asarray(rows, dtype=np.int32)


def _local_lanes(idx_scratch_ref, meta_ref):
    """Derive this tile's local one-hot targets from the cached finest
    index: cascade division by the tile's level divisor, then shift by the
    tile's base column.  Out-of-tile items (and zero-pad rows of the block)
    simply match no lane."""
    idx_fine = idx_scratch_ref[...]                          # int32[B]
    div = meta_ref[0, 0]
    base = meta_ref[0, 1]
    return jax.lax.div(idx_fine, div) - base


def _hier_kernel_int(hplan: HierPlan, tile_h: int,
                     chunks_ref, flo_ref, fhi_ref, q_ref, r_ref, meta_ref,
                     table_in_ref, table_out_ref, idx_scratch_ref):
    """One (row, global tile) step: int table, two 12-bit frequency limbs."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _hash_once():
        # ONE composite hash per (row, item), cached for all tiles/levels
        idx_scratch_ref[...] = row_indices(
            hplan.plan, chunks_ref[...], q_ref[0], r_ref[0])

    local = _local_lanes(idx_scratch_ref, meta_ref)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)    # [B, TH]
    dot_lo = jnp.dot(flo_ref[...][None, :], onehot,
                     preferred_element_type=jnp.float32)      # [1, TH]
    dot_hi = jnp.dot(fhi_ref[...][None, :], onehot,
                     preferred_element_type=jnp.float32)
    delta = dot_lo.astype(jnp.int32) + (dot_hi.astype(jnp.int32) << _LIMB_BITS)
    table_out_ref[...] = table_in_ref[...] + delta


def _hier_kernel_f32(hplan: HierPlan, tile_h: int,
                     chunks_ref, f_ref, q_ref, r_ref, meta_ref,
                     table_in_ref, table_out_ref, idx_scratch_ref):
    """float32-table variant (gradient sketches): single MXU contraction."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _hash_once():
        idx_scratch_ref[...] = row_indices(
            hplan.plan, chunks_ref[...], q_ref[0], r_ref[0])

    local = _local_lanes(idx_scratch_ref, meta_ref)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)
    delta = jnp.dot(f_ref[...][None, :], onehot,
                    preferred_element_type=jnp.float32)
    table_out_ref[...] = table_in_ref[...] + delta[0][None, :]


@functools.partial(
    jax.jit, static_argnames=("hplan", "interpret"), donate_argnums=(1,)
)
def hier_update_pallas(
    hplan: HierPlan,
    table: jax.Array,    # [w, hplan.padded_cols] int or float32 concat table
    chunks: jax.Array,   # uint32[B, C] finest-layout 16-bit key digits
    freqs: jax.Array,    # int32[B] or float32[B]
    q: jax.Array,        # uint32[w, C] shared-family multipliers
    r: jax.Array,        # uint32[w, m] shared-family offsets
    *,
    interpret: bool = True,
) -> jax.Array:
    """Fold one stream block into every level's table in ONE pallas_call.

    Returns the new concatenated table (input buffer donated).  Zero-pad
    rows of the block are no-ops (freq 0); level pad columns are never hit
    (indices < h_l).
    """
    w, cols = table.shape
    if cols != hplan.padded_cols:
        raise ValueError(
            f"concatenated table has {cols} columns, plan expects "
            f"{hplan.padded_cols}")
    tile_h = hplan.tile_h
    b, c = chunks.shape
    grid = (w, hplan.n_tiles)
    meta = jnp.asarray(_tile_meta(hplan))

    chunk_spec = pl.BlockSpec((b, c), lambda k, t: (0, 0))
    f_spec = pl.BlockSpec((b,), lambda k, t: (0,))
    q_spec = pl.BlockSpec((1, c), lambda k, t: (k, 0))
    r_spec = pl.BlockSpec((1, r.shape[1]), lambda k, t: (k, 0))
    meta_spec = pl.BlockSpec((1, 2), lambda k, t: (t, 0))
    tbl_spec = pl.BlockSpec((1, tile_h), lambda k, t: (k, t))
    scratch = [pltpu.VMEM((b,), jnp.int32)]

    if jnp.issubdtype(table.dtype, jnp.integer):
        flo = (freqs.astype(jnp.int32) & _LIMB_MASK).astype(jnp.float32)
        fhi = (freqs.astype(jnp.int32) >> _LIMB_BITS).astype(jnp.float32)
        kernel = functools.partial(_hier_kernel_int, hplan, tile_h)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[chunk_spec, f_spec, f_spec, q_spec, r_spec, meta_spec,
                      tbl_spec],
            out_specs=tbl_spec,
            out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
            scratch_shapes=scratch,
            input_output_aliases={6: 0},
            interpret=interpret,
        )(chunks, flo, fhi, q, r, meta, table)
    else:
        kernel = functools.partial(_hier_kernel_f32, hplan, tile_h)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[chunk_spec, f_spec, q_spec, r_spec, meta_spec,
                      tbl_spec],
            out_specs=tbl_spec,
            out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
            scratch_shapes=scratch,
            input_output_aliases={5: 0},
            interpret=interpret,
        )(chunks, freqs.astype(table.dtype), q, r, meta, table)


# --------------------------------------------------------------------------
# Signed (Count-Sketch) fused hierarchy fold
# --------------------------------------------------------------------------
#
# Same single-launch cascade with a second VMEM scratch: the packed
# cumulative sign-parity bits (kernels/hashes.row_sign_bits) are hashed once
# per row alongside the finest index, and each tile reads ITS level's sign
# as one bit -- the metadata grows a third column carrying the tile's level
# index.  The sign multiplies the frequency limbs before the MXU
# contraction, exactly as in kernels/sketch_update.py's signed kernels.

def _tile_meta_signed(hplan: HierPlan) -> np.ndarray:
    """int32[n_tiles, 3]: (level divisor, tile base column, level index)."""
    rows = []
    for l, pad in enumerate(hplan.level_pads):
        for t in range(pad // hplan.tile_h):
            rows.append((hplan.level_divs[l], t * hplan.tile_h, l))
    return np.asarray(rows, dtype=np.int32)


def _hier_kernel_signed_int(hplan: HierPlan, tile_h: int,
                            chunks_ref, flo_ref, fhi_ref, q_ref, r_ref,
                            sq_ref, sr_ref, meta_ref,
                            table_in_ref, table_out_ref,
                            idx_scratch_ref, bits_scratch_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _hash_once():
        idx_scratch_ref[...] = row_indices(
            hplan.plan, chunks_ref[...], q_ref[0], r_ref[0])
        bits_scratch_ref[...] = row_sign_bits(
            hplan.plan, chunks_ref[...], sq_ref[0], sr_ref[0])

    local = _local_lanes(idx_scratch_ref, meta_ref)
    s = signs_from_bits(bits_scratch_ref[...], meta_ref[0, 2])  # f32[B]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)      # [B, TH]
    dot_lo = jnp.dot((s * flo_ref[...])[None, :], onehot,
                     preferred_element_type=jnp.float32)        # [1, TH]
    dot_hi = jnp.dot((s * fhi_ref[...])[None, :], onehot,
                     preferred_element_type=jnp.float32)
    delta = dot_lo.astype(jnp.int32) + (dot_hi.astype(jnp.int32) << _LIMB_BITS)
    table_out_ref[...] = table_in_ref[...] + delta


def _hier_kernel_signed_f32(hplan: HierPlan, tile_h: int,
                            chunks_ref, f_ref, q_ref, r_ref,
                            sq_ref, sr_ref, meta_ref,
                            table_in_ref, table_out_ref,
                            idx_scratch_ref, bits_scratch_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _hash_once():
        idx_scratch_ref[...] = row_indices(
            hplan.plan, chunks_ref[...], q_ref[0], r_ref[0])
        bits_scratch_ref[...] = row_sign_bits(
            hplan.plan, chunks_ref[...], sq_ref[0], sr_ref[0])

    local = _local_lanes(idx_scratch_ref, meta_ref)
    s = signs_from_bits(bits_scratch_ref[...], meta_ref[0, 2])
    lanes = jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)
    delta = jnp.dot((s * f_ref[...])[None, :], onehot,
                    preferred_element_type=jnp.float32)
    table_out_ref[...] = table_in_ref[...] + delta[0][None, :]


@functools.partial(
    jax.jit, static_argnames=("hplan", "interpret"), donate_argnums=(1,)
)
def hier_update_signed_pallas(
    hplan: HierPlan,
    table: jax.Array,    # [w, hplan.padded_cols] int32 or float32
    chunks: jax.Array,   # uint32[B, C] finest-layout 16-bit key digits
    freqs: jax.Array,    # int32[B] or float32[B], signed
    q: jax.Array,        # uint32[w, C] bucket multipliers
    r: jax.Array,        # uint32[w, m] bucket offsets
    sq: jax.Array,       # uint32[w, C] sign multipliers
    sr: jax.Array,       # uint32[w, m] sign offsets
    *,
    interpret: bool = True,
) -> jax.Array:
    """Signed cascade fold into every level's table in ONE pallas_call.

    cell_L += sign_L(row, item) * f, where sign_L is bit L of the packed
    cumulative parities -- bit-exact vs core.countsketch.hier_update on
    int32 tables (|f| < 2^24, negatives allowed).  Same donation contract
    as :func:`hier_update_pallas`."""
    w, cols = table.shape
    if cols != hplan.padded_cols:
        raise ValueError(
            f"concatenated table has {cols} columns, plan expects "
            f"{hplan.padded_cols}")
    tile_h = hplan.tile_h
    b, c = chunks.shape
    grid = (w, hplan.n_tiles)
    meta = jnp.asarray(_tile_meta_signed(hplan))

    chunk_spec = pl.BlockSpec((b, c), lambda k, t: (0, 0))
    f_spec = pl.BlockSpec((b,), lambda k, t: (0,))
    q_spec = pl.BlockSpec((1, c), lambda k, t: (k, 0))
    r_spec = pl.BlockSpec((1, r.shape[1]), lambda k, t: (k, 0))
    meta_spec = pl.BlockSpec((1, 3), lambda k, t: (t, 0))
    tbl_spec = pl.BlockSpec((1, tile_h), lambda k, t: (k, t))
    scratch = [pltpu.VMEM((b,), jnp.int32), pltpu.VMEM((b,), jnp.int32)]

    if jnp.issubdtype(table.dtype, jnp.integer):
        fi = freqs.astype(jnp.int32)
        flo = (fi & _LIMB_MASK).astype(jnp.float32)
        fhi = (fi >> _LIMB_BITS).astype(jnp.float32)   # arithmetic shift
        kernel = functools.partial(_hier_kernel_signed_int, hplan, tile_h)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[chunk_spec, f_spec, f_spec, q_spec, r_spec,
                      q_spec, r_spec, meta_spec, tbl_spec],
            out_specs=tbl_spec,
            out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
            scratch_shapes=scratch,
            input_output_aliases={8: 0},
            interpret=interpret,
        )(chunks, flo, fhi, q, r, sq, sr, meta, table)
    else:
        kernel = functools.partial(_hier_kernel_signed_f32, hplan, tile_h)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[chunk_spec, f_spec, q_spec, r_spec,
                      q_spec, r_spec, meta_spec, tbl_spec],
            out_specs=tbl_spec,
            out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
            scratch_shapes=scratch,
            input_output_aliases={7: 0},
            interpret=interpret,
        )(chunks, freqs.astype(table.dtype), q, r, sq, sr, meta, table)


@functools.partial(jax.jit, static_argnames=("hplan",))
def hier_update_signed_ref(
    hplan: HierPlan,
    table: jax.Array,
    chunks: jax.Array,
    freqs: jax.Array,
    q: jax.Array,
    r: jax.Array,
    sq: jax.Array,
    sr: jax.Array,
) -> jax.Array:
    """jnp oracle for the signed fused fold over the same concatenated
    padded table: hash indices + sign bits once per row, cascade divisions,
    per-level signed scatter-adds."""
    idx_fine = jnp.stack([row_indices(hplan.plan, chunks, q[k], r[k])
                          for k in range(hplan.plan.width)], axis=0)
    bits = jnp.stack([row_sign_bits(hplan.plan, chunks, sq[k], sr[k])
                      for k in range(hplan.plan.width)], axis=0)  # [w, B]
    w = idx_fine.shape[0]
    out = table
    for lvl, (off, div) in enumerate(zip(hplan.level_offsets,
                                         hplan.level_divs)):
        idx = jax.lax.div(idx_fine, jnp.int32(div)) + off
        flat = (jnp.arange(w, dtype=jnp.int32)[:, None] * table.shape[1]
                + idx).reshape(-1)
        s = signs_from_bits(bits, lvl)
        f = (s * freqs.astype(jnp.float32)[None, :]).astype(table.dtype)
        out = out.reshape(-1).at[flat].add(f.reshape(-1)).reshape(table.shape)
    return out


@functools.partial(jax.jit, static_argnames=("hplan",))
def hier_update_ref(
    hplan: HierPlan,
    table: jax.Array,
    chunks: jax.Array,
    freqs: jax.Array,
    q: jax.Array,
    r: jax.Array,
) -> jax.Array:
    """jnp oracle over the SAME concatenated padded table: per-row composite
    hash once, cascade divisions, per-level scatter-adds (bit-identical to
    per-level core.sketch.update under the shared params)."""
    rows = [row_indices(hplan.plan, chunks, q[k], r[k])
            for k in range(hplan.plan.width)]
    idx_fine = jnp.stack(rows, axis=0)                        # int32[w, B]
    w = idx_fine.shape[0]
    out = table
    for off, div in zip(hplan.level_offsets, hplan.level_divs):
        idx = jax.lax.div(idx_fine, jnp.int32(div)) + off
        flat = (jnp.arange(w, dtype=jnp.int32)[:, None] * table.shape[1]
                + idx).reshape(-1)
        f = jnp.broadcast_to(freqs.astype(table.dtype),
                             (w, freqs.shape[0])).reshape(-1)
        out = out.reshape(-1).at[flat].add(f).reshape(table.shape)
    return out
