"""Pallas TPU kernels for the sketch hot path (linear update = one-hot MXU
matmul, conservative update = VMEM-resident sequential min/max, signed
update = sign-weighted one-hot MXU matmul, query = one-hot gather + row
reduce), with jnp oracles in ref.py and jitd wrappers in ops.py.
Validated in interpret mode on CPU; set interpret=False on TPU."""
from repro.kernels.hashes import IndexPlan, make_plan  # noqa: F401
from repro.kernels.hier_query import (  # noqa: F401
    hier_candidate_query,
    hier_candidate_query_ref,
    hier_candidate_query_signed,
    hier_candidate_query_signed_ref,
)
from repro.kernels.hier_update import (  # noqa: F401
    HierPlan,
    hier_update_pallas,
    hier_update_ref,
    hier_update_signed_pallas,
    hier_update_signed_ref,
    make_hier_plan,
)
from repro.kernels.ops import (  # noqa: F401
    KernelHierarchy,
    KernelSketch,
    default_interpret,
)
from repro.kernels.sketch_update import (  # noqa: F401
    sketch_update_pallas,
    sketch_update_signed_pallas,
)
from repro.kernels.sketch_query import (  # noqa: F401
    sketch_query_pallas,
    sketch_query_signed_pallas,
)
from repro.kernels.sketch_update_conservative import (  # noqa: F401
    sketch_update_conservative_pallas,
)
