"""Pallas TPU kernels for the sketch hot path (linear update = one-hot MXU
matmul, conservative update = VMEM-resident sequential min/max, query =
one-hot gather + row-min), with jnp oracles in ref.py and jitd wrappers in
ops.py.  Validated in interpret mode on CPU; set interpret=False on TPU."""
from repro.kernels.hashes import IndexPlan, make_plan  # noqa: F401
from repro.kernels.hier_query import (  # noqa: F401
    hier_candidate_query,
    hier_candidate_query_ref,
)
from repro.kernels.hier_update import (  # noqa: F401
    HierPlan,
    hier_update_pallas,
    hier_update_ref,
    make_hier_plan,
)
from repro.kernels.ops import (  # noqa: F401
    KernelHierarchy,
    KernelSketch,
    default_interpret,
)
from repro.kernels.sketch_update_conservative import (  # noqa: F401
    sketch_update_conservative_pallas,
)
