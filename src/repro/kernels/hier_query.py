"""Pallas TPU kernel: all candidate children of heavy prefixes in one launch.

The hierarchical heavy-hitter descent (core/hierarchy.py) expands P
surviving prefixes by C candidate values of the next module group and needs
a Count-Min estimate for every child.  The mixed-radix cell address is
separable -- ``idx(p, c) = pp[k, p] + cp[k, c]`` per row k, with the prefix
partial pre-scaled by the last group's range and the child partial's stride
equal to 1 -- so the kernel takes the two partial-index factors and
evaluates the full P x C grid without ever materializing the P*C key
matrix or rehashing anything in-kernel.

Per (row k, range tile t): form the child indices for the whole grid,
one-hot them against the tile's lanes, and gather via an MXU contraction
exactly like kernels/sketch_query.py -- table values split into two 16-bit
limbs so the f32 matmuls are exact for int32 counts.  The (w, P*C) per-row
estimates accumulate across tiles by output revisiting; the final Count-Min
min over the w rows is a VPU reduce fused into the jit'd wrapper.

Grid = (w, h_pad / TILE_H); one launch per descent level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hier_kernel(tile_h: int, pp_ref, cp_ref, tlo_ref, thi_ref, out_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pp = pp_ref[0]                                            # int32[P]
    cp = cp_ref[0]                                            # int32[C]
    p, c = pp.shape[0], cp.shape[0]
    idx = (pp[:, None] + cp[None, :]).reshape(p * c)          # int32[P*C]
    local = idx - t * tile_h
    lanes = jax.lax.broadcasted_iota(jnp.int32, (p * c, tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)    # [P*C, TH]
    glo = jnp.dot(onehot, tlo_ref[0][:, None],
                  preferred_element_type=jnp.float32)         # [P*C, 1]
    ghi = jnp.dot(onehot, thi_ref[0][:, None],
                  preferred_element_type=jnp.float32)
    val = glo.astype(jnp.int32) + (ghi.astype(jnp.int32) << 16)
    out_ref[...] = out_ref[...] + val[:, 0][None, :]


@functools.partial(jax.jit, static_argnames=("tile_h", "interpret"))
def hier_candidate_query(
    table: jax.Array,   # int32[w, h] (padded internally to tile_h)
    pp: jax.Array,      # uint32[w, P] prefix partial indices (pre-scaled)
    cp: jax.Array,      # uint32[w, C] child partial indices (stride 1)
    *,
    tile_h: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Count-Min estimates for every (prefix, candidate) child: int32[P, C].

    The two-limb gather assumes cell counts fit int32; other table dtypes
    must take :func:`hier_candidate_query_ref`.
    """
    if table.dtype != jnp.int32:
        raise ValueError(
            f"hier_candidate_query supports int32 tables only (got "
            f"{table.dtype}); use hier_candidate_query_ref")
    w, h = table.shape
    h_pad = ((h + tile_h - 1) // tile_h) * tile_h
    if h_pad != h:
        # padding cells are zero and no child index reaches them (< h)
        table = jnp.pad(table, ((0, 0), (0, h_pad - h)))
    n_tiles = h_pad // tile_h
    p = pp.shape[1]
    c = cp.shape[1]
    grid = (w, n_tiles)

    ti = table.astype(jnp.int32)
    tlo = (ti & jnp.int32(0xFFFF)).astype(jnp.float32)
    thi = ((ti >> 16) & jnp.int32(0xFFFF)).astype(jnp.float32)

    per_row = pl.pallas_call(
        functools.partial(_hier_kernel, tile_h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, p), lambda k, t: (k, 0)),
            pl.BlockSpec((1, c), lambda k, t: (k, 0)),
            pl.BlockSpec((1, tile_h), lambda k, t: (k, t)),
            pl.BlockSpec((1, tile_h), lambda k, t: (k, t)),
        ],
        out_specs=pl.BlockSpec((1, p * c), lambda k, t: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((w, p * c), jnp.int32),
        interpret=interpret,
    )(pp.astype(jnp.int32), cp.astype(jnp.int32), tlo, thi)
    return jnp.min(per_row, axis=0).reshape(p, c)


@jax.jit
def hier_candidate_query_ref(table: jax.Array, pp: jax.Array,
                             cp: jax.Array) -> jax.Array:
    """Pure-jnp oracle: same signature minus tiling, [P, C] in the table's
    dtype (unlike the kernel it is exact for int64 / float tables too)."""
    w = table.shape[0]
    idx = (pp.astype(jnp.int32)[:, :, None]
           + cp.astype(jnp.int32)[:, None, :]).reshape(w, -1)
    vals = jnp.take_along_axis(table, idx, axis=1)
    return jnp.min(vals, axis=0).reshape(pp.shape[1], cp.shape[1])


# --------------------------------------------------------------------------
# Signed (Count-Sketch) candidate grid
# --------------------------------------------------------------------------
#
# The signed descent needs the same P x C gather with two extra separable
# factors: the sign of child (p, c) at row k is ``sp[k, p] * sc[k, c]``
# (cumulative parities XOR, so +-1 signs multiply), computed OUTSIDE the
# kernel by core.countsketch.candidate_signed_partials exactly like the
# bucket partials.  The kernel gathers the exact int32 cell value and
# multiplies by the +-1 product in int32; the median over rows is the
# wrapper's caller's reduce (rows are returned so the estimator stays
# bit-comparable to the jnp reference).

def _hier_kernel_signed(tile_h: int, pp_ref, cp_ref, sp_ref, sc_ref,
                        tlo_ref, thi_ref, out_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pp = pp_ref[0]                                            # int32[P]
    cp = cp_ref[0]                                            # int32[C]
    p, c = pp.shape[0], cp.shape[0]
    idx = (pp[:, None] + cp[None, :]).reshape(p * c)          # int32[P*C]
    local = idx - t * tile_h
    lanes = jax.lax.broadcasted_iota(jnp.int32, (p * c, tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)    # [P*C, TH]
    glo = jnp.dot(onehot, tlo_ref[0][:, None],
                  preferred_element_type=jnp.float32)         # [P*C, 1]
    ghi = jnp.dot(onehot, thi_ref[0][:, None],
                  preferred_element_type=jnp.float32)
    val = glo.astype(jnp.int32) + (ghi.astype(jnp.int32) << 16)
    sgn = (sp_ref[0][:, None] * sc_ref[0][None, :]).reshape(p * c)
    out_ref[...] = out_ref[...] + (val[:, 0] * sgn)[None, :]


@functools.partial(jax.jit, static_argnames=("tile_h", "interpret"))
def hier_candidate_query_signed(
    table: jax.Array,   # int32[w, h] (padded internally to tile_h)
    pp: jax.Array,      # uint32[w, P] prefix partial indices (pre-scaled)
    cp: jax.Array,      # uint32[w, C] child partial indices (stride 1)
    sp: jax.Array,      # +-1[w, P] prefix sign partials
    sc: jax.Array,      # +-1[w, C] child sign partials
    *,
    tile_h: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Per-row signed estimates for every (prefix, candidate) child:
    int32[w, P, C].  The caller takes the median over rows (float); keeping
    the rows int32 keeps the gather bit-exact vs the jnp reference."""
    if table.dtype != jnp.int32:
        raise ValueError(
            f"hier_candidate_query_signed supports int32 tables only (got "
            f"{table.dtype}); use hier_candidate_query_signed_ref")
    w, h = table.shape
    h_pad = ((h + tile_h - 1) // tile_h) * tile_h
    if h_pad != h:
        # padding cells are zero and no child index reaches them (< h)
        table = jnp.pad(table, ((0, 0), (0, h_pad - h)))
    n_tiles = h_pad // tile_h
    p = pp.shape[1]
    c = cp.shape[1]
    grid = (w, n_tiles)

    tlo = (table & jnp.int32(0xFFFF)).astype(jnp.float32)
    thi = ((table >> 16) & jnp.int32(0xFFFF)).astype(jnp.float32)

    per_row = pl.pallas_call(
        functools.partial(_hier_kernel_signed, tile_h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, p), lambda k, t: (k, 0)),
            pl.BlockSpec((1, c), lambda k, t: (k, 0)),
            pl.BlockSpec((1, p), lambda k, t: (k, 0)),
            pl.BlockSpec((1, c), lambda k, t: (k, 0)),
            pl.BlockSpec((1, tile_h), lambda k, t: (k, t)),
            pl.BlockSpec((1, tile_h), lambda k, t: (k, t)),
        ],
        out_specs=pl.BlockSpec((1, p * c), lambda k, t: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((w, p * c), jnp.int32),
        interpret=interpret,
    )(pp.astype(jnp.int32), cp.astype(jnp.int32),
      sp.astype(jnp.int32), sc.astype(jnp.int32), tlo, thi)
    return per_row.reshape(w, p, c)


@jax.jit
def hier_candidate_query_signed_ref(table: jax.Array, pp: jax.Array,
                                    cp: jax.Array, sp: jax.Array,
                                    sc: jax.Array) -> jax.Array:
    """Pure-jnp signed oracle: float32[w, P, C] per-row estimates (exact
    for int32 tables; dtype-preserving gather, sign applied in float)."""
    w = table.shape[0]
    p, c = pp.shape[1], cp.shape[1]
    idx = (pp.astype(jnp.int32)[:, :, None]
           + cp.astype(jnp.int32)[:, None, :]).reshape(w, -1)
    vals = jnp.take_along_axis(table, idx, axis=1).astype(jnp.float32)
    vals = vals.reshape(w, p, c)
    return vals * sp.astype(jnp.float32)[:, :, None] \
        * sc.astype(jnp.float32)[:, None, :]


# --------------------------------------------------------------------------
# Request axis: Q concurrent queries in the one launch
# --------------------------------------------------------------------------
#
# The grid evaluates P*C independent lanes per (row, tile); nothing ties a
# lane to "one query", so Q concurrent requests' prefix sets ride the lane
# axis: [w, Q, P] prefix partials flatten to [w, Q*P], the SAME pallas_call
# runs once with Q*P*C lanes, and the output folds back to [Q, P, C].
# Each lane's estimate is computed independently (one-hot gather + min over
# rows), so every request's [P, C] slab is bit-identical to its own
# single-request launch -- batching Q queries costs one launch per level
# instead of Q (the sketch serving engine's batched descent).

@functools.partial(jax.jit, static_argnames=("tile_h", "interpret"))
def hier_candidate_query_batched(
    table: jax.Array,   # int32[w, h]
    pp: jax.Array,      # uint32[w, Q, P] per-request prefix partials
    cp: jax.Array,      # uint32[w, C] child partials (shared by all requests)
    *,
    tile_h: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Count-Min estimates for Q requests' (prefix, candidate) grids:
    int32[Q, P, C], one ``pallas_call`` total."""
    w, q, p = pp.shape
    flat = hier_candidate_query(table, pp.reshape(w, q * p), cp,
                                tile_h=tile_h, interpret=interpret)
    return flat.reshape(q, p, cp.shape[1])


@jax.jit
def hier_candidate_query_batched_ref(table: jax.Array, pp: jax.Array,
                                     cp: jax.Array) -> jax.Array:
    """Request-axis jnp oracle: [w, Q, P] partials -> [Q, P, C] estimates
    in the table's dtype (the non-int32 / non-kernel batched path)."""
    w, q, p = pp.shape
    flat = hier_candidate_query_ref(table, pp.reshape(w, q * p), cp)
    return flat.reshape(q, p, cp.shape[1])
