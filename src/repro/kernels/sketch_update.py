"""Pallas TPU kernel: sketch update as one-hot x frequency MXU matmuls.

TPU adaptation of the paper's scalar update loop (DESIGN.md S4): scatter-add
is the canonical TPU anti-pattern, so a stream block of B items becomes, per
(row k, range tile t), a dense one-hot matrix ``onehot[b, j] = (idx_b ==
tile_start + j)`` contracted with the frequency vector on the MXU:

    table[k, tile] += f^T . onehot          # collisions sum inside the MXU

Grid = (w, h/TILE_H).  Per-step VMEM: the (B, TILE_H) one-hot + the (1,
TILE_H) table tile + the (B, C) chunk block -- e.g. B=1024, TILE_H=512 is
~2.2 MB, comfortably inside ~16 MB VMEM, with TILE_H a multiple of the
128-lane width.  Hash evaluation (uint32 limb CW, core/hashing.py) runs on
the VPU inside the kernel; it is recomputed per tile, which is deliberate --
it is cheap VPU work that overlaps the MXU contraction and avoids an HBM
round-trip for a (w, B) index tensor.

Exactness for integer tables: frequencies are split into two 12-bit limbs so
every f32 matmul accumulates sums < 2^23 (exactly representable); limbs are
recombined in int32.  Valid for per-arrival f < 2^24 (wrapper-checked);
larger weights take the jnp reference path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.hashes import (
    IndexPlan,
    row_indices,
    row_sign_bits,
    signs_from_bits,
)

_LIMB_BITS = 12
_LIMB_MASK = (1 << _LIMB_BITS) - 1


def _update_kernel_int(plan: IndexPlan, tile_h: int,
                       chunks_ref, flo_ref, fhi_ref, q_ref, r_ref,
                       table_in_ref, table_out_ref):
    """One (row, tile) step: int32 table, two 12-bit frequency limbs."""
    t = pl.program_id(1)
    idx = row_indices(plan, chunks_ref[...], q_ref[0], r_ref[0])      # int32[B]
    local = idx - t * tile_h
    lanes = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)            # [B, TH]
    dot_lo = jnp.dot(flo_ref[...][None, :], onehot,
                     preferred_element_type=jnp.float32)              # [1, TH]
    dot_hi = jnp.dot(fhi_ref[...][None, :], onehot,
                     preferred_element_type=jnp.float32)
    delta = dot_lo.astype(jnp.int32) + (dot_hi.astype(jnp.int32) << _LIMB_BITS)
    table_out_ref[...] = table_in_ref[...] + delta


def _update_kernel_f32(plan: IndexPlan, tile_h: int,
                       chunks_ref, f_ref, q_ref, r_ref,
                       table_in_ref, table_out_ref):
    """float32-table variant (gradient sketches): single MXU contraction."""
    t = pl.program_id(1)
    idx = row_indices(plan, chunks_ref[...], q_ref[0], r_ref[0])
    local = idx - t * tile_h
    lanes = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)
    delta = jnp.dot(f_ref[...][None, :], onehot,
                    preferred_element_type=jnp.float32)
    table_out_ref[...] = table_in_ref[...] + delta[0][None, :]


def _update_kernel_signed_int(plan: IndexPlan, tile_h: int,
                              chunks_ref, flo_ref, fhi_ref, q_ref, r_ref,
                              sq_ref, sr_ref, table_in_ref, table_out_ref):
    """Signed mode, int32 table: the +-1 sign multiplies both frequency
    limbs before the contraction.  Limbs come from the arithmetic split
    f = (f & 0xFFF) + ((f >> 12) << 12), so negative values decompose
    exactly; per-limb partial sums stay < 2^23 in magnitude (|s*limb| <=
    4095, B <= 1024 checked by the wrapper path's callers), hence exact in
    f32, and the int32 recombination wraps identically to the jnp
    scatter-add reference."""
    t = pl.program_id(1)
    idx = row_indices(plan, chunks_ref[...], q_ref[0], r_ref[0])      # int32[B]
    bits = row_sign_bits(plan, chunks_ref[...], sq_ref[0], sr_ref[0])
    s = signs_from_bits(bits, len(plan.group_cols) - 1)               # f32[B]
    local = idx - t * tile_h
    lanes = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)            # [B, TH]
    dot_lo = jnp.dot((s * flo_ref[...])[None, :], onehot,
                     preferred_element_type=jnp.float32)              # [1, TH]
    dot_hi = jnp.dot((s * fhi_ref[...])[None, :], onehot,
                     preferred_element_type=jnp.float32)
    delta = dot_lo.astype(jnp.int32) + (dot_hi.astype(jnp.int32) << _LIMB_BITS)
    table_out_ref[...] = table_in_ref[...] + delta


def _update_kernel_signed_f32(plan: IndexPlan, tile_h: int,
                              chunks_ref, f_ref, q_ref, r_ref,
                              sq_ref, sr_ref, table_in_ref, table_out_ref):
    """Signed mode, float32 table (gradient sketches): one contraction of
    the sign-flipped values."""
    t = pl.program_id(1)
    idx = row_indices(plan, chunks_ref[...], q_ref[0], r_ref[0])
    bits = row_sign_bits(plan, chunks_ref[...], sq_ref[0], sr_ref[0])
    s = signs_from_bits(bits, len(plan.group_cols) - 1)
    local = idx - t * tile_h
    lanes = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)
    delta = jnp.dot((s * f_ref[...])[None, :], onehot,
                    preferred_element_type=jnp.float32)
    table_out_ref[...] = table_in_ref[...] + delta[0][None, :]


def padded_table_size(h: int, tile_h: int) -> int:
    return ((h + tile_h - 1) // tile_h) * tile_h


@functools.partial(
    jax.jit, static_argnames=("plan", "tile_h", "interpret"),
    donate_argnums=(1,),
)
def sketch_update_pallas(
    plan: IndexPlan,
    table: jax.Array,    # [w, h_pad] int32 or float32, h_pad % tile_h == 0
    chunks: jax.Array,   # uint32[B, C]
    freqs: jax.Array,    # int32[B] or float32[B]
    q: jax.Array,        # uint32[w, C]
    r: jax.Array,        # uint32[w, m]
    *,
    tile_h: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Fold one stream block into the (padded) table. Returns the new table.

    The table buffer is DONATED (effective on CPU and TPU): per-block
    ingest accumulates in place instead of copying the table every call.
    Callers must rebind to the returned table (KernelSketch.update does).
    """
    w, h_pad = table.shape
    if h_pad % tile_h:
        raise ValueError(f"padded table width {h_pad} not a multiple of {tile_h}")
    n_tiles = h_pad // tile_h
    b, c = chunks.shape
    grid = (w, n_tiles)

    chunk_spec = pl.BlockSpec((b, c), lambda k, t: (0, 0))
    f_spec = pl.BlockSpec((b,), lambda k, t: (0,))
    q_spec = pl.BlockSpec((1, c), lambda k, t: (k, 0))
    r_spec = pl.BlockSpec((1, r.shape[1]), lambda k, t: (k, 0))
    tbl_spec = pl.BlockSpec((1, tile_h), lambda k, t: (k, t))

    if jnp.issubdtype(table.dtype, jnp.integer):
        flo = (freqs.astype(jnp.int32) & _LIMB_MASK).astype(jnp.float32)
        fhi = (freqs.astype(jnp.int32) >> _LIMB_BITS).astype(jnp.float32)
        kernel = functools.partial(_update_kernel_int, plan, tile_h)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[chunk_spec, f_spec, f_spec, q_spec, r_spec, tbl_spec],
            out_specs=tbl_spec,
            out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
            input_output_aliases={5: 0},
            interpret=interpret,
        )(chunks, flo, fhi, q, r, table)
    else:
        kernel = functools.partial(_update_kernel_f32, plan, tile_h)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[chunk_spec, f_spec, q_spec, r_spec, tbl_spec],
            out_specs=tbl_spec,
            out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
            input_output_aliases={4: 0},
            interpret=interpret,
        )(chunks, freqs.astype(table.dtype), q, r, table)


@functools.partial(
    jax.jit, static_argnames=("plan", "tile_h", "interpret"),
    donate_argnums=(1,),
)
def sketch_update_signed_pallas(
    plan: IndexPlan,
    table: jax.Array,    # [w, h_pad] int32 or float32, h_pad % tile_h == 0
    chunks: jax.Array,   # uint32[B, C]
    freqs: jax.Array,    # int32[B] or float32[B], signed
    q: jax.Array,        # uint32[w, C]
    r: jax.Array,        # uint32[w, m]
    sq: jax.Array,       # uint32[w, C]   sign-hash multipliers
    sr: jax.Array,       # uint32[w, m]   sign-hash offsets
    *,
    tile_h: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Signed (Count-Sketch) fold: cell += sign(row, item) * f.

    Same donation contract as :func:`sketch_update_pallas`; freqs may be
    negative (turnstile).  Bit-exact vs core.countsketch.update on int32
    tables for |f| < 2^24."""
    w, h_pad = table.shape
    if h_pad % tile_h:
        raise ValueError(f"padded table width {h_pad} not a multiple of {tile_h}")
    n_tiles = h_pad // tile_h
    b, c = chunks.shape
    grid = (w, n_tiles)

    chunk_spec = pl.BlockSpec((b, c), lambda k, t: (0, 0))
    f_spec = pl.BlockSpec((b,), lambda k, t: (0,))
    q_spec = pl.BlockSpec((1, c), lambda k, t: (k, 0))
    r_spec = pl.BlockSpec((1, r.shape[1]), lambda k, t: (k, 0))
    tbl_spec = pl.BlockSpec((1, tile_h), lambda k, t: (k, t))

    if jnp.issubdtype(table.dtype, jnp.integer):
        fi = freqs.astype(jnp.int32)
        flo = (fi & _LIMB_MASK).astype(jnp.float32)
        fhi = (fi >> _LIMB_BITS).astype(jnp.float32)   # arithmetic shift
        kernel = functools.partial(_update_kernel_signed_int, plan, tile_h)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[chunk_spec, f_spec, f_spec, q_spec, r_spec,
                      q_spec, r_spec, tbl_spec],
            out_specs=tbl_spec,
            out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
            input_output_aliases={7: 0},
            interpret=interpret,
        )(chunks, flo, fhi, q, r, sq, sr, table)
    else:
        kernel = functools.partial(_update_kernel_signed_f32, plan, tile_h)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[chunk_spec, f_spec, q_spec, r_spec,
                      q_spec, r_spec, tbl_spec],
            out_specs=tbl_spec,
            out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
            input_output_aliases={6: 0},
            interpret=interpret,
        )(chunks, freqs.astype(table.dtype), q, r, sq, sr, table)
