"""Pallas TPU kernel: conservative (Estan-Varghese) sketch update.

A conservative step for item b with frequency f is

    cur_k = table[k, idx_k(b)]            (min-gather over the w rows)
    est   = min_k cur_k + f
    table[k, idx_k(b)] = max(cur_k, est)  (max-scatter)

Two structural facts rule out the linear kernel's (w, h/TILE_H) one-hot
matmul grid (sketch_update.py):

  * the min couples all w rows of one item, and each row's cell lands in a
    *different* h-tile, so no single (row, tile) step ever sees the values
    the min needs;
  * the update is sequential in B -- item b+1 must read item b's writes
    (duplicate keys inside one block are the common case for skewed
    streams), so the per-item work cannot be reordered or batched into one
    contraction.

The kernel therefore keeps the full w-row working set -- the (w, h_pad)
table -- resident in VMEM and makes the *stream* the grid axis: TPU Pallas
grid steps execute sequentially on a core, so grid=(B/CHUNK_B,) walks the
block in stream order while the pipeline double-buffers the next chunk's
(chunks, freqs) inputs behind the current chunk's compute.  The table
in/out blocks use a constant index map (the reduction-by-revisiting
pattern), so the table is fetched once, stays in VMEM across steps, and is
written back once at the end.  Within a step the chunk's per-item row
indices are recomputed on the VPU (kernels/hashes.row_indices -- cheap,
and it avoids an HBM round-trip for a (w, B) index tensor), then a
``fori_loop`` applies the B-sequential min-gather/max-scatter.

Unlike the linear kernel there is no MXU contraction and hence no float
accumulation: gather / integer-or-float min / add / max are exact in both
int32 and float32, so the kernel is bit-identical to
``core.sketch.update_conservative`` for both table dtypes (no limb split
needed).

VMEM budget: the resident set is ``2 * w * h_pad * itemsize`` (aliased
table in + out blocks) plus the double-buffered chunk inputs.
:func:`conservative_chunk_b` picks the largest power-of-two B-chunk that
fits beside the table -- the chunked-B variant -- and returns None when the
table itself cannot fit, in which case the caller must take the jnp
reference path (``kernels/ops.KernelSketch`` does this automatically).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.hashes import IndexPlan, row_indices

_VMEM_BUDGET_BYTES = 14 * 2**20   # leave ~2 MB of the ~16 MB VMEM for slack


def conservative_chunk_b(
    b: int,
    c: int,
    w: int,
    h_pad: int,
    itemsize: int,
    vmem_limit_bytes: int = _VMEM_BUDGET_BYTES,
) -> Optional[int]:
    """Largest B-chunk (a divisor of b, found by halving while even) whose
    double-buffered inputs fit next to the VMEM-resident table; None when
    even chunk=1 cannot fit (the caller must fall back to the jnp
    reference path).  Halving an even divisor of b yields a divisor of b,
    so the returned chunk always divides b; an odd over-budget chunk drops
    straight to 1."""
    table_bytes = 2 * w * h_pad * itemsize        # aliased in + out blocks

    def fits(chunk: int) -> bool:
        return table_bytes + 2 * chunk * (c * 4 + itemsize) <= vmem_limit_bytes

    chunk = b
    while chunk > 1 and not fits(chunk):
        chunk = chunk // 2 if chunk % 2 == 0 else 1
    return chunk if fits(chunk) else None


def _conservative_kernel(plan: IndexPlan,
                         chunks_ref, f_ref, q_ref, r_ref,
                         table_in_ref, table_out_ref):
    """One B-chunk step: sequential min-gather / max-scatter over the chunk."""
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        table_out_ref[...] = table_in_ref[...]

    # per-item composite cell index for every row: int32[w, CHUNK_B]
    idx = jnp.stack(
        [row_indices(plan, chunks_ref[...], q_ref[k], r_ref[k])
         for k in range(plan.width)], axis=0)
    f = f_ref[...]

    def body(i, carry):
        cur = [table_out_ref[k, idx[k, i]] for k in range(plan.width)]
        est = functools.reduce(jnp.minimum, cur) + f[i]
        for k in range(plan.width):
            table_out_ref[k, idx[k, i]] = jnp.maximum(cur[k], est)
        return carry

    jax.lax.fori_loop(0, f.shape[0], body, 0)


@functools.partial(
    jax.jit,
    static_argnames=("plan", "chunk_b", "vmem_limit_bytes", "interpret"),
)
def sketch_update_conservative_pallas(
    plan: IndexPlan,
    table: jax.Array,    # [w, h_pad] int32 or float32
    chunks: jax.Array,   # uint32[B, C]
    freqs: jax.Array,    # [B], non-negative; cast to the table dtype
    q: jax.Array,        # uint32[w, C]
    r: jax.Array,        # uint32[w, m]
    *,
    chunk_b: Optional[int] = None,
    vmem_limit_bytes: int = _VMEM_BUDGET_BYTES,
    interpret: bool = True,
) -> jax.Array:
    """Conservatively fold one stream block into the (padded) table.

    Bit-identical to ``core.sketch.update_conservative`` applied to the
    same item order (zero-frequency pad items are no-ops: est = min <= cur).
    Raises when the table working set exceeds ``vmem_limit_bytes``; use
    :func:`conservative_chunk_b` to pre-check and route to the reference
    path instead.
    """
    w, h_pad = table.shape
    b, c = chunks.shape
    if chunk_b is None:
        chunk_b = conservative_chunk_b(b, c, w, h_pad, table.dtype.itemsize,
                                       vmem_limit_bytes)
        if chunk_b is None:
            raise ValueError(
                f"conservative table working set 2*{w}*{h_pad}*"
                f"{table.dtype.itemsize}B exceeds the VMEM budget "
                f"({vmem_limit_bytes}B): take the core.sketch reference path")
    if b % chunk_b:
        raise ValueError(f"block length {b} not a multiple of chunk_b={chunk_b}")

    grid = (b // chunk_b,)
    kernel = functools.partial(_conservative_kernel, plan)
    tbl_spec = pl.BlockSpec((w, h_pad), lambda s: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk_b, c), lambda s: (s, 0)),
            pl.BlockSpec((chunk_b,), lambda s: (s,)),
            pl.BlockSpec((w, c), lambda s: (0, 0)),
            pl.BlockSpec((w, r.shape[1]), lambda s: (0, 0)),
            tbl_spec,
        ],
        out_specs=tbl_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(chunks, freqs.astype(table.dtype), q, r, table)
