"""Pallas TPU kernel: batched point queries as one-hot gathers + row-min.

Per (row k, range tile t): each of Q queries hits at most one cell of the
tile, so the gather is an MXU contraction ``vals[q] = onehot[q, :] .
table[k, tile]`` accumulated over tiles (every query hits exactly one tile
per row).  Table values are split into two 16-bit limbs before the f32
contraction -- each query's sum is a single limb value < 2^16, so the gather
is exact for counts up to 2^32.  The final Count-Min ``min`` over the w rows
is a trivial VPU reduce done by the wrapper.

Grid = (w, h/TILE_H); the output (w, Q) block for row k is revisited across
the tile axis (initialized at t == 0, accumulated after) -- the standard
Pallas TPU reduction-by-revisiting pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.hashes import IndexPlan, row_indices, row_sign_bits


def _query_kernel(plan: IndexPlan, tile_h: int,
                  chunks_ref, q_ref, r_ref, tlo_ref, thi_ref, out_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = row_indices(plan, chunks_ref[...], q_ref[0], r_ref[0])     # int32[Q]
    local = idx - t * tile_h
    lanes = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)           # [Q, TH]
    glo = jnp.dot(onehot, tlo_ref[0][:, None],
                  preferred_element_type=jnp.float32)                # [Q, 1]
    ghi = jnp.dot(onehot, thi_ref[0][:, None],
                  preferred_element_type=jnp.float32)
    val = glo.astype(jnp.int32) + (ghi.astype(jnp.int32) << 16)      # exact
    out_ref[...] = out_ref[...] + val[:, 0][None, :]


@functools.partial(jax.jit, static_argnames=("plan", "tile_h", "interpret"))
def sketch_query_pallas(
    plan: IndexPlan,
    table: jax.Array,    # int32[w, h_pad]
    chunks: jax.Array,   # uint32[Q, C]
    q: jax.Array,        # uint32[w, C]
    r: jax.Array,        # uint32[w, m]
    *,
    tile_h: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Count-Min estimates for Q queries: int32[Q]."""
    w, h_pad = table.shape
    if h_pad % tile_h:
        raise ValueError(f"padded table width {h_pad} not a multiple of {tile_h}")
    n_tiles = h_pad // tile_h
    nq, c = chunks.shape
    grid = (w, n_tiles)

    ti = table.astype(jnp.int32)
    tlo = (ti & jnp.int32(0xFFFF)).astype(jnp.float32)
    thi = ((ti >> 16) & jnp.int32(0xFFFF)).astype(jnp.float32)

    per_row = pl.pallas_call(
        functools.partial(_query_kernel, plan, tile_h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nq, c), lambda k, t: (0, 0)),
            pl.BlockSpec((1, c), lambda k, t: (k, 0)),
            pl.BlockSpec((1, r.shape[1]), lambda k, t: (k, 0)),
            pl.BlockSpec((1, tile_h), lambda k, t: (k, t)),
            pl.BlockSpec((1, tile_h), lambda k, t: (k, t)),
        ],
        out_specs=pl.BlockSpec((1, nq), lambda k, t: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((w, nq), jnp.int32),
        interpret=interpret,
    )(chunks, q, r, tlo, thi)
    return jnp.min(per_row, axis=0)


def _query_kernel_signed(plan: IndexPlan, tile_h: int,
                         chunks_ref, q_ref, r_ref, sq_ref, sr_ref,
                         tlo_ref, thi_ref, out_ref):
    """Signed point query: the same exact two-limb gather, multiplied by the
    in-kernel +-1 sign (int32, so negative cell values reconstructed by the
    two's-complement wrap stay exact)."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = row_indices(plan, chunks_ref[...], q_ref[0], r_ref[0])     # int32[Q]
    local = idx - t * tile_h
    lanes = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], tile_h), 1)
    onehot = (local[:, None] == lanes).astype(jnp.float32)           # [Q, TH]
    glo = jnp.dot(onehot, tlo_ref[0][:, None],
                  preferred_element_type=jnp.float32)                # [Q, 1]
    ghi = jnp.dot(onehot, thi_ref[0][:, None],
                  preferred_element_type=jnp.float32)
    val = glo.astype(jnp.int32) + (ghi.astype(jnp.int32) << 16)      # exact
    bits = row_sign_bits(plan, chunks_ref[...], sq_ref[0], sr_ref[0])
    s = 1 - 2 * ((bits >> jnp.int32(len(plan.group_cols) - 1))
                 & jnp.int32(1))                                     # int32[Q]
    out_ref[...] = out_ref[...] + (val[:, 0] * s)[None, :]


@functools.partial(jax.jit, static_argnames=("plan", "tile_h", "interpret"))
def sketch_query_signed_pallas(
    plan: IndexPlan,
    table: jax.Array,    # int32[w, h_pad]
    chunks: jax.Array,   # uint32[Q, C]
    q: jax.Array,        # uint32[w, C]
    r: jax.Array,        # uint32[w, m]
    sq: jax.Array,       # uint32[w, C]
    sr: jax.Array,       # uint32[w, m]
    *,
    tile_h: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Per-row signed estimates: int32[w, Q] (caller takes the median).

    Returning the rows rather than the median keeps the kernel output
    bit-comparable to core.countsketch.query_rows and lets callers apply
    row-level robustness filters."""
    w, h_pad = table.shape
    if h_pad % tile_h:
        raise ValueError(f"padded table width {h_pad} not a multiple of {tile_h}")
    if table.dtype != jnp.int32:
        raise ValueError("signed query kernel covers int32 tables only; "
                         "use the jnp reference for other dtypes")
    n_tiles = h_pad // tile_h
    nq, c = chunks.shape
    grid = (w, n_tiles)

    tlo = (table & jnp.int32(0xFFFF)).astype(jnp.float32)
    thi = ((table >> 16) & jnp.int32(0xFFFF)).astype(jnp.float32)

    return pl.pallas_call(
        functools.partial(_query_kernel_signed, plan, tile_h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nq, c), lambda k, t: (0, 0)),
            pl.BlockSpec((1, c), lambda k, t: (k, 0)),
            pl.BlockSpec((1, r.shape[1]), lambda k, t: (k, 0)),
            pl.BlockSpec((1, c), lambda k, t: (k, 0)),
            pl.BlockSpec((1, r.shape[1]), lambda k, t: (k, 0)),
            pl.BlockSpec((1, tile_h), lambda k, t: (k, t)),
            pl.BlockSpec((1, tile_h), lambda k, t: (k, t)),
        ],
        out_specs=pl.BlockSpec((1, nq), lambda k, t: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((w, nq), jnp.int32),
        interpret=interpret,
    )(chunks, q, r, sq, sr, tlo, thi)
