"""Pure-jnp oracles for the Pallas kernels (same signatures, no tiling)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.hashes import IndexPlan, row_indices


def _all_indices(plan: IndexPlan, chunks: jax.Array, q: jax.Array,
                 r: jax.Array) -> jax.Array:
    """int32[w, B] composite indices, one row per hash-function set."""
    rows = [row_indices(plan, chunks, q[k], r[k]) for k in range(plan.width)]
    return jnp.stack(rows, axis=0)


@functools.partial(jax.jit, static_argnames=("plan",))
def sketch_update_ref(
    plan: IndexPlan,
    table: jax.Array,
    chunks: jax.Array,
    freqs: jax.Array,
    q: jax.Array,
    r: jax.Array,
) -> jax.Array:
    """Scatter-add oracle over the (padded) table."""
    w, h_pad = table.shape
    idx = _all_indices(plan, chunks, q, r)                        # [w, B]
    flat = (jnp.arange(w, dtype=jnp.int32)[:, None] * h_pad + idx).reshape(-1)
    f = jnp.broadcast_to(freqs.astype(table.dtype), (w, freqs.shape[0])).reshape(-1)
    return table.reshape(-1).at[flat].add(f).reshape(w, h_pad)


@functools.partial(jax.jit, static_argnames=("plan",))
def sketch_query_ref(
    plan: IndexPlan,
    table: jax.Array,
    chunks: jax.Array,
    q: jax.Array,
    r: jax.Array,
) -> jax.Array:
    """Gather + min oracle: int32[Q]."""
    idx = _all_indices(plan, chunks, q, r)                        # [w, Q]
    vals = jnp.take_along_axis(table.astype(jnp.int32), idx, axis=1)
    return jnp.min(vals, axis=0)
