"""In-kernel composite index computation (shared by update/query kernels).

TPU Pallas has no 64-bit integer lanes, so all hashing is the uint32
two-limb Carter-Wegman arithmetic from ``repro.core.hashing`` -- those
functions are pure jnp and run unchanged inside Pallas kernel bodies.
This module provides the kernel-side "compute the composite cell index for
one sketch row" helper plus the static chunk-layout metadata both kernels
need.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax.numpy as jnp

from repro.core.hashing import addmod_p31, mulmod_p31_16
from repro.core.sketch import SketchSpec


class IndexPlan(NamedTuple):
    """Static (hashable) layout extracted from a SketchSpec for kernels."""
    group_cols: Tuple[Tuple[int, ...], ...]   # chunk columns per group
    ranges: Tuple[int, ...]
    strides: Tuple[int, ...]
    total_chunks: int
    width: int

    @property
    def table_size(self) -> int:
        out = 1
        for r in self.ranges:
            out *= int(r)
        return out


def make_plan(spec: SketchSpec) -> IndexPlan:
    return IndexPlan(
        group_cols=tuple(spec.group_chunk_columns(j) for j in range(spec.n_groups)),
        ranges=spec.ranges,
        strides=spec.strides,
        total_chunks=spec.schema.total_chunks,
        width=spec.width,
    )


def row_indices(plan: IndexPlan, chunks: jnp.ndarray, q_row: jnp.ndarray,
                r_row: jnp.ndarray) -> jnp.ndarray:
    """Composite cell index for ONE sketch row.

    chunks: uint32[B, C]   16-bit key digits
    q_row:  uint32[C]      this row's multipliers
    r_row:  uint32[m]      this row's per-group offsets
    returns int32[B] cell indices in [0, h)
    """
    b = chunks.shape[0]
    idx = jnp.zeros((b,), dtype=jnp.uint32)
    for j, (cols, rng_j, stride_j) in enumerate(
        zip(plan.group_cols, plan.ranges, plan.strides)
    ):
        acc = jnp.broadcast_to(r_row[j], (b,)).astype(jnp.uint32)
        for c in cols:
            acc = addmod_p31(acc, mulmod_p31_16(q_row[c], chunks[:, c]))
        idx = idx + (acc % jnp.uint32(rng_j)) * jnp.uint32(stride_j)
    return idx.astype(jnp.int32)


def row_sign_bits(plan: IndexPlan, chunks: jnp.ndarray, sq_row: jnp.ndarray,
                  sr_row: jnp.ndarray) -> jnp.ndarray:
    """Packed cumulative sign-parity bits for ONE sketch row (signed mode).

    Bit L is the XOR of the per-group CW-hash parities of groups 0..L,
    i.e. the +-1 sign of the level-L prefix under the cascade (the flat /
    finest sign is the top group's bit) -- the kernel-side twin of
    core.countsketch.sign_bits, bit-identical per row.

    chunks: uint32[B, C]   16-bit key digits
    sq_row: uint32[C]      this row's sign multipliers
    sr_row: uint32[m]      this row's per-group sign offsets
    returns int32[B] packed parity bits
    """
    b = chunks.shape[0]
    bits = jnp.zeros((b,), dtype=jnp.uint32)
    cum = jnp.zeros((b,), dtype=jnp.uint32)
    for j, cols in enumerate(plan.group_cols):
        acc = jnp.broadcast_to(sr_row[j], (b,)).astype(jnp.uint32)
        for c in cols:
            acc = addmod_p31(acc, mulmod_p31_16(sq_row[c], chunks[:, c]))
        cum = cum ^ (acc & jnp.uint32(1))
        bits = bits | (cum << jnp.uint32(j))
    return bits.astype(jnp.int32)


def signs_from_bits(bits: jnp.ndarray, level) -> jnp.ndarray:
    """float32 +-1 signs for one level from packed cumulative parity bits.

    ``level`` may be a Python int or a traced scalar (the fused hierarchy
    kernel reads it from per-tile metadata)."""
    par = (bits >> jnp.int32(level)) & jnp.int32(1)
    return 1.0 - 2.0 * par.astype(jnp.float32)
