"""Jit'd public wrappers around the Pallas sketch kernels.

These adapt the high-level ``SketchSpec``/``SketchState`` API (core/sketch.py)
to the kernels: chunk extraction, padding the table width to the tile size,
padding stream blocks to a fixed block length (so one compiled kernel serves
the whole stream), and CPU fallback via ``interpret=True`` (the kernel body
executes in Python on CPU -- bit-identical logic, which is how the kernels
are validated in this container; on TPU set ``interpret=False``).

Three update modes share the wrapper:

  * ``mode="linear"`` (default): the one-hot MXU matmul update
    (kernels/sketch_update.py).  The table stays linear in the stream, so
    sketches merge cell-wise (:meth:`KernelSketch.merge`) and compose with
    the distributed runtime.
  * ``mode="conservative"``: the Estan-Varghese conservative update
    (kernels/sketch_update_conservative.py) -- strictly tighter estimates,
    but the table is NOT linear in the stream, so ``merge``/``state()``
    (the cell-wise merge surfaces) are refused; query-side use is
    unchanged.  When the table working set exceeds the VMEM budget the
    update transparently takes the jnp reference path
    (core.sketch.update_conservative), block by block.
  * ``mode="signed"``: the Count-Sketch variant (core/countsketch.py) --
    the same one-hot limb matmul with the per-group composite +-1 sign
    folded into the frequency limbs, a median-of-rows estimator on the
    query side, and signed (turnstile) frequencies allowed on int tables.
    Signed tables ARE linear, so merge / sharded psum folds / table
    donation all apply exactly as in linear mode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import countsketch as cskt
from repro.core import sketch as sk
from repro.kernels.hashes import make_plan
from repro.kernels.hier_update import (
    hier_update_pallas,
    hier_update_signed_pallas,
    make_hier_plan,
)
from repro.kernels.sketch_update import (
    padded_table_size,
    sketch_update_pallas,
    sketch_update_signed_pallas,
)
from repro.kernels.sketch_update_conservative import (
    conservative_chunk_b,
    sketch_update_conservative_pallas,
)
from repro.kernels.sketch_query import (
    sketch_query_pallas,
    sketch_query_signed_pallas,
)

_MAX_KERNEL_FREQ = 1 << 24  # two 12-bit limbs

MODES = ("linear", "conservative", "signed")


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def check_linear_kernel_freqs(freqs: np.ndarray, table_dtype) -> None:
    """Reject frequencies the linear one-hot kernels cannot represent.

    The int path uses a two-12-bit-limb split whose f32 partial sums are
    exact only for magnitudes < 2^24, so that bound applies to |f|, not
    just positive f -- and negative frequencies are rejected outright
    rather than silently relying on arithmetic-shift limb behaviour.
    Float tables are unconstrained (turnstile / gradient weights).  Shared
    by KernelSketch (flat) and KernelHierarchy (fused multi-level).
    """
    if freqs.size == 0 or not jnp.issubdtype(table_dtype, jnp.integer):
        return
    if np.abs(freqs).max() >= _MAX_KERNEL_FREQ:
        raise ValueError(
            "per-arrival |frequency| >= 2^24 overflows the int-table "
            "limb split: use the core.sketch path")
    if freqs.min() < 0:
        raise ValueError(
            "negative frequencies are not supported on int tables: "
            "use the core.sketch path (or a float32 table)")


def check_signed_kernel_freqs(freqs: np.ndarray, table_dtype) -> None:
    """Signed-mode frequency guard: negatives are the point (turnstile /
    gradient deltas), so only the limb-split magnitude bound applies.  The
    signed kernels split f arithmetically -- f = (f & 0xFFF) + ((f >> 12)
    << 12) -- which is exact for |f| < 2^24 including negative f."""
    if freqs.size == 0 or not jnp.issubdtype(table_dtype, jnp.integer):
        return
    if np.abs(freqs).max() >= _MAX_KERNEL_FREQ:
        raise ValueError(
            "per-arrival |frequency| >= 2^24 overflows the int-table "
            "limb split: use the core.countsketch path")


class KernelSketch:
    """Sketch whose table lives padded for the Pallas kernels."""

    def __init__(self, spec: sk.SketchSpec, key: jax.Array, *,
                 tile_h: int = 512, block_b: int = 1024,
                 dtype=jnp.int32, interpret: Optional[bool] = None,
                 mode: str = "linear"):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self.spec = spec
        self.plan = make_plan(spec)
        if mode == "signed":
            # draw through countsketch so the jnp reference built from the
            # same key is bit-identical (bucket AND sign hashes)
            self.cs_params = cskt.init_params(spec, key)
            self.params = self.cs_params.base
        else:
            self.cs_params = None
            self.params = sk.init_params(spec, key)
        self.tile_h = int(tile_h)
        self.block_b = int(block_b)
        self.h_pad = padded_table_size(spec.table_size, tile_h)
        self.table = jnp.zeros((spec.width, self.h_pad), dtype=dtype)
        self.interpret = default_interpret() if interpret is None else interpret
        self.mode = mode
        self._sharded_folds: dict = {}  # (mesh, data_axes) -> jitted fold

    # -- stream ops ---------------------------------------------------------
    def _check_freqs(self, freqs: np.ndarray) -> None:
        """Reject frequencies the kernel paths cannot represent.

        The *linear* int path uses a two-12-bit-limb split whose f32
        partial sums are exact only for magnitudes < 2^24, so that bound
        applies to |f|, not just positive f -- and negative frequencies are
        rejected outright rather than silently relying on arithmetic-shift
        limb behaviour.  The conservative kernel has no limb split
        (gather/min/add/max, bit-exact at any int32 magnitude) so only the
        non-negativity requirement applies there (f < 0 would be a silent
        no-op: est = min + f <= every cell).  Turnstile streams take the
        core.sketch reference path or a float table.
        """
        if freqs.size == 0:
            return
        if self.mode == "conservative":
            sk.check_conservative_freqs(freqs, self.table.dtype)
            return
        if self.mode == "signed":
            check_signed_kernel_freqs(freqs, self.table.dtype)
            return
        check_linear_kernel_freqs(freqs, self.table.dtype)

    def update(self, items, freqs) -> None:
        items = np.asarray(items, dtype=np.uint32)
        freqs = np.asarray(freqs)
        self._check_freqs(freqs)
        b = self.block_b
        for s in range(0, items.shape[0], b):
            blk_i = items[s : s + b]
            blk_f = freqs[s : s + b]
            if blk_i.shape[0] < b:
                pad = b - blk_i.shape[0]
                blk_i = np.pad(blk_i, ((0, pad), (0, 0)))
                blk_f = np.pad(blk_f, (0, pad))
            chunks = self.spec.schema.module_chunks(jnp.asarray(blk_i))
            if self.mode == "conservative":
                self._update_block_conservative(blk_i, chunks,
                                                jnp.asarray(blk_f))
            elif self.mode == "signed":
                self.table = sketch_update_signed_pallas(
                    self.plan, self.table, chunks, jnp.asarray(blk_f),
                    self.params.q, self.params.r,
                    self.cs_params.sign_q, self.cs_params.sign_r,
                    tile_h=self.tile_h, interpret=self.interpret,
                )
            else:
                self.table = sketch_update_pallas(
                    self.plan, self.table, chunks, jnp.asarray(blk_f),
                    self.params.q, self.params.r,
                    tile_h=self.tile_h, interpret=self.interpret,
                )

    def _update_block_conservative(self, blk_i, chunks, blk_f) -> None:
        w, h_pad = self.table.shape
        chunk_b = conservative_chunk_b(
            chunks.shape[0], chunks.shape[1], w, h_pad,
            self.table.dtype.itemsize)
        if chunk_b is not None:
            self.table = sketch_update_conservative_pallas(
                self.plan, self.table, chunks, blk_f,
                self.params.q, self.params.r,
                chunk_b=chunk_b, interpret=self.interpret,
            )
        else:
            # table working set exceeds VMEM: jnp reference path, same math
            h = self.spec.table_size
            state = sk.SketchState(params=self.params,
                                   table=self.table[:, :h])
            state = sk.update_conservative_jit(
                self.spec, state, jnp.asarray(blk_i), blk_f)
            self.table = self.table.at[:, :h].set(state.table)

    def query(self, items) -> np.ndarray:
        """Point estimates: min over rows (linear/conservative) or the
        unbiased median over signed rows (signed mode, float32)."""
        items = np.asarray(items, dtype=np.uint32)
        if self.mode == "signed":
            rows = self.query_rows(items)
            return np.median(rows.astype(np.float32), axis=0)
        chunks = self.spec.schema.module_chunks(jnp.asarray(items))
        est = sketch_query_pallas(
            self.plan, self.table, chunks, self.params.q, self.params.r,
            tile_h=self.tile_h, interpret=self.interpret,
        )
        return np.asarray(est)

    def query_rows(self, items) -> np.ndarray:
        """Signed mode only: per-row signed estimates [w, Q] (the medians'
        raw material; bit-exact vs core.countsketch.query_rows on int32
        tables).  Float tables take the jnp reference gather."""
        if self.mode != "signed":
            raise ValueError("query_rows is the signed-mode estimator; "
                             "linear/conservative sketches use query()")
        items = np.asarray(items, dtype=np.uint32)
        if self.table.dtype == jnp.int32:
            chunks = self.spec.schema.module_chunks(jnp.asarray(items))
            rows = sketch_query_signed_pallas(
                self.plan, self.table, chunks, self.params.q, self.params.r,
                self.cs_params.sign_q, self.cs_params.sign_r,
                tile_h=self.tile_h, interpret=self.interpret,
            )
            return np.asarray(rows)
        rows, _ = cskt.query_rows(self.spec, self.cs_state(),
                                  jnp.asarray(items))
        return np.asarray(rows)

    def sharded_update(self, mesh, data_axes, items, freqs) -> None:
        """Distributed fold: shard the block over ``data_axes``, psum-merge
        the per-device deltas, add to the table.  Linear mode only -- the
        conservative table is not linear in the stream, so sharded folds of
        it cannot be psum-merged (core.distributed.require_linear).

        Inside shard_map each device runs the jnp reference fold (the
        Pallas one-hot kernel is a per-device drop-in on TPU; off-TPU the
        interpret path inside a shard_map would be pure overhead), which is
        bit-identical to the kernel by the parity tests.  The jitted fold
        is cached per (mesh, data_axes) and the per-shard row count padded
        to the next power of two: an eager shard_map re-traces on every
        call, which would dominate streaming ingest (same fix as
        ShardedTopKService's cached wrappers).
        """
        from repro.core import distributed as dist

        dist.require_linear(self.mode, "KernelSketch.sharded_update")
        items = np.asarray(items, dtype=np.uint32)
        freqs = np.asarray(freqs)
        # no _check_freqs here: the limb-split bounds only constrain the
        # Pallas kernel path, and this fold runs the exact jnp reference
        # inside shard_map (turnstile / large-weight streams are fine)
        n_shards = int(np.prod([mesh.shape[a] for a in data_axes],
                               dtype=np.int64))
        items, freqs, _ = dist.pad_block_pow2(items, freqs, n_shards)
        cache_key = (mesh, tuple(data_axes))
        fold = self._sharded_folds.get(cache_key)
        if fold is None:
            if self.mode == "signed":
                fold = jax.jit(lambda it, fr: dist.sharded_signed_build(
                    self.spec, self.cs_params, mesh, tuple(data_axes),
                    it, fr, table_dtype=self.table.dtype))
            else:
                fold = jax.jit(lambda it, fr: dist.sharded_build(
                    self.spec, self.params, mesh, tuple(data_axes), it, fr,
                    table_dtype=self.table.dtype))
            self._sharded_folds[cache_key] = fold
        delta = fold(jnp.asarray(items), jnp.asarray(freqs))
        h = self.spec.table_size
        self.table = self.table.at[:, :h].add(delta)

    # -- interop ------------------------------------------------------------
    def merge(self, other: "KernelSketch") -> None:
        """Cell-wise in-place merge (cross-shard fold), linear mode only.

        Conservative tables are not linear in the stream -- the sum of two
        conservatively built tables is NOT the table of the concatenated
        stream -- so merging them is refused rather than silently wrong.
        """
        if self.mode == "conservative" or other.mode == "conservative":
            raise ValueError(
                "merge is only defined for linear-table sketches (linear "
                "or signed mode): conservative tables are not linear in "
                "the stream")
        if self.mode != other.mode:
            raise ValueError(
                "merge requires identical modes (a min-estimated and a "
                "median-estimated table are different objects even though "
                "both are linear)")
        if self.spec != other.spec or self.h_pad != other.h_pad:
            raise ValueError("merge requires identical specs and padding")
        if self.table.dtype != other.table.dtype:
            raise ValueError(
                "merge requires identical table dtypes (an int32+float32 "
                "sum would silently promote and lose exact counts)")
        if not (np.array_equal(np.asarray(self.params.q), np.asarray(other.params.q))
                and np.array_equal(np.asarray(self.params.r), np.asarray(other.params.r))):
            raise ValueError(
                "merge requires identical hash params (same spec and key)")
        if self.mode == "signed" and not (
                np.array_equal(np.asarray(self.cs_params.sign_q),
                               np.asarray(other.cs_params.sign_q))
                and np.array_equal(np.asarray(self.cs_params.sign_r),
                                   np.asarray(other.cs_params.sign_r))):
            raise ValueError(
                "merge requires identical sign-hash params (same spec "
                "and key)")
        self.table = self.table + other.table

    def state(self) -> sk.SketchState:
        """Unpadded SketchState view (for merge with the reference path).

        Refused in conservative mode: SketchState is the cell-wise-merge /
        psum currency of the distributed runtime, and conservative tables
        must not enter it.  Use :meth:`table_view` for read-only access.
        """
        if self.mode != "linear":
            raise ValueError(
                "state() feeds the min-estimated SketchState cell-wise merge "
                "path; conservative tables must not enter it and signed "
                "tables carry sign params it cannot hold -- use cs_state() "
                "(signed) or table_view()/query()")
        return sk.SketchState(params=self.params,
                              table=self.table[:, : self.spec.table_size])

    def cs_state(self) -> "cskt.CountSketchState":
        """Unpadded CountSketchState view (signed mode's merge/reference
        currency, the analogue of :meth:`state`)."""
        if self.mode != "signed":
            raise ValueError("cs_state() is the signed-mode view; "
                             "linear sketches use state()")
        return cskt.CountSketchState(
            params=self.cs_params,
            table=self.table[:, : self.spec.table_size])

    def table_view(self) -> np.ndarray:
        """Read-only unpadded table copy (inspection/tests; any mode)."""
        return np.asarray(self.table[:, : self.spec.table_size])

    # -- durable state (serving/recovery.py snapshot currency) ---------------

    def state_dict(self) -> dict:
        """Full sketch state for ALL THREE modes as ``{key: ndarray}``.

        Unlike :meth:`state` (the linear merge currency) this is the
        *recovery* currency: the padded table plus every hash param the
        mode uses (bucket q/r always, sign q/r when signed), so a restored
        sketch is bit-identical regardless of linearity -- a conservative
        table round-trips too, it just must be rebuilt by ordered WAL
        replay rather than fold when the table itself is lost.
        """
        out = {
            "meta.fingerprint": np.frombuffer(
                (f"kernel|{self.spec!r}|mode={self.mode}"
                 f"|dtype={self.table.dtype}|h_pad={self.h_pad}"
                 ).encode(), dtype=np.uint8).copy(),
            "table": np.asarray(self.table),
            "params.q": np.asarray(self.params.q),
            "params.r": np.asarray(self.params.r),
        }
        if self.mode == "signed":
            out["params.sign_q"] = np.asarray(self.cs_params.sign_q)
            out["params.sign_r"] = np.asarray(self.cs_params.sign_r)
        return out

    def load_state_dict(self, sd: dict) -> None:
        """Restore state saved by :meth:`state_dict`; bit-exact round trip."""
        fp = np.frombuffer(
            (f"kernel|{self.spec!r}|mode={self.mode}"
             f"|dtype={self.table.dtype}|h_pad={self.h_pad}").encode(),
            dtype=np.uint8)
        got = np.asarray(sd["meta.fingerprint"], dtype=np.uint8)
        if not np.array_equal(fp, got):
            raise ValueError(
                "kernel state_dict fingerprint mismatch: saved "
                f"{bytes(got).decode(errors='replace')!r}, this sketch is "
                f"{bytes(fp).decode(errors='replace')!r}")
        self.table = jnp.asarray(sd["table"])
        params = sk.SketchParams(q=jnp.asarray(sd["params.q"]),
                                 r=jnp.asarray(sd["params.r"]))
        if self.mode == "signed":
            self.cs_params = cskt.CountSketchParams(
                base=params,
                sign_q=jnp.asarray(sd["params.sign_q"]),
                sign_r=jnp.asarray(sd["params.sign_r"]))
            self.params = self.cs_params.base
        else:
            self.params = params


class KernelHierarchy:
    """Hierarchy whose level tables live concatenated + padded for the fused
    single-launch Pallas update (kernels/hier_update.py).

    The ingest counterpart of the one-launch query kernel: every stream
    block is folded into ALL levels by one pallas_call against the
    ``[w, sum_L h_L_pad]`` concatenated table, hashing each item once per
    row and deriving the level cells by the mixed-radix cascade.  Linear
    mode only -- the conservative update's row-coupling min forces a
    sequential per-level fold; conservative hierarchies take
    core.hierarchy.update_conservative (which shares the same index
    cascade) instead.

    :meth:`state` materializes the standard ``HierarchyState`` view (per
    level: unpadded table slice + prefix-sliced shared params), cached
    until the next ingest, so the descent/query stack runs unchanged on
    kernel-ingested hierarchies.
    """

    def __init__(self, hspec, key: jax.Array, *, tile_h: int = 512,
                 block_b: int = 1024, dtype=jnp.int32,
                 interpret: Optional[bool] = None, mode: str = "linear"):
        if mode not in ("linear", "signed"):
            raise ValueError(
                "KernelHierarchy modes are 'linear' and 'signed' "
                "(conservative hierarchies take "
                f"core.hierarchy.update_conservative), got {mode!r}")
        from repro.core import hierarchy as hh

        self._hh = hh
        self.hspec = hspec
        self.hplan = make_hier_plan(hspec, tile_h)
        self.mode = mode
        if mode == "signed":
            # same-key bit parity with the core.countsketch hierarchy
            self.cs_params = cskt.init_params(hspec.levels[-1], key)
            self.params = self.cs_params.base
        else:
            self.cs_params = None
            self.params = sk.init_params(hspec.levels[-1], key)  # shared family
        self.block_b = int(block_b)
        self.table = jnp.zeros((hspec.base.width, self.hplan.padded_cols),
                               dtype=dtype)
        self.interpret = default_interpret() if interpret is None else interpret
        self._state_cache: Optional[object] = None

    @classmethod
    def from_state(cls, hspec, state, *, tile_h: int = 512,
                   block_b: int = 1024,
                   interpret: Optional[bool] = None) -> "KernelHierarchy":
        """Adopt an existing (shared-params) HierarchyState's tables+params."""
        self = cls.__new__(cls)
        from repro.core import hierarchy as hh

        self._hh = hh
        self.hspec = hspec
        self.hplan = make_hier_plan(hspec, tile_h)
        self.mode = "linear"   # HierarchyState carries no sign params
        self.cs_params = None
        self.params = state.states[-1].params
        self.block_b = int(block_b)
        self.interpret = default_interpret() if interpret is None else interpret
        self._state_cache = None
        self.load_state(state)
        return self

    # -- state interop -------------------------------------------------------
    def load_state(self, state) -> None:
        """Pack a HierarchyState into the concatenated padded table.

        The state must carry the shared-prefix params of
        ``init_hierarchy`` (validated host-side): the fused kernel hashes
        with the finest params only and derives every level by division,
        which is meaningless for independently drawn per-level params.
        """
        if self.mode != "linear":
            raise ValueError(
                "load_state() takes a (sign-less) HierarchyState and is "
                "linear-mode only; signed hierarchies are built by ingest "
                "from their own key")
        if not self._hh.params_share_prefix(state):
            raise ValueError(
                "KernelHierarchy requires the shared per-group hash family "
                "(level params must be prefix slices of the finest "
                "level's, as drawn by init_hierarchy)")
        self.params = state.states[-1].params
        parts = []
        for st_l, h_l, pad_l in zip(state.states, self.hplan.level_sizes,
                                    self.hplan.level_pads):
            if st_l.table.shape[1] != h_l:
                raise ValueError("state tables do not match the spec")
            parts.append(jnp.pad(st_l.table, ((0, 0), (0, pad_l - h_l))))
        self.table = jnp.concatenate(parts, axis=1)
        self._state_cache = None

    def state(self):
        """HierarchyState view (sliced, unpadded); cached until next ingest.

        Linear mode only: HierarchyState is the min-estimated descent/merge
        currency and carries no sign params -- the signed view is
        :meth:`cs_state`."""
        if self.mode != "linear":
            raise ValueError(
                "state() is the linear (Count-Min) hierarchy view; signed "
                "hierarchies use cs_state()")
        if self._state_cache is None:
            states = []
            for l, (off, h_l) in enumerate(zip(self.hplan.level_offsets,
                                               self.hplan.level_sizes)):
                states.append(sk.SketchState(
                    params=self._hh.level_params(self.hspec, self.params, l),
                    table=self.table[:, off : off + h_l]))
            self._state_cache = self._hh.HierarchyState(states=tuple(states))
        return self._state_cache

    def cs_state(self) -> "cskt.CountSketchHierarchy":
        """CountSketchHierarchy view (sliced, unpadded); cached until next
        ingest -- feeds the signed candidate queries and threshold descent
        (core.countsketch.candidate_estimates / find_heavy_hitters)."""
        if self.mode != "signed":
            raise ValueError("cs_state() is the signed hierarchy view; "
                             "linear hierarchies use state()")
        if self._state_cache is None:
            tables = tuple(
                self.table[:, off : off + h_l]
                for off, h_l in zip(self.hplan.level_offsets,
                                    self.hplan.level_sizes))
            self._state_cache = cskt.CountSketchHierarchy(
                params=self.cs_params, tables=tables)
        return self._state_cache

    # -- ingest --------------------------------------------------------------
    def update(self, items, freqs) -> None:
        """Fold a weighted block: one fused launch per fixed-size sub-block."""
        items = np.asarray(items, dtype=np.uint32)
        freqs = np.asarray(freqs)
        if self.mode == "signed":
            check_signed_kernel_freqs(freqs, self.table.dtype)
        else:
            check_linear_kernel_freqs(freqs, self.table.dtype)
        schema = self.hspec.levels[-1].schema
        n_fine = self.hspec.n_levels - 1
        b = self.block_b
        for s in range(0, items.shape[0], b):
            blk_i = items[s : s + b]
            blk_f = freqs[s : s + b]
            if blk_i.shape[0] < b:
                pad = b - blk_i.shape[0]
                blk_i = np.pad(blk_i, ((0, pad), (0, 0)))
                blk_f = np.pad(blk_f, (0, pad))
            # group-major column order = the finest level's chunk layout
            ordered = np.asarray(self.hspec.level_items(n_fine, blk_i))
            chunks = schema.module_chunks(jnp.asarray(ordered))
            if self.mode == "signed":
                self.table = hier_update_signed_pallas(
                    self.hplan, self.table, chunks, jnp.asarray(blk_f),
                    self.params.q, self.params.r,
                    self.cs_params.sign_q, self.cs_params.sign_r,
                    interpret=self.interpret,
                )
            else:
                self.table = hier_update_pallas(
                    self.hplan, self.table, chunks, jnp.asarray(blk_f),
                    self.params.q, self.params.r, interpret=self.interpret,
                )
        self._state_cache = None
