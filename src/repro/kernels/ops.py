"""Jit'd public wrappers around the Pallas sketch kernels.

These adapt the high-level ``SketchSpec``/``SketchState`` API (core/sketch.py)
to the kernels: chunk extraction, padding the table width to the tile size,
padding stream blocks to a fixed block length (so one compiled kernel serves
the whole stream), and CPU fallback via ``interpret=True`` (the kernel body
executes in Python on CPU -- bit-identical logic, which is how the kernels
are validated in this container; on TPU set ``interpret=False``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.kernels import ref
from repro.kernels.hashes import IndexPlan, make_plan
from repro.kernels.sketch_update import padded_table_size, sketch_update_pallas
from repro.kernels.sketch_query import sketch_query_pallas

_MAX_KERNEL_FREQ = 1 << 24  # two 12-bit limbs


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


class KernelSketch:
    """Sketch whose table lives padded for the Pallas kernels."""

    def __init__(self, spec: sk.SketchSpec, key: jax.Array, *,
                 tile_h: int = 512, block_b: int = 1024,
                 dtype=jnp.int32, interpret: Optional[bool] = None):
        self.spec = spec
        self.plan = make_plan(spec)
        self.params = sk.init_params(spec, key)
        self.tile_h = int(tile_h)
        self.block_b = int(block_b)
        self.h_pad = padded_table_size(spec.table_size, tile_h)
        self.table = jnp.zeros((spec.width, self.h_pad), dtype=dtype)
        self.interpret = default_interpret() if interpret is None else interpret

    # -- stream ops ---------------------------------------------------------
    def update(self, items, freqs) -> None:
        items = np.asarray(items, dtype=np.uint32)
        freqs = np.asarray(freqs)
        if freqs.max(initial=0) >= _MAX_KERNEL_FREQ:
            raise ValueError("per-arrival frequency >= 2^24: use core.sketch path")
        b = self.block_b
        for s in range(0, items.shape[0], b):
            blk_i = items[s : s + b]
            blk_f = freqs[s : s + b]
            if blk_i.shape[0] < b:
                pad = b - blk_i.shape[0]
                blk_i = np.pad(blk_i, ((0, pad), (0, 0)))
                blk_f = np.pad(blk_f, (0, pad))
            chunks = self.spec.schema.module_chunks(jnp.asarray(blk_i))
            self.table = sketch_update_pallas(
                self.plan, self.table, chunks, jnp.asarray(blk_f),
                self.params.q, self.params.r,
                tile_h=self.tile_h, interpret=self.interpret,
            )

    def query(self, items) -> np.ndarray:
        items = np.asarray(items, dtype=np.uint32)
        chunks = self.spec.schema.module_chunks(jnp.asarray(items))
        est = sketch_query_pallas(
            self.plan, self.table, chunks, self.params.q, self.params.r,
            tile_h=self.tile_h, interpret=self.interpret,
        )
        return np.asarray(est)

    # -- interop ------------------------------------------------------------
    def state(self) -> sk.SketchState:
        """Unpadded SketchState view (for merge with the reference path)."""
        return sk.SketchState(params=self.params,
                              table=self.table[:, : self.spec.table_size])
