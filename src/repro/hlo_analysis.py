"""Loop-aware cost analysis of optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
so any scanned layer stack (our models scan over blocks, SSD chunks,
attention q-chunks) under-reports FLOPs/bytes/collectives by the trip
count.  This module re-derives the three roofline inputs from the
*post-partitioning* HLO text with loops multiplied out:

  * symbol table per computation (shapes of every instruction),
  * dot FLOPs = 2 x |result| x |contracting dims| (batch dims included in
    the result), elementwise/reduce ops counted at 1 FLOP/elem,
  * bytes = operands + result for top-level ops; fusions count their
    boundary (operands/result) for bytes but their interior for FLOPs --
    matching the HBM-traffic meaning of the memory roofline term,
  * while trip counts from ``known_trip_count`` backend configs when
    present, else the loop-bound constant in the condition computation,
  * collectives scaled by the enclosing loops' trip product, with ring
    wire-byte factors per op (see repro.roofline).

Everything is per-partition (the compiled module is the local SPMD
program), so terms divide by per-chip peaks directly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
# result is either a tuple "( ... )" (may contain /*index=k*/ comments but no
# nested parens) or a single token like "bf16[16,4096]{1,0}"
_OPCODE = re.compile(r"^(\(.*?\)|\S+)\s+([a-z][a-z0-9\-]*)\(")
_TRIP_BC = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "floor", "ceil", "round-nearest-afz", "logistic", "cosine", "sine",
    "expm1", "log1p", "atan2", "remainder", "select", "compare", "and",
    "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "clamp", "convert",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for m in _SHAPE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr]
    order: List[str]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost") -> "Cost":
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_wire_bytes += o.coll_wire_bytes
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v
        for k, v in o.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0) + v
        for k, v in o.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0) + v
        return self

    def scaled(self, t: float) -> "Cost":
        return Cost(
            flops=self.flops * t,
            bytes=self.bytes * t,
            coll_wire_bytes=self.coll_wire_bytes * t,
            coll_counts={k: v * t for k, v in self.coll_counts.items()},
            coll_bytes={k: v * t for k, v in self.coll_bytes.items()},
            bytes_by_op={k: v * t for k, v in self.bytes_by_op.items()},
        )


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if (not line[:1].isspace() and line.rstrip().endswith("{")
                and (line.startswith("%") or line.startswith("ENTRY"))):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(name=m.group(1), instrs={}, order=[])
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        mo = _OPCODE.match(rhs)
        if not mo:
            continue
        shape_str, opcode = mo.group(1), mo.group(2)
        # operand names: %refs inside the first (...) group
        args_m = re.search(re.escape(opcode) + r"\(([^)]*)\)", rhs)
        operands = re.findall(r"%([\w.\-]+)", args_m.group(1)) if args_m else []
        cur.instrs[name] = Instr(name=name, shape_str=shape_str, opcode=opcode,
                                 operands=operands, raw=rhs)
        cur.order.append(name)
    return comps, entry


def _group_size(raw: str) -> int:
    m = _GROUPS_RE.search(raw)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_ARR_RE.search(raw)
    if m:
        return int(m.group(2))
    return 2


def _trip_count(comps: Dict[str, Computation], instr: Instr) -> float:
    m = _TRIP_BC.search(instr.raw)
    if m:
        return float(m.group(1))
    mc = _COND.search(instr.raw)
    if mc and mc.group(1) in comps:
        consts = [int(x) for x in _CONST_INT.findall(
            "\n".join(i.raw for i in comps[mc.group(1)].instrs.values()))]
        if consts:
            return float(max(consts))
    return 1.0


def _dot_flops(comp: Computation, instr: Instr) -> float:
    res_elems, _ = _shape_elems_bytes(instr.shape_str)
    contract = 1
    mc = _CONTRACT.search(instr.raw)
    if mc and instr.operands:
        lhs = comp.instrs.get(instr.operands[0])
        if lhs is not None:
            dims_s = _SHAPE.search(lhs.shape_str)
            if dims_s:
                dims = [int(d) for d in dims_s.group(2).split(",") if d]
                for ci in mc.group(1).split(","):
                    if ci:
                        idx = int(ci)
                        if idx < len(dims):
                            contract *= dims[idx]
    return 2.0 * res_elems * contract


_PARAM_IDX = re.compile(r"parameter\((\d+)\)")
_SLICE_READERS = {"dynamic-slice", "gather", "slice"}


def _fusion_boundary_bytes(comps: Dict[str, Computation], callee: str,
                           call_ins: Instr, caller: Computation) -> float:
    """HBM traffic across a fusion boundary, use-aware.

    A parameter whose only internal uses are (dynamic-)slice/gather reads
    contributes the sliced bytes, not the full buffer (the canonical case:
    the loop-carried residual stack read one layer per iteration).  A root
    that is a dynamic-update-slice writes only the update.
    """
    comp = comps.get(callee)
    if comp is None:
        return 0.0
    # caller-side operand sizes by parameter index
    opnd_sizes: List[float] = []
    for o in call_ins.operands:
        if o in caller.instrs:
            opnd_sizes.append(_shape_elems_bytes(caller.instrs[o].shape_str)[1])
        else:
            opnd_sizes.append(0.0)
    total = 0.0
    root_name = comp.order[-1] if comp.order else None
    for iname in comp.order:
        ins = comp.instrs[iname]
        if ins.opcode != "parameter":
            continue
        midx = _PARAM_IDX.search(ins.raw)
        pidx = int(midx.group(1)) if midx else -1
        uses = [comp.instrs[u] for u in comp.order
                if iname in comp.instrs[u].operands]
        if uses and all(u.opcode in _SLICE_READERS for u in uses):
            total += sum(_shape_elems_bytes(u.shape_str)[1] for u in uses)
        elif 0 <= pidx < len(opnd_sizes):
            total += opnd_sizes[pidx]
    if root_name is not None:
        root = comp.instrs[root_name]
        if root.opcode == "dynamic-update-slice" and root.operands:
            upd = root.operands[1] if len(root.operands) > 1 else None
            total += (_shape_elems_bytes(comp.instrs[upd].shape_str)[1]
                      if upd in comp.instrs else 0.0)
        else:
            total += _shape_elems_bytes(root.shape_str)[1]
    return total


def analyze(text: str) -> Cost:
    comps, entry = parse_module(text)
    memo: Dict[str, Cost] = {}

    def comp_cost(name: str, for_bytes: bool = True) -> Cost:
        key = name + ("/b" if for_bytes else "/f")
        if key in memo:
            return memo[key]
        total = Cost()
        comp = comps.get(name)
        if comp is None:
            return total
        for iname in comp.order:
            ins = comp.instrs[iname]
            op = ins.opcode
            res_elems, res_bytes = _shape_elems_bytes(ins.shape_str)

            def _op_bytes(idx: int) -> int:
                if idx < len(ins.operands) and ins.operands[idx] in comp.instrs:
                    return _shape_elems_bytes(
                        comp.instrs[ins.operands[idx]].shape_str)[1]
                return 0

            opnd_bytes = sum(_op_bytes(i) for i in range(len(ins.operands)))
            # in-place slice updates move only the slice, not the buffer
            if op == "dynamic-update-slice":
                opnd_bytes = 2 * _op_bytes(1)
                res_bytes = 0
            elif op == "dynamic-slice":
                opnd_bytes = res_bytes
            elif op == "scatter":
                opnd_bytes = 2 * _op_bytes(2) + _op_bytes(1)
                res_bytes = 0
            elif op == "gather":
                opnd_bytes = res_bytes + _op_bytes(1)
            c = Cost()
            if op == "dot":
                c.flops = _dot_flops(comp, ins)
                if for_bytes:
                    c.bytes = opnd_bytes + res_bytes
            elif op in ("fusion", "call"):
                mcall = _CALLS.search(ins.raw)
                if mcall:
                    inner = comp_cost(mcall.group(1), for_bytes=False)
                    c += inner
                    if for_bytes:
                        c.bytes += _fusion_boundary_bytes(
                            comps, mcall.group(1), ins, comp)
                elif for_bytes:
                    c.bytes += opnd_bytes + res_bytes
            elif op == "while":
                trip = _trip_count(comps, ins)
                mb = _BODY.search(ins.raw)
                if mb:
                    c += comp_cost(mb.group(1), for_bytes=for_bytes).scaled(trip)
            elif op == "conditional":
                branches = re.findall(r"(?:true_computation|false_computation|"
                                      r"branch_computations=\{)([^,}]*)",
                                      ins.raw)
                names = re.findall(r"%([\w.\-]+)", ",".join(branches))
                if names:
                    cs = [comp_cost(n, for_bytes=for_bytes) for n in names]
                    best = max(cs, key=lambda x: x.flops + x.bytes)
                    c += best
                if for_bytes:
                    c.bytes += opnd_bytes + res_bytes
            elif op.startswith(_COLLECTIVES) or any(
                    op == x or op == x + "-start" for x in _COLLECTIVES):
                base = op.replace("-start", "")
                if base in _COLLECTIVES and not op.endswith("-done"):
                    g = _group_size(ins.raw)
                    if base == "all-reduce":
                        wire = 2 * res_bytes * max(0, g - 1) / max(1, g)
                    elif base == "all-gather":
                        wire = res_bytes * max(0, g - 1) / max(1, g)
                    elif base == "reduce-scatter":
                        wire = res_bytes * max(0, g - 1)
                    else:
                        wire = res_bytes
                    c.coll_wire_bytes = wire
                    c.coll_counts[base] = 1
                    c.coll_bytes[base] = res_bytes
                    if for_bytes:
                        c.bytes = opnd_bytes + res_bytes
            elif op in ("reduce", "reduce-window"):
                c.flops = float(opnd_bytes and res_elems or res_elems)
                # approximate: one op per input element
                in_elems = sum(_shape_elems_bytes(comp.instrs[o].shape_str)[0]
                               for o in ins.operands if o in comp.instrs)
                c.flops = float(in_elems)
                if for_bytes:
                    c.bytes = opnd_bytes + res_bytes
            elif op in _ELEMWISE:
                c.flops = float(res_elems)
                if for_bytes:
                    c.bytes = opnd_bytes + res_bytes
            elif op in ("parameter", "constant", "get-tuple-element", "tuple",
                        "bitcast", "copy-start", "copy-done",
                        "after-all", "partition-id", "replica-id"):
                pass
            else:
                # data movement (scatter, gather, dynamic-slice, transpose,
                # broadcast, reshape, concatenate, pad, copy, iota, ...)
                if for_bytes:
                    c.bytes = opnd_bytes + res_bytes
            if c.bytes and op not in ("while", "conditional"):
                # tag direct contributions only (loop bodies keep their own tags)
                direct = c.bytes - sum(c.bytes_by_op.values())
                if direct > 0:
                    c.bytes_by_op[op] = c.bytes_by_op.get(op, 0) + direct
            total += c
        memo[key] = total
        return total

    if entry is None:
        return Cost()
    return comp_cost(entry, for_bytes=True)
