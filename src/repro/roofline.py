"""Three-term roofline analysis from compiled dry-run artifacts.

    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = wire_bytes_per_chip / link_bw

Sources: ``compiled.cost_analysis()`` (flops / bytes accessed -- these are
*per-partition* numbers: the analyzed module is the post-SPMD local module)
and the optimized HLO text for collectives.  cost_analysis is not collective
aware, so wire bytes are derived per op from the (local) result shape with
ring-algorithm factors:

    all-reduce        2 x bytes          (reduce-scatter + all-gather phases)
    all-gather        1 x bytes          (result is the gathered local copy)
    reduce-scatter    (G-1) x bytes      (result is the scattered shard)
    all-to-all        1 x bytes
    collective-permute 1 x bytes

Hardware constants: TPU v5e-class -- 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]
    wire_bytes: int

    def as_dict(self) -> Dict:
        return {"counts": self.counts, "result_bytes": self.result_bytes,
                "wire_bytes": self.wire_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum collective wire bytes (per device) from (local-shape) HLO text."""
    counts: Dict[str, int] = {}
    rbytes: Dict[str, int] = {}
    wire = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2)
        op = m.group(3)
        if "-done(" in line:
            continue  # async pair: count the -start only
        b = _shape_bytes(shape_str)
        gsize = _group_size(line)
        if op == "all-reduce":
            wb = 2 * b * max(0, gsize - 1) // max(1, gsize)
        elif op == "all-gather":
            wb = b * max(0, gsize - 1) // max(1, gsize)
        elif op == "reduce-scatter":
            wb = b * max(0, gsize - 1)
        else:  # all-to-all / collective-permute
            wb = b
        counts[op] = counts.get(op, 0) + 1
        rbytes[op] = rbytes.get(op, 0) + b
        wire += wb
    return CollectiveStats(counts=counts, result_bytes=rbytes, wire_bytes=wire)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_ARR_RE.search(line)
    if m:  # iota format replica_groups=[G,N] -> N per group
        return int(m.group(2))
    return 2


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    model_flops: float               # 6*N(_active)*D tokens (global)
    collectives: Dict

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (chips * HLO flops): remat/redundancy waste."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_frac(self) -> float:
        """Achievable fraction of peak: useful model FLOP-time over the
        max of the three terms (what fraction of the bound is useful)."""
        t_model = self.model_flops / self.chips / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_model / t_bound if t_bound else 0.0

    def as_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
            "collectives": self.collectives,
        }


def model_flops_for(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode step), N = active.

    Enc-dec models split the seq budget between the stacks (each sees s/2),
    so the token count is halved to keep the useful-FLOPs ratio honest.
    """
    n = cfg.param_count()["active"]
    if cfg.n_enc_layers:
        seq = max(1, seq // 2)
    if shape_kind == "train":
        return 6.0 * n * batch * seq
    if shape_kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch          # decode: one token per sequence


def build_roofline(arch: str, shape: str, mesh_name: str, chips: int,
                   cost: Dict, hlo_text: str, model_flops: float) -> Roofline:
    coll = parse_collectives(hlo_text)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=float(cost.get("flops", 0.0)),
        hbm_bytes_per_chip=float(cost.get("bytes accessed", 0.0)),
        wire_bytes_per_chip=float(coll.wire_bytes),
        model_flops=model_flops,
        collectives=coll.as_dict(),
    )
