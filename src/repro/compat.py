"""Version compatibility shims for the jax API surface this repo uses.

The runtime targets the modern ``jax.shard_map`` entry point; older
installs (<= 0.4.x, like this container's 0.4.37) only ship
``jax.experimental.shard_map`` whose replication check is spelled
``check_rep``.  Route every call through :func:`shard_map` so both work.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
