"""Sharded heavy-hitter serving on multi-device meshes.

:class:`ShardedTopKService` runs the full hierarchical heavy-hitter
pipeline (core/hierarchy.py) on a data-parallel device mesh:

  ingest   the stream block is split over the mesh's data axes and every
           shard folds its slice into per-level *local* tables
           (core.distributed.lazy_hierarchy_update -- ONE shard_map over
           all levels: each item is hashed once and every level's cell
           derived by the mixed-radix cascade; no collective on the
           ingest hot path, local tables donated into the jitted fold),
           while per-shard space-saving pools (core/summary.py) admit
           candidate group values;
  sync     at explicit sync points the local tables are psum-merged per
           level (core.distributed.merge_local_hierarchy -- exact by
           linearity) into the serving snapshot, and the shard pools fold
           into global pools with the mergeable-summaries rule
           (SpaceSaving.fold);
  query    ``heavy_hitters`` / ``topk`` run the recursive descent
           (core.hierarchy.find_heavy_hitters, optionally the Pallas
           candidate kernel kernels/hier_query.py) against the merged
           level tables.

Shard-count invariance: every level table is linear in the stream and
integer addition is exact and order-free, so the merged tables -- and with
them the query output -- are *bit-identical* for any shard count and any
split of the same stream (1, 2, 4 and 8 shards all agree; enforced by
tests/test_sharded_topk.py).  The candidate pools stay invariant as long
as they are under capacity (the fold is then an exact union); the
service's ``candidates()`` sorts rows lexicographically so the descent
order never depends on pool iteration order.

Conservative tables are non-linear and cannot psum: the service refuses
``mode="conservative"`` at construction, as do the underlying distributed
entry points (core.distributed.require_linear) and the single-shard
endpoint's :meth:`~repro.serving.sketch_engine.SketchTopKEndpoint.to_sharded`
promotion.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as dist
from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.core.summary import SpaceSaving
from repro.serving.migration import MigratingSurface, require_not_migrating


def threshold_descent_topk(
    heavy_hitters_fn: Callable[..., Tuple[np.ndarray, np.ndarray]],
    candidates: Sequence[np.ndarray],
    k: int,
    *,
    total: int,
    n_modules: int,
    min_threshold: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k by estimate: geometric threshold descent until k keys found.

    Shared by SketchTopKEndpoint.topk and ShardedTopKService.topk.
    ``min_threshold`` floors the descent; the default scales with the
    stream (total / 2^17) because at threshold ~1 every candidate survives
    every level and the leaf evaluates the full candidate cross-product --
    exactly the blowup the hierarchy avoids.  Pass ``min_threshold=1``
    explicitly to force exhaustive descent on small candidate pools.
    """
    if min_threshold is None:
        min_threshold = max(1, total >> 17)
    thr = max(total, 1)
    items = np.zeros((0, n_modules), np.uint32)
    est = np.zeros((0,), np.int64)
    while thr >= min_threshold:
        items, est = heavy_hitters_fn(thr, candidates=candidates)
        if len(est) >= k or thr == min_threshold:
            break
        thr = max(min_threshold, thr // 4)
    return items[:k], est[:k]


class ShardedTopKService(MigratingSurface):
    """Heavy-hitter / top-k serving over a data-parallel device mesh.

    One service instance owns the whole mesh: ``n_shards`` is the product
    of the ``data_axes`` sizes, each shard ingesting a contiguous slice of
    every block.  Hash params are drawn once from ``key`` (all shards and
    all shard counts share them -- cell-wise sums of differently hashed
    tables would be garbage), so two services built from the same spec and
    key are merge-compatible snapshots of each other.

    ``sync_every`` controls the psum cadence: the merge all-reduce runs
    after that many ingested blocks (1 = synchronous, the sharded_build
    shape).  Pass ``sync_every=None`` for fully manual sync points; any
    query forces a sync first, so results are never stale.

    Hot spec migration (serving/migration.py): ``begin_migration`` opens
    a double-write window onto a successor service on the same mesh;
    queries serve from the old tables until the successor has absorbed
    ``warmup`` mass, then the service cuts over wholesale.  Because the
    successor is itself shard-count invariant, a migration is
    bit-identical across shard counts end to end.
    """

    def __init__(self, base_spec: sk.SketchSpec, key: jax.Array, mesh, *,
                 data_axes: Optional[Tuple[str, ...]] = None,
                 max_candidates_per_group: int = 1 << 16,
                 sync_every: Optional[int] = 1,
                 use_kernel: bool = False, dtype=jnp.int32,
                 mode: str = "linear"):
        dist.require_linear(mode, "ShardedTopKService")
        from repro.launch.mesh import sketch_data_axes

        self.mode = mode
        self.mesh = mesh
        if data_axes is None:
            data_axes = sketch_data_axes(mesh)
        self.data_axes = tuple(data_axes)
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.data_axes],
                                    dtype=np.int64))
        self.hspec = hh.HierarchySpec.from_spec(base_spec)
        self.merged = hh.init_hierarchy(self.hspec, key, dtype=dtype)
        self._local = tuple(
            jnp.zeros((self.n_shards,) + st.table.shape, dtype=dtype)
            for st in self.merged.states)
        self.max_candidates = int(max_candidates_per_group)
        self.use_kernel = use_kernel
        self.sync_every = sync_every
        self._dtype = dtype
        self._migration = None
        self.total = 0
        self._blocks_since_sync = 0
        self._dirty = False
        self._pools_dirty = False
        self._shard_pools: List[List[SpaceSaving]] = [
            [SpaceSaving(self.max_candidates, len(g))
             for g in base_spec.partition]
            for _ in range(self.n_shards)
        ]
        self._global_pools: List[SpaceSaving] = [
            SpaceSaving(self.max_candidates, len(g))
            for g in base_spec.partition
        ]
        self._build_jit_wrappers()

    def _build_jit_wrappers(self) -> None:
        """(Re)build the jit-cached shard_map wrappers for the CURRENT mesh.

        jit wrappers cached per service: an eager shard_map re-traces on
        every call, which would dominate the ingest hot path.  Params are
        dynamic args (not closed over) so a promoted endpoint's params
        (to_sharded swaps self.merged) hit the same compiled executable.
        The local tables are DONATED: the per-shard fold (which now
        hashes each item once and cascades to every level inside one
        shard_map) accumulates in place instead of copying every level
        table per block.  ``ingest`` rebinds self._local to the result,
        which is the only live reference.

        The lambdas close over ``self.mesh``/``self.data_axes`` *at trace
        time*, so anything that changes the mesh (``remesh``) MUST call
        this again -- reusing the old function objects would silently
        replay executables compiled for the old device set (the same
        staleness hazard migration's ``_adopt`` documents).
        """
        self._fold = jax.jit(
            lambda local, params, it, fr: dist.lazy_hierarchy_update(
                self.hspec, self.mesh, self.data_axes, local, params,
                it, fr),
            donate_argnums=(0,))
        self._merge = jax.jit(
            lambda local: dist.merge_local_hierarchy(
                self.mesh, self.data_axes, local))

    # -- ingest (per-shard lazy fold, no collective) ------------------------

    def ingest(self, items: np.ndarray,
               freqs: Optional[np.ndarray] = None) -> None:
        """Fold a weighted key block, sharded over the mesh's data axes.

        The block is padded so every shard sees the same power-of-two row
        count (zero-frequency pad rows are no-ops in the linear update and
        are skipped by the pools), then each shard folds its contiguous
        slice into its local per-level tables -- no collective until the
        next sync point.
        """
        items = np.asarray(items, dtype=np.uint32)
        if items.shape[0] == 0:
            return
        if freqs is None:
            freqs = np.ones(items.shape[0], dtype=np.int64)
        freqs = np.asarray(freqs)
        self.total += int(freqs.sum())
        raw_items, raw_freqs = items, freqs
        items, freqs, per = dist.pad_block_pow2(items, freqs, self.n_shards)
        for s in range(self.n_shards):
            sl = slice(s * per, (s + 1) * per)
            for j, g in enumerate(self.hspec.base.partition):
                self._shard_pools[s][j].offer(items[sl][:, list(g)],
                                              freqs[sl])
        params = tuple(st.params for st in self.merged.states)
        self._local = self._fold(self._local, params, jnp.asarray(items),
                                 jnp.asarray(freqs))
        self._dirty = True
        self._pools_dirty = True
        self._blocks_since_sync += 1
        if self.sync_every and self._blocks_since_sync >= self.sync_every:
            self.sync()
        # double-write window: the successor service pads/splits the raw
        # block itself, exactly like a fresh service would -- the padded
        # copy above must NOT leak into it
        self._migration_tick(raw_items, raw_freqs)

    # -- hot spec migration hooks (serving/migration.MigratingSurface) ------

    def _build_successor(self, new_spec: sk.SketchSpec,
                         key: jax.Array) -> "ShardedTopKService":
        """A fresh service on ``new_spec`` over the SAME mesh/data axes
        (same pool capacity, sync cadence, table dtype).  Shard-count
        invariance is preserved end to end: the successor is itself
        bit-identical across shard counts."""
        return ShardedTopKService(
            new_spec, key, self.mesh, data_axes=self.data_axes,
            max_candidates_per_group=self.max_candidates,
            sync_every=self.sync_every, use_kernel=self.use_kernel,
            dtype=self._dtype)

    def _adopt(self, inc: "ShardedTopKService") -> None:
        """Adopt the successor's state wholesale; free the old tables.

        The successor's jit-cached fold/merge wrappers come along (they
        close over the successor's static spec/mesh config, which is
        exactly this service's config from here on); the old wrappers,
        local/merged tables, and pools lose their last references.
        """
        self.hspec = inc.hspec
        self.merged = inc.merged
        self._local = inc._local
        self._dirty = inc._dirty
        self._pools_dirty = inc._pools_dirty
        self._blocks_since_sync = inc._blocks_since_sync
        self._shard_pools = inc._shard_pools
        self._global_pools = inc._global_pools
        self.total = inc.total
        self._fold = inc._fold
        self._merge = inc._merge

    # -- sync (explicit psum point) -----------------------------------------

    def sync(self) -> None:
        """psum-merge local deltas into the serving snapshot.

        Tables: per-level all-reduce of the lazily accumulated local
        tables, folded into ``merged`` and reset (exact by linearity).
        The candidate-pool fold is deferred to the first query that reads
        ``candidates()`` -- the fold is pure host-side dict work with no
        collective, so paying it per sync (per block at sync_every=1)
        would burden the ingest hot path for nothing.
        """
        if not self._dirty:
            return
        deltas = self._merge(self._local)
        self.merged = hh.HierarchyState(states=tuple(
            sk.SketchState(params=st.params, table=st.table + d)
            for st, d in zip(self.merged.states, deltas)))
        self._local = tuple(jnp.zeros_like(t) for t in self._local)
        self._dirty = False
        self._blocks_since_sync = 0

    def _ensure_synced(self) -> None:
        if self._dirty:
            self.sync()

    # -- elastic N->M re-meshing --------------------------------------------

    def remesh(self, new_mesh, *,
               data_axes: Optional[Tuple[str, ...]] = None) -> None:
        """Move this service onto a different mesh (grow or shrink), live.

        Exact by linearity, no drain needed: ``sync()`` psum-merges every
        survivor shard's local deltas into the replicated serving tables,
        then the merged state is re-scattered onto the new mesh (via
        training/fault_tolerance.elastic_remesh) with FRESH zero locals on
        the new data axes -- merged-plus-zeros is the same sum as any
        other split, so queries before and after the remesh are
        bit-identical, at any N -> M.  Candidate pools fold into the new
        shard 0 (exact union under capacity, the same argument as
        ``to_sharded``); subsequent ingest fills all M shards' pools.

        The jit-cached shard_map wrappers are REBUILT for the new mesh:
        the old lambdas close over the old mesh at trace time, so reusing
        them would silently replay executables compiled for the old
        device set.

        Refused mid-migration (the successor would need the same remesh).
        """
        from repro.launch.mesh import sketch_data_axes
        from repro.training.fault_tolerance import elastic_remesh

        require_not_migrating(self._migration, "ShardedTopKService.remesh")
        self.sync()
        if data_axes is None:
            data_axes = sketch_data_axes(new_mesh)
        data_axes = tuple(data_axes)
        new_n = int(np.prod([new_mesh.shape[a] for a in data_axes],
                            dtype=np.int64))
        # fold every old shard's pools before the shard list is resized
        folded = [SpaceSaving.fold([pools[j] for pools in self._shard_pools])
                  for j in range(len(self._global_pools))]
        self.mesh = new_mesh
        self.data_axes = data_axes
        self.n_shards = new_n
        # merged tables + params are logically replicated; re-place them on
        # the new device set so nothing still lives on a lost device
        self.merged = elastic_remesh(self.merged, new_mesh, lambda x: dist.P())
        self._local = dist.init_local_tables(
            new_mesh, data_axes, new_n,
            [st.table.shape for st in self.merged.states], self._dtype)
        self._shard_pools = (
            [folded]
            + [[SpaceSaving(self.max_candidates, len(g))
                for g in self.hspec.base.partition]
               for _ in range(new_n - 1)])
        self._pools_dirty = True
        self._dirty = False
        self._blocks_since_sync = 0
        self._build_jit_wrappers()

    # -- durable state (serving/recovery.py snapshot currency) ---------------

    def _config_fingerprint(self) -> np.ndarray:
        desc = (f"sharded|{self.hspec.base!r}|mode={self.mode}"
                f"|dtype={jnp.dtype(self._dtype)}|cap={self.max_candidates}")
        return np.frombuffer(desc.encode(), dtype=np.uint8).copy()

    def state_dict(self) -> dict:
        """Full service state as a flat ``{key: ndarray}`` mapping.

        Syncs first, so the snapshot is the CANONICAL form -- merged
        tables hold everything ingested, locals are zero.  The sync is
        query-bit-neutral (any query would have forced the same psum), so
        "snapshot then crash then restore" and "never crashed" agree
        bitwise.  The fingerprint deliberately excludes the mesh/shard
        count: a 4-shard snapshot restores into a 2-shard service (pools
        fold into shard 0, same exactness argument as ``remesh``).
        """
        if self._migration is not None:
            raise ValueError(
                "cannot checkpoint a service mid-migration: the warmup "
                "successor's state is transient; call abort_migration() to "
                "roll back to the active surface (or wait for cutover), "
                "then snapshot")
        self.sync()
        out = {
            "meta.total": np.asarray(self.total, dtype=np.int64),
            "meta.n_shards": np.asarray(self.n_shards, dtype=np.int64),
            "meta.fingerprint": self._config_fingerprint(),
            "params.q": np.asarray(self.merged.states[-1].params.q),
            "params.r": np.asarray(self.merged.states[-1].params.r),
        }
        for i, st in enumerate(self.merged.states):
            out[f"level{i}.table"] = np.asarray(st.table)
        for s, pools in enumerate(self._shard_pools):
            for j, p in enumerate(pools):
                for k, v in p.state_dict().items():
                    out[f"shard{s}.pool{j}.{k}"] = v
        return out

    def load_state_dict(self, sd: dict) -> None:
        """Restore state saved by :meth:`state_dict`; bit-exact round trip.

        When the saved shard count matches, every shard's pool is restored
        in place; otherwise all saved pools fold into shard 0 (exact union
        under capacity) -- either way the merged tables, totals, and query
        output are bit-identical to the snapshotted service's.
        """
        fp = self._config_fingerprint()
        got = np.asarray(sd["meta.fingerprint"], dtype=np.uint8)
        if not np.array_equal(fp, got):
            raise ValueError(
                "sharded state_dict fingerprint mismatch: saved "
                f"{bytes(got).decode(errors='replace')!r}, this service is "
                f"{bytes(fp).decode(errors='replace')!r}")
        base = sk.SketchParams(q=jnp.asarray(sd["params.q"]),
                               r=jnp.asarray(sd["params.r"]))
        self.merged = hh.HierarchyState(states=tuple(
            sk.SketchState(params=hh.level_params(self.hspec, base, i),
                           table=jnp.asarray(sd[f"level{i}.table"]))
            for i in range(self.hspec.n_levels)))
        self._local = tuple(jnp.zeros_like(t) for t in self._local)
        self.total = int(sd["meta.total"])
        self._dirty = False
        self._blocks_since_sync = 0
        saved_shards = int(sd["meta.n_shards"])

        def load_pool(s: int, j: int) -> SpaceSaving:
            p = SpaceSaving(self.max_candidates,
                            len(self.hspec.base.partition[j]))
            p.load_state(sd[f"shard{s}.pool{j}.rows"],
                         sd[f"shard{s}.pool{j}.counts"],
                         sd[f"shard{s}.pool{j}.errs"])
            return p

        n_groups = len(self.hspec.base.partition)
        if saved_shards == self.n_shards:
            self._shard_pools = [[load_pool(s, j) for j in range(n_groups)]
                                 for s in range(saved_shards)]
        else:
            folded = [SpaceSaving.fold([load_pool(s, j)
                                        for s in range(saved_shards)])
                      for j in range(n_groups)]
            self._shard_pools = (
                [folded]
                + [[SpaceSaving(self.max_candidates, len(g))
                    for g in self.hspec.base.partition]
                   for _ in range(self.n_shards - 1)])
        self._pools_dirty = True

    # -- queries (descent against the merged level tables) ------------------

    def state(self) -> hh.HierarchyState:
        """The merged (serving-snapshot) hierarchy state."""
        self._ensure_synced()
        return self.merged

    def candidates(self) -> List[np.ndarray]:
        """Per-group candidate arrays from the folded global pools.

        Rows are sorted lexicographically (np.unique) so the descent --
        and hence top-k tie order -- never depends on the dict iteration
        order of the folded pools, which varies with shard count.  The
        global pools are (re-)folded here from the cumulative shard pools
        with the mergeable-summaries rule when ingest has run since the
        last fold; recomputing from scratch avoids compounding fold floors
        fold over fold.
        """
        self._ensure_synced()
        if self._pools_dirty:
            self._global_pools = [
                SpaceSaving.fold([pools[j] for pools in self._shard_pools])
                for j in range(len(self._global_pools))
            ]
            self._pools_dirty = False
        out = []
        for p in self._global_pools:
            vals = p.values()
            out.append(np.unique(vals, axis=0) if len(vals) else vals)
        return out

    def heavy_hitters(self, threshold: int,
                      candidates: Optional[List[np.ndarray]] = None,
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Every key estimated >= threshold, from the merged tables."""
        self._ensure_synced()
        if candidates is None:
            candidates = self.candidates()
        return hh.find_heavy_hitters(
            self.hspec, self.merged, threshold, candidates,
            use_kernel=self.use_kernel)

    def topk(self, k: int, min_threshold: Optional[int] = None,
             ) -> Tuple[np.ndarray, np.ndarray]:
        self._ensure_synced()
        return threshold_descent_topk(
            self.heavy_hitters, self.candidates(), k, total=self.total,
            n_modules=self.hspec.base.schema.modularity,
            min_threshold=min_threshold)
