"""Windowed heavy-hitter / top-k serving over the epoch ring.

:class:`WindowedTopKService` answers "top-k in the last W epochs" (or with
exponential time decay) by wrapping core/window.py's ring of per-epoch
hierarchies behind the same ingest/query surface as the since-boot
endpoints (serving/sketch_engine.SketchTopKEndpoint, sharded_topk):

  ingest    fold a weighted key block into the CURRENT epoch's tables via
            the shared-family hash cascade, and into that epoch's
            per-group space-saving candidate pools;
  advance   close the epoch: the oldest ring slot expires (dropped, or
            folded into the landmark accumulator) together with its
            candidate pools, and -- on the incremental tumbling path --
            its tables are SUBTRACTED from the cached window sum, exact by
            linearity and bit-identical to lazily re-summing the live
            slots (tests/test_window.py enforces the equivalence);
  query     heavy_hitters / topk run the recursive descent against the
            merged window state with candidates folded from the LIVE
            epochs' pools only, so expired keys cannot re-enter the
            candidate sets and every key of the live window is reachable
            (the no-false-negative guarantee survives expiry).

Incremental window sum (``incremental=True``, tumbling/landmark int
tables): the service keeps running per-level window tables, adds each
ingested block into them alongside the head epoch, and subtracts expiring
tables on advance -- O(1) table stacks per query instead of O(W).  Decay
mode always merges lazily (the Horner scale-then-fold re-weights every
epoch on every advance, so there is no cheap incremental form).

Everything here is linear-mode only.  Conservative tables can be neither
merged nor subtracted cell-wise, so the service refuses
``mode="conservative"`` at construction via the same
``core.distributed.require_linear`` guard as every sharded surface --
windowing composes with sharding for exactly the same reason psum does
(linearity), and ``merge_from`` below is that composition: per-slot
cell-wise adds of two aligned services' rings.

See docs/architecture.md for the layer map.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.core import window as win
from repro.core.distributed import require_linear
from repro.core.summary import SpaceSaving
from repro.serving.sharded_topk import threshold_descent_topk


class WindowedTopKService:
    """Sliding-window / decayed heavy-hitter serving on one device.

    ``n_epochs`` fixes the ring size W; ``window_mode`` picks
    tumbling/landmark/decay (see core/window.py for the semantics);
    ``advance()`` is the epoch clock -- call it on whatever cadence the
    caller's timestamps dictate (streams/dstream.py drives it from batch
    timestamps).  Hash params are drawn once from ``key``: all epochs (and
    any merge-compatible sibling service) share them, which is what makes
    the per-epoch tables cell-wise mergeable at all.
    """

    def __init__(self, base_spec: sk.SketchSpec, key: jax.Array, *,
                 n_epochs: int, window_mode: str = "tumbling",
                 decay: float = 1.0,
                 max_candidates_per_group: int = 1 << 16,
                 use_kernel: bool = False, dtype=None,
                 incremental: bool = True, mode: str = "linear"):
        require_linear(mode, "WindowedTopKService")
        self.mode = mode
        self.wspec = win.WindowSpec(base=base_spec, n_epochs=int(n_epochs),
                                    mode=window_mode, decay=float(decay))
        self.hspec = self.wspec.hspec
        self.wstate = win.init_window(self.wspec, key, dtype=dtype)
        self.max_candidates = int(max_candidates_per_group)
        self.use_kernel = use_kernel
        # decay re-weights every live epoch on advance; only the equal-
        # weight modes admit the add/subtract running sum
        self.incremental = bool(incremental) and window_mode != "decay"
        self._window_sum: Optional[Tuple[jax.Array, ...]] = (
            tuple(jnp.zeros_like(t) for t in self.wstate.ring[0])
            if self.incremental else None)
        # ring of per-epoch per-group candidate pools, expired with their
        # epoch's tables so dead keys cannot linger in the candidate sets
        self._pools: List[List[SpaceSaving]] = [
            self._fresh_pools() for _ in range(self.wspec.n_epochs)]
        self._epoch_totals = [0] * self.wspec.n_epochs
        self._retired_total = 0

    def _fresh_pools(self) -> List[SpaceSaving]:
        return [SpaceSaving(self.max_candidates, len(g))
                for g in self.wspec.base.partition]

    # -- ingest / epoch clock ----------------------------------------------

    def ingest(self, items: np.ndarray,
               freqs: Optional[np.ndarray] = None) -> None:
        """Fold a weighted key block into the current epoch."""
        items = np.asarray(items, dtype=np.uint32)
        if items.shape[0] == 0:
            return
        if freqs is None:
            freqs = np.ones(items.shape[0], dtype=np.int64)
        freqs = np.asarray(freqs)
        self._epoch_totals[self.wstate.head] += int(freqs.sum())
        pools = self._pools[self.wstate.head]
        for j, g in enumerate(self.wspec.base.partition):
            pools[j].offer(items[:, list(g)], freqs)
        # pad to the next power of two like every other ingest surface
        # (zero-frequency pad rows are no-ops and never reach the pools)
        from repro.core.distributed import pad_block_pow2
        items, freqs, _ = pad_block_pow2(items, freqs, 1)
        self.wstate = win.window_update(self.wspec, self.wstate, items, freqs)
        if self._window_sum is not None:
            # the same block folds into the running window sum; identical
            # cascade, so sum-of-epochs and running sum stay bit-equal
            live = hh.update_jit(
                self.hspec,
                win._hier_state(self.wspec, self.wstate, self._window_sum),
                jnp.asarray(items), jnp.asarray(freqs))
            self._window_sum = tuple(st.table for st in live.states)

    def advance(self) -> None:
        """Close the current epoch and open a fresh one.

        Tumbling: the expiring slot's tables are subtracted from the
        running window sum (incremental path) or simply dropped from the
        lazy merge; its candidate pools and total expire with it.
        Landmark: tables fold into the retired accumulator and the
        expiring pools fold into a retained landmark pool seeded into the
        fresh slot, so since-boot candidates stay reachable."""
        new_head = (self.wstate.head + 1) % self.wspec.n_epochs
        expiring_tables = self.wstate.ring[new_head]
        if self._window_sum is not None and self.wspec.mode == "tumbling":
            self._window_sum = win.subtract_tables(self._window_sum,
                                                   expiring_tables)
        self.wstate = win.advance_window(self.wspec, self.wstate)
        if self.wspec.mode == "landmark":
            # nothing leaves a landmark window: fold the expiring pools
            # into the fresh slot so their values stay candidates, and
            # keep their mass in the window total
            self._retired_total += self._epoch_totals[new_head]
            carried = [SpaceSaving.fold([p]) for p in self._pools[new_head]]
            self._pools[new_head] = carried
        else:
            self._pools[new_head] = self._fresh_pools()
        self._epoch_totals[new_head] = 0

    # -- window views -------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self.wstate.epoch

    @property
    def total(self) -> int:
        """Stream mass inside the current window (decay: Horner-weighted,
        rounded -- it only seeds the top-k threshold descent)."""
        live = win.live_slots(self.wspec, self.wstate)
        if self.wspec.mode == "decay":
            acc = 0.0
            for s in live:
                acc = acc * self.wspec.decay + self._epoch_totals[s]
            return max(1, int(acc))
        return self._retired_total + sum(self._epoch_totals[s] for s in live)

    def state(self) -> hh.HierarchyState:
        """The merged window hierarchy the queries run against.

        The running sum needs no retired adjustment: tumbling subtracts
        expiring epochs so it holds exactly the live window, and landmark
        never subtracts, so it already holds everything since boot (the
        ``retired`` accumulator only serves the lazy-merge path)."""
        if self._window_sum is not None:
            return win._hier_state(self.wspec, self.wstate, self._window_sum)
        return win.merged_state(self.wspec, self.wstate)

    def candidates(self) -> List[np.ndarray]:
        """Per-group candidates folded from the LIVE epochs' pools.

        Expired epochs' pools are gone, so a key seen only outside the
        window cannot re-enter the descent; a key inside the window sits in
        some live pool (under capacity: surely; at capacity: iff it carries
        > W_epoch/m of its epoch's weight).  Rows sorted lexicographically
        so descent order never depends on pool/dict iteration order."""
        live = win.live_slots(self.wspec, self.wstate)
        out = []
        for j in range(len(self.wspec.base.partition)):
            folded = SpaceSaving.fold([self._pools[s][j] for s in live])
            vals = folded.values()
            out.append(np.unique(vals, axis=0) if len(vals) else vals)
        return out

    # -- durable state (serving/recovery.py snapshot currency) ---------------

    def _config_fingerprint(self) -> np.ndarray:
        dtype = self.wstate.ring[0][0].dtype
        desc = (f"windowed|{self.wspec!r}|dtype={dtype}"
                f"|cap={self.max_candidates}|inc={self.incremental}")
        return np.frombuffer(desc.encode(), dtype=np.uint8).copy()

    def state_dict(self) -> dict:
        """Full windowed state as a flat ``{key: ndarray}`` mapping.

        Every ring slot's tables, the retired accumulator, the shared hash
        params (finest level's arrays), the epoch clock (head + epoch
        counter), per-slot totals and pools, and -- on the incremental
        path -- the running window sum, persisted rather than recomputed
        so the round trip is bitwise-exact for any table dtype."""
        out = {
            "meta.fingerprint": self._config_fingerprint(),
            "meta.head": np.asarray(self.wstate.head, dtype=np.int64),
            "meta.epoch": np.asarray(self.wstate.epoch, dtype=np.int64),
            "meta.epoch_totals": np.asarray(self._epoch_totals,
                                            dtype=np.int64),
            "meta.retired_total": np.asarray(self._retired_total,
                                             dtype=np.int64),
            "params.q": np.asarray(self.wstate.level_params[-1].q),
            "params.r": np.asarray(self.wstate.level_params[-1].r),
        }
        for s, tables in enumerate(self.wstate.ring):
            for l, t in enumerate(tables):
                out[f"ring{s}.level{l}.table"] = np.asarray(t)
        for l, t in enumerate(self.wstate.retired):
            out[f"retired.level{l}.table"] = np.asarray(t)
        if self._window_sum is not None:
            for l, t in enumerate(self._window_sum):
                out[f"wsum.level{l}.table"] = np.asarray(t)
        for s, pools in enumerate(self._pools):
            for j, p in enumerate(pools):
                for k, v in p.state_dict().items():
                    out[f"slot{s}.pool{j}.{k}"] = v
        return out

    def load_state_dict(self, sd: dict) -> None:
        """Restore state saved by :meth:`state_dict`; bit-exact round trip."""
        fp = self._config_fingerprint()
        got = np.asarray(sd["meta.fingerprint"], dtype=np.uint8)
        if not np.array_equal(fp, got):
            raise ValueError(
                "windowed state_dict fingerprint mismatch: saved "
                f"{bytes(got).decode(errors='replace')!r}, this service is "
                f"{bytes(fp).decode(errors='replace')!r}")
        base = sk.SketchParams(q=jnp.asarray(sd["params.q"]),
                               r=jnp.asarray(sd["params.r"]))
        level_params = tuple(hh.level_params(self.hspec, base, i)
                             for i in range(self.hspec.n_levels))
        n_levels = self.hspec.n_levels
        ring = tuple(
            tuple(jnp.asarray(sd[f"ring{s}.level{l}.table"])
                  for l in range(n_levels))
            for s in range(self.wspec.n_epochs))
        retired = tuple(jnp.asarray(sd[f"retired.level{l}.table"])
                        for l in range(n_levels))
        self.wstate = self.wstate._replace(
            level_params=level_params, ring=ring, retired=retired,
            head=int(sd["meta.head"]), epoch=int(sd["meta.epoch"]))
        self._window_sum = (
            tuple(jnp.asarray(sd[f"wsum.level{l}.table"])
                  for l in range(n_levels))
            if self.incremental else None)
        self._epoch_totals = [int(x) for x in sd["meta.epoch_totals"]]
        self._retired_total = int(sd["meta.retired_total"])
        for s, pools in enumerate(self._pools):
            for j, p in enumerate(pools):
                p.load_state(sd[f"slot{s}.pool{j}.rows"],
                             sd[f"slot{s}.pool{j}.counts"],
                             sd[f"slot{s}.pool{j}.errs"])

    # -- queries ------------------------------------------------------------

    def heavy_hitters(self, threshold: int,
                      candidates: Optional[List[np.ndarray]] = None,
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Every key whose WINDOWED estimate is >= threshold."""
        if candidates is None:
            candidates = self.candidates()
        return hh.find_heavy_hitters(
            self.hspec, self.state(), threshold, candidates,
            use_kernel=self.use_kernel)

    def topk(self, k: int, min_threshold: Optional[int] = None,
             ) -> Tuple[np.ndarray, np.ndarray]:
        """The k keys with the largest windowed estimates."""
        return threshold_descent_topk(
            self.heavy_hitters, self.candidates(), k, total=self.total,
            n_modules=self.wspec.base.schema.modularity,
            min_threshold=min_threshold)

    # -- cross-shard composition (linearity, again) -------------------------

    def merge_from(self, other: "WindowedTopKService") -> None:
        """Fold a sibling service's window in, slot by slot.

        Shard a stream over N windowed services (same spec, same key, same
        advance cadence) and fold at query time: per-slot cell-wise adds
        are exact by linearity, exactly the psum contract of the sharded
        since-boot service.  Requires aligned epoch clocks and identical
        hash params -- mismatches are refused, not silently accepted."""
        if self.wspec != other.wspec:
            raise ValueError("merge_from requires identical WindowSpecs")
        if (self.wstate.head != other.wstate.head
                or self.wstate.epoch != other.wstate.epoch):
            raise ValueError(
                "merge_from requires aligned epoch clocks (same number of "
                "advance() calls on both services)")
        for pa, pb in zip(self.wstate.level_params,
                          other.wstate.level_params):
            if not (np.array_equal(np.asarray(pa.q), np.asarray(pb.q))
                    and np.array_equal(np.asarray(pa.r), np.asarray(pb.r))):
                raise ValueError(
                    "merge_from requires identical hash params on both "
                    "services (build them from the same spec and key)")
        ring = tuple(win._add_tables(a, b) for a, b
                     in zip(self.wstate.ring, other.wstate.ring))
        retired = win._add_tables(self.wstate.retired, other.wstate.retired)
        self.wstate = self.wstate._replace(ring=ring, retired=retired)
        if self._window_sum is not None:
            if other._window_sum is not None:
                other_sum = other._window_sum
            else:
                # the lazy merge has the same coverage as a running sum:
                # live window for tumbling, since-boot (incl. retired) for
                # landmark
                other_sum = tuple(
                    s.table for s in
                    win.merged_state(other.wspec, other.wstate).states)
            self._window_sum = win._add_tables(self._window_sum, other_sum)
        for s in range(self.wspec.n_epochs):
            self._epoch_totals[s] += other._epoch_totals[s]
            for mine, theirs in zip(self._pools[s], other._pools[s]):
                mine.merge_from(theirs)
        self._retired_total += other._retired_total
