"""Online strategy auto-tuning: live stats -> greedy re-search -> migration.

Closes the loop the offline pipeline leaves open.  The offline flow picks
a composite-hash strategy from a pre-stream sample (core/greedy.py) and
then the spec is frozen -- if the stream's per-module skew drifts (a
narrow hot module goes wide, a wide one collapses), the frozen strategy
keeps paying collision error the drifted stream no longer justifies.

:class:`AutoTuner` watches a serving endpoint and periodically:

  1. derives :class:`repro.streams.livestats.LiveStats` from state the
     endpoint already maintains (pools + level tables -- no stream pass);
  2. re-runs the greedy search over the live proxy sample
     (``propose_spec``) under the SAME space budget (h, w) as the
     current spec unless overridden;
  3. scores current vs proposed spec on that sample
     (core.selection.migration_gain, the Thm 4/5 cell-std criterion) and
     triggers ``endpoint.begin_migration`` only when the proposal wins by
     a real margin (``sigma_new < min_improvement * sigma_cur``);
  4. the endpoint then runs the double-write warmup window and cuts over
     on its own (serving/migration.py) -- the tuner never serves queries
     and never touches tables.

Everything here is policy; mechanism lives in livestats / selection /
migration.  Linear mode only, inherited from ``begin_migration``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import numpy as np

from repro.core.selection import migration_gain
from repro.streams.livestats import LiveStats, collect_live_stats, propose_spec


@dataclasses.dataclass
class TuneDecision:
    """One auto-tune evaluation (kept for tests / bench reporting)."""
    at_total: int                 # endpoint mass when evaluated
    sigma_current: float
    sigma_proposed: float
    migrated: bool
    reason: str                   # 'migrated' | 'no-gain' | 'same-spec'
    #                             | 'too-few-keys' | 'already-migrating'
    proposed_partition: Optional[tuple] = None
    proposed_ranges: Optional[tuple] = None


class AutoTuner:
    """Periodic re-tune policy over one serving endpoint.

    ``endpoint`` is a SketchTopKEndpoint or ShardedTopKService (anything
    with ``hspec``, ``total``, ``topk``, ``candidates``, ``migrating`` and
    ``begin_migration``).  Call :meth:`step` after ingesting -- it is a
    cheap no-op until ``retune_every`` stream mass has accumulated since
    the last evaluation.

    ``h``/``w`` default to the current spec's budget (prod(ranges),
    width), so re-tuning never changes the memory footprint unless asked.
    ``min_improvement`` guards against migration churn: the proposed
    spec's sample cell-std must be below ``min_improvement * sigma_cur``
    (strictly) to justify a double-write window.

    ``search='greedy'`` re-draws the full strategy (Algorithm 1);
    ``search='ranges'`` keeps the current partition -- and with it the
    hierarchy's descent levels -- and re-optimizes only the per-group
    ranges from the live alpha ratios (SIV-A), the cheaper knob that
    tracks per-module skew drift.
    """

    def __init__(self, endpoint, key: jax.Array, *,
                 retune_every: int,
                 warmup: int,
                 h: Optional[int] = None,
                 w: Optional[int] = None,
                 min_improvement: float = 0.9,
                 sample_k: int = 512,
                 min_threshold: Optional[int] = None,
                 agg: str = "median",
                 search: str = "greedy"):
        if retune_every < 1:
            raise ValueError("retune_every must be >= 1 stream mass units")
        if not (0.0 < min_improvement <= 1.0):
            raise ValueError("min_improvement must be in (0, 1]")
        if search not in ("greedy", "ranges"):
            raise ValueError(f"search must be 'greedy' or 'ranges', got {search!r}")
        self.endpoint = endpoint
        self.key = key
        self.retune_every = int(retune_every)
        self.warmup = int(warmup)
        base = endpoint.hspec.base
        self.h = int(h) if h is not None else int(np.prod(base.ranges))
        self.w = int(w) if w is not None else int(base.width)
        self.min_improvement = float(min_improvement)
        self.sample_k = int(sample_k)
        self.min_threshold = min_threshold
        self.agg = agg
        self.search = search
        self._next_at = int(endpoint.total) + self.retune_every
        self._round = 0
        self.decisions: List[TuneDecision] = []

    # -- policy ----------------------------------------------------------

    @property
    def last_decision(self) -> Optional[TuneDecision]:
        return self.decisions[-1] if self.decisions else None

    def step(self) -> Optional[TuneDecision]:
        """Evaluate a re-tune if due; returns the decision, else None."""
        total = int(self.endpoint.total)
        if total < self._next_at:
            return None
        self._next_at = total + self.retune_every
        return self._evaluate(total)

    def force(self) -> TuneDecision:
        """Evaluate a re-tune now regardless of the schedule."""
        total = int(self.endpoint.total)
        self._next_at = total + self.retune_every
        return self._evaluate(total)

    # -- one evaluation --------------------------------------------------

    def _record(self, d: TuneDecision) -> TuneDecision:
        self.decisions.append(d)
        return d

    def _evaluate(self, total: int) -> TuneDecision:
        self._round += 1
        key = jax.random.fold_in(self.key, self._round)
        if self.endpoint.migrating:
            return self._record(TuneDecision(
                at_total=total, sigma_current=float("nan"),
                sigma_proposed=float("nan"), migrated=False,
                reason="already-migrating"))

        stats: LiveStats = collect_live_stats(
            self.endpoint, k=self.sample_k, min_threshold=self.min_threshold)
        if stats.items.shape[0] < 2:
            return self._record(TuneDecision(
                at_total=total, sigma_current=float("nan"),
                sigma_proposed=float("nan"), migrated=False,
                reason="too-few-keys"))

        current = self.endpoint.hspec.base
        proposal = propose_spec(
            stats, self.h, self.w, jax.random.fold_in(key, 0), agg=self.agg,
            partition=current.partition if self.search == "ranges" else None)
        new_spec = proposal.spec
        if (new_spec.partition == current.partition
                and new_spec.ranges == current.ranges):
            return self._record(TuneDecision(
                at_total=total, sigma_current=0.0, sigma_proposed=0.0,
                migrated=False, reason="same-spec",
                proposed_partition=new_spec.partition,
                proposed_ranges=new_spec.ranges))

        sigma_cur, sigma_new = migration_gain(
            current, new_spec, stats.items, stats.freqs,
            jax.random.fold_in(key, 1))
        if not sigma_new < self.min_improvement * sigma_cur:
            return self._record(TuneDecision(
                at_total=total, sigma_current=sigma_cur,
                sigma_proposed=sigma_new, migrated=False, reason="no-gain",
                proposed_partition=new_spec.partition,
                proposed_ranges=new_spec.ranges))

        self.endpoint.begin_migration(
            new_spec, jax.random.fold_in(key, 2), warmup=self.warmup)
        return self._record(TuneDecision(
            at_total=total, sigma_current=sigma_cur,
            sigma_proposed=sigma_new, migrated=True, reason="migrated",
            proposed_partition=new_spec.partition,
            proposed_ranges=new_spec.ranges))
