"""Durable snapshots + write-ahead block log: crash recovery for serving.

The serving surfaces hold their whole world in device tables, host-side
candidate pools, and a handful of counters -- state that dies with the
process.  This layer makes any of them durable with two complementary
pieces, exploiting the linearity structure the paper's composite sketches
already have:

**Snapshots** (:meth:`DurableSketchEngine.snapshot`): the backend's
``state_dict()`` -- every level table, hash params, space-saving pools,
totals, window clocks -- plus the engine's staleness watermark, written
atomically through :class:`repro.training.checkpoint.AsyncCheckpointer`
with a versioned manifest and a CRC32 per array.  Restore is bit-identical
to the snapshotted state; a corrupted array fails its CRC and
:func:`recover` falls back to the previous snapshot instead of serving
garbage.

**Write-ahead block log** (:class:`BlockLog`): every ingested block (and
every window ``advance``) is appended -- raw and unpadded -- *before* it
touches the engine, as a CRC-framed record in an append-only segment file.
Recovery = restore the newest intact snapshot, then replay the log in
order from the snapshot's sequence number.  Per-mode contract:

  =============  =====================================================
  linear/signed  replay is a fold; tables are linear in the stream, so
                 snapshot + replayed blocks == uninterrupted run, bitwise
  conservative   the fold is order-dependent (Estan-Varghese reads the
                 table it writes), but the log preserves ingest order
                 exactly, so ordered replay is STILL bit-exact
  =============  =====================================================

Either way the loss bound is explicit: a crash loses at most the blocks
whose ``ingest`` call had not yet returned (the WAL append happens first;
with ``fsync=True`` a returned ingest is on disk).  Everything already
appended replays; duplicates (a retried append that survived the crash)
are skipped by sequence number; a genuinely missing record raises
:class:`WALGapError` rather than silently serving a stream with a hole.

Segment hygiene rides the snapshot cadence: ``snapshot()`` rotates the log
so each segment covers one inter-snapshot window, and segments wholly
covered by the newest durable snapshot are pruned.  Torn tails (a crash
mid-append) are truncated when the log reopens -- only ever the last
record of the last segment, which by the ordering above was never applied
anywhere that matters.

See docs/architecture.md section 9 for the dataflow diagram, and
serving/faults.py for the fault-injection harness that enforces all of
this bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import io
import os
import struct
import zlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.training import checkpoint as ckpt

_MAGIC = 0x574C3031  # "WL01"
_HEADER = struct.Struct("<IIQI")  # magic, payload_len, seq, crc32(payload)


class WALGapError(RuntimeError):
    """The log is missing a sequence number: replay would skip stream mass."""


def _encode_payload(kind: str, items: Optional[np.ndarray],
                    freqs: Optional[np.ndarray]) -> bytes:
    buf = io.BytesIO()
    if kind == "block":
        np.savez(buf, kind=np.frombuffer(b"block", dtype=np.uint8),
                 items=np.asarray(items, dtype=np.uint32),
                 freqs=np.asarray(freqs))
    else:
        np.savez(buf, kind=np.frombuffer(b"advance", dtype=np.uint8))
    return buf.getvalue()


def _decode_payload(payload: bytes):
    with np.load(io.BytesIO(payload)) as z:
        kind = bytes(z["kind"]).decode()
        if kind == "block":
            return kind, z["items"], z["freqs"]
        return kind, None, None


@dataclasses.dataclass(frozen=True)
class WALRecord:
    seq: int
    kind: str                      # 'block' | 'advance'
    items: Optional[np.ndarray]
    freqs: Optional[np.ndarray]


class BlockLog:
    """Append-only segmented write-ahead log of raw ingest operations.

    Segments are ``wal/seg_{first_seq:012d}.log``; each record is a fixed
    header (magic, payload length, sequence number, payload CRC32)
    followed by an npz payload holding the raw unpadded block (dtype
    preserved -- int64 counts and f32 gradient weights both round-trip
    bitwise).  Opening the log scans existing segments, truncates a torn
    tail on the LAST segment (a crash mid-append), and continues the
    sequence numbering where it left off.
    """

    def __init__(self, directory: str, *, fsync: bool = True):
        self.directory = os.path.join(directory, "wal")
        self.fsync = bool(fsync)
        os.makedirs(self.directory, exist_ok=True)
        self._fh = None
        self.next_seq = 0
        segs = self._segments()
        if segs:
            # Resume at max(seq)+1 over EVERY segment, not the last record
            # on disk: a duplicate append that survived a retry sits at the
            # tail with a stale lower seq, and rotation can leave the last
            # segment empty -- either would regress the cursor and make new
            # appends reuse live sequence numbers.
            max_seq = -1
            for i, name in enumerate(segs):
                recs, _ = self._scan_segment(
                    name, truncate_torn=(i == len(segs) - 1))
                if recs:
                    max_seq = max(max_seq, max(r.seq for r in recs))
            if max_seq >= 0:
                self.next_seq = max_seq + 1
            else:
                self.next_seq = int(segs[-1].split("_")[1].split(".")[0])
        self._open_tail()

    # -- segment bookkeeping -------------------------------------------------

    def _segments(self) -> List[str]:
        return sorted(f for f in os.listdir(self.directory)
                      if f.startswith("seg_") and f.endswith(".log"))

    def _seg_path(self, name: str) -> str:
        return os.path.join(self.directory, name)

    def _open_tail(self) -> None:
        segs = self._segments()
        if segs:
            path = self._seg_path(segs[-1])
        else:
            path = self._seg_path(f"seg_{self.next_seq:012d}.log")
        self._fh = open(path, "ab")

    def rotate(self) -> None:
        """Start a fresh segment at the current sequence number.

        Called at snapshot time so each segment covers one inter-snapshot
        window -- then :meth:`prune` can drop whole files instead of
        rewriting them."""
        self._fh.close()
        path = self._seg_path(f"seg_{self.next_seq:012d}.log")
        self._fh = open(path, "ab")

    def prune(self, watermark: int) -> None:
        """Delete segments wholly covered by a durable snapshot.

        ``watermark`` is the snapshot's sequence count: every record with
        ``seq < watermark`` is reconstructible from the snapshot alone.  A
        segment is prunable when the NEXT segment starts at or below the
        watermark (so nothing >= watermark can live in it)."""
        segs = self._segments()
        for name, nxt in zip(segs, segs[1:]):
            nxt_first = int(nxt.split("_")[1].split(".")[0])
            if nxt_first <= watermark:
                os.remove(self._seg_path(name))

    # -- append --------------------------------------------------------------

    def _append(self, payload: bytes) -> int:
        seq = self.next_seq
        self._fh.write(_HEADER.pack(_MAGIC, len(payload), seq,
                                    zlib.crc32(payload) & 0xFFFFFFFF))
        self._fh.write(payload)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self.next_seq = seq + 1
        return seq

    def append_block(self, items: np.ndarray, freqs: np.ndarray) -> int:
        """Log one raw ingest block; returns its sequence number."""
        return self._append(_encode_payload("block", items, freqs))

    def append_advance(self) -> int:
        """Log a window epoch advance (moves no mass, but changes tables)."""
        return self._append(_encode_payload("advance", None, None))

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- scan / replay -------------------------------------------------------

    def _scan_segment(self, name: str, *, truncate_torn: bool = False,
                      ) -> Tuple[List[WALRecord], Optional[int]]:
        """Parse one segment; optionally truncate a torn tail in place.

        A record is torn when the file ends mid-header/mid-payload, the
        magic is wrong, or the payload fails its CRC -- all the signatures
        of a crash mid-append.  Only trailing corruption is repairable;
        everything after the first bad frame is unparseable (frame lengths
        chain), so the scan stops there and reports the offset.
        """
        path = self._seg_path(name)
        recs: List[WALRecord] = []
        torn_at: Optional[int] = None
        with open(path, "rb") as f:
            data = f.read()
        off = 0
        while off < len(data):
            if off + _HEADER.size > len(data):
                torn_at = off
                break
            magic, plen, seq, crc = _HEADER.unpack_from(data, off)
            end = off + _HEADER.size + plen
            if magic != _MAGIC or end > len(data):
                torn_at = off
                break
            payload = data[off + _HEADER.size:end]
            if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
                torn_at = off
                break
            kind, items, freqs = _decode_payload(payload)
            recs.append(WALRecord(seq=seq, kind=kind, items=items,
                                  freqs=freqs))
            off = end
        if torn_at is not None and truncate_torn:
            with open(path, "ab") as f:
                f.truncate(torn_at)
        return recs, torn_at

    def records(self, from_seq: int = 0) -> List[WALRecord]:
        """All intact records with ``seq >= from_seq``, in order.

        Duplicates (a record re-appended by a retried writer) are dropped
        by sequence number; a missing sequence number raises
        :class:`WALGapError` -- replaying across a hole would silently
        reconstruct a different stream, the one thing a recovery layer
        must never do.  Torn tails on the last segment were truncated at
        open; torn data in an EARLIER segment is a real gap and raises.
        """
        out: List[WALRecord] = []
        seen = -1
        segs = self._segments()
        for i, name in enumerate(segs):
            recs, torn_at = self._scan_segment(name)
            if torn_at is not None and i != len(segs) - 1:
                raise WALGapError(
                    f"segment {name} is corrupt mid-file at byte {torn_at}: "
                    "records after it are unrecoverable")
            for r in recs:
                if r.seq <= seen:
                    continue               # duplicate append, skip
                if seen >= 0 and r.seq != seen + 1:
                    raise WALGapError(
                        f"log jumps from seq {seen} to {r.seq}: "
                        f"{r.seq - seen - 1} record(s) missing")
                seen = r.seq
                if r.seq >= from_seq:
                    out.append(r)
        if out and out[0].seq != from_seq:
            raise WALGapError(
                f"replay must start at seq {from_seq} but the log's first "
                f"surviving record is seq {out[0].seq}")
        return out


# --------------------------------------------------------------------------
# durable engine + recovery
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RecoveryReport:
    """What :func:`recover` did: which snapshot, what it skipped, what replayed."""
    restored_step: Optional[int]        # None = no usable snapshot, fresh start
    corrupted_steps: List[int]          # snapshots that failed CRC, newest first
    replayed_blocks: int
    replayed_advances: int
    next_seq: int                       # the log position serving resumes at


class DurableSketchEngine:
    """A :class:`~repro.serving.sketch_engine.SketchServeEngine` with a WAL.

    Wraps an engine (over ANY backend with a ``state_dict`` surface --
    endpoint, sharded, windowed) so that every ingest and advance is
    logged before it is applied, and a snapshot of the full backend +
    watermark state is taken every ``snapshot_every`` operations (or on
    explicit :meth:`snapshot`).  Queries pass straight through.

    Write ordering is the whole durability story: WAL append (fsync'd by
    default) -> engine apply.  A crash at any point between loses nothing
    that ``ingest`` ever returned from; :func:`recover` rebuilds the exact
    pre-crash state from snapshot + replay.
    """

    def __init__(self, engine, directory: str, *,
                 snapshot_every: Optional[int] = None,
                 fsync: bool = True, keep_snapshots: int = 3,
                 _log: Optional[BlockLog] = None):
        self.engine = engine
        self.directory = directory
        self.snapshot_every = snapshot_every
        self.log = _log if _log is not None else BlockLog(directory,
                                                          fsync=fsync)
        self.writer = ckpt.AsyncCheckpointer(
            os.path.join(directory, "snapshots"), keep_last=keep_snapshots)
        self._ops_since_snapshot = 0

    @property
    def backend(self):
        return self.engine.backend

    # -- durable ingest path -------------------------------------------------

    def ingest(self, items: np.ndarray,
               freqs: Optional[np.ndarray] = None) -> None:
        """WAL-append the raw block, then apply it to the engine.

        Empty blocks are logged too: every operation must map 1:1 onto a
        WAL sequence number (the supervisor uses ``next_seq`` as its stream
        cursor), so even a no-op block advances the log.  The wrapped
        engine skips the empty apply itself.
        """
        items = np.asarray(items, dtype=np.uint32)
        if freqs is None:
            freqs = np.ones(items.shape[0], dtype=np.int64)
        freqs = np.asarray(freqs)
        self.log.append_block(items, freqs)
        self.engine.ingest(items, freqs)
        self._maybe_snapshot()

    def advance(self) -> None:
        """WAL-append an epoch advance, then apply it (windowed backends)."""
        self.log.append_advance()
        self.engine.advance()
        self._maybe_snapshot()

    def _maybe_snapshot(self) -> None:
        self._ops_since_snapshot += 1
        if (self.snapshot_every
                and self._ops_since_snapshot >= self.snapshot_every):
            self.snapshot()

    def snapshot(self, wait: bool = True) -> int:
        """Write a durable snapshot; returns its step (= WAL watermark).

        The step number IS the log position: a snapshot at step ``s``
        contains exactly the effect of records ``0..s-1``, so recovery
        replays from ``s``.  The log rotates here (new segment starts at
        ``s``) and, once the write is durable, segments below the OLDEST
        retained snapshot are pruned -- not below ``s``: an on-disk
        corruption of the newest snapshot must leave enough log to replay
        from any older retained one.  ``wait=False`` leaves the write in
        flight on the async writer -- pruning then waits for the NEXT
        snapshot/wait.
        """
        self.engine.drain()
        watermark = self.log.next_seq
        trees = {
            "backend": self.engine.backend.state_dict(),
            "engine": {"mass": np.asarray(self.engine.ingested_mass,
                                          dtype=np.int64)},
        }
        self.log.rotate()
        self.writer.submit(watermark, trees)
        if wait:
            self.writer.wait()
            retained = ckpt.list_steps(os.path.join(self.directory,
                                                    "snapshots"))
            # prune only what is covered REDUNDANTLY: with a single
            # snapshot on disk, a corruption of that one snapshot must
            # still leave the full log for a fresh-start replay
            if len(retained) >= 2:
                self.log.prune(min(retained))
        self._ops_since_snapshot = 0
        return watermark

    def close(self) -> None:
        self.writer.wait()
        self.log.close()

    # -- query passthrough ---------------------------------------------------

    def sync(self):
        return self.engine.sync()

    def drain(self) -> None:
        self.engine.drain()

    def topk(self, k: int, min_threshold: Optional[int] = None):
        return self.engine.topk(k, min_threshold)

    def heavy_hitters(self, threshold: int):
        return self.engine.heavy_hitters(threshold)

    def submit(self, request):
        return self.engine.submit(request)

    def submit_topk(self, k: int, min_threshold: Optional[int] = None):
        return self.engine.submit_topk(k, min_threshold)

    def submit_heavy_hitters(self, threshold: int):
        return self.engine.submit_heavy_hitters(threshold)

    def flush(self):
        return self.engine.flush()


def recover(
    directory: str,
    backend_factory: Callable[[], object],
    *,
    engine_kwargs: Optional[Dict] = None,
    snapshot_every: Optional[int] = None,
    fsync: bool = True,
    keep_snapshots: int = 3,
) -> Tuple[DurableSketchEngine, RecoveryReport]:
    """Rebuild a durable engine from disk: newest intact snapshot + replay.

    ``backend_factory`` must build a backend CONFIGURED like the one that
    crashed (same spec, key, mode, capacities -- the state_dict
    fingerprint enforces this); its state is then overwritten from the
    snapshot.  Snapshots are tried newest-first: one that fails its CRC
    (:class:`~repro.training.checkpoint.CheckpointCorruptionError`) is
    recorded and skipped, falling back to the previous one -- the WAL
    still holds every record since the OLDER snapshot (pruning never goes
    below the oldest retained snapshot), so the deeper replay reconverges
    on the same bit-exact state.

    With no usable snapshot at all, recovery starts from the factory's
    fresh backend and replays the log from seq 0.
    """
    snap_dir = os.path.join(directory, "snapshots")
    corrupted: List[int] = []
    restored_step: Optional[int] = None
    trees: Optional[Dict] = None
    for step in reversed(ckpt.list_steps(snap_dir)):
        try:
            _, trees = ckpt.restore_trees(snap_dir, step=step)
            restored_step = step
            break
        except ckpt.CheckpointCorruptionError:
            corrupted.append(step)

    backend = backend_factory()
    from repro.serving.sketch_engine import SketchServeEngine

    if trees is not None:
        backend.load_state_dict(trees["backend"])
    engine = SketchServeEngine(backend, **(engine_kwargs or {}))
    if trees is not None:
        engine.restore_watermark(int(trees["engine"]["mass"]))

    log = BlockLog(directory, fsync=fsync)
    from_seq = restored_step if restored_step is not None else 0
    blocks = advances = 0
    for rec in log.records(from_seq):
        if rec.kind == "block":
            engine.ingest(rec.items, rec.freqs)
            blocks += 1
        else:
            engine.advance()
            advances += 1
    engine.drain()

    durable = DurableSketchEngine(
        engine, directory, snapshot_every=snapshot_every, fsync=fsync,
        keep_snapshots=keep_snapshots, _log=log)
    report = RecoveryReport(
        restored_step=restored_step, corrupted_steps=corrupted,
        replayed_blocks=blocks, replayed_advances=advances,
        next_seq=log.next_seq)
    return durable, report
