"""The shared engine protocol both serving stacks sit behind.

The model engine (serving/model_engine.SlotScheduler) and the sketch
engine (serving/sketch_engine.SketchServeEngine) serve different requests
-- token generations vs threshold/top-k sketch queries -- but expose the
same request lifecycle, so launchers and benchmarks can drive either
through one shape:

  ``submit(request)``  enqueue one request; cheap, never blocks on device
                       work;
  ``flush()``          run every pending request to completion (batched
                       however the engine sees fit) and return the
                       completed requests/results, FIFO.

The protocol is deliberately minimal: batching policy (decode slots vs
packed descent grids), state (KV caches vs table snapshots), and staleness
semantics are engine concerns, not protocol concerns.
"""
from __future__ import annotations

from typing import Any, Protocol, Sequence, runtime_checkable


@runtime_checkable
class ServeEngineProtocol(Protocol):
    """Submit/flush request lifecycle shared by the serving engines."""

    def submit(self, request: Any) -> Any:
        """Enqueue one request for the next :meth:`flush`."""
        ...

    def flush(self) -> Sequence[Any]:
        """Run all pending requests to completion; return them in FIFO
        submission order."""
        ...
