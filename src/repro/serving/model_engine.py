"""Batched model-serving engine: prefill + decode with a static KV cache.

The lowered unit is ``serve_step`` = one new token for every sequence in the
batch against a ``seq_len`` cache -- exactly the assigned ``decode_*`` /
``long_*`` dry-run cells.  The engine adds request batching (uniform
position; left-padded prompts), greedy/temperature sampling, and a simple
slot scheduler for continuous batching at the granularity of whole steps.

This module is the model half of the serving stack; the sketch half
(SketchTopKEndpoint, SketchServeEngine) lives in serving/sketch_engine.py.
Both sit behind the same submit/flush engine protocol
(serving/protocol.py); ``repro.serving.engine`` re-exports everything for
callers that predate the split.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    temperature: float = 0.0     # 0 = greedy
    eos_id: int = -1             # -1 = never stop early


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: PyTree, scfg: ServeConfig,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, t, e: tfm.prefill(cfg, p, t, embeds=e,
                                        max_len=scfg.max_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(cfg, p, c, t, pos))

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def generate(
        self,
        prompts: np.ndarray,                # int32[B, S] (uniform length)
        max_new_tokens: int,
        embeds: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        cfg = self.cfg
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s = prompts.shape
        n_prefix = 0
        if cfg.frontend and not cfg.n_enc_layers:
            n_prefix = cfg.frontend_len
        if embeds is not None:
            embeds = jnp.asarray(embeds, cfg.activation_dtype)
        logits, cache = self._prefill(self.params, prompts, embeds)
        out = [self._sample(logits)[:, None]]
        pos = n_prefix + s
        for _ in range(max_new_tokens - 1):
            lg, cache = self._decode(self.params, cache, out[-1], jnp.int32(pos))
            out.append(self._sample(lg[:, 0, :])[:, None])
            pos += 1
        return np.asarray(jnp.concatenate(out, axis=1))


# --------------------------------------------------------------------------
# continuous batching (step-granular slot scheduler)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class SlotScheduler:
    """Admit requests into fixed decode slots; refill as sequences finish.

    Real continuous batching interleaves per-token; at the benchmark
    granularity used here, slots turn over between generate() calls of
    uniform-length cohorts, which preserves the serving-throughput shape
    while keeping the lowered step static.
    """

    def __init__(self, engine: ServeEngine, n_slots: int):
        self.engine = engine
        self.n_slots = n_slots
        self.queue: List[Request] = []
        self.completed: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self) -> List[Request]:
        while self.queue:
            cohort = self.queue[: self.n_slots]
            self.queue = self.queue[self.n_slots:]
            s = min(len(r.prompt) for r in cohort)
            prompts = np.stack([r.prompt[:s] for r in cohort])
            max_new = max(r.max_new for r in cohort)
            toks = self.engine.generate(prompts, max_new)
            for r, row in zip(cohort, toks):
                r.out = row[: r.max_new].tolist()
                r.done = True
                self.completed.append(r)
        return self.completed

    def flush(self) -> List[Request]:
        """Engine-protocol alias for :meth:`run` (serving/protocol.py)."""
        return self.run()
