"""Fault injection + supervised serving: the recovery layer's adversary.

serving/recovery.py promises bit-exact crash recovery; this module is the
machinery that tries to break the promise.  Three kinds of pieces:

**Injectors** -- functions that damage durable state the way real
infrastructure does: flip bytes inside a checkpointed array (silent disk
corruption; the manifest CRC must catch it), drop a WAL record (a lost
write; replay must refuse, not silently skip mass), duplicate a WAL
record (a retried append that survived; replay must apply it once).

**FaultPlan** -- a declarative schedule of injected failures for one
supervised run: kill the process after N operations, corrupt the newest
snapshot before recovery, drop/duplicate a log record, or stall to
trigger straggler detection.

**ServingSupervisor** -- the retry/backoff wrapper that drives a durable
engine through an operation stream, catches injected (or real) crashes,
recovers from disk, and RESUMES from the exact operation the recovered
log position points at -- the WAL sequence number doubles as the cursor
into the operation stream, so nothing is skipped and nothing is applied
twice.  tests/test_recovery.py runs the full kill/corrupt/remesh matrix
through it and asserts bitwise equality against an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving import recovery as rec
from repro.training import checkpoint as ckpt

# An operation stream entry: ("block", items, freqs) or ("advance",).
Op = Tuple


class InjectedCrash(RuntimeError):
    """The fault plan killed the serving process here."""


# --------------------------------------------------------------------------
# injectors
# --------------------------------------------------------------------------

def corrupt_checkpoint_array(directory: str, step: Optional[int] = None,
                             which: int = 0) -> str:
    """Byte-flip one stored array inside a snapshot, leaving the manifest.

    Rewrites the npz archive with a single element of array ``which``
    perturbed, exactly what a silent disk corruption looks like: the
    archive still loads, the manifest still parses, only the CRC check
    can tell.  Returns the key of the damaged array.
    """
    snap_dir = os.path.join(directory, "snapshots")
    steps = ckpt.list_steps(snap_dir)
    if step is None:
        step = max(steps)
    path = os.path.join(snap_dir, f"step_{step:08d}", "proc00_shard000.npz")
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    keys = sorted(arrays)
    key = keys[which % len(keys)]
    arr = arrays[key]
    flat = arr.reshape(-1).copy()
    if flat.size == 0:
        raise ValueError(f"array {key} is empty; pick another index")
    raw = flat.view(np.uint8)
    raw[0] ^= 0xFF
    arrays[key] = flat.reshape(arr.shape)
    np.savez(path, **arrays)
    return key


def drop_wal_record(directory: str, seq: int) -> None:
    """Remove one record from the log (a lost write; replay must raise)."""
    _rewrite_wal(directory, lambda r: None if r.seq == seq else r)


def duplicate_wal_record(directory: str, seq: int) -> None:
    """Append a stale copy of record ``seq`` at the tail (a survived retry;
    replay must apply it exactly once)."""
    log = rec.BlockLog(directory, fsync=False)
    target = [r for r in log.records(0) if r.seq == seq]
    if not target:
        log.close()
        raise ValueError(f"no record with seq {seq} in the log")
    r = target[0]
    payload = rec._encode_payload(r.kind, r.items, r.freqs)
    import zlib
    log._fh.write(rec._HEADER.pack(rec._MAGIC, len(payload), r.seq,
                                   zlib.crc32(payload) & 0xFFFFFFFF))
    log._fh.write(payload)
    log._fh.flush()
    log.close()


def _rewrite_wal(directory: str,
                 fn: Callable[[rec.WALRecord], Optional[rec.WALRecord]],
                 ) -> None:
    """Rewrite every segment through ``fn`` (None drops the record)."""
    import zlib
    log = rec.BlockLog(directory, fsync=False)
    segs = log._segments()
    per_seg = {name: log._scan_segment(name)[0] for name in segs}
    log.close()
    for name, recs in per_seg.items():
        path = os.path.join(directory, "wal", name)
        with open(path, "wb") as f:
            for r in recs:
                r2 = fn(r)
                if r2 is None:
                    continue
                payload = rec._encode_payload(r2.kind, r2.items, r2.freqs)
                f.write(rec._HEADER.pack(rec._MAGIC, len(payload), r2.seq,
                                         zlib.crc32(payload) & 0xFFFFFFFF))
                f.write(payload)


# --------------------------------------------------------------------------
# fault plan + supervisor
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FaultPlan:
    """One run's injected failures (all optional, combinable).

    ``crash_after_ops``: raise :class:`InjectedCrash` once that many
    operations have been applied in the current life (counted per life, so
    a plan can kill the same run repeatedly until ``max_crashes``).
    ``corrupt_newest_snapshot``: before each recovery, byte-flip an array
    in the newest snapshot so recovery must CRC-fail it and fall back.
    ``straggle_op`` / ``straggle_seconds``: sleep before that operation,
    feeding the straggler monitor an outlier step time.
    """
    crash_after_ops: Optional[int] = None
    max_crashes: int = 1
    corrupt_newest_snapshot: bool = False
    straggle_op: Optional[int] = None
    straggle_seconds: float = 0.0
    crashes: int = dataclasses.field(default=0, init=False)

    def should_crash(self, ops_this_life: int) -> bool:
        if self.crash_after_ops is None or self.crashes >= self.max_crashes:
            return False
        return ops_this_life >= self.crash_after_ops


@dataclasses.dataclass
class SupervisedRunReport:
    """What happened across one supervised run: crashes, recoveries, timing."""
    crashes: int
    recoveries: List[rec.RecoveryReport]
    op_times: List[float]               # per-op wall time (straggler feed)


class ServingSupervisor:
    """Retry/backoff wrapper: feed an op stream, survive injected crashes.

    The operation stream maps 1:1 onto WAL sequence numbers (each block or
    advance appends exactly one record), so after a recovery the log's
    ``next_seq`` IS the index of the next operation to apply -- the
    supervisor resumes there, replaying nothing at the stream level
    (recovery already replayed the logged records) and skipping nothing.
    """

    def __init__(self, directory: str, backend_factory: Callable[[], object],
                 *, max_restarts: int = 3, backoff: float = 0.0,
                 engine_kwargs: Optional[Dict] = None,
                 snapshot_every: Optional[int] = None,
                 fsync: bool = True):
        self.directory = directory
        self.backend_factory = backend_factory
        self.max_restarts = int(max_restarts)
        self.backoff = float(backoff)
        self.engine_kwargs = engine_kwargs or {}
        self.snapshot_every = snapshot_every
        self.fsync = fsync

    def _build(self) -> Tuple[rec.DurableSketchEngine, rec.RecoveryReport]:
        return rec.recover(
            self.directory, self.backend_factory,
            engine_kwargs=self.engine_kwargs,
            snapshot_every=self.snapshot_every, fsync=self.fsync)

    def run(self, ops: Sequence[Op], fault: Optional[FaultPlan] = None,
            ) -> Tuple[rec.DurableSketchEngine, SupervisedRunReport]:
        """Apply every operation, recovering through any crash.

        Returns the live durable engine (caller queries it) and the run
        report.  Raises once ``max_restarts`` is exceeded -- a fleet that
        cannot stop crashing needs a human, not another retry.
        """
        fault = fault or FaultPlan()
        restarts = 0
        recoveries: List[rec.RecoveryReport] = []
        op_times: List[float] = []
        engine, report = self._build()
        recoveries.append(report)
        while True:
            ops_this_life = 0
            try:
                while engine.log.next_seq < len(ops):
                    i = engine.log.next_seq
                    if fault.should_crash(ops_this_life):
                        fault.crashes += 1
                        # simulate a hard kill: no drain, no snapshot --
                        # whatever is on disk is all recovery gets
                        raise InjectedCrash(f"killed before op {i}")
                    if fault.straggle_op == i and fault.straggle_seconds:
                        time.sleep(fault.straggle_seconds)
                    t0 = time.perf_counter()
                    op = ops[i]
                    if op[0] == "block":
                        engine.ingest(op[1], op[2])
                    elif op[0] == "advance":
                        engine.advance()
                    else:
                        raise ValueError(f"unknown op kind {op[0]!r}")
                    op_times.append(time.perf_counter() - t0)
                    ops_this_life += 1
                break
            except InjectedCrash:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                if self.backoff > 0:
                    time.sleep(self.backoff * 2 ** (restarts - 1))
                # the crashed engine may still have a snapshot in flight on
                # its async writer; let it settle (success or failure) so it
                # cannot race the rebuilt engine's recovery and writer in
                # the same snapshots directory
                try:
                    engine.writer.wait()
                except Exception:
                    pass
                engine.log.close()
                if fault.corrupt_newest_snapshot and ckpt.list_steps(
                        os.path.join(self.directory, "snapshots")):
                    corrupt_checkpoint_array(self.directory)
                engine, report = self._build()
                recoveries.append(report)
        return engine, SupervisedRunReport(
            crashes=restarts, recoveries=recoveries, op_times=op_times)
