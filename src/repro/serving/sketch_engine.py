"""Sketch-serving stack: the streaming top-k endpoint + the async engine.

This module is the sketch half of the serving split (the model half lives
in serving/model_engine.py; both sit behind the submit/flush protocol of
serving/protocol.py).  Two layers:

:class:`SketchTopKEndpoint`
    the single-shard hierarchical heavy-hitter endpoint -- synchronous
    ingest/query, hot spec migration via the MigratingSurface mixin
    (serving/migration.py), promotion to a sharded service, cross-shard
    merge.  Unchanged semantics from before the split;
    ``repro.serving.engine`` re-exports it for old callers.

:class:`SketchServeEngine`
    the async serving engine every sketch surface (endpoint, sharded,
    windowed) can sit behind:

      * **pipelined ingest** -- on the plain linear endpoint the hash
        cascade of block k+1 is dispatched while block k's fold is still
        executing against the donated, ping-ponging table buffers
        (core.hierarchy.stage_indices / fold_indices); bit-identical to
        synchronous ingest because the split factors ``update_jit``
        exactly;
      * **snapshot queries with a staleness bound** -- queries run against
        a copied table snapshot; ``max_staleness`` bounds how much stream
        mass may have been ingested since the snapshot was taken
        (0 = always refresh first, bit-identical to the synchronous
        surfaces; None = only explicit ``sync()`` refreshes);
      * **batched multi-request descent** -- ``submit`` + ``flush`` pack
        all concurrent threshold/top-k requests into shared per-level
        launches (core.hierarchy.batched_find_heavy_hitters): Q queries
        cost one P x C x Q launch per level instead of Q separate
        descents, each request's answer bit-identical to its serial run;
      * **one integration point each** for background psum sync (sharded
        backends, cadence from the BENCH_SHARDED sweep), auto-tuning
        (AutoTuner.step on every ``sync()``), and migration (double-write
        rides inside the ingest path of the backend itself).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.migration import MigratingSurface, require_not_migrating


# --------------------------------------------------------------------------
# streaming top-k endpoint (hierarchical heavy-hitter sketch)
# --------------------------------------------------------------------------

class SketchTopKEndpoint(MigratingSurface):
    """Serving endpoint for streaming heavy-hitter / top-k queries.

    Ingests weighted key blocks (telemetry: routed-token pairs, request
    n-grams, edge events) into a hierarchical composite-hash sketch
    (core/hierarchy.py) and answers

      * ``heavy_hitters(threshold)`` -- every key estimated >= threshold,
      * ``topk(k)`` -- the k keys with the largest estimates,

    without storing the stream.  Memory is the hierarchy's tables plus
    bounded per-group candidate pools.  Admission is a weighted
    space-saving summary per group (core/summary.py): at capacity m, a new
    value evicts the lightest entry instead of being dropped, so any group
    value carrying more than total/m of the stream's weight is in the pool
    no matter how late it first arrives; the no-false-negative guarantee
    of the descent is conditional on that W/m admission bound.

    ``mode="conservative"`` applies the Estan-Varghese conservative update
    per level: strictly tighter estimates, but the tables are no longer
    linear in the stream, so such an endpoint refuses ``merge_from`` (both
    directions) and must stay single-shard -- conservative tables are
    excluded from the cell-wise merge and psum paths of
    core/distributed.py.

    Every ingest path hashes each item ONCE and derives all level indices
    by the mixed-radix cascade (core/hierarchy.py's shared per-group hash
    family).  ``use_update_kernel=True`` additionally folds each block into
    all level tables with the fused single-launch Pallas kernel
    (kernels/ops.KernelHierarchy); linear mode only -- a conservative
    endpoint silently keeps the jnp per-level sequential folds, which
    already share the cascade's one hash pass.

    Linear endpoints shard naturally: run one per ingest worker and fold
    with ``merge_from`` at query time (tables cell-wise, exact by
    linearity; candidate summaries via the mergeable-summaries rule).

    Hot spec migration (serving/migration.py's MigratingSurface mixin):
    ``begin_migration`` opens a double-write window onto a fresh successor
    endpoint built on a re-tuned spec; queries keep serving from the old
    tables until the successor has absorbed ``warmup`` stream mass, then
    the endpoint cuts over to the successor's state wholesale and frees
    the old tables.  Linear mode only; ``merge_from``/``to_sharded`` are
    refused mid-window (the successor would not see the same state
    change).
    """

    def __init__(self, base_spec, key, *, max_candidates_per_group: int = 1 << 16,
                 use_kernel: bool = False, use_update_kernel: bool = False,
                 dtype=jnp.int32, mode: str = "linear"):
        from repro.core import hierarchy as hh
        from repro.core.summary import SpaceSaving

        if mode not in ("linear", "conservative"):
            raise ValueError(f"mode must be 'linear' or 'conservative', got {mode!r}")
        self._hh = hh
        self._kh = None
        self._migration = None
        self._use_update_kernel = bool(use_update_kernel)
        self.hspec = hh.HierarchySpec.from_spec(base_spec)
        self.state = hh.init_hierarchy(self.hspec, key, dtype=dtype)
        self.max_candidates = int(max_candidates_per_group)
        self.use_kernel = use_kernel
        self.mode = mode
        self.total = 0
        self._pools: List[SpaceSaving] = [
            SpaceSaving(self.max_candidates, len(g))
            for g in base_spec.partition
        ]
        if use_update_kernel and mode == "linear":
            from repro.kernels.ops import KernelHierarchy

            # the endpoint's state moves into the kernel wrapper's
            # concatenated padded table; ``state`` stays visible as a
            # lazily sliced view (see the property below)
            self._kh = KernelHierarchy.from_state(self.hspec, self._state)
            self._state = None

    @property
    def state(self):
        """The hierarchy state (assembled lazily on the fused-kernel path)."""
        if self._kh is not None:
            return self._kh.state()
        return self._state

    @state.setter
    def state(self, value) -> None:
        if getattr(self, "_kh", None) is not None:
            self._kh.load_state(value)
        else:
            self._state = value

    def _ingest_active(self, items: np.ndarray, freqs: np.ndarray) -> None:
        """Fold one normalized block into the ACTIVE (serving) tables."""
        if self.mode == "conservative":
            from repro.core.sketch import check_conservative_freqs
            check_conservative_freqs(freqs, self.state.states[0].table.dtype)
        if self._kh is not None:
            # reject kernel-unrepresentable weights BEFORE touching pools
            # or totals, so a failed ingest leaves the endpoint unchanged
            from repro.kernels.ops import check_linear_kernel_freqs
            check_linear_kernel_freqs(freqs, self._kh.table.dtype)
        self.total += int(freqs.sum())
        for j, g in enumerate(self.hspec.base.partition):
            self._pools[j].offer(items[:, list(g)], freqs)
        if self._kh is not None:
            # fused single-launch path: KernelHierarchy pads blocks to its
            # own fixed block_b (zero-frequency pad rows are no-ops)
            self._kh.update(items, freqs)
            return
        # pad blocks to the next power of two so the jitted multi-level
        # update compiles O(log B) variants, not one per block length
        # (zero-frequency pad items are no-ops and stay out of the pools)
        from repro.core.distributed import pad_block_pow2
        items, freqs, _ = pad_block_pow2(items, freqs, 1)
        fold = (self._hh.update_conservative_jit
                if self.mode == "conservative" else self._hh.update_jit)
        self.state = fold(self.hspec, self.state, jnp.asarray(items),
                          jnp.asarray(freqs))

    def ingest(self, items: np.ndarray,
               freqs: Optional[np.ndarray] = None) -> None:
        items = np.asarray(items, dtype=np.uint32)
        if items.shape[0] == 0:
            return
        if freqs is None:
            freqs = np.ones(items.shape[0], dtype=np.int64)
        freqs = np.asarray(freqs)
        self._ingest_active(items, freqs)
        # double-write window: the successor sees every block verbatim
        # (unpadded -- it pads its own blocks exactly like a fresh endpoint
        # would, which keeps cutover bit-identical to a fresh build)
        self._migration_tick(items, freqs)

    # -- two-phase ingest (the serve engine's pipeline) ----------------------

    def stage_block(self, items: np.ndarray,
                    freqs: Optional[np.ndarray] = None) -> Optional["StagedBlock"]:
        """Pipeline stage A: normalize + pad the block, dispatch the cascade.

        Returns a :class:`StagedBlock` whose level indices were computed
        against the CURRENT hash params; nothing is folded and no
        endpoint state changes until :meth:`fold_staged`.  The cascade
        reads only the (never-donated) params, so it runs while a
        previous block's fold is still executing on the donated table
        buffers -- that overlap is the engine's ingest pipeline.

        Plain linear jnp path only: the fused update kernel folds inside
        one launch (nothing to split) and conservative updates read the
        tables they write (no table-free stage exists).
        """
        if self.mode != "linear" or self._kh is not None:
            raise ValueError(
                "stage_block requires the plain linear jnp update path: "
                "conservative updates read the tables during the fold and "
                "the fused update kernel is already a single launch -- use "
                "ingest() on those endpoints")
        items = np.asarray(items, dtype=np.uint32)
        if items.shape[0] == 0:
            return None
        if freqs is None:
            freqs = np.ones(items.shape[0], dtype=np.int64)
        freqs = np.asarray(freqs)
        from repro.core.distributed import pad_block_pow2
        p_items, p_freqs, _ = pad_block_pow2(items, freqs, 1)
        idxs = self._hh.stage_indices(self.hspec, self.state,
                                      jnp.asarray(p_items))
        return StagedBlock(idxs=idxs, freqs=jnp.asarray(p_freqs),
                           raw_items=items, raw_freqs=freqs,
                           mass=int(freqs.sum()))

    def fold_staged(self, staged: Optional["StagedBlock"]) -> None:
        """Pipeline stage B: fold a staged block's pre-computed indices.

        ``fold_staged(stage_block(items, freqs))`` is bit-identical to
        ``ingest(items, freqs)`` -- same totals, same pool offers, same
        tables (fold_indices == update_jit by construction), same
        migration double-write.  The caller must not swap the endpoint's
        state between stage and fold (the engine folds before staging the
        next block, so a migration cutover can never strand staged
        indices computed under the old params).
        """
        if staged is None:
            return
        self.total += staged.mass
        for j, g in enumerate(self.hspec.base.partition):
            self._pools[j].offer(staged.raw_items[:, list(g)],
                                 staged.raw_freqs)
        self._state = self._hh.fold_indices(self._state, staged.idxs,
                                            staged.freqs)
        self._migration_tick(staged.raw_items, staged.raw_freqs)

    def candidates(self) -> List[np.ndarray]:
        """Per-group candidate value arrays from the space-saving pools."""
        return [p.values() for p in self._pools]

    # -- durable state (serving/recovery.py snapshot currency) ----------------

    def _config_fingerprint(self) -> np.ndarray:
        dtype = self.state.states[0].table.dtype
        desc = (f"endpoint|{self.hspec.base!r}|mode={self.mode}"
                f"|dtype={dtype}|cap={self.max_candidates}")
        return np.frombuffer(desc.encode(), dtype=np.uint8).copy()

    def state_dict(self) -> "dict":
        """Full endpoint state as a flat ``{key: ndarray}`` mapping.

        Covers everything a bit-exact restore needs: every level table,
        the shared hash params (the finest level's arrays -- every
        coarser level's params are prefix slices of them), the stream
        total, and each group's space-saving pool in insertion order.
        A config fingerprint guards against restoring into an endpoint
        built on a different spec/mode/dtype.

        Refused mid-migration: the successor's tables are transient
        double-write state with no stable identity to restore into --
        call ``abort_migration()`` (or wait for cutover) first.
        """
        if self._migration is not None:
            raise ValueError(
                "cannot checkpoint an endpoint mid-migration: the warmup "
                "successor's state is transient; call abort_migration() to "
                "roll back to the active surface (or wait for cutover), "
                "then snapshot")
        state = self.state
        out = {
            "meta.total": np.asarray(self.total, dtype=np.int64),
            "meta.fingerprint": self._config_fingerprint(),
            # finest level's params ARE the full shared family; coarser
            # levels' params are rebuilt as prefix slices on load
            "params.q": np.asarray(state.states[-1].params.q),
            "params.r": np.asarray(state.states[-1].params.r),
        }
        for i, st in enumerate(state.states):
            out[f"level{i}.table"] = np.asarray(st.table)
        for j, p in enumerate(self._pools):
            for k, v in p.state_dict().items():
                out[f"pool{j}.{k}"] = v
        return out

    def load_state_dict(self, sd: "dict") -> None:
        """Restore state saved by :meth:`state_dict`; bit-exact round trip."""
        from repro.core import sketch as sk

        fp = self._config_fingerprint()
        got = np.asarray(sd["meta.fingerprint"], dtype=np.uint8)
        if not np.array_equal(fp, got):
            raise ValueError(
                "endpoint state_dict fingerprint mismatch: saved "
                f"{bytes(got).decode(errors='replace')!r}, this endpoint is "
                f"{bytes(fp).decode(errors='replace')!r}")
        base = sk.SketchParams(q=jnp.asarray(sd["params.q"]),
                               r=jnp.asarray(sd["params.r"]))
        states = []
        for i in range(self.hspec.n_levels):
            params = self._hh.level_params(self.hspec, base, i)
            states.append(sk.SketchState(
                params=params, table=jnp.asarray(sd[f"level{i}.table"])))
        self.state = self._hh.HierarchyState(states=tuple(states))
        self.total = int(sd["meta.total"])
        for j, p in enumerate(self._pools):
            p.load_state(sd[f"pool{j}.rows"], sd[f"pool{j}.counts"],
                         sd[f"pool{j}.errs"])

    # -- hot spec migration hooks (serving/migration.MigratingSurface) -------

    def _build_successor(self, new_spec, key) -> "SketchTopKEndpoint":
        return SketchTopKEndpoint(
            new_spec, key,
            max_candidates_per_group=self.max_candidates,
            use_kernel=self.use_kernel,
            use_update_kernel=self._use_update_kernel,
            dtype=self.state.states[0].table.dtype, mode="linear")

    def _adopt(self, inc: "SketchTopKEndpoint") -> None:
        """Adopt the successor's state wholesale; free the old tables.

        After this, the endpoint is bit-identical to a fresh endpoint
        built on the new spec (same key) and fed exactly the blocks since
        ``begin_migration`` -- the successor IS that endpoint.  ``total``
        restarts at the post-warmup-start mass: estimates and totals
        describe the same (new) stream window, which is what the top-k
        descent's threshold scaling assumes.
        """
        self.hspec = inc.hspec
        self._kh = inc._kh
        self._state = inc._state
        self._pools = inc._pools
        self.total = inc.total
        # old tables/pools: last references dropped above -> freed

    def heavy_hitters(self, threshold: int,
                      candidates: Optional[List[np.ndarray]] = None,
                      ) -> Tuple[np.ndarray, np.ndarray]:
        if candidates is None:
            candidates = self.candidates()
        return self._hh.find_heavy_hitters(
            self.hspec, self.state, threshold, candidates,
            use_kernel=self.use_kernel)

    def topk(self, k: int,
             min_threshold: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k by estimate: geometric threshold descent until k found.

        See :func:`repro.serving.sharded_topk.threshold_descent_topk` (the
        descent is shared with the sharded service) for the
        ``min_threshold`` semantics.  Candidates are hoisted: the pools
        don't change mid-descent.
        """
        from repro.serving.sharded_topk import threshold_descent_topk

        return threshold_descent_topk(
            self.heavy_hitters, self.candidates(), k, total=self.total,
            n_modules=self.hspec.base.schema.modularity,
            min_threshold=min_threshold)

    def to_sharded(self, mesh, *, data_axes=None,
                   sync_every: Optional[int] = 1,
                   ) -> "object":
        """Promote this single-shard endpoint to a ShardedTopKService.

        Carries over the hierarchy tables, hash params, candidate pools,
        and stream total; subsequent ingest runs sharded over the mesh.
        Linear endpoints only: a conservative endpoint's tables are not
        linear in the stream and must never enter the psum sync path, so
        promotion is refused (same contract as merge_from).
        """
        from repro.core.sketch import SketchState
        from repro.core.summary import SpaceSaving
        from repro.serving.sharded_topk import ShardedTopKService

        require_not_migrating(self._migration,
                              "SketchTopKEndpoint.to_sharded")
        if self.mode != "linear":
            raise ValueError(
                "to_sharded is only defined for linear endpoints: "
                "conservative tables cannot be psum-merged, so a "
                "conservative endpoint must stay single-shard")
        svc = ShardedTopKService(
            self.hspec.base, jax.random.PRNGKey(0), mesh,
            data_axes=data_axes,
            max_candidates_per_group=self.max_candidates,
            sync_every=sync_every, use_kernel=self.use_kernel,
            dtype=self.state.states[0].table.dtype)
        # the service's freshly drawn params are discarded: the promoted
        # state keeps this endpoint's params so existing tables stay valid.
        # Tables are COPIED, not aliased: the endpoint's ingest path
        # donates its table buffers (hierarchy.update_jit), so a later
        # ep.ingest() would delete buffers the service still reads.
        # Params are never donated, so sharing them is safe.
        state = self.state
        svc.merged = self._hh.HierarchyState(states=tuple(
            SketchState(params=st.params, table=jnp.array(st.table))
            for st in state.states))
        svc.total = self.total
        svc._shard_pools[0] = [SpaceSaving.fold([p]) for p in self._pools]
        svc._global_pools = [SpaceSaving.fold([p]) for p in self._pools]
        return svc

    def merge_from(self, other: "SketchTopKEndpoint") -> None:
        """Fold another endpoint's sketch + pools in (cross-shard merge).

        Only defined for linear endpoints: conservative tables are not
        linear in the stream, so a cell-wise sum of two conservatively
        built hierarchies is not the hierarchy of the union stream --
        conservative endpoints are single-shard by construction and
        rejected here (both directions).

        Shards must share the base spec and hash parameters (same spec +
        PRNG key): cell-wise sums of tables hashed with different params --
        or with the same params but permuted partition axes -- are garbage,
        so mismatches are rejected rather than silently accepted.
        """
        require_not_migrating(self._migration,
                              "SketchTopKEndpoint.merge_from")
        require_not_migrating(other._migration,
                              "SketchTopKEndpoint.merge_from (source side)")
        if self.mode != "linear" or other.mode != "linear":
            raise ValueError(
                "merge_from is only defined for linear endpoints: "
                "conservative tables cannot be merged cell-wise")
        if self.hspec.base != other.hspec.base:
            raise ValueError(
                "merge_from requires identical base specs on both endpoints")
        for sa, sb in zip(self.state.states, other.state.states):
            if not (np.array_equal(np.asarray(sa.params.q), np.asarray(sb.params.q))
                    and np.array_equal(np.asarray(sa.params.r), np.asarray(sb.params.r))):
                raise ValueError(
                    "merge_from requires identical hash params on both "
                    "endpoints (build them from the same spec and key)")
        self.state = self._hh.merge(self.state, other.state)
        self.total += other.total
        for mine, theirs in zip(self._pools, other._pools):
            mine.merge_from(theirs)


@dataclasses.dataclass
class StagedBlock:
    """One in-flight pipelined block: dispatched cascade + deferred fold."""
    idxs: Tuple[jax.Array, ...]    # per-level cell indices (async, in flight)
    freqs: jax.Array               # padded frequencies matching idxs
    raw_items: np.ndarray          # unpadded block (pools + double-write)
    raw_freqs: np.ndarray
    mass: int                      # int(raw_freqs.sum())


# --------------------------------------------------------------------------
# async serve engine: pipelined ingest, snapshots, batched descent
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SketchQuery:
    """One serving request for the engine's submit/flush lifecycle.

    ``kind`` is ``"topk"`` (uses ``k``/``min_threshold``) or
    ``"heavy_hitters"`` (uses ``threshold``).  ``items``/``est`` carry the
    answer after the flush that served it, exactly what the synchronous
    ``topk``/``heavy_hitters`` call would have returned against the same
    snapshot.
    """
    rid: int
    kind: str                                  # 'topk' | 'heavy_hitters'
    k: int = 0
    threshold: int = 0
    min_threshold: Optional[int] = None
    items: Optional[np.ndarray] = None
    est: Optional[np.ndarray] = None
    done: bool = False


@dataclasses.dataclass(frozen=True)
class SketchSnapshot:
    """An immutable query view of a backend: copied tables + frozen pools.

    ``total`` is the backend's stream mass when taken (seeds the top-k
    threshold descent); ``mass`` is the ENGINE's cumulative ingested mass
    at the same instant -- the staleness watermark.  The two differ
    exactly when the backend has restarted its own total (migration
    cutover, window advance), which is why staleness is measured against
    the engine counter and never against ``backend.total``.
    """
    hspec: Any
    state: Any                                 # HierarchyState, tables copied
    candidates: List[np.ndarray]
    total: int
    mass: int


class SketchServeEngine:
    """Async serving engine over any sketch backend (endpoint/sharded/windowed).

    ``backend`` is a :class:`SketchTopKEndpoint`, a
    :class:`~repro.serving.sharded_topk.ShardedTopKService`, or a
    :class:`~repro.serving.windowed_topk.WindowedTopKService` -- anything
    with ``ingest``/``state``/``candidates``/``total``/``hspec``.  The
    engine owns three asynchrony mechanisms, all individually inert at
    their default settings:

    **Pipelined ingest.**  On a plain linear endpoint (no fused update
    kernel, not conservative), each ingested block is only *staged*: its
    hash cascade is dispatched immediately, but the fold into the donated
    table buffers is deferred until the next ingest (or a sync) -- so the
    cascade of block k+1 overlaps the fold of block k.  The fold always
    runs BEFORE the next stage, so a migration cutover triggered by a
    fold can never strand staged indices computed under the old params.
    Every other backend (kernel, conservative, sharded, windowed, or
    mid-migration) ingests synchronously through the same entry point.
    Pipelined or not, the tables after a drain are bit-identical to
    direct backend ingest.

    **Snapshot queries with a staleness bound.**  Queries never touch the
    live tables; they run against a :class:`SketchSnapshot` whose tables
    were COPIED at the last refresh (the ingest path donates its buffers,
    so aliasing them would read freed memory).  ``max_staleness`` bounds
    the stream mass ingested since the snapshot: a query whose bound is
    exceeded triggers a refresh first.  ``max_staleness=0`` refreshes on
    every post-ingest query -- bit-identical to the synchronous surfaces
    (enforced by tests/test_serve_engine.py); ``None`` means only explicit
    :meth:`sync` refreshes (unbounded staleness, maximum overlap).

    **Batched multi-request descent.**  :meth:`submit` queues
    :class:`SketchQuery` requests; :meth:`flush` serves ALL of them
    against one snapshot, packing every still-active request's per-level
    candidate grid into a single launch
    (core.hierarchy.batched_find_heavy_hitters).  Each request's descent
    trajectory -- thresholds tried, pruning, final answer -- is
    bit-identical to its own serial ``topk``/``heavy_hitters`` call.
    The engine satisfies serving/protocol.ServeEngineProtocol, same as
    the model stack's SlotScheduler.

    Background maintenance plugs in at exactly one place each: a sharded
    backend's psum merge runs every ``shard_sync_every`` ingested blocks
    (default 4, the BENCH_SHARDED sweep's knee -- amortizes the
    all-reduce without unbounded local-delta growth); an optional
    ``tuner`` (serving/autotune.AutoTuner) steps on every :meth:`sync`,
    so retune decisions and migrations happen at snapshot boundaries;
    migration double-writes ride inside the backend's own ingest/fold.

    Thread safety: one re-entrant lock around every entry point, so an
    ingest thread and query threads can share the engine (see
    examples/async_serving.py); queries serialize against ingest but
    never against device work already dispatched.
    """

    def __init__(self, backend, *, max_staleness: Optional[int] = 0,
                 shard_sync_every: Optional[int] = 4, tuner=None):
        self.backend = backend
        self.max_staleness = max_staleness
        self.shard_sync_every = shard_sync_every
        self.tuner = tuner
        self._lock = threading.RLock()
        self._staged: Optional[StagedBlock] = None
        self._mass = 0                       # engine staleness watermark
        self._blocks_since_psum = 0
        self._queue: List[SketchQuery] = []
        self._next_rid = 0
        self._is_sharded = hasattr(backend, "sync") and hasattr(backend, "n_shards")
        self._snap: Optional[SketchSnapshot] = None
        self._snap = self._take_snapshot()

    # -- ingest side ---------------------------------------------------------

    def _can_pipeline(self) -> bool:
        b = self.backend
        return (isinstance(b, SketchTopKEndpoint) and b.mode == "linear"
                and b._kh is None and not b.migrating)

    def ingest(self, items: np.ndarray,
               freqs: Optional[np.ndarray] = None) -> None:
        """Ingest one weighted block (pipelined where the backend allows)."""
        with self._lock:
            items = np.asarray(items, dtype=np.uint32)
            if items.shape[0] == 0:
                return
            if freqs is None:
                freqs = np.ones(items.shape[0], dtype=np.int64)
            freqs = np.asarray(freqs)
            self._fold_pending()             # fold k before staging k+1
            if self._can_pipeline():
                self._staged = self.backend.stage_block(items, freqs)
            else:
                self.backend.ingest(items, freqs)
            self._mass += int(freqs.sum())
            if self._is_sharded and self.shard_sync_every:
                self._blocks_since_psum += 1
                if self._blocks_since_psum >= self.shard_sync_every:
                    # background psum cadence: merge local deltas into the
                    # backend's serving tables WITHOUT refreshing the
                    # engine snapshot (that stays on the staleness clock)
                    self._fold_pending()
                    self.backend.sync()
                    self._blocks_since_psum = 0

    def _fold_pending(self) -> None:
        if self._staged is not None:
            staged, self._staged = self._staged, None
            self.backend.fold_staged(staged)

    def drain(self) -> None:
        """Fold any staged block; the backend then holds every ingested item."""
        with self._lock:
            self._fold_pending()

    def advance(self) -> None:
        """Epoch clock passthrough for windowed backends.

        Advancing changes the window tables WITHOUT moving stream mass, so
        the staleness bound alone cannot see it -- the snapshot is
        invalidated explicitly and the next query refreshes.
        """
        with self._lock:
            self._fold_pending()
            self.backend.advance()
            self._snap = None

    # -- snapshot / staleness -------------------------------------------------

    def _take_snapshot(self) -> SketchSnapshot:
        from repro.core import hierarchy as hh
        from repro.core import sketch as sk

        b = self.backend
        st = b.state
        if callable(st):                     # sharded/windowed expose a method
            st = st()
        state = hh.HierarchyState(states=tuple(
            sk.SketchState(params=s.params, table=jnp.array(s.table))
            for s in st.states))
        return SketchSnapshot(hspec=b.hspec, state=state,
                              candidates=b.candidates(),
                              total=int(b.total), mass=self._mass)

    @property
    def staleness(self) -> int:
        """Stream mass ingested since the serving snapshot was taken."""
        with self._lock:
            return self._mass - self._snap.mass if self._snap else self._mass

    @property
    def ingested_mass(self) -> int:
        """The engine's cumulative-mass watermark (staleness clock)."""
        with self._lock:
            return self._mass

    def restore_watermark(self, mass: int) -> None:
        """Reset the staleness clock after a backend restore.

        The recovery layer restores the backend's state out-of-band, so
        the engine's cumulative-mass counter must be put back to the saved
        watermark (otherwise staleness would measure against a counter
        from a different life).  Retakes the snapshot so queries see the
        restored tables immediately.
        """
        with self._lock:
            self._staged = None             # staged indices from the old life
            self._mass = int(mass)
            self._blocks_since_psum = 0
            self._snap = self._take_snapshot()

    def sync(self) -> SketchSnapshot:
        """Drain the pipeline, psum-merge (sharded), refresh the snapshot,
        and tick the auto-tuner.  The one barrier in the engine."""
        with self._lock:
            self._fold_pending()
            if self._is_sharded:
                self.backend.sync()
                self._blocks_since_psum = 0
            self._snap = self._take_snapshot()
            if self.tuner is not None:
                # retune on snapshot boundaries only: a migration decision
                # here opens the double-write window inside the backend's
                # own ingest path; queries keep serving old tables per the
                # migration contract, which this snapshot already is
                self.tuner.step()
            return self._snap

    def _fresh_snapshot(self) -> SketchSnapshot:
        if self._snap is None or (
                self.max_staleness is not None
                and self._mass - self._snap.mass > self.max_staleness):
            self.sync()
        return self._snap

    # -- synchronous query surface (one request) ------------------------------

    def heavy_hitters(self, threshold: int) -> Tuple[np.ndarray, np.ndarray]:
        """Every key estimated >= threshold, within the staleness bound."""
        from repro.core import hierarchy as hh

        with self._lock:
            snap = self._fresh_snapshot()
            return hh.find_heavy_hitters(
                snap.hspec, snap.state, threshold, snap.candidates,
                use_kernel=self.backend.use_kernel)

    def topk(self, k: int, min_threshold: Optional[int] = None,
             ) -> Tuple[np.ndarray, np.ndarray]:
        """The k keys with the largest estimates, within the staleness bound."""
        from repro.core import hierarchy as hh
        from repro.serving.sharded_topk import threshold_descent_topk

        with self._lock:
            snap = self._fresh_snapshot()

            def hh_fn(thr, candidates):
                return hh.find_heavy_hitters(
                    snap.hspec, snap.state, thr, candidates,
                    use_kernel=self.backend.use_kernel)

            return threshold_descent_topk(
                hh_fn, snap.candidates, k, total=snap.total,
                n_modules=snap.hspec.base.schema.modularity,
                min_threshold=min_threshold)

    # -- batched query surface (submit/flush protocol) -------------------------

    def submit_topk(self, k: int,
                    min_threshold: Optional[int] = None) -> SketchQuery:
        """Queue a top-k request for the next :meth:`flush`."""
        return self.submit(SketchQuery(rid=-1, kind="topk", k=int(k),
                                       min_threshold=min_threshold))

    def submit_heavy_hitters(self, threshold: int) -> SketchQuery:
        """Queue a heavy-hitters request for the next :meth:`flush`."""
        return self.submit(SketchQuery(rid=-1, kind="heavy_hitters",
                                       threshold=int(threshold)))

    def submit(self, request: SketchQuery) -> SketchQuery:
        with self._lock:
            if request.kind not in ("topk", "heavy_hitters"):
                raise ValueError(
                    f"kind must be 'topk' or 'heavy_hitters', got "
                    f"{request.kind!r}")
            request.rid = self._next_rid
            self._next_rid += 1
            self._queue.append(request)
            return request

    def flush(self) -> List[SketchQuery]:
        """Serve every queued request against ONE snapshot, batched.

        All requests see the same snapshot (mutually consistent answers);
        each individual answer is bit-identical to the serial
        ``topk``/``heavy_hitters`` call against that snapshot.  Returns
        the requests in submission order.
        """
        with self._lock:
            reqs, self._queue = self._queue, []
            if not reqs:
                return []
            snap = self._fresh_snapshot()
            self._serve_batched(snap, reqs)
            return reqs

    def _serve_batched(self, snap: SketchSnapshot,
                       reqs: List[SketchQuery]) -> None:
        """The packed threshold descent: one launch per level per round.

        Replicates :func:`~repro.serving.sharded_topk.threshold_descent_topk`
        per request -- same starting threshold ``max(total, 1)``, same
        ``max(1, total >> 17)`` floor, same geometric /4 schedule, same
        stop condition -- but evaluates every still-descending request's
        round together via core.hierarchy.batched_find_heavy_hitters.
        Requests drop out of the batch as they complete.
        """
        from repro.core import hierarchy as hh

        total = snap.total
        thr, floor = {}, {}
        for r in reqs:
            if r.kind == "heavy_hitters":
                thr[r.rid] = int(r.threshold)
                floor[r.rid] = None          # single evaluation, no descent
            else:
                m = (r.min_threshold if r.min_threshold is not None
                     else max(1, total >> 17))
                floor[r.rid] = int(m)
                thr[r.rid] = max(total, 1)

        # a floor above the starting threshold never evaluates at all in
        # the serial descent (`while thr >= min_threshold` fails upfront)
        n_mods = snap.hspec.base.schema.modularity
        pending = []
        for r in reqs:
            if r.kind == "topk" and thr[r.rid] < floor[r.rid]:
                r.items = np.zeros((0, n_mods), np.uint32)
                r.est = np.zeros((0,), np.int64)
                r.done = True
            else:
                pending.append(r)
        while pending:
            results = hh.batched_find_heavy_hitters(
                snap.hspec, snap.state, [thr[r.rid] for r in pending],
                snap.candidates, use_kernel=self.backend.use_kernel)
            nxt = []
            for r, (items, est) in zip(pending, results):
                if r.kind == "heavy_hitters":
                    r.items, r.est, r.done = items, est, True
                elif len(est) >= r.k or thr[r.rid] == floor[r.rid]:
                    r.items, r.est, r.done = items[: r.k], est[: r.k], True
                else:
                    thr[r.rid] = max(floor[r.rid], thr[r.rid] // 4)
                    nxt.append(r)
            pending = nxt
