"""Hot spec migration: the double-write window between two sketch specs.

A serving endpoint cannot atomically swap to a re-tuned SketchSpec: the
new spec's tables start empty, so cutting over immediately would answer
queries from a sketch that has seen nothing.  The migration protocol both
serving surfaces (serving/sketch_engine.SketchTopKEndpoint,
serving/sharded_topk.ShardedTopKService) implement by mixing in
:class:`MigratingSurface` on top of this holder:

  1. ``begin_migration(new_spec, key, warmup=W)`` builds a FRESH successor
     service on the new spec (empty tables, empty pools, total = 0);
  2. every subsequent ingest **double-writes**: the block folds into the
     active (old-spec) tables as always AND into the successor;
  3. queries keep serving from the active tables -- the successor is
     invisible until it has absorbed ``W`` stream mass;
  4. once the successor's total reaches ``W``, the service **cuts over**:
     the successor's state (tables, pools, hash params, total) becomes the
     service's state wholesale and the old tables are freed (last
     references dropped).

Post-cutover the service is *bit-identical* to a fresh service built on
the new spec from the same key and fed exactly the post-warmup-start
stream -- the successor IS such a service, fed block-for-block.  That is
the migration-correctness contract tests/test_migration.py enforces, and
it composes with shard invariance: a sharded successor is itself
shard-count invariant, so a migration is bit-identical across 1/2/4
shards too.

Linear mode only.  A conservative (Estan-Varghese) endpoint could in
principle double-write, but its post-cutover total/estimate semantics
could not be validated against the linear merge/fold contracts the rest
of the stack leans on, and every consumer of migration (auto-tuning, the
coming elastic re-meshing) runs on the linear psum paths -- so
``begin_migration`` refuses conservative mode via
``core.distributed.require_linear``, same as every sharded surface.

Mutating the spec-carrying state mid-window is also refused:
``merge_from`` / ``to_sharded`` during warmup would have to be replayed
into the successor to keep the bit-identity contract, which is exactly
the kind of silent divergence this layer exists to prevent
(:func:`require_not_migrating`).
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class MigratingSurface:
    """Mixin: the migration scaffolding shared by every serving surface.

    SketchTopKEndpoint and ShardedTopKService used to carry identical
    copies of the migration plumbing (the ``migrating`` /
    ``migration_progress`` properties, the one-at-a-time guard, the
    offer -> ready -> cutover ingest tail); this mixin is that plumbing,
    written once.  A surface contributes exactly two hooks:

      ``_build_successor(new_spec, key)``  a fresh, EMPTY sibling service
          on the new spec, mirroring this surface's own configuration
          (pool capacity, dtype, kernel settings, mesh, ...);
      ``_adopt(successor)``  copy the successor's state fields over
          wholesale at cutover (the per-surface field list).

    and calls ``_migration_tick(raw_items, raw_freqs)`` at the end of its
    ingest with the UNPADDED block -- the successor pads/splits its own
    blocks exactly like a fresh service would, which is what keeps
    cutover bit-identical to a fresh build on the new spec.
    """

    _migration: Optional["SpecMigration"] = None
    mode: str = "linear"

    @property
    def migrating(self) -> bool:
        return self._migration is not None

    @property
    def migration_progress(self) -> float:
        """Warmup progress in [0, 1]; 1.0 when no migration is in flight."""
        return 1.0 if self._migration is None else self._migration.progress

    def begin_migration(self, new_spec, key, *, warmup: int) -> None:
        """Open a double-write window onto a fresh service on ``new_spec``.

        From the next ingest on, every block folds into BOTH the active
        tables and a successor built by ``_build_successor`` (same pool
        capacity, table dtype, kernel/mesh settings as this surface).
        Queries keep answering from the active tables until the successor
        has absorbed ``warmup`` stream mass (sum of ingested
        frequencies); the ingest that crosses the threshold cuts over:
        the successor's state becomes this surface's state wholesale and
        the old tables are freed.

        Linear mode only -- conservative tables are excluded from every
        migration consumer (auto-tuning, re-meshing) and refused here via
        the same guard as the sharded surfaces.  One migration at a time.
        """
        from repro.core.distributed import require_linear

        require_linear(self.mode, f"{type(self).__name__}.begin_migration")
        if self._migration is not None:
            raise ValueError(
                "a spec migration is already in flight "
                f"({self._migration.progress:.0%} of warmup); one at a time")
        self._migration = SpecMigration(
            self._build_successor(new_spec, key), warmup)

    def abort_migration(self) -> None:
        """Roll back an in-flight migration to the active surface.

        Safe at any warmup point: double-write only ever writes the
        *successor*, the active tables/pools/totals are untouched by the
        migration machinery, so dropping the successor leaves no residue
        -- queries before and after the abort are answered from the same
        active state.  No-op when no migration is in flight (aborting
        twice, or after cutover already happened, is not an error)."""
        self._migration = None

    def _migration_tick(self, raw_items: np.ndarray,
                        raw_freqs: Optional[np.ndarray]) -> None:
        """Double-write one ingested block; cut over when warmup is done."""
        if self._migration is None:
            return
        self._migration.offer(raw_items, raw_freqs)
        if self._migration.ready:
            inc = self._migration.incoming
            self._migration = None
            self._adopt(inc)

    # -- per-surface hooks --------------------------------------------------

    def _build_successor(self, new_spec, key):
        raise NotImplementedError

    def _adopt(self, successor) -> None:
        raise NotImplementedError


class SpecMigration:
    """State holder for one in-flight migration: the successor + its window.

    ``incoming`` is the freshly built successor service (any object with
    ``ingest(items, freqs)`` and an integer ``total``); ``warmup`` is the
    stream mass (sum of frequencies, the same unit as ``total``) the
    successor must absorb before cutover.
    """

    def __init__(self, incoming, warmup: int):
        warmup = int(warmup)
        if warmup < 1:
            raise ValueError("warmup must be >= 1 stream mass units")
        if int(incoming.total) != 0:
            raise ValueError(
                "the migration successor must start empty (total == 0): "
                "bit-identity with a fresh service on the new spec is the "
                "whole contract")
        self.incoming = incoming
        self.warmup = warmup

    def offer(self, items: np.ndarray, freqs: Optional[np.ndarray]) -> None:
        """Double-write one ingested block into the successor."""
        self.incoming.ingest(items, freqs)

    @property
    def ready(self) -> bool:
        """True once the successor has absorbed the warmup mass."""
        return int(self.incoming.total) >= self.warmup

    @property
    def progress(self) -> float:
        """Warmup progress in [0, 1]."""
        return min(1.0, int(self.incoming.total) / self.warmup)


def require_not_migrating(migration: Optional[SpecMigration],
                          entry: str) -> None:
    """Refuse state-mutating entry points while a migration is in flight.

    Folding foreign state (``merge_from``) or re-homing the tables
    (``to_sharded``) mid-warmup would change the active state without the
    successor seeing the same change, silently breaking the post-cutover
    bit-identity contract -- refused loudly instead, finish (or never
    start) the warmup first.
    """
    if migration is not None:
        raise ValueError(
            f"{entry} is not allowed while a spec migration is in its "
            "warmup window: the successor would not see the same state "
            "change and cutover would diverge from a fresh-build of the "
            "new spec; wait for cutover (or don't start the migration)")
