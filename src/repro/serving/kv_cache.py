"""KV/SSM cache utilities for the serving engine.

The cache structures themselves are defined next to the layers that use
them (attention.init_kv_cache, ssm.init_ssm_cache) and stacked per block by
transformer.init_cache; this module adds serving-side helpers: sizing and
trimming for slot reuse.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm

PyTree = Any


def cache_bytes(cache: PyTree) -> int:
    """Total bytes held by a decode cache (capacity planning)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def new_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    enc_len = cfg.frontend_len if cfg.n_enc_layers else 0
    return tfm.init_cache(cfg, batch, max_len, enc_len=enc_len)


def reset_slots(cache: PyTree, slot_mask) -> PyTree:
    """Zero the cache rows of finished slots (bool[B]) for reuse."""
    def z(x):
        if x.ndim >= 2 and x.shape[1] == slot_mask.shape[0]:
            shape = (1, -1) + (1,) * (x.ndim - 2)
            return x * (~slot_mask).reshape(shape).astype(x.dtype)
        return x
    return jax.tree.map(z, cache)
