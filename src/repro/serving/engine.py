"""Batched serving engine: prefill + decode with a static KV cache.

The lowered unit is ``serve_step`` = one new token for every sequence in the
batch against a ``seq_len`` cache -- exactly the assigned ``decode_*`` /
``long_*`` dry-run cells.  The engine adds request batching (uniform
position; left-padded prompts), greedy/temperature sampling, and a simple
slot scheduler for continuous batching at the granularity of whole steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    temperature: float = 0.0     # 0 = greedy
    eos_id: int = -1             # -1 = never stop early


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: PyTree, scfg: ServeConfig,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, t, e: tfm.prefill(cfg, p, t, embeds=e,
                                        max_len=scfg.max_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(cfg, p, c, t, pos))

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def generate(
        self,
        prompts: np.ndarray,                # int32[B, S] (uniform length)
        max_new_tokens: int,
        embeds: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        cfg = self.cfg
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s = prompts.shape
        n_prefix = 0
        if cfg.frontend and not cfg.n_enc_layers:
            n_prefix = cfg.frontend_len
        if embeds is not None:
            embeds = jnp.asarray(embeds, cfg.activation_dtype)
        logits, cache = self._prefill(self.params, prompts, embeds)
        out = [self._sample(logits)[:, None]]
        pos = n_prefix + s
        for _ in range(max_new_tokens - 1):
            lg, cache = self._decode(self.params, cache, out[-1], jnp.int32(pos))
            out.append(self._sample(lg[:, 0, :])[:, None])
            pos += 1
        return np.asarray(jnp.concatenate(out, axis=1))


# --------------------------------------------------------------------------
# continuous batching (step-granular slot scheduler)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class SlotScheduler:
    """Admit requests into fixed decode slots; refill as sequences finish.

    Real continuous batching interleaves per-token; at the benchmark
    granularity used here, slots turn over between generate() calls of
    uniform-length cohorts, which preserves the serving-throughput shape
    while keeping the lowered step static.
    """

    def __init__(self, engine: ServeEngine, n_slots: int):
        self.engine = engine
        self.n_slots = n_slots
        self.queue: List[Request] = []
        self.completed: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self) -> List[Request]:
        while self.queue:
            cohort = self.queue[: self.n_slots]
            self.queue = self.queue[self.n_slots:]
            s = min(len(r.prompt) for r in cohort)
            prompts = np.stack([r.prompt[:s] for r in cohort])
            max_new = max(r.max_new for r in cohort)
            toks = self.engine.generate(prompts, max_new)
            for r, row in zip(cohort, toks):
                r.out = row[: r.max_new].tolist()
                r.done = True
                self.completed.append(r)
        return self.completed


# --------------------------------------------------------------------------
# streaming top-k endpoint (hierarchical heavy-hitter sketch)
# --------------------------------------------------------------------------

class SketchTopKEndpoint:
    """Serving endpoint for streaming heavy-hitter / top-k queries.

    Ingests weighted key blocks (telemetry: routed-token pairs, request
    n-grams, edge events) into a hierarchical composite-hash sketch
    (core/hierarchy.py) and answers

      * ``heavy_hitters(threshold)`` -- every key estimated >= threshold,
      * ``topk(k)`` -- the k keys with the largest estimates,

    without storing the stream.  Memory is the hierarchy's tables plus
    bounded per-group candidate pools.  Admission is a weighted
    space-saving summary per group (core/summary.py): at capacity m, a new
    value evicts the lightest entry instead of being dropped, so any group
    value carrying more than total/m of the stream's weight is in the pool
    no matter how late it first arrives; the no-false-negative guarantee
    of the descent is conditional on that W/m admission bound.

    ``mode="conservative"`` applies the Estan-Varghese conservative update
    per level: strictly tighter estimates, but the tables are no longer
    linear in the stream, so such an endpoint refuses ``merge_from`` (both
    directions) and must stay single-shard -- conservative tables are
    excluded from the cell-wise merge and psum paths of
    core/distributed.py.

    Every ingest path hashes each item ONCE and derives all level indices
    by the mixed-radix cascade (core/hierarchy.py's shared per-group hash
    family).  ``use_update_kernel=True`` additionally folds each block into
    all level tables with the fused single-launch Pallas kernel
    (kernels/ops.KernelHierarchy); linear mode only -- a conservative
    endpoint silently keeps the jnp per-level sequential folds, which
    already share the cascade's one hash pass.

    Linear endpoints shard naturally: run one per ingest worker and fold
    with ``merge_from`` at query time (tables cell-wise, exact by
    linearity; candidate summaries via the mergeable-summaries rule).

    Hot spec migration (serving/migration.py): ``begin_migration`` opens a
    double-write window onto a fresh successor endpoint built on a
    re-tuned spec; queries keep serving from the old tables until the
    successor has absorbed ``warmup`` stream mass, then the endpoint cuts
    over to the successor's state wholesale and frees the old tables.
    Linear mode only; ``merge_from``/``to_sharded`` are refused mid-window
    (the successor would not see the same state change).
    """

    def __init__(self, base_spec, key, *, max_candidates_per_group: int = 1 << 16,
                 use_kernel: bool = False, use_update_kernel: bool = False,
                 dtype=jnp.int32, mode: str = "linear"):
        from repro.core import hierarchy as hh
        from repro.core.summary import SpaceSaving

        if mode not in ("linear", "conservative"):
            raise ValueError(f"mode must be 'linear' or 'conservative', got {mode!r}")
        self._hh = hh
        self._kh = None
        self._migration = None
        self._use_update_kernel = bool(use_update_kernel)
        self.hspec = hh.HierarchySpec.from_spec(base_spec)
        self.state = hh.init_hierarchy(self.hspec, key, dtype=dtype)
        self.max_candidates = int(max_candidates_per_group)
        self.use_kernel = use_kernel
        self.mode = mode
        self.total = 0
        self._pools: List[SpaceSaving] = [
            SpaceSaving(self.max_candidates, len(g))
            for g in base_spec.partition
        ]
        if use_update_kernel and mode == "linear":
            from repro.kernels.ops import KernelHierarchy

            # the endpoint's state moves into the kernel wrapper's
            # concatenated padded table; ``state`` stays visible as a
            # lazily sliced view (see the property below)
            self._kh = KernelHierarchy.from_state(self.hspec, self._state)
            self._state = None

    @property
    def state(self):
        """The hierarchy state (assembled lazily on the fused-kernel path)."""
        if self._kh is not None:
            return self._kh.state()
        return self._state

    @state.setter
    def state(self, value) -> None:
        if getattr(self, "_kh", None) is not None:
            self._kh.load_state(value)
        else:
            self._state = value

    def _ingest_active(self, items: np.ndarray, freqs: np.ndarray) -> None:
        """Fold one normalized block into the ACTIVE (serving) tables."""
        if self.mode == "conservative":
            from repro.core.sketch import check_conservative_freqs
            check_conservative_freqs(freqs, self.state.states[0].table.dtype)
        if self._kh is not None:
            # reject kernel-unrepresentable weights BEFORE touching pools
            # or totals, so a failed ingest leaves the endpoint unchanged
            from repro.kernels.ops import check_linear_kernel_freqs
            check_linear_kernel_freqs(freqs, self._kh.table.dtype)
        self.total += int(freqs.sum())
        for j, g in enumerate(self.hspec.base.partition):
            self._pools[j].offer(items[:, list(g)], freqs)
        if self._kh is not None:
            # fused single-launch path: KernelHierarchy pads blocks to its
            # own fixed block_b (zero-frequency pad rows are no-ops)
            self._kh.update(items, freqs)
            return
        # pad blocks to the next power of two so the jitted multi-level
        # update compiles O(log B) variants, not one per block length
        # (zero-frequency pad items are no-ops and stay out of the pools)
        from repro.core.distributed import pad_block_pow2
        items, freqs, _ = pad_block_pow2(items, freqs, 1)
        fold = (self._hh.update_conservative_jit
                if self.mode == "conservative" else self._hh.update_jit)
        self.state = fold(self.hspec, self.state, jnp.asarray(items),
                          jnp.asarray(freqs))

    def ingest(self, items: np.ndarray,
               freqs: Optional[np.ndarray] = None) -> None:
        items = np.asarray(items, dtype=np.uint32)
        if items.shape[0] == 0:
            return
        if freqs is None:
            freqs = np.ones(items.shape[0], dtype=np.int64)
        freqs = np.asarray(freqs)
        self._ingest_active(items, freqs)
        if self._migration is not None:
            # double-write window: the successor sees every block verbatim
            # (unpadded -- it pads its own blocks exactly like a fresh
            # endpoint would, which is what keeps cutover bit-identical
            # to a fresh build on the new spec)
            self._migration.offer(items, freqs)
            if self._migration.ready:
                self._cutover()

    def candidates(self) -> List[np.ndarray]:
        """Per-group candidate value arrays from the space-saving pools."""
        return [p.values() for p in self._pools]

    # -- hot spec migration (serving/migration.py) --------------------------

    @property
    def migrating(self) -> bool:
        return self._migration is not None

    @property
    def migration_progress(self) -> float:
        """Warmup progress in [0, 1]; 1.0 when no migration is in flight."""
        return 1.0 if self._migration is None else self._migration.progress

    def begin_migration(self, new_spec, key, *, warmup: int) -> None:
        """Open a double-write window onto a fresh endpoint on ``new_spec``.

        From the next ``ingest`` on, every block folds into BOTH the
        active tables and a successor endpoint freshly built from
        ``new_spec``/``key`` (same pool capacity, table dtype, and kernel
        settings as this endpoint).  Queries keep answering from the
        active tables until the successor has absorbed ``warmup`` stream
        mass (sum of ingested frequencies); the ingest that crosses the
        threshold cuts over: the successor's state becomes this
        endpoint's state wholesale and the old tables are freed.

        Linear mode only -- conservative tables are excluded from every
        migration consumer (auto-tuning, re-meshing) and refused here via
        the same guard as the sharded surfaces.  One migration at a time.
        """
        from repro.core.distributed import require_linear
        from repro.serving.migration import SpecMigration

        require_linear(self.mode, "SketchTopKEndpoint.begin_migration")
        if self._migration is not None:
            raise ValueError(
                "a spec migration is already in flight "
                f"({self._migration.progress:.0%} of warmup); one at a time")
        incoming = SketchTopKEndpoint(
            new_spec, key,
            max_candidates_per_group=self.max_candidates,
            use_kernel=self.use_kernel,
            use_update_kernel=self._use_update_kernel,
            dtype=self.state.states[0].table.dtype, mode="linear")
        self._migration = SpecMigration(incoming, warmup)

    def _cutover(self) -> None:
        """Adopt the successor's state wholesale; free the old tables.

        After this, the endpoint is bit-identical to a fresh endpoint
        built on the new spec (same key) and fed exactly the blocks since
        ``begin_migration`` -- the successor IS that endpoint.  ``total``
        restarts at the post-warmup-start mass: estimates and totals
        describe the same (new) stream window, which is what the top-k
        descent's threshold scaling assumes.
        """
        inc = self._migration.incoming
        self._migration = None
        self.hspec = inc.hspec
        self._kh = inc._kh
        self._state = inc._state
        self._pools = inc._pools
        self.total = inc.total
        # old tables/pools: last references dropped above -> freed

    def heavy_hitters(self, threshold: int,
                      candidates: Optional[List[np.ndarray]] = None,
                      ) -> Tuple[np.ndarray, np.ndarray]:
        if candidates is None:
            candidates = self.candidates()
        return self._hh.find_heavy_hitters(
            self.hspec, self.state, threshold, candidates,
            use_kernel=self.use_kernel)

    def topk(self, k: int,
             min_threshold: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k by estimate: geometric threshold descent until k found.

        See :func:`repro.serving.sharded_topk.threshold_descent_topk` (the
        descent is shared with the sharded service) for the
        ``min_threshold`` semantics.  Candidates are hoisted: the pools
        don't change mid-descent.
        """
        from repro.serving.sharded_topk import threshold_descent_topk

        return threshold_descent_topk(
            self.heavy_hitters, self.candidates(), k, total=self.total,
            n_modules=self.hspec.base.schema.modularity,
            min_threshold=min_threshold)

    def to_sharded(self, mesh, *, data_axes=None,
                   sync_every: Optional[int] = 1,
                   ) -> "object":
        """Promote this single-shard endpoint to a ShardedTopKService.

        Carries over the hierarchy tables, hash params, candidate pools,
        and stream total; subsequent ingest runs sharded over the mesh.
        Linear endpoints only: a conservative endpoint's tables are not
        linear in the stream and must never enter the psum sync path, so
        promotion is refused (same contract as merge_from).
        """
        from repro.core.sketch import SketchState
        from repro.core.summary import SpaceSaving
        from repro.serving.migration import require_not_migrating
        from repro.serving.sharded_topk import ShardedTopKService

        require_not_migrating(self._migration,
                              "SketchTopKEndpoint.to_sharded")
        if self.mode != "linear":
            raise ValueError(
                "to_sharded is only defined for linear endpoints: "
                "conservative tables cannot be psum-merged, so a "
                "conservative endpoint must stay single-shard")
        svc = ShardedTopKService(
            self.hspec.base, jax.random.PRNGKey(0), mesh,
            data_axes=data_axes,
            max_candidates_per_group=self.max_candidates,
            sync_every=sync_every, use_kernel=self.use_kernel,
            dtype=self.state.states[0].table.dtype)
        # the service's freshly drawn params are discarded: the promoted
        # state keeps this endpoint's params so existing tables stay valid.
        # Tables are COPIED, not aliased: the endpoint's ingest path
        # donates its table buffers (hierarchy.update_jit), so a later
        # ep.ingest() would delete buffers the service still reads.
        # Params are never donated, so sharing them is safe.
        state = self.state
        svc.merged = self._hh.HierarchyState(states=tuple(
            SketchState(params=st.params, table=jnp.array(st.table))
            for st in state.states))
        svc.total = self.total
        svc._shard_pools[0] = [SpaceSaving.fold([p]) for p in self._pools]
        svc._global_pools = [SpaceSaving.fold([p]) for p in self._pools]
        return svc

    def merge_from(self, other: "SketchTopKEndpoint") -> None:
        """Fold another endpoint's sketch + pools in (cross-shard merge).

        Only defined for linear endpoints: conservative tables are not
        linear in the stream, so a cell-wise sum of two conservatively
        built hierarchies is not the hierarchy of the union stream --
        conservative endpoints are single-shard by construction and
        rejected here (both directions).

        Shards must share the base spec and hash parameters (same spec +
        PRNG key): cell-wise sums of tables hashed with different params --
        or with the same params but permuted partition axes -- are garbage,
        so mismatches are rejected rather than silently accepted.
        """
        from repro.serving.migration import require_not_migrating

        require_not_migrating(self._migration,
                              "SketchTopKEndpoint.merge_from")
        require_not_migrating(other._migration,
                              "SketchTopKEndpoint.merge_from (source side)")
        if self.mode != "linear" or other.mode != "linear":
            raise ValueError(
                "merge_from is only defined for linear endpoints: "
                "conservative tables cannot be merged cell-wise")
        if self.hspec.base != other.hspec.base:
            raise ValueError(
                "merge_from requires identical base specs on both endpoints")
        for sa, sb in zip(self.state.states, other.state.states):
            if not (np.array_equal(np.asarray(sa.params.q), np.asarray(sb.params.q))
                    and np.array_equal(np.asarray(sa.params.r), np.asarray(sb.params.r))):
                raise ValueError(
                    "merge_from requires identical hash params on both "
                    "endpoints (build them from the same spec and key)")
        self.state = self._hh.merge(self.state, other.state)
        self.total += other.total
        for mine, theirs in zip(self._pools, other._pools):
            mine.merge_from(theirs)
