"""Compatibility shim: the serving engine split into model + sketch halves.

``repro.serving.engine`` used to hold both the LLM serving engine and the
streaming sketch endpoint in one module.  They now live in

  * serving/model_engine.py -- ServeConfig, ServeEngine, Request,
    SlotScheduler (token generation, KV-cache decode slots);
  * serving/sketch_engine.py -- SketchTopKEndpoint plus the async
    SketchServeEngine (pipelined ingest, snapshot queries, batched
    descent);

behind the shared submit/flush protocol of serving/protocol.py.  This
module re-exports every pre-split name verbatim so existing imports keep
working; new code should import from the split modules directly.
"""
from __future__ import annotations

from repro.serving.model_engine import (
    PyTree,
    Request,
    ServeConfig,
    ServeEngine,
    SlotScheduler,
)
from repro.serving.sketch_engine import SketchTopKEndpoint

__all__ = [
    "PyTree",
    "Request",
    "ServeConfig",
    "ServeEngine",
    "SlotScheduler",
    "SketchTopKEndpoint",
]
