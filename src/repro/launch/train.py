"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant training loop on the local devices (full-config
lowering at production scale is the dry-run's job; this driver actually
executes steps, so defaults target the reduced configs / small models).
The MOD-Sketch n-gram statistics run inside the step; checkpoints restart
automatically via the Supervisor.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_reduced
from repro.core import sketch as sk
from repro.training import train_loop as tl
from repro.training.grad_compression import CompressionConfig
from repro.training.optimizer import OptimizerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--full", action="store_true",
                    help="full config (default: reduced smoke config)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--no-sketch", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    tcfg = tl.TrainConfig(
        optimizer=OptimizerConfig(lr=args.lr, total_steps=args.steps,
                                  warmup_steps=max(1, args.steps // 20)),
        microbatches=args.microbatches,
        sketch_enabled=not args.no_sketch,
        compression=CompressionConfig(enabled=args.grad_compression),
    )
    print(f"arch={cfg.name} params~{cfg.param_count()['total']:,} "
          f"steps={args.steps} batch={args.batch}x{args.seq}")
    t0 = time.perf_counter()
    state, history = tl.train(cfg, tcfg, args.steps, args.batch, args.seq,
                              jax.random.PRNGKey(args.seed),
                              ckpt_dir=args.ckpt_dir)
    dt = time.perf_counter() - t0
    losses = history["loss"]
    print(f"done in {dt:.1f}s; loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if tcfg.sketch_enabled:
        spec = tl.make_sketch_spec(cfg)
        st = sk.SketchState(params=state["sketch_params"],
                            table=state["sketch_table"])
        # top bigram frequency probe
        toks = tl.synthetic_batches(cfg, args.batch, args.seq)(0)["tokens"]
        grams = np.stack([toks[:, :-1].reshape(-1), toks[:, 1:].reshape(-1)],
                         axis=1).astype(np.uint32)[:8]
        est = np.asarray(sk.query_jit(spec, st, jnp.asarray(grams)))
        print("sketch n-gram estimates (first batch bigrams):", est.tolist())


if __name__ == "__main__":
    main()
