"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first backend init, and only
dryrun.py sets the 512-device host-platform flag).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) for two."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale tests (requires >= prod(shape) devices)."""
    return jax.make_mesh(shape, axes)


def sketch_data_axes(mesh) -> tuple:
    """Data-parallel axes for sketch serving on any of the meshes above.

    Sketch ingest shards the *stream*, never the table rows, so every axis
    except "model" is a data axis: ("data",) on the single pod / test mesh,
    ("pod", "data") on the two-pod mesh.  Used by the sharded serving
    dry-run cells and ShardedTopKService's default."""
    return tuple(a for a in mesh.axis_names if a != "model")
