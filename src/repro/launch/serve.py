"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Boots the batched engine (prefill + decode with KV/SSM caches) on local
devices and runs a synthetic batched-request workload through the slot
scheduler, reporting decode throughput.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config, get_reduced
from repro.models import transformer as tfm
from repro.serving.engine import Request, ServeConfig, ServeEngine, SlotScheduler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(args.seed))
    scfg = ServeConfig(max_len=args.prompt_len + args.max_new + 8,
                       temperature=args.temperature)
    engine = ServeEngine(cfg, params, scfg, seed=args.seed)
    sched = SlotScheduler(engine, n_slots=args.slots)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=(args.prompt_len,)).astype(np.int32)
        sched.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s decode incl. prefill)")
    print("sample output:", done[0].out[:8])


if __name__ == "__main__":
    main()
