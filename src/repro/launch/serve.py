"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Boots the batched engine (prefill + decode with KV/SSM caches) on local
devices and runs a synthetic batched-request workload through the slot
scheduler, reporting decode throughput.

``--sketch-autotune`` runs the other serving stack instead: a
SketchTopKEndpoint under an online AutoTuner, fed a module-skew-flip
stream (streams.dstream.skew_flip_batches).  The tuner derives live
stats from the endpoint's own pools/tables, re-runs the greedy strategy
search, and hot-migrates the endpoint to the re-drawn spec through a
double-write warmup window -- the launcher reports every tune decision
and the final heavy-hitter error of the migrated endpoint next to a
stale (never-retuned) twin fed the same stream.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def run_sketch_autotune(args) -> None:
    from repro.core import sketch as sk
    from repro.core.hashing import KeySchema
    from repro.serving.autotune import AutoTuner
    from repro.serving.sketch_engine import (SketchServeEngine,
                                             SketchTopKEndpoint)
    from repro.streams import skew_flip_batches
    from repro.streams.stats import topk_point_are

    domains = (args.domain, args.domain)
    schema = KeySchema(domains=domains)
    key = jax.random.PRNGKey(args.seed)

    # Deliberately stale spec: ranges tuned for a skewed module 0 / wide
    # module 1 -- the stream flips that halfway through.
    h = args.sketch_h
    stale = sk.mod_sketch_spec(schema, [(0,), (1,)],
                               (max(2, h // 64), 64), args.sketch_w)
    live = SketchTopKEndpoint(stale, key)
    tuner = AutoTuner(live, jax.random.fold_in(key, 1),
                      retune_every=args.retune_every, warmup=args.warmup,
                      min_improvement=args.min_improvement, sample_k=256,
                      min_threshold=1, search=args.search)
    # the tuner plugs into the serving engine at exactly one place: it
    # ticks on every sync() (snapshot boundary), so retune decisions --
    # and the migrations they open -- happen between pipelined blocks,
    # never against half-folded tables
    engine = SketchServeEngine(live, max_staleness=None, tuner=tuner)

    batches = list(skew_flip_batches(domains, args.batches,
                                     args.rows_per_batch, seed=args.seed))
    window_start = 0          # first batch the CURRENT tables have seen
    t0 = time.perf_counter()
    for b, batch in enumerate(batches):
        n_prev = len(tuner.decisions)
        engine.ingest(batch.items, batch.freqs)
        engine.sync()
        d = tuner.decisions[-1] if len(tuner.decisions) > n_prev else None
        if d is not None:
            print(f"[batch {b:3d} total={d.at_total:,}] {d.reason}: "
                  f"sigma {d.sigma_current:.2f} -> {d.sigma_proposed:.2f}"
                  + (f" ranges {d.proposed_ranges}" if d.migrated else ""))
        if d is not None and d.migrated:
            # the successor starts absorbing from the NEXT ingest; after
            # cutover the endpoint's window starts here
            window_start = b + 1
        if live.migrating:
            print(f"[batch {b:3d}] warmup {live.migration_progress:.0%}")
    dt = time.perf_counter() - t0

    # Post-cutover the endpoint describes its post-migration window, so
    # score it against that window's exact counts -- and against a twin
    # endpoint on the STALE spec fed exactly the same window, isolating
    # the spec effect (same comparison as benchmarks/migrate_bench.py).
    frozen = SketchTopKEndpoint(stale, key)
    exact: dict = {}
    for batch in batches[window_start:]:
        frozen.ingest(batch.items, batch.freqs)
        for it, f in zip(batch.items.tolist(), batch.freqs.tolist()):
            exact[tuple(it)] = exact.get(tuple(it), 0) + f
    top = sorted(exact.items(), key=lambda kv: -kv[1])[:args.topk]
    q = np.array([k for k, _ in top], dtype=np.uint32)
    true = np.array([v for _, v in top], dtype=np.int64)

    def are(ep):
        # twin scoring shared with the DStream harness (streams/stats.py)
        return topk_point_are(ep.hspec, ep.state, q, true)

    print(f"\n{args.batches} batches in {dt:.2f}s; "
          f"migrations={sum(d.migrated for d in tuner.decisions)} "
          f"(spec now partition={live.hspec.base.partition} "
          f"ranges={live.hspec.base.ranges})")
    print(f"window batches [{window_start}:{len(batches)}] "
          f"top-{args.topk} ARE  auto-tuned={are(live):.4f}  "
          f"stale={are(frozen):.4f}")


def run_model_serving(args) -> None:
    from repro.configs import get_config, get_reduced
    from repro.models import transformer as tfm
    from repro.serving.model_engine import (Request, ServeConfig, ServeEngine,
                                            SlotScheduler)

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(args.seed))
    scfg = ServeConfig(max_len=args.prompt_len + args.max_new + 8,
                       temperature=args.temperature)
    engine = ServeEngine(cfg, params, scfg, seed=args.seed)
    sched = SlotScheduler(engine, n_slots=args.slots)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=(args.prompt_len,)).astype(np.int32)
        sched.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s decode incl. prefill)")
    print("sample output:", done[0].out[:8])


def main() -> None:
    from repro.configs import ARCHS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS,
                    help="model arch to serve (omit with --sketch-autotune)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # sketch auto-tune mode
    ap.add_argument("--sketch-autotune", action="store_true",
                    help="serve a sketch endpoint under the online "
                         "auto-tuner over a skew-flip drift stream")
    ap.add_argument("--domain", type=int, default=1 << 16)
    ap.add_argument("--batches", type=int, default=24)
    ap.add_argument("--rows-per-batch", type=int, default=4_000)
    ap.add_argument("--sketch-h", type=int, default=4_096)
    ap.add_argument("--sketch-w", type=int, default=4)
    ap.add_argument("--retune-every", type=int, default=20_000)
    ap.add_argument("--warmup", type=int, default=8_000)
    ap.add_argument("--min-improvement", type=float, default=0.9)
    ap.add_argument("--search", choices=("greedy", "ranges"),
                    default="ranges")
    ap.add_argument("--topk", type=int, default=32)
    args = ap.parse_args()

    if args.sketch_autotune:
        run_sketch_autotune(args)
    else:
        if args.arch is None:
            ap.error("--arch is required unless --sketch-autotune is set")
        run_model_serving(args)


if __name__ == "__main__":
    main()
