import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the 512
host-platform placeholder devices let ``jax.make_mesh`` build the production
meshes -- (16,16) ("data","model") single pod and (2,16,16)
("pod","data","model") for two pods -- and XLA:CPU compiles the fully
partitioned SPMD module, surfacing sharding mismatches, compile-time OOMs,
and unsupported collectives exactly as a TPU lowering would.

Per cell we record ``memory_analysis()`` (fits-per-chip proof),
``cost_analysis()`` (FLOPs / bytes for SRoofline), and the collective mix
parsed from the optimized HLO.  Results go to JSON (one file per cell,
resumable); EXPERIMENTS.md SDry-run/SRoofline read from them.

Usage:
    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
    python -m repro.launch.dryrun --all --variant <name>   # SPerf knobs
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import hlo_analysis as ha
from repro import roofline as rl
from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh, make_test_mesh, \
    sketch_data_axes
from repro.models import shard_ctx
from repro.models import sharding as shd
from repro.models import transformer as tfm
from repro.training import train_loop as tl

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


# --------------------------------------------------------------------------
# SPerf variants: config/sharding transformations exercised by hillclimbing.
# Each entry may transform the ModelConfig and/or flags read below.
# --------------------------------------------------------------------------
VARIANTS: Dict[str, Dict[str, Any]] = {
    "baseline": {},
    # hillclimb knobs (see EXPERIMENTS.md SPerf for the iteration log)
    "noremat": {"cfg": {"remat": False}},
    "attn_chunk_512": {"cfg": {"attn_chunk": 512}},
    "attn_chunk_2048": {"cfg": {"attn_chunk": 2048}},
    "ssm_chunk_256": {"cfg": {"ssm_chunk": 256}},
    "ssm_chunk_512": {"cfg": {"ssm_chunk": 512}},
    "no_sketch": {"sketch": False},
    "cap_factor_1": {"cfg": {"capacity_factor": 1.0}},
    "loss_chunk512": {"cfg": {"loss_chunk": 512}},
    "moe_local": {"cfg": {"moe_dispatch": "local"}},
    "moe_local_lc": {"cfg": {"moe_dispatch": "local", "loss_chunk": 512}},
    "mamba_opt": {"cfg": {"loss_chunk": 512, "ssm_chunk": 256}},
    "mamba_opt2": {"cfg": {"loss_chunk": 512, "ssm_chunk": 512}},
    "moe_local_v2": {"cfg": {"moe_dispatch": "local"}},
    "moe_local_v2_lc": {"cfg": {"moe_dispatch": "local", "loss_chunk": 512}},
    "moe_local_cap1": {"cfg": {"moe_dispatch": "local", "capacity_factor": 1.0}},
    "moe_local_fshard": {"cfg": {"moe_dispatch": "local",
                                 "moe_weight_shard": "f_allaxes"}},
    "moe_best": {"cfg": {"moe_dispatch": "local", "capacity_factor": 1.0,
                         "moe_weight_shard": "f_allaxes"}},
    "moe_ep": {"cfg": {"moe_dispatch": "ep_shardmap"}},
    "moe_2d_global": {"cfg": {"moe_dispatch": "global"}},  # original baseline
    "moe_ep_cap1": {"cfg": {"moe_dispatch": "ep_shardmap",
                            "capacity_factor": 1.0}},
    "vocab_pad": {"cfg": {"vocab_pad_multiple": 256}},
    "mamba_best": {"cfg": {"vocab_pad_multiple": 256, "loss_chunk": 512}},
}


def _apply_variant(cfg, variant: str):
    v = VARIANTS[variant]
    if "cfg" in v:
        cfg = dataclasses.replace(cfg, **{k: val for k, val in v["cfg"].items()
                                          if hasattr(cfg, k)})
    return cfg, v


def _replicated_like(tree):
    return jax.tree.map(lambda _: P(), tree)


def _compiled_stats(compiled):
    """cost_analysis / memory_analysis / optimized HLO of a compiled cell
    (shared by the model cells and the sketch-serving cells)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    mem = compiled.memory_analysis()
    mem_d: Dict[str, float] = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if mem is not None and hasattr(mem, attr):
            mem_d[attr] = float(getattr(mem, attr))
    return cost, mem_d, compiled.as_text()


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    variant: str = "baseline",
) -> Dict[str, Any]:
    cfg = get_config(arch)
    cfg, vflags = _apply_variant(cfg, variant)
    if not vflags.get("sketch", True):
        pass  # handled through TrainConfig below
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.size
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]

    t0 = time.perf_counter()
    if kind == "train":
        tcfg = sp.default_train_config(cfg)
        if not vflags.get("sketch", True):
            tcfg = dataclasses.replace(tcfg, sketch_enabled=False)
        state_sds = sp.train_state_specs(cfg, tcfg)
        batch_sds = sp.batch_input_specs(cfg, b, s)

        pspecs = shd.param_specs(cfg, state_sds["params"], mesh)
        state_specs: Dict[str, Any] = {
            "params": pspecs,
            "opt": shd.opt_state_specs(cfg, state_sds["opt"], pspecs, mesh),
        }
        if tcfg.sketch_enabled:
            state_specs["sketch_params"] = _replicated_like(
                state_sds["sketch_params"])
            state_specs["sketch_table"] = P()
        bspecs = shd.sanitize_specs(
            shd.batch_specs(cfg, mesh, "embeds" in batch_sds), batch_sds, mesh)

        state_sh = shd.to_shardings(mesh, state_specs)
        batch_sh = shd.to_shardings(mesh, bspecs)
        step = tl.make_train_step(cfg, tcfg)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
        with shard_ctx.activation_sharding(mesh):
            lowered = fn.lower(state_sds, batch_sds)
    elif kind == "prefill":
        params_sds = jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                                    jax.random.PRNGKey(0))
        batch_sds = sp.batch_input_specs(cfg, b, s)
        pspecs = shd.param_specs(cfg, params_sds, mesh)
        cache_sds = jax.eval_shape(
            lambda p, t, e: tfm.prefill(cfg, p, t, embeds=e, max_len=None)[1],
            params_sds, batch_sds["tokens"], batch_sds.get("embeds"))
        cspecs = shd.cache_specs(cfg, cache_sds, mesh, b)
        bspecs = shd.sanitize_specs(
            shd.batch_specs(cfg, mesh, "embeds" in batch_sds), batch_sds, mesh)
        fn = jax.jit(
            lambda p, batch: tfm.prefill(cfg, p, batch["tokens"],
                                         embeds=batch.get("embeds"),
                                         max_len=None),
            in_shardings=(shd.to_shardings(mesh, pspecs),
                          shd.to_shardings(mesh, bspecs)),
            out_shardings=(None, shd.to_shardings(mesh, cspecs)),
        )
        with shard_ctx.activation_sharding(mesh):
            lowered = fn.lower(params_sds, batch_sds)
    else:  # decode
        params_sds = jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                                    jax.random.PRNGKey(0))
        din = sp.decode_input_specs(cfg, b, s)
        pspecs = shd.param_specs(cfg, params_sds, mesh)
        cspecs = shd.cache_specs(cfg, din["cache"], mesh, b)
        dp_axes, _ = shd.mesh_axes(mesh)
        dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        tok_spec = P(dp, None) if b >= mesh.shape[dp_axes[0]] else P(None, None)
        fn = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(cfg, p, c, t, pos),
            in_shardings=(shd.to_shardings(mesh, pspecs),
                          shd.to_shardings(mesh, cspecs),
                          NamedSharding(mesh, tok_spec),
                          NamedSharding(mesh, P())),
            out_shardings=(None, shd.to_shardings(mesh, cspecs)),
            donate_argnums=(1,),
        )
        with shard_ctx.activation_sharding(mesh):
            lowered = fn.lower(params_sds, din["cache"], din["tokens_last"],
                               din["pos"])
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    cost, mem_d, hlo = _compiled_stats(compiled)
    model_flops = rl.model_flops_for(cfg, kind, b, s)
    hcost = ha.analyze(hlo)  # loop-aware: scan bodies x trip counts
    top_bytes = dict(sorted(hcost.bytes_by_op.items(),
                            key=lambda kv: -kv[1])[:10])
    roof = rl.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_chip=hcost.flops,
        hbm_bytes_per_chip=hcost.bytes,
        wire_bytes_per_chip=hcost.coll_wire_bytes,
        model_flops=model_flops,
        collectives={"counts": hcost.coll_counts,
                     "result_bytes": hcost.coll_bytes,
                     "wire_bytes": hcost.coll_wire_bytes},
    )

    out = {
        **roof.as_dict(),
        "variant": variant,
        "kind": kind,
        "global_batch": b,
        "seq_len": s,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory_analysis": mem_d,
        "cost_flops": float(cost.get("flops", 0.0)),
        "cost_bytes": float(cost.get("bytes accessed", 0.0)),
        "n_params_total": cfg.param_count()["total"],
        "n_params_active": cfg.param_count()["active"],
        "bytes_by_op": top_bytes,
    }
    return out


def cell_path(arch: str, shape: str, mesh_name: str, variant: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}__{variant}.json")


def run_cells(archs, shapes, meshes, variant: str, skip_existing: bool = True):
    summary = []
    for multi_pod in meshes:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        for arch in archs:
            cfg = get_config(arch)
            for shape in shapes:
                if not shape_applicable(cfg, shape):
                    print(f"SKIP {arch} x {shape} (inapplicable: "
                          f"{'needs sub-quadratic decode' if shape == 'long_500k' else '?'})",
                          flush=True)
                    continue
                path = cell_path(arch, shape, mesh_name, variant)
                if skip_existing and os.path.exists(path):
                    print(f"HAVE {arch} x {shape} x {mesh_name}", flush=True)
                    continue
                print(f"CELL {arch} x {shape} x {mesh_name} ...", flush=True)
                try:
                    res = lower_cell(arch, shape, multi_pod, variant)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    print(f"  ok: compile={res['compile_s']:.1f}s "
                          f"bottleneck={res['bottleneck']} "
                          f"t=({res['t_compute_s']:.2e},{res['t_memory_s']:.2e},"
                          f"{res['t_collective_s']:.2e})s "
                          f"mem={res['memory_analysis']}", flush=True)
                    summary.append(res)
                except Exception as e:
                    err = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "variant": variant, "error": str(e),
                           "traceback": traceback.format_exc()}
                    with open(path + ".err", "w") as f:
                        json.dump(err, f, indent=1)
                    print(f"  FAIL: {type(e).__name__}: {str(e)[:300]}",
                          flush=True)
    return summary


# --------------------------------------------------------------------------
# Sketch-serving cells: the sharded heavy-hitter pipeline lowered on the
# production meshes (and the CI-scale test mesh), alongside the model cells.
# Three lowered units cover the ShardedTopKService data path
# (serving/sharded_topk.py):
#   sketch_ingest -- per-shard lazy fold of one stream block into every
#                    hierarchy level (no collective; the ingest hot path),
#   sketch_sync   -- the explicit psum sync point merging the per-shard
#                    level tables (the only collective in the pipeline),
#   sketch_build  -- synchronous fold + psum in one program
#                    (core.hierarchy.sharded_hierarchy_build).
# The descent itself is a host-driven loop over batched queries and is
# exercised by tests/benchmarks, not lowered as one XLA program.
# --------------------------------------------------------------------------

SKETCH_CELLS = ("sketch_ingest", "sketch_sync", "sketch_build")
SKETCH_MESHES = ("pod16x16", "pod2x16x16", "test2x2")
SKETCH_BATCH = 1 << 20          # rows per ingested block (global)


def _sketch_mesh(mesh_kind: str):
    if mesh_kind == "test2x2":
        return make_test_mesh()
    return make_production_mesh(multi_pod=(mesh_kind == "pod2x16x16"))


def lower_sketch_cell(cell: str, mesh_kind: str,
                      batch: int = SKETCH_BATCH) -> Dict[str, Any]:
    from repro.core import distributed as dist
    from repro.core import hierarchy as hhm
    from repro.core import sketch as sks
    from repro.core.hashing import KeySchema

    mesh = _sketch_mesh(mesh_kind)
    data_axes = sketch_data_axes(mesh)
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    b = max(batch // n_shards, 1) * n_shards

    # telemetry-shaped keys: two 32-bit modules (edge / routed-token pairs)
    schema = KeySchema(domains=(1 << 32, 1 << 32))
    base = sks.mod_sketch_spec(schema, [(0,), (1,)], (512, 512), 4)
    hspec = hhm.HierarchySpec.from_spec(base)
    state = hhm.init_hierarchy(hspec, jax.random.PRNGKey(0))
    params = tuple(st.params for st in state.states)
    local_sds = tuple(
        jax.ShapeDtypeStruct((n_shards,) + st.table.shape, st.table.dtype)
        for st in state.states)
    items_sds = jax.ShapeDtypeStruct((b, 2), jnp.uint32)
    freqs_sds = jax.ShapeDtypeStruct((b,), jnp.int32)

    t0 = time.perf_counter()
    if cell == "sketch_ingest":
        fn = jax.jit(lambda local, it, fr: dist.lazy_hierarchy_update(
            hspec, mesh, data_axes, local, params, it, fr))
        lowered = fn.lower(local_sds, items_sds, freqs_sds)
    elif cell == "sketch_sync":
        fn = jax.jit(lambda local: dist.merge_local_hierarchy(
            mesh, data_axes, local))
        lowered = fn.lower(local_sds)
    elif cell == "sketch_build":
        fn = jax.jit(lambda st_, it, fr: hhm.sharded_hierarchy_build(
            hspec, st_, mesh, data_axes, it, fr))
        state_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        lowered = fn.lower(state_sds, items_sds, freqs_sds)
    else:
        raise ValueError(f"unknown sketch cell {cell!r}")
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    cost, mem_d, hlo = _compiled_stats(compiled)
    hcost = ha.analyze(hlo)
    return {
        "cell": cell,
        "mesh": mesh_kind,
        "chips": mesh.size,
        "n_shards": n_shards,
        "data_axes": list(data_axes),
        "batch": b,
        "levels": hspec.n_levels,
        "table_cells": hspec.table_cells,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "flops_per_chip": hcost.flops,
        "hbm_bytes_per_chip": hcost.bytes,
        "collectives": {"counts": hcost.coll_counts,
                        "result_bytes": hcost.coll_bytes,
                        "wire_bytes": hcost.coll_wire_bytes},
        "memory_analysis": mem_d,
        "cost_flops": float(cost.get("flops", 0.0)),
        "cost_bytes": float(cost.get("bytes accessed", 0.0)),
    }


def sketch_cell_path(cell: str, mesh_kind: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"sketch__{cell}__{mesh_kind}.json")


def run_sketch_cells(skip_existing: bool = True):
    summary = []
    for mesh_kind in SKETCH_MESHES:
        for cell in SKETCH_CELLS:
            path = sketch_cell_path(cell, mesh_kind)
            if skip_existing and os.path.exists(path):
                print(f"HAVE {cell} x {mesh_kind}", flush=True)
                continue
            print(f"CELL {cell} x {mesh_kind} ...", flush=True)
            try:
                res = lower_sketch_cell(cell, mesh_kind)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                coll = res["collectives"]["counts"]
                print(f"  ok: compile={res['compile_s']:.1f}s "
                      f"shards={res['n_shards']} collectives={coll} "
                      f"mem={res['memory_analysis']}", flush=True)
                summary.append(res)
            except Exception as e:
                err = {"cell": cell, "mesh": mesh_kind, "error": str(e),
                       "traceback": traceback.format_exc()}
                with open(path + ".err", "w") as f:
                    json.dump(err, f, indent=1)
                print(f"  FAIL: {type(e).__name__}: {str(e)[:300]}",
                      flush=True)
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--sketch-cells", action="store_true",
                    help="lower the sharded sketch-serving cells "
                         "(ingest/sync/build on every mesh) instead of the "
                         "model cells")
    args = ap.parse_args()

    try:  # persistent compilation cache speeds up resumed sweeps
        jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 10)
    except Exception:
        pass

    if args.sketch_cells:
        run_sketch_cells(skip_existing=not args.force)
        return

    archs = ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]
    run_cells(archs, shapes, meshes, args.variant,
              skip_existing=not args.force)


if __name__ == "__main__":
    main()
