"""ShapeDtypeStruct stand-ins for every lowered step's inputs.

No device allocation ever happens here: parameter/optimizer/cache trees come
from ``jax.eval_shape`` over the real init functions, so the dry-run lowers
the exact structures the runtime would use.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.training import train_loop as tl
from repro.training.optimizer import OptimizerConfig

PyTree = Any


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_state_specs(cfg: ModelConfig, tcfg: tl.TrainConfig) -> PyTree:
    """eval_shape of init_train_state: params + opt (+ sketch) shapes."""
    return jax.eval_shape(
        lambda k: tl.init_train_state(cfg, tcfg, k), jax.random.PRNGKey(0))


def batch_input_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Token batch (+ stub frontend embeddings) for one train/prefill step."""
    out: Dict[str, Any] = {}
    if cfg.n_enc_layers:
        # enc-dec: seq budget split between source frames and target tokens
        s_dec = max(2, seq // 2)
        out["tokens"] = sds((batch, s_dec), jnp.int32)
        out["embeds"] = sds((batch, seq - s_dec, cfg.d_model), cfg.activation_dtype)
    elif cfg.frontend:
        s_text = max(2, seq - cfg.frontend_len)
        out["tokens"] = sds((batch, s_text), jnp.int32)
        out["embeds"] = sds((batch, cfg.frontend_len, cfg.d_model),
                            cfg.activation_dtype)
    else:
        out["tokens"] = sds((batch, seq), jnp.int32)
    return out


def decode_cache_specs(cfg: ModelConfig, batch: int, seq: int) -> PyTree:
    enc_len = cfg.frontend_len if cfg.n_enc_layers else 0
    return jax.eval_shape(
        lambda: tfm.init_cache(cfg, batch, seq, enc_len=enc_len))


def decode_input_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    return {
        "cache": decode_cache_specs(cfg, batch, seq),
        "tokens_last": sds((batch, 1), jnp.int32),
        "pos": sds((), jnp.int32),
    }


def input_specs(arch: str, shape_name: str,
                tcfg: Optional[tl.TrainConfig] = None) -> Dict[str, Any]:
    """All ShapeDtypeStruct inputs for one (arch x shape) dry-run cell."""
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    tcfg = tcfg or default_train_config(cfg)
    kind = sh["kind"]
    if kind == "train":
        return {
            "kind": "train",
            "state": train_state_specs(cfg, tcfg),
            "batch": batch_input_specs(cfg, b, s),
        }
    if kind == "prefill":
        params = jax.eval_shape(
            lambda k: tfm.init_params(cfg, k), jax.random.PRNGKey(0))
        return {
            "kind": "prefill",
            "params": params,
            "batch": batch_input_specs(cfg, b, s),
        }
    # decode: one new token against a seq_len cache
    params = jax.eval_shape(
        lambda k: tfm.init_params(cfg, k), jax.random.PRNGKey(0))
    return {
        "kind": "decode",
        "params": params,
        **decode_input_specs(cfg, b, s),
    }


def default_train_config(cfg: ModelConfig) -> tl.TrainConfig:
    """Per-arch training defaults: int8 moments for >=100B-param models."""
    n = cfg.param_count()["total"]
    opt = OptimizerConfig(name="adamw8bit" if n > 60e9 else "adamw")
    return tl.TrainConfig(optimizer=opt)
