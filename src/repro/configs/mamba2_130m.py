"""mamba2-130m [ssm]: 24L d_model=768, attention-free SSD, vocab 50280,
ssm_state=128 [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,          # unused (attention-free); kept for uniform tooling
    n_kv_heads=12,
    d_ff=0,              # no MLP: pure Mamba2 blocks
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,     # d_inner = 2*768 = 1536 -> 24 SSD heads
    ssm_expand=2,
    ssm_chunk=128,
    tie_embeddings=True,
    norm_type="rmsnorm",
    sub_quadratic=True,  # O(1)-state decode: runs long_500k
)

REDUCED = ModelConfig(
    name="mamba2-130m-reduced",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_expand=2,
    ssm_chunk=16,
    tie_embeddings=True,
    sub_quadratic=True,
)
