"""command-r-35b [dense]: 40L d=8192 64H (GQA kv=8) d_ff=22528 vocab=256000,
no-bias, parallel attn||mlp blocks [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    mlp_type="swiglu",
    use_bias=False,
    parallel_block=True,
    tie_embeddings=True,
    norm_type="layernorm",
    rope_theta=8_000_000.0,
)

REDUCED = ModelConfig(
    name="command-r-35b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    mlp_type="swiglu",
    parallel_block=True,
    tie_embeddings=True,
    norm_type="layernorm",
)
