"""starcoder2-7b [dense]: 32L d=4608 36H (GQA kv=4) d_ff=18432 vocab=49152,
RoPE, LayerNorm + biases, gelu MLP [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    mlp_type="gelu",
    norm_type="layernorm",
    use_bias=True,
    rope_theta=100_000.0,
)

REDUCED = ModelConfig(
    name="starcoder2-7b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    mlp_type="gelu",
    norm_type="layernorm",
    use_bias=True,
)
