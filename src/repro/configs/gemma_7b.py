"""gemma-7b [dense]: 28L d=3072 16H (GQA kv=16, i.e. MHA on 7b; MQA is the
2b variant) d_ff=24576 GeGLU head_dim=256 vocab=256000 [arXiv:2403.08295]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp_type="geglu",
    embed_scale=True,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma-7b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=192,
    vocab_size=512,
    mlp_type="geglu",
    embed_scale=True,
    tie_embeddings=True,
)
