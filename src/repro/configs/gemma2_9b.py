"""gemma2-9b [dense]: 42L d=3584 16H (GQA kv=8) d_ff=14336 vocab=256000,
local(4096)+global alternating, attn softcap 50, logit softcap 30, GeGLU,
head_dim=256, post-norms [arXiv:2408.00118]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    mlp_type="geglu",
    embed_scale=True,
    tie_embeddings=True,
    sliding_window=4096,
    local_global_period=2,     # [local, global] pairs
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_block_norm=True,
)

REDUCED = ModelConfig(
    name="gemma2-9b-reduced",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    mlp_type="geglu",
    embed_scale=True,
    tie_embeddings=True,
    sliding_window=16,
    local_global_period=2,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_block_norm=True,
)
