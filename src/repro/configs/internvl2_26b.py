"""internvl2-26b [vlm]: InternLM2-20B backbone, 48L d=6144 48H (GQA kv=8)
d_ff=16384 vocab=92553 [arXiv:2404.16821].  InternViT frontend is a stub:
input_specs() provides precomputed patch embeddings (assignment note)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    frontend="patch",
    frontend_len=256,
)

REDUCED = ModelConfig(
    name="internvl2-26b-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    mlp_type="swiglu",
    frontend="patch",
    frontend_len=8,
)
