"""dbrx-132b [moe]: 40L d=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
fine-grained MoE 16 experts top-4 [hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    mlp_type="swiglu",
    norm_type="layernorm",
    n_experts=16,
    top_k=4,
    rope_theta=500_000.0,
    moe_dispatch="ep_shardmap",  # SPerf iteration 5: explicit shard_map EP
)

REDUCED = ModelConfig(
    name="dbrx-132b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=512,
    mlp_type="swiglu",
    norm_type="layernorm",
    n_experts=8,
    top_k=4,
)
