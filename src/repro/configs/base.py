"""Model configuration system: one frozen config per assigned architecture.

Families:
  dense   -- decoder-only transformer (GQA/MQA, RoPE, optional SWA /
             local-global alternation / softcaps / parallel blocks)
  moe     -- dense + mixture-of-experts FFN (top-k, capacity dispatch)
  ssm     -- attention-free Mamba2 (SSD) stack
  hybrid  -- Jamba-style interleave: 1 attention per `attn_period` layers,
             MoE on alternating layers
  vlm     -- dense decoder backbone; patch-embedding frontend is a stub
             (input_specs supplies precomputed patch embeddings)
  audio   -- encoder-decoder; frame-embedding frontend is a stub

The layer stack is organized in repeating *blocks* of ``block_period``
layers so heterogeneous stacks (gemma2 local/global pairs, jamba 8-layer
periods) scan over homogeneous stacked params (DESIGN.md S5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"         # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    use_bias: bool = False
    parallel_block: bool = False     # command-r style attn || mlp
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: embeddings * sqrt(d)
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention
    local_global_period: int = 0     # gemma2: alternate [local, global]
    attn_softcap: float = 0.0        # gemma2 tanh softcap on attn logits
    logit_softcap: float = 0.0       # gemma2 tanh softcap on final logits
    post_block_norm: bool = False    # gemma2 post-norms
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1               # MoE replaces MLP every k-th layer
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4
    attn_every: int = 0              # hybrid: attention at layer i % attn_every == attn_offset
    attn_offset: int = 0
    # --- encoder-decoder / frontends ---
    n_enc_layers: int = 0
    frontend: str = ""               # "" | patch | frame  (stub: embeds provided)
    frontend_len: int = 256          # prefix embeddings per sequence
    # --- numerics / runtime ---
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 1024           # blockwise-attention q-chunk for long seqs
    attn_chunk_threshold: int = 8192 # use blockwise attention above this seq len
    sub_quadratic: bool = False      # can run long_500k decode
    loss_chunk: int = 0              # chunked cross-entropy (tokens/chunk; 0=off)
    moe_dispatch: str = "global"     # global | local (per-DP-shard capacity)
    moe_weight_shard: str = "2d"     # 2d (D x dp, F x mp) | f_allaxes (F x dp*mp)
    vocab_pad_multiple: int = 1      # pad embedding rows so vocab shards on TP
    # --- sketch integration (the paper's feature, on by default) ---
    sketch_ngrams: int = 2
    sketch_width: int = 5
    sketch_range: int = 1 << 16

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.family not in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"):
            raise ValueError(f"unknown family {self.family}")
        if self.family != "ssm" and self.n_heads % max(1, self.n_kv_heads):
            raise ValueError("n_heads must be divisible by n_kv_heads")
        if self.block_period and self.n_layers % self.block_period:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"block period {self.block_period}"
            )

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = max(1, self.vocab_pad_multiple)
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def block_period(self) -> int:
        """Layers per scanned block (homogeneous repeating unit)."""
        if self.family == "hybrid":
            return self.attn_every or 8
        if self.local_global_period:
            return self.local_global_period
        if self.family == "moe" and self.moe_every > 1:
            return self.moe_every
        return 1

    @property
    def n_blocks(self) -> int:
        return self.n_layers // self.block_period

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def layer_kind(self, i: int) -> str:
        """Kind of layer i within a block: attn | mamba."""
        if self.family == "ssm":
            return "mamba"
        if self.family == "hybrid":
            return "attn" if i % self.block_period == self.attn_offset else "mamba"
        return "attn"

    def layer_window(self, i: int) -> int:
        """Sliding window for layer i (0 = full attention)."""
        if self.local_global_period:
            # even position in the period -> local (windowed), odd -> global
            return self.sliding_window if (i % self.local_global_period == 0) else 0
        return self.sliding_window

    def layer_is_moe(self, i: int) -> bool:
        if not self.n_experts:
            return False
        if self.family == "hybrid":
            return i % 2 == 1  # MoE on alternating layers (Jamba)
        return i % self.moe_every == 0

    # -- parameter count (for MODEL_FLOPS = 6*N*D roofline term) ----------
    def param_count(self) -> Dict[str, int]:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qo = self.n_heads * hd
        kv = self.n_kv_heads * hd
        attn = d * qo + 2 * d * kv + qo * d
        glu = 3 * d * f if self.mlp_type in ("swiglu", "geglu") else 2 * d * f
        moe = self.n_experts * glu if self.n_experts else 0
        moe_active = self.top_k * glu if self.n_experts else 0
        din = self.ssm_inner
        nheads = self.ssm_heads if self.ssm_state else 0
        mamba = (d * (2 * din + 2 * self.ssm_state + nheads)
                 + din * d + self.ssm_conv * (din + 2 * self.ssm_state)
                 + 2 * nheads + din) if self.ssm_state else 0

        total = active = 0
        n_dec = self.n_layers
        for i in range(n_dec):
            kind = self.layer_kind(i % max(1, self.block_period))
            if kind == "attn":
                total += attn
                active += attn
            else:
                total += mamba
                active += mamba
            if self.layer_is_moe(i % max(1, self.block_period)):
                total += moe + d * self.n_experts
                active += moe_active + d * self.n_experts
            elif f:
                total += glu
                active += glu
        for _ in range(self.n_enc_layers):
            total += attn + glu
            active += attn + glu
        if self.n_enc_layers:  # decoder cross-attention
            total += n_dec * attn
            active += n_dec * attn
        emb = v * d * (1 if self.tie_embeddings else 2)
        total += emb
        active += emb
        return {"total": total, "active": active}
