"""mixtral-8x22b [moe]: 56L d=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
8 experts top-2, SWA [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    mlp_type="swiglu",
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    moe_dispatch="ep_shardmap",  # SPerf iteration 5: explicit shard_map EP
)

REDUCED = ModelConfig(
    name="mixtral-8x22b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    mlp_type="swiglu",
    n_experts=4,
    top_k=2,
    sliding_window=16,
)
