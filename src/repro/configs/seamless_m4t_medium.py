"""seamless-m4t-medium [audio]: enc-dec, 12L each, d=1024 16H (MHA kv=16)
d_ff=4096 vocab=256206 [arXiv:2308.11596].  The speech frontend
(conformer feature extractor) is a stub per the assignment: input_specs()
provides precomputed frame embeddings for the encoder."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,          # decoder layers
    n_enc_layers=12,      # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    mlp_type="gelu",
    norm_type="layernorm",
    use_bias=True,
    frontend="frame",
    frontend_len=256,
)

REDUCED = ModelConfig(
    name="seamless-m4t-medium-reduced",
    family="audio",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    mlp_type="gelu",
    norm_type="layernorm",
    use_bias=True,
    frontend="frame",
    frontend_len=8,
)
