"""Architecture registry: ``get_config(arch)`` / ``get_reduced(arch)``.

All ten assigned architectures plus the paper's own workload are selectable
via ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES: Dict[str, str] = {
    "mamba2-130m": "repro.configs.mamba2_130m",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "command-r-35b": "repro.configs.command_r_35b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "gemma-7b": "repro.configs.gemma_7b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
}

ARCHS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    return importlib.import_module(_MODULES[arch]).REDUCED


# ---------------------------------------------------------------------------
# Assigned input shapes (LM-family: seq_len x global_batch).  decode_* and
# long_* lower serve_step (one token against a seq_len KV cache), not
# train_step; long_500k requires sub-quadratic decode (cfg.sub_quadratic).
# ---------------------------------------------------------------------------
SHAPES = {
    "train_4k": {"kind": "train", "seq_len": 4_096, "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32_768, "global_batch": 32},
    "decode_32k": {"kind": "decode", "seq_len": 32_768, "global_batch": 128},
    "long_500k": {"kind": "decode", "seq_len": 524_288, "global_batch": 1},
}


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """Which (arch x shape) cells run (skips recorded in DESIGN.md S7)."""
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True
