"""jamba-1.5-large-398b [hybrid]: 72L d=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, Mamba+attention 1:7 interleave, MoE 16e top-2 on alternating
layers [arXiv:2403.19887].

Hardware-adaptation note (DESIGN.md S4): Jamba's Mamba-1 layers are
implemented with the Mamba2/SSD chunked formulation -- same recurrence
shape, MXU-friendly (scalar-per-head A instead of per-channel); the
system-level compute/memory profile is preserved.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    mlp_type="swiglu",
    n_experts=16,
    top_k=2,
    attn_every=8,        # 1 attention layer per 8 (1:7 attn:mamba)
    attn_offset=4,
    ssm_state=16,
    ssm_head_dim=128,    # d_inner = 16384 -> 128 SSD heads
    ssm_expand=2,
    ssm_chunk=128,
    sub_quadratic=True,  # 1/8 attention layers: decode-time KV is tractable
    moe_dispatch="ep_shardmap",  # SPerf iteration 5: explicit shard_map EP
)

REDUCED = ModelConfig(
    name="jamba-1.5-large-398b-reduced",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    mlp_type="swiglu",
    n_experts=4,
    top_k=2,
    attn_every=8,
    attn_offset=4,
    ssm_state=8,
    ssm_head_dim=32,
    ssm_expand=2,
    ssm_chunk=16,
    sub_quadratic=True,
)
