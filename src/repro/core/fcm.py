"""FCM sketch (Thomas et al., ICDE'09) and FMOD = MOD-Sketch on FCM (SVI-E).

FCM augments Count-Min with frequency-aware hashing: a Misra-Gries counter
identifies heavy hitters online; high-frequency (HF) items are hashed into a
*smaller* subset of rows and low-frequency (LF) items into a larger one, the
subset chosen per item by two extra hashes computing an ``offset`` and a
``gap`` over the w rows.  This separates HF mass from LF cells and cuts the
error for the long tail.

FMOD keeps FCM's row-subset mechanism but replaces the per-row *cell* index
with MOD-Sketch composite indexing -- the paper's generalizability demo
(Fig. 10): FMOD < FCM < Count-Min in observed error.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.hashing import KeySchema, draw_hash_params_np, cw_hash_np


# --------------------------------------------------------------------------
# Batched Misra-Gries heavy-hitter counter (host side)
# --------------------------------------------------------------------------

class MisraGries:
    """Misra-Gries with batched (numpy) ingestion.

    Classic MG keeps k counters; on overflow it decrements all counters by the
    amount that empties at least one slot.  The batched variant ingests a
    chunk of (item, freq) pairs at once: it merges exact chunk counts into the
    counter set, then removes the smallest counters by subtracting the
    (size-k)-th largest value -- the same L1-decrement argument bounds the
    undercount by L/k, preserving the MG guarantee.
    """

    def __init__(self, k: int):
        self.k = int(k)
        self.counters: Dict[int, int] = {}
        self.total = 0

    def offer(self, keys: np.ndarray, freqs: np.ndarray) -> None:
        keys = np.asarray(keys, dtype=np.uint64)
        freqs = np.asarray(freqs, dtype=np.int64)
        uniq, inv = np.unique(keys, return_inverse=True)
        sums = np.bincount(inv, weights=freqs.astype(np.float64)).astype(np.int64)
        self.total += int(freqs.sum())
        for key, s in zip(uniq.tolist(), sums.tolist()):
            self.counters[key] = self.counters.get(key, 0) + s
        if len(self.counters) > self.k:
            vals = np.fromiter(self.counters.values(), dtype=np.int64)
            # subtract the value that leaves at most k strictly-positive slots
            cut = np.partition(vals, len(vals) - self.k - 1)[len(vals) - self.k - 1]
            self.counters = {
                key: v - cut for key, v in self.counters.items() if v > cut
            }

    def heavy_hitters(self) -> Dict[int, int]:
        return dict(self.counters)

    def is_heavy(self, keys: np.ndarray) -> np.ndarray:
        hh = self.counters
        return np.fromiter((int(k) in hh for k in np.asarray(keys, dtype=np.uint64)),
                           dtype=bool, count=len(keys))


def pack_keys(schema: KeySchema, items: np.ndarray) -> np.ndarray:
    """Injective uint64 packing of a full key (for MG bookkeeping only)."""
    out = np.zeros(items.shape[0], dtype=np.uint64)
    for m, d in enumerate(schema.domains):
        out = out * np.uint64(d) + items[:, m].astype(np.uint64)
    return out


# --------------------------------------------------------------------------
# FCM / FMOD
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FCMSpec:
    base: sk.SketchSpec          # cell indexing: CM-style for FCM, MOD for FMOD
    d_hf: int                    # rows used by heavy hitters
    d_lf: int                    # rows used by the tail
    mg_k: int                    # Misra-Gries capacity

    def __post_init__(self):
        if not (1 <= self.d_hf <= self.base.width and 1 <= self.d_lf <= self.base.width):
            raise ValueError("row subset sizes must be within [1, w]")


class FCMState(NamedTuple):
    params: sk.SketchParams
    table: jax.Array
    offset_qr: jax.Array     # uint32[2, C+1]: q-vector + r for the offset hash
    gap_qr: jax.Array        # uint32[2, C+1]


class FCM:
    """Stateful FCM/FMOD wrapper (MG classification is inherently sequential)."""

    def __init__(self, spec: FCMSpec, key: jax.Array, seed: int = 0):
        self.spec = spec
        base = spec.base
        self.params = sk.init_params(base, key)
        self.table = np.zeros((base.width, base.table_size), dtype=np.int64)
        rng = np.random.default_rng(seed)
        c = base.schema.total_chunks
        self._off_q = draw_hash_params_np(rng, (c,))
        self._off_r = int(draw_hash_params_np(rng, (1,))[0])
        self._gap_q = draw_hash_params_np(rng, (c,))
        self._gap_r = int(draw_hash_params_np(rng, (1,))[0])
        self.mg = MisraGries(spec.mg_k)

    # -- row subset ---------------------------------------------------------
    def _rows(self, items: np.ndarray, heavy: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(rows uint32[B, max_d], valid bool[B, max_d]) for each item."""
        base = self.spec.base
        chunks = base.schema.module_chunks_np(items)
        w = base.width
        off = cw_hash_np(chunks, self._off_q, self._off_r) % np.uint32(w)
        gap = cw_hash_np(chunks, self._gap_q, self._gap_r) % np.uint32(max(1, w - 1)) + np.uint32(1)
        d_item = np.where(heavy, self.spec.d_hf, self.spec.d_lf)
        max_d = max(self.spec.d_hf, self.spec.d_lf)
        j = np.arange(max_d, dtype=np.uint32)[None, :]
        rows = (off[:, None] + j * gap[:, None]) % np.uint32(w)
        valid = j < d_item[:, None]
        return rows, valid

    # -- stream ops ---------------------------------------------------------
    def update(self, items: np.ndarray, freqs: np.ndarray) -> None:
        items = np.asarray(items, dtype=np.uint32)
        freqs = np.asarray(freqs, dtype=np.int64)
        keys = pack_keys(self.spec.base.schema, items)
        self.mg.offer(keys, freqs)
        heavy = self.mg.is_heavy(keys)
        rows, valid = self._rows(items, heavy)
        cells = sk.compute_indices_np(self.spec.base, self.params, items)  # [w, B]
        B, max_d = rows.shape
        b_idx = np.broadcast_to(np.arange(B)[:, None], rows.shape)
        flat_rows = rows[valid].astype(np.int64)
        flat_cols = cells[flat_rows, b_idx[valid]].astype(np.int64)
        np.add.at(self.table, (flat_rows, flat_cols), np.broadcast_to(freqs[:, None], rows.shape)[valid])

    def query(self, items: np.ndarray) -> np.ndarray:
        items = np.asarray(items, dtype=np.uint32)
        keys = pack_keys(self.spec.base.schema, items)
        heavy = self.mg.is_heavy(keys)
        rows, valid = self._rows(items, heavy)
        cells = sk.compute_indices_np(self.spec.base, self.params, items)
        B, max_d = rows.shape
        b_idx = np.broadcast_to(np.arange(B)[:, None], rows.shape)
        vals = self.table[rows.astype(np.int64), cells[rows.astype(np.int64), b_idx]]
        vals = np.where(valid, vals, np.iinfo(np.int64).max)
        return vals.min(axis=1)


def fcm_spec(schema: KeySchema, h: int, w: int, mg_k: int = 256,
             d_hf: Optional[int] = None, d_lf: Optional[int] = None) -> FCMSpec:
    """FCM: Count-Min cell indexing + frequency-aware row subsets."""
    d_hf = d_hf or max(1, w // 3)
    d_lf = d_lf or max(d_hf + 1, (2 * w) // 3)
    return FCMSpec(base=sk.count_min_spec(schema, h, w), d_hf=d_hf, d_lf=d_lf, mg_k=mg_k)


def fmod_spec(schema: KeySchema, partition, ranges, w: int, mg_k: int = 256,
              d_hf: Optional[int] = None, d_lf: Optional[int] = None) -> FCMSpec:
    """FMOD: MOD-Sketch composite cell indexing under FCM row selection."""
    d_hf = d_hf or max(1, w // 3)
    d_lf = d_lf or max(d_hf + 1, (2 * w) // 3)
    return FCMSpec(base=sk.mod_sketch_spec(schema, partition, ranges, w),
                   d_hf=d_hf, d_lf=d_lf, mg_k=mg_k)
