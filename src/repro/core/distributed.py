"""Distributed sketch runtime (shard_map + psum).

The sketch table is *linear* in the stream, so the cluster-scale pattern is:

  1. shard the incoming stream over the data-parallel mesh axes,
  2. every device folds its shard into a device-local table (Pallas kernel
     or jnp scatter -- contention-free either way),
  3. merge by ``psum`` over the DP axes at sync points (exact by linearity).

Queries run anywhere once merged; for row-sharded tables (w split over the
"model" axis) a ``pmin`` over row-groups completes the Count-Min min.

These helpers are mesh-generic: they work on the production (16,16) /
(2,16,16) meshes in the dry-run and on small host-platform meshes in tests.

Every path in this module assumes the *linear* update (core.sketch.update /
the one-hot-matmul kernel).  Conservative tables
(core.sketch.update_conservative, kernels/sketch_update_conservative.py)
are NOT linear in the stream and are excluded from the cell-wise merge and
psum paths here: a psum of conservatively built tables is not the table of
the union stream.  Conservative mode is single-shard only (see
kernels/ops.KernelSketch and serving.engine.SketchTopKEndpoint, which
refuse merge in that mode).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import sketch as sk


def require_linear(mode: str, entry: str) -> None:
    """Refuse conservative tables on any sharded/merge entry point.

    Every distributed path in this repo relies on the table being linear in
    the stream (psum of shard tables == table of the union stream).  That
    holds for mode="linear" and for mode="signed" (Count-Sketch cells are
    sums of +-1-weighted arrivals, so shard tables psum exactly).
    Conservative tables (Estan-Varghese) are not linear, so each sharded
    entry point calls this guard up front and fails loudly instead of
    producing a silently wrong merged table.
    """
    if mode not in ("linear", "signed"):
        raise ValueError(
            f"{entry} is only defined for linear tables (got mode="
            f"{mode!r}): conservative tables are not linear in the stream, "
            "so per-shard folds cannot be psum-merged -- conservative mode "
            "is single-shard by construction")


def pad_block_pow2(items: np.ndarray, freqs: np.ndarray, n_shards: int):
    """Pad a stream block so each of ``n_shards`` contiguous slices has the
    same power-of-two length.

    Zero-frequency pad rows are no-ops under the linear update and are
    skipped by the candidate pools, so padding never changes any table --
    which is what keeps the sharded entry points bit-exact with the serial
    build.  The power-of-two rounding bounds the jitted fold at O(log B)
    compiled variants per shard count.  One helper shared by every sharded
    ingest surface (ShardedTopKService.ingest, KernelSketch.sharded_update,
    SketchTopKEndpoint.ingest with n_shards=1): the copies must agree for
    cross-entry-point parity to hold.

    Returns (items, freqs, rows_per_shard).
    """
    n = items.shape[0]
    per = -(-n // n_shards)
    per = 1 << max(per - 1, 0).bit_length()
    m = per * n_shards
    if m != n:
        items = np.pad(items, ((0, m - n), (0, 0)))
        freqs = np.pad(freqs, (0, m - n))
    return items, freqs, per


def sharded_build(
    spec: sk.SketchSpec,
    params: sk.SketchParams,
    mesh: Mesh,
    data_axes: Tuple[str, ...],
    items: jax.Array,
    freqs: jax.Array,
    table_dtype=jnp.int32,
) -> jax.Array:
    """Build the *merged* table from a stream sharded over ``data_axes``.

    items: uint32[B, n] with B divisible by the product of data-axis sizes.
    Returns the replicated merged table [w, h].
    """

    def local_fold(items_l, freqs_l):
        state = sk.SketchState(
            params=params,
            table=jnp.zeros((spec.width, spec.table_size), dtype=table_dtype),
        )
        state = sk.update(spec, state, items_l, freqs_l)
        return jax.lax.psum(state.table, data_axes)

    fn = shard_map(
        local_fold,
        mesh=mesh,
        in_specs=(P(data_axes), P(data_axes)),
        out_specs=P(),
        check_vma=False,
    )
    return fn(items, freqs)


def sharded_signed_build(
    spec: sk.SketchSpec,
    params,                      # core.countsketch.CountSketchParams
    mesh: Mesh,
    data_axes: Tuple[str, ...],
    items: jax.Array,
    freqs: jax.Array,            # signed (turnstile) weights
    table_dtype=jnp.int32,
) -> jax.Array:
    """Signed (Count-Sketch) counterpart of :func:`sharded_build`.

    Each device hashes its stream slice once (bucket indices + composite
    sign bits), folds sign-weighted arrivals into a device-local table, and
    psum-merges over ``data_axes``.  Exact by linearity: signed cells are
    plain sums, so the merged table is bit-identical to the serial fold for
    integer dtypes.  Returns the replicated merged delta [w, h].
    """
    from repro.core import countsketch as cs

    def local_fold(items_l, freqs_l):
        idx = sk.compute_indices(spec, params.base, items_l)
        s = cs.signs(spec, params, items_l)
        tbl = jnp.zeros((spec.width, spec.table_size), dtype=table_dtype)
        sf = (s * freqs_l.astype(jnp.float32)[None, :]).astype(table_dtype)
        return jax.lax.psum(cs.add_signed(tbl, idx, sf), data_axes)

    fn = shard_map(
        local_fold,
        mesh=mesh,
        in_specs=(P(data_axes), P(data_axes)),
        out_specs=P(),
        check_vma=False,
    )
    return fn(items, freqs)


def sharded_update(
    spec: sk.SketchSpec,
    mesh: Mesh,
    data_axes: Tuple[str, ...],
    state: sk.SketchState,
    items: jax.Array,
    freqs: jax.Array,
) -> sk.SketchState:
    """One synchronous distributed update step: local fold + psum merge."""
    delta = sharded_build(spec, state.params, mesh, data_axes, items, freqs,
                          table_dtype=state.table.dtype)
    return sk.SketchState(params=state.params, table=state.table + delta)


def lazy_local_update(
    spec: sk.SketchSpec,
    mesh: Mesh,
    data_axes: Tuple[str, ...],
    local_tables: jax.Array,  # [w, h] per device, sharded "stacked" on axis 0
    params: sk.SketchParams,
    items: jax.Array,
    freqs: jax.Array,
) -> jax.Array:
    """Asynchronous variant: devices accumulate local tables; no collective.

    ``local_tables`` has a leading device axis sharded over ``data_axes``;
    call :func:`merge_local_tables` at sync intervals.  This is the
    overlap-friendly mode used by the training integration (the merge
    all-reduce is amortized over many steps and can overlap compute).
    """

    def fold(tbl_l, items_l, freqs_l):
        st = sk.SketchState(params=params, table=tbl_l[0])
        st = sk.update(spec, st, items_l, freqs_l)
        return st.table[None]

    fn = shard_map(
        fold,
        mesh=mesh,
        in_specs=(P(data_axes), P(data_axes), P(data_axes)),
        out_specs=P(data_axes),
        check_vma=False,
    )
    return fn(local_tables, items, freqs)


def merge_local_tables(
    mesh: Mesh, data_axes: Tuple[str, ...], local_tables: jax.Array
) -> jax.Array:
    """psum-merge the lazily accumulated per-device tables."""

    def m(tbl_l):
        return jax.lax.psum(tbl_l[0], data_axes)[None]

    fn = shard_map(
        m, mesh=mesh, in_specs=(P(data_axes),), out_specs=P(data_axes),
        check_vma=False,
    )
    merged = fn(local_tables)
    # every shard now holds the global table; take shard 0's copy
    return merged[0]


def lazy_hierarchy_update(
    hspec,                      # core.hierarchy.HierarchySpec
    mesh: Mesh,
    data_axes: Tuple[str, ...],
    local_tables: Sequence[jax.Array],  # per level: [n_shards, w, h_level]
    params: Sequence[sk.SketchParams],  # per level
    items: jax.Array,           # uint32[B, n_modules], B % n_shards == 0
    freqs: jax.Array,
    *,
    mode: str = "linear",
) -> Tuple[jax.Array, ...]:
    """Lazy local fold of ALL hierarchy levels in one shard_map: no
    collective on ingest, no per-level re-hash, no per-level dispatch.

    Every shard hashes its stream slice ONCE (the finest level's composite
    index), derives each level's cell indices by the mixed-radix cascade
    (core.hierarchy.hierarchy_indices -- exact under the shared per-group
    params every ``init_hierarchy`` state carries), and scatter-adds into
    its local copy of every level's table.  The psum merge is deferred to
    the explicit sync point (:func:`merge_local_hierarchy`).  On TPU the
    per-device fold body is a drop-in for the fused one-launch Pallas
    kernel (kernels/hier_update.py); the jnp body is bit-identical to it
    by the parity tests.

    ``params`` keeps the one-entry-per-level shape of ``HierarchyState``
    for compatibility; the cascade only reads the finest level's entry
    (every other level's params are prefix slices of it).

    Only valid for linear tables; the conservative update is excluded from
    every psum path (see :func:`require_linear`).
    """
    require_linear(mode, "lazy_hierarchy_update")
    from repro.core import hierarchy as hh

    items = jnp.asarray(items)
    fine_params = params[-1]
    n_levels = len(local_tables)

    def fold(tbls, items_l, freqs_l):
        idxs = hh.hierarchy_indices(hspec, fine_params, items_l)
        return tuple(sk.add_at_indices(t[0], idx, freqs_l)[None]
                     for t, idx in zip(tbls, idxs))

    fn = shard_map(
        fold,
        mesh=mesh,
        in_specs=(tuple(P(data_axes) for _ in range(n_levels)),
                  P(data_axes), P(data_axes)),
        out_specs=tuple(P(data_axes) for _ in range(n_levels)),
        check_vma=False,
    )
    return fn(tuple(local_tables), items, freqs)


def sharded_hierarchy_fold(
    hspec,                      # core.hierarchy.HierarchySpec
    fine_params: sk.SketchParams,
    mesh: Mesh,
    data_axes: Tuple[str, ...],
    items: jax.Array,           # uint32[B, n_modules], B % n_shards == 0
    freqs: jax.Array,
    *,
    table_dtypes: Sequence = (),
) -> Tuple[jax.Array, ...]:
    """Synchronous sharded build of every level's MERGED delta in one
    shard_map: hash each stream slice once, cascade to all level indices,
    scatter-add per level, psum per level (exact by linearity).

    The hierarchy counterpart of :func:`sharded_build`; used by
    core.hierarchy.sharded_hierarchy_build.  ``fine_params`` is the finest
    level's (shared-family) params; ``table_dtypes`` gives one dtype per
    level (defaults to int32).
    """
    from repro.core import hierarchy as hh

    dtypes = (tuple(table_dtypes)
              or (jnp.int32,) * hspec.n_levels)

    def fold(items_l, freqs_l):
        idxs = hh.hierarchy_indices(hspec, fine_params, items_l)
        out = []
        for spec_l, idx, dt in zip(hspec.levels, idxs, dtypes):
            tbl = jnp.zeros((spec_l.width, spec_l.table_size), dtype=dt)
            out.append(jax.lax.psum(sk.add_at_indices(tbl, idx, freqs_l),
                                    data_axes))
        return tuple(out)

    fn = shard_map(
        fold,
        mesh=mesh,
        in_specs=(P(data_axes), P(data_axes)),
        out_specs=tuple(P() for _ in range(hspec.n_levels)),
        check_vma=False,
    )
    return fn(items, freqs)


def merge_local_hierarchy(
    mesh: Mesh, data_axes: Tuple[str, ...],
    local_tables: Sequence[jax.Array],
) -> Tuple[jax.Array, ...]:
    """psum-merge every level's lazily accumulated per-shard tables.

    The sync point of the sharded serving path: returns one replicated
    [w, h_level] table per level, exact by linearity (integer psum is exact
    addition, so the result is bit-identical for any shard count)."""
    return tuple(merge_local_tables(mesh, data_axes, t) for t in local_tables)


def init_local_tables(
    mesh: Mesh, data_axes: Tuple[str, ...],
    n_shards: int, level_shapes: Sequence[Tuple[int, ...]], dtype,
) -> Tuple[jax.Array, ...]:
    """Zeroed per-shard local table stacks, placed shard-per-device.

    One ``[n_shards, w, h_level]`` stack per level, sharded on axis 0 over
    the mesh's data axes (the layout ``lazy_hierarchy_update`` consumes).
    Shared by the sharded service's constructor and its N->M ``remesh``,
    so a re-meshed service's fresh locals land on the NEW devices instead
    of wherever the old stack happened to live.
    """
    return tuple(
        jax.device_put(jnp.zeros((n_shards,) + tuple(shape), dtype=dtype),
                       NamedSharding(mesh, P(data_axes)))
        for shape in level_shapes)


def row_sharded_query(
    spec: sk.SketchSpec,
    mesh: Mesh,
    model_axis: str,
    params: sk.SketchParams,
    table: jax.Array,     # [w, h] sharded on rows over model_axis
    items: jax.Array,     # replicated queries
) -> jax.Array:
    """Count-Min query with the w rows sharded over the model axis.

    Each shard takes the min over its local rows, then a pmin over the axis
    completes the global min.  w must be divisible by the axis size.
    """

    def q(params_l, table_l, items_l):
        w_local = table_l.shape[0]
        # local min over this shard's rows: reuse compute_indices on a
        # width-w_local view of the spec with this shard's params
        sub_spec = sk.SketchSpec(spec.schema, spec.partition, spec.ranges, w_local)
        idx = sk.compute_indices(sub_spec, params_l, items_l)
        vals = jnp.take_along_axis(table_l, idx.astype(jnp.int32), axis=1)
        return jax.lax.pmin(jnp.min(vals, axis=0), model_axis)

    fn = shard_map(
        q,
        mesh=mesh,
        in_specs=(
            sk.SketchParams(q=P(model_axis), r=P(model_axis)),
            P(model_axis),
            P(),
        ),
        out_specs=P(),
        check_vma=False,
    )
    return fn(params, table, items)
