"""Space-saving (Misra-Gries style) summaries of weighted value streams.

Used by the serving endpoint's candidate-pool admission
(serving/engine.py): each partition group keeps one bounded summary of the
group values seen so far, so late-arriving heavy values still enter the
candidate sets by evicting the lightest entry instead of being dropped by a
first-come cap.

Standard weighted space-saving (Metwally et al. 2005): at capacity, an
unseen value replaces the minimum-count entry and inherits its count (the
``err`` field records that inherited overestimate).  Guarantees, with
capacity m over total weight W:

  * count(v) >= true(v)            (counts only overestimate),
  * count(v) - true(v) <= W / m    (the inherited error is bounded),
  * every value with true(v) > W / m is in the summary.

Counts are float64 so fractional weights (f32 gradient streams) admit
normally; float64 sums of integer weights stay exact below 2^53.  Only the
*values* feed the heavy-hitter descent (estimates come from the sketch
tables, not from these counts), so the counts' job is eviction ranking and
the W/m admission guarantee.  Eviction uses a lazy min-heap (stale entries
skipped on pop), so a block of d distinct rows costs O(d log m), not
O(d * m).
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Tuple

import numpy as np

Row = Tuple[int, ...]


class SpaceSaving:
    """Bounded weighted summary over fixed-width uint32 value rows."""

    def __init__(self, capacity: int, n_cols: int):
        if capacity < 1:
            raise ValueError("capacity >= 1 required")
        self.capacity = int(capacity)
        self.n_cols = int(n_cols)
        self._count: Dict[Row, float] = {}
        self._err: Dict[Row, float] = {}
        self._heap: List[Tuple[float, Row]] = []   # lazy: may hold stale counts

    def __len__(self) -> int:
        return len(self._count)

    def offer(self, values: np.ndarray, freqs: np.ndarray | None = None) -> None:
        """Fold a block of value rows with weights into the summary."""
        values = np.asarray(values, dtype=np.uint32)
        if values.ndim != 2 or values.shape[1] != self.n_cols:
            raise ValueError(f"values must be [N, {self.n_cols}]")
        if values.shape[0] == 0:
            return
        if freqs is None:
            freqs = np.ones(values.shape[0], dtype=np.int64)
        freqs = np.asarray(freqs, dtype=np.float64)
        # aggregate the block first: one summary op per *distinct* row
        uniq, inv = np.unique(values, axis=0, return_inverse=True)
        tot = np.bincount(inv.reshape(-1), weights=freqs)
        for row, f in zip(uniq.tolist(), tot.tolist()):
            if f <= 0:
                continue  # zero-weight pad rows are not observations
            self._insert(tuple(row), float(f))

    def _pop_min(self) -> Tuple[float, Row]:
        """Pop the live minimum-count entry, discarding stale heap entries."""
        while True:
            c, row = heapq.heappop(self._heap)
            if self._count.get(row) == c:
                return c, row

    def _insert(self, row: Row, f: float) -> None:
        if row in self._count:
            self._count[row] += f
        elif len(self._count) < self.capacity:
            self._count[row] = f
            self._err[row] = 0.0
        else:
            floor, victim = self._pop_min()
            del self._count[victim]
            del self._err[victim]
            self._count[row] = floor + f
            self._err[row] = floor
        heapq.heappush(self._heap, (self._count[row], row))
        if len(self._heap) > 4 * self.capacity:
            self._compact_heap()

    def _compact_heap(self) -> None:
        """Drop stale entries (bounds the heap at O(capacity) regardless of
        how many increments long-lived hot rows accumulate)."""
        self._heap = [(c, r) for r, c in self._count.items()]
        heapq.heapify(self._heap)

    def values(self) -> np.ndarray:
        """All summarized rows: uint32[K, n_cols] (admission order arbitrary)."""
        if not self._count:
            return np.zeros((0, self.n_cols), dtype=np.uint32)
        return np.asarray(list(self._count), dtype=np.uint32)

    def counts(self) -> Dict[Row, float]:
        return dict(self._count)

    # -- durable state (serving/recovery.py snapshot currency) --------------

    def state_dict(self) -> Dict[str, np.ndarray]:
        """The summary as three flat arrays: rows / counts / errs.

        Row order is the dict's insertion order, which matters: the plain
        endpoint feeds ``values()`` to the descent unsorted, so a restore
        that permuted rows could permute top-k tie order.  ``load_state``
        re-inserts in the same order, making the round trip bit-exact --
        including all later evictions, which depend only on dict contents
        and order."""
        rows = self.values()
        return {
            "rows": rows,
            "counts": np.asarray([self._count[tuple(r)] for r in rows.tolist()],
                                 dtype=np.float64),
            "errs": np.asarray([self._err[tuple(r)] for r in rows.tolist()],
                               dtype=np.float64),
        }

    def load_state(self, rows: np.ndarray, counts: np.ndarray,
                   errs: np.ndarray) -> None:
        """Restore a summary saved by :meth:`state_dict` (same capacity/width).

        Overwrites the current contents wholesale; the rebuilt heap is the
        compacted form of the loaded counts, so eviction behaviour after a
        restore is identical to the uninterrupted summary's."""
        rows = np.asarray(rows, dtype=np.uint32)
        if rows.ndim != 2 or rows.shape[1] != self.n_cols:
            raise ValueError(f"rows must be [K, {self.n_cols}]")
        if rows.shape[0] > self.capacity:
            raise ValueError(
                f"loaded summary has {rows.shape[0]} rows but capacity is "
                f"{self.capacity}: capacity must match the saved summary")
        counts = np.asarray(counts, dtype=np.float64)
        errs = np.asarray(errs, dtype=np.float64)
        self._count = {tuple(r): float(c)
                       for r, c in zip(rows.tolist(), counts.tolist())}
        self._err = {tuple(r): float(e)
                     for r, e in zip(rows.tolist(), errs.tolist())}
        self._compact_heap()

    @classmethod
    def fold(cls, summaries: List["SpaceSaving"]) -> "SpaceSaving":
        """Fold shard summaries into one fresh summary (cross-shard cascade).

        Capacity and width come from the first summary; each shard is
        folded in with :meth:`merge_from`, so the result carries the
        mergeable-summaries guarantees: counts upper-bound true weights and
        the inherited error is at most the sum of the shards' floors (each
        <= W_i / m).  When every shard is under capacity the fold is exact
        -- counts are plain sums and no row is lost -- which is what makes
        the sharded serving candidate pools shard-count invariant below
        capacity (serving/sharded_topk.py)."""
        summaries = list(summaries)
        if not summaries:
            raise ValueError("fold requires at least one summary")
        out = cls(summaries[0].capacity, summaries[0].n_cols)
        for s in summaries:
            out.merge_from(s)
        return out

    def merge_from(self, other: "SpaceSaving") -> None:
        """Fold another summary in (cross-shard candidate merge).

        Mergeable-summaries rule (Agarwal et al. 2012): a row absent from
        one side contributes that side's min count when the side is at
        capacity (its worst-case possible count there -- the row may have
        been evicted with up to that much mass) and 0 when the side is
        under capacity (absent then means truly unseen).  The union is
        truncated back to capacity keeping the largest counts.  This
        preserves count(v) >= true(v) for every retained row, so a value
        heavy on either shard still out-ranks light entries in the merged
        summary; the error bound grows to the sum of the two floors.
        """
        if other.n_cols != self.n_cols:
            raise ValueError("cannot merge summaries of different widths")
        m_self = (min(self._count.values())
                  if len(self._count) >= self.capacity else 0.0)
        m_other = (min(other._count.values())
                   if len(other._count) >= other.capacity else 0.0)
        count, err = {}, {}
        for row in set(self._count) | set(other._count):
            cs, co = self._count.get(row), other._count.get(row)
            count[row] = ((cs if cs is not None else m_self)
                          + (co if co is not None else m_other))
            err[row] = ((self._err[row] if cs is not None else m_self)
                        + (other._err[row] if co is not None else m_other))
        if len(count) > self.capacity:
            keep = sorted(count, key=count.__getitem__,
                          reverse=True)[: self.capacity]
            count = {r: count[r] for r in keep}
            err = {r: err[r] for r in keep}
        self._count, self._err = count, err
        self._compact_heap()
