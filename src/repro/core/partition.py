"""Module-combination partitions and Bell numbers (paper Thm 6, Table I)."""
from __future__ import annotations

from functools import lru_cache
from math import comb
from typing import Iterator, List, Sequence, Tuple


@lru_cache(maxsize=None)
def bell_number(n: int) -> int:
    """T(n): #ways to combine the modules of a modularity-n key (Thm 6).

    T(n) = sum_{k=0}^{n-1} C(n-1, k) * T(n-k-1),  T(0) = T(1) = 1.
    """
    if n < 0:
        raise ValueError("n >= 0 required")
    if n <= 1:
        return 1
    return sum(comb(n - 1, k) * bell_number(n - k - 1) for k in range(n))


def all_partitions(modules: Sequence[int]) -> Iterator[Tuple[Tuple[int, ...], ...]]:
    """Enumerate every set partition of ``modules`` in canonical form.

    Canonical form: elements sorted within groups, groups sorted by their
    smallest element.  Count equals ``bell_number(len(modules))``.
    """
    modules = list(modules)
    if not modules:
        yield ()
        return
    first, rest = modules[0], modules[1:]
    for sub in all_partitions(rest):
        # put `first` into its own group
        yield tuple(sorted([(first,)] + list(sub), key=lambda g: g[0]))
        # or into each existing group
        for gi in range(len(sub)):
            groups: List[Tuple[int, ...]] = [
                tuple(sorted(g + (first,))) if i == gi else g for i, g in enumerate(sub)
            ]
            yield tuple(sorted(groups, key=lambda g: g[0]))


def canonical(partition: Sequence[Sequence[int]]) -> Tuple[Tuple[int, ...], ...]:
    return tuple(sorted((tuple(sorted(g)) for g in partition), key=lambda g: g[0]))
