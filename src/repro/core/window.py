"""Sliding-window & time-decayed hierarchies: a ring of per-epoch tables.

Real traffic asks "top-k in the last hour", not "top-k since boot".  Every
level table of a (linearly built) hierarchy is linear in the stream, so
windowing is cell-wise arithmetic on per-epoch tables:

    ring slot e : hierarchy tables of the items ingested during epoch e
    window      : merge of the live epochs' tables (expired epochs dropped
                  from the lazy merge, or subtracted from a running sum --
                  both exact by linearity on integer tables)

All epochs share ONE per-group hash family (the same draw
``core.hierarchy.init_hierarchy`` makes for the ingest cascade), so epoch
tables are merge-compatible by construction: the merged window tables are
bit-identical to the tables of a hierarchy freshly built over exactly the
window's stream contents (enforced by tests/test_window.py).  Every query
path of the hierarchy -- the recursive descent, the Pallas candidate
kernel, the marginal queries -- runs unchanged against the merged state.

Three window modes (:class:`WindowSpec.mode`):

  * ``tumbling``  -- the last ``n_epochs`` epochs, equally weighted.  The
    ring's oldest slot is zeroed on :func:`advance_window`; the lazy merge
    sums the live slots (a serving-side running sum may instead subtract
    the expiring tables -- identical result by linearity, see
    serving/windowed_topk.py).
  * ``landmark``  -- everything since boot.  Expiring slots fold into a
    ``retired`` accumulator instead of being lost, so memory stays at
    ``n_epochs + 1`` table stacks while the merge covers the whole stream.
  * ``decay``     -- exponential decay over the last ``n_epochs`` epochs:
    an epoch of age ``a`` contributes with weight ``decay**a``.  The merge
    is the scale-then-fold (Horner) recurrence over live epochs, oldest
    first::

        acc <- acc * decay + table_e

    which is still linear in each epoch's stream, so sharding / psum /
    donation machinery carries over unchanged.  Tables are float32 (the
    scale leaves the integers); the recompute-from-scratch reference
    replays the identical recurrence, so parity is still bit-exact.

Linear mode only: conservative (Estan-Varghese) tables are not linear in
the stream, so per-epoch tables could be neither merged nor subtracted --
every windowed entry point refuses ``mode="conservative"`` via the same
``core.distributed.require_linear`` guard the sharded surfaces use.

See docs/architecture.md for where this sits in the stack.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.core.distributed import require_linear


# --------------------------------------------------------------------------
# Spec
# --------------------------------------------------------------------------

_MODES = ("tumbling", "landmark", "decay")


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    """Static description of a windowed hierarchy.

    ``n_epochs`` is the ring capacity W: tumbling/decay windows cover the
    last W epochs, landmark keeps W live slots plus the retired
    accumulator.  ``decay`` is the per-epoch multiplier for mode="decay"
    (ignored otherwise)."""
    base: sk.SketchSpec
    n_epochs: int
    mode: str = "tumbling"
    decay: float = 1.0

    def __post_init__(self):
        if self.n_epochs < 1:
            raise ValueError("n_epochs >= 1 required")
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got {self.mode!r}")
        if self.mode == "decay" and not (0.0 < self.decay <= 1.0):
            raise ValueError("decay in (0, 1] required for mode='decay'")

    @functools.cached_property
    def hspec(self) -> hh.HierarchySpec:
        return hh.HierarchySpec.from_spec(self.base)


class WindowState(NamedTuple):
    """Ring of per-epoch level tables sharing one hash family.

    ``level_params[l]`` is level l's prefix slice of the one shared draw
    (exactly what ``init_hierarchy`` produces);  ``ring[e][l]`` is epoch
    slot e's level-l table;  ``retired[l]`` accumulates expired epochs in
    landmark mode (zeros otherwise);  ``head`` is the slot receiving
    ingest;  ``epoch`` counts advances since boot (current epoch id)."""
    level_params: Tuple[sk.SketchParams, ...]
    ring: Tuple[Tuple[jax.Array, ...], ...]
    retired: Tuple[jax.Array, ...]
    head: int
    epoch: int


def _hier_state(wspec: WindowSpec, state: WindowState,
                tables: Tuple[jax.Array, ...]) -> hh.HierarchyState:
    """Assemble a HierarchyState view over one table stack (shared params)."""
    return hh.HierarchyState(states=tuple(
        sk.SketchState(params=p, table=t)
        for p, t in zip(state.level_params, tables)))


def init_window(wspec: WindowSpec, key: jax.Array, *,
                dtype=None, mode: str = "linear") -> WindowState:
    """Draw the shared hash family and zero every ring slot.

    ``dtype`` defaults to int32 (exact integer arithmetic; merge and
    subtract are bit-exact) and to float32 for decay mode, whose scale
    leaves the integers.  ``mode`` exists only to be refused: windowed
    tables must merge and subtract cell-wise, which conservative tables
    cannot (require_linear -- same contract as every sharded surface)."""
    require_linear(mode, "window.init_window")
    if dtype is None:
        dtype = jnp.float32 if wspec.mode == "decay" else jnp.int32
    if wspec.mode == "decay" and not jnp.issubdtype(dtype, jnp.floating):
        raise ValueError(
            "decay mode scales tables by a float factor each epoch; use a "
            "float table dtype (int tables would truncate the decay)")
    template = hh.init_hierarchy(wspec.hspec, key, dtype=dtype)
    zeros = tuple(st.table for st in template.states)
    return WindowState(
        level_params=tuple(st.params for st in template.states),
        ring=tuple(tuple(jnp.zeros_like(t) for t in zeros)
                   for _ in range(wspec.n_epochs)),
        retired=tuple(jnp.zeros_like(t) for t in zeros),
        head=0,
        epoch=0,
    )


# --------------------------------------------------------------------------
# Ingest / advance
# --------------------------------------------------------------------------

def window_update(wspec: WindowSpec, state: WindowState,
                  items, freqs, *, mode: str = "linear") -> WindowState:
    """Fold a weighted key block into the CURRENT epoch's tables.

    Runs the shared-family ingest cascade (one hash pass, every level's
    cell by mixed-radix division -- core.hierarchy.update_jit) against the
    head slot; the head tables are donated into the jitted fold, so
    callers rebind the state to the return value like every other
    streaming build here."""
    require_linear(mode, "window.window_update")
    items = jnp.asarray(np.asarray(items, dtype=np.uint32))
    freqs = jnp.asarray(freqs)
    head_state = _hier_state(wspec, state, state.ring[state.head])
    new_head = hh.update_jit(wspec.hspec, head_state, items, freqs)
    ring = list(state.ring)
    ring[state.head] = tuple(st.table for st in new_head.states)
    return state._replace(ring=tuple(ring))


@jax.jit
def _add_tables(a, b):
    return tuple(x + y for x, y in zip(a, b))


def advance_window(wspec: WindowSpec, state: WindowState) -> WindowState:
    """Close the current epoch and open a fresh one.

    The slot the head moves into holds the OLDEST live epoch; its tables
    expire: dropped (zeroed) in tumbling/decay mode, folded into the
    ``retired`` accumulator in landmark mode (nothing ever leaves a
    landmark window).  Advancing before the ring is full expires an empty
    slot, which is a no-op by linearity."""
    new_head = (state.head + 1) % wspec.n_epochs
    expiring = state.ring[new_head]
    retired = state.retired
    if wspec.mode == "landmark":
        retired = _add_tables(retired, expiring)
    ring = list(state.ring)
    ring[new_head] = tuple(jnp.zeros_like(t) for t in expiring)
    return state._replace(ring=tuple(ring), retired=retired,
                          head=new_head, epoch=state.epoch + 1)


# --------------------------------------------------------------------------
# Lazy query-time merge
# --------------------------------------------------------------------------

def live_slots(wspec: WindowSpec, state: WindowState) -> Tuple[int, ...]:
    """Ring slots of the live epochs, oldest -> newest (head last).

    Before the ring has wrapped, only ``epoch + 1`` slots have ever
    received ingest; the rest are all-zero and excluded (including them
    would not change any sum, but Horner decay weights depend on the
    number of folded terms, so the slot list must be exact)."""
    n_live = min(state.epoch + 1, wspec.n_epochs)
    return tuple((state.head - a) % wspec.n_epochs
                 for a in reversed(range(n_live)))


@functools.partial(jax.jit, static_argnums=(0,))
def _merge_sum(n_levels: int, stacks):
    """Per-level cell-wise sum over a sequence of table stacks."""
    return tuple(
        functools.reduce(jnp.add, [s[l] for s in stacks])
        for l in range(n_levels))


@functools.partial(jax.jit, static_argnums=(0,))
def _merge_horner(n_levels: int, decay: float, stacks):
    """Scale-then-fold over table stacks, OLDEST FIRST:
    acc = acc * decay + table, so age-a epochs carry weight decay**a."""
    out = []
    for l in range(n_levels):
        acc = stacks[0][l]
        for s in stacks[1:]:
            acc = acc * jnp.asarray(decay, acc.dtype) + s[l]
        out.append(acc)
    return tuple(out)


def merged_state(wspec: WindowSpec, state: WindowState) -> hh.HierarchyState:
    """The window's hierarchy, lazily merged from the live epoch tables.

    tumbling: sum of live slots;  landmark: retired + sum of live slots;
    decay: Horner scale-then-fold oldest->newest.  The result is a
    first-class HierarchyState -- find_heavy_hitters, the Pallas candidate
    kernel, marginal queries all run against it unchanged -- and for
    tumbling/landmark int tables it is bit-identical to a hierarchy built
    from scratch over the window's stream contents (tests/test_window.py).
    """
    stacks = [state.ring[s] for s in live_slots(wspec, state)]
    n = wspec.hspec.n_levels
    if wspec.mode == "decay":
        tables = _merge_horner(n, float(wspec.decay), tuple(stacks))
    else:
        if wspec.mode == "landmark":
            stacks = [state.retired] + stacks
        tables = _merge_sum(n, tuple(stacks))
    return _hier_state(wspec, state, tables)


def subtract_tables(window_sum: Tuple[jax.Array, ...],
                    expiring: Tuple[jax.Array, ...]) -> Tuple[jax.Array, ...]:
    """The incremental-expiry primitive: running window sum minus an
    expiring epoch's tables, per level.  Exact on integer tables by
    linearity -- ``sum(live) == sum(prev live) - expired`` cell by cell --
    so a serving cache maintained this way stays bit-identical to the lazy
    resum (the equivalence test in tests/test_window.py).  Linear tables
    only, like every windowed surface."""
    return _sub_tables(window_sum, expiring)


@jax.jit
def _sub_tables(a, b):
    return tuple(x - y for x, y in zip(a, b))


# --------------------------------------------------------------------------
# Recompute-from-scratch references (test oracles)
# --------------------------------------------------------------------------

def reference_window_state(
    wspec: WindowSpec,
    key: jax.Array,
    epoch_blocks,          # sequence of (items, freqs) per epoch, oldest first
    *,
    dtype=None,
) -> hh.HierarchyState:
    """Oracle: the merged window built from scratch, no ring involved.

    ``epoch_blocks`` must be the LIVE epochs' streams (already truncated /
    retained according to the mode), oldest first.  tumbling/landmark:
    one fresh hierarchy over the concatenation (linearity makes epoch
    boundaries irrelevant).  decay: one fresh hierarchy per epoch, folded
    through the same Horner recurrence as :func:`merged_state` -- the
    identical float operations in the identical order, hence bit-exact."""
    if dtype is None:
        dtype = jnp.float32 if wspec.mode == "decay" else jnp.int32
    hspec = wspec.hspec
    if wspec.mode != "decay":
        its = [np.asarray(i, dtype=np.uint32) for i, _ in epoch_blocks]
        frs = [np.asarray(f) for _, f in epoch_blocks]
        n_mod = wspec.base.schema.modularity
        items = (np.concatenate(its, axis=0) if its
                 else np.zeros((0, n_mod), np.uint32))
        freqs = np.concatenate(frs) if frs else np.zeros((0,), np.int64)
        state = hh.init_hierarchy(hspec, key, dtype=dtype)
        if len(items):
            state = hh.update_jit(hspec, state, jnp.asarray(items),
                                  jnp.asarray(freqs))
        return state
    stacks = []
    params_state = None
    for items, freqs in epoch_blocks:
        st = hh.init_hierarchy(hspec, key, dtype=dtype)
        if len(np.asarray(items)):
            st = hh.update_jit(
                hspec, st,
                jnp.asarray(np.asarray(items, dtype=np.uint32)),
                jnp.asarray(freqs))
        params_state = st
        stacks.append(tuple(s.table for s in st.states))
    if params_state is None:
        return hh.init_hierarchy(hspec, key, dtype=dtype)
    tables = _merge_horner(hspec.n_levels, float(wspec.decay), tuple(stacks))
    return hh.HierarchyState(states=tuple(
        sk.SketchState(params=s.params, table=t)
        for s, t in zip(params_state.states, tables)))
