"""Exhaustive baseline (paper SVI-A2 comparing method (3)).

Enumerate every set partition of the modules (T(n) of them, Thm 6); for each
partition, *experimentally* search hash-range allocations and keep the
configuration with the smallest observed error on sample queries.  Exactly as
in the paper, this is exponential and guarded to small modularity (the paper
itself could not finish n = 8 within 100 hours).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.core.partition import all_partitions, bell_number
from repro.core.range_opt import aggregate_sample, recursive_ranges


def observed_error(est: np.ndarray, true: np.ndarray) -> float:
    """Paper SVI-A4 metric: sum |est - true| / sum true over queried items."""
    true = np.asarray(true, dtype=np.float64)
    est = np.asarray(est, dtype=np.float64)
    denom = float(true.sum())
    return float(np.abs(est - true).sum() / max(denom, 1.0))


def _range_candidates(m: int, h: float, items, freqs, groups, grid: int) -> List[Tuple[int, ...]]:
    """Candidate range allocations for one partition.

    Includes the equal split, the SV-B1 recursive solution, and (for m == 2)
    a geometric sweep over a -- the 'experimentally find the best choice'
    step of the paper, made tractable.
    """
    cands: List[Tuple[int, ...]] = []
    base = max(2, int(round(h ** (1.0 / m))))
    eq = [base] * m
    eq[-1] = max(2, int(round(h / max(1, int(np.prod(eq[:-1], dtype=np.int64))))))
    cands.append(tuple(eq))
    cands.append(recursive_ranges(items, freqs, groups, h, "median", {}))
    if m == 2:
        for t in np.linspace(-0.8, 0.8, grid):
            a = max(2, int(round(math.sqrt(h) * (10.0 ** t))))
            b = max(2, int(round(h / a)))
            cands.append((a, b))
    elif m > 2:
        # perturb the recursive solution multiplicatively on each axis, then
        # renormalize a partner axis so the product stays ~ h (space budget)
        rec = list(cands[-1])
        for axis in range(m):
            for f in (0.5, 2.0):
                c = list(rec)
                c[axis] = max(2, int(round(c[axis] * f)))
                partner = (axis + 1) % m
                rest = np.prod([c[i] for i in range(m) if i != partner], dtype=np.float64)
                c[partner] = max(2, int(round(h / max(1.0, rest))))
                cands.append(tuple(c))
    # dedup + enforce the space budget (reject > 1.15x h cells per row)
    out, seen = [], set()
    for c in cands:
        prod = float(np.prod(c, dtype=np.float64))
        if c not in seen and prod <= 1.15 * h:
            seen.add(c)
            out.append(c)
    return out


@dataclasses.dataclass
class ExhaustiveResult:
    spec: sk.SketchSpec
    error: float
    n_configs: int
    elapsed_s: float


def exhaustive_config(
    items: np.ndarray,
    freqs: np.ndarray,
    schema: KeySchema,
    h: int,
    w: int,
    key: jax.Array,
    grid: int = 9,
    max_modularity: int = 4,
    query_top: int = 200,
) -> ExhaustiveResult:
    """Best (partition, ranges) by brute force over the sample.

    Error is evaluated on the sample's top-`query_top` items against the
    sample's exact frequencies (the paper's observed-error protocol applied
    to the search sample).
    """
    n = schema.modularity
    if n > max_modularity:
        raise ValueError(
            f"exhaustive search over modularity {n} enumerates T({n}) = "
            f"{bell_number(n)} partitions; refusing beyond {max_modularity} "
            "(the paper's Exhaustive did not finish n=8 in 100 hours)"
        )
    t0 = time.perf_counter()
    uniq, f = aggregate_sample(items, freqs)
    top = np.argsort(-f)[:query_top]
    q_items, q_true = uniq[top], f[top]

    best: Optional[Tuple[float, sk.SketchSpec]] = None
    n_configs = 0
    for pi, part in enumerate(all_partitions(range(n))):
        groups = [list(g) for g in part]
        for ri, ranges in enumerate(_range_candidates(len(part), float(h), uniq, f, groups, grid)):
            spec = sk.SketchSpec(schema, part, ranges, w)
            state = sk.build_sketch(spec, jax.random.fold_in(key, 7919 * pi + ri), uniq, f)
            est = np.asarray(sk.query_jit(spec, state, np.asarray(q_items, dtype=np.uint32)))
            err = observed_error(est, q_true)
            n_configs += 1
            if best is None or err < best[0]:
                best = (err, spec)
    return ExhaustiveResult(spec=best[1], error=best[0], n_configs=n_configs,
                            elapsed_s=time.perf_counter() - t0)
