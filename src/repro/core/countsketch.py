"""Signed Count-Sketch mode of the composite-hash family (beyond paper).

Same partitioned indexing machinery as core/sketch.py, plus a +-1 sign per
(row, item) built *compositely*: one CW parity hash per module group, exactly
like the bucket hashes, so the sign factors over the same group prefixes as
the cell address.  The level-L sign is the product (XOR of parities) of
groups 0..L, which makes the sign cascade with the hierarchy the same way
the mixed-radix index does:

    sign_L(key) = sign_{L-1}(prefix) * parity_L(g_L value)

``sign_bits`` packs all levels' signs into one integer per (row, item) --
bit L is the cumulative parity of groups 0..L -- so ingest hashes signs once
and every level reads its bit, mirroring ``hierarchy_indices``.

Median-of-rows estimates are unbiased; signed tables stay *linear* in the
stream, so merge / psum folds / table-buffer donation all apply verbatim
(unlike conservative mode, which every linear surface refuses).  This is the
right primitive for gradient sketching (training/grad_compression.py), where
values are real and cancellation matters, and it supports ``l2estimate``
(AMS-style F2 from the row norms) plus a median *threshold descent* over the
hierarchy (|estimate| thresholds; signs make over- and under-estimates
symmetric, so the descent keeps any prefix whose magnitude clears the bar).

The performance path is ``mode="signed"`` of kernels/ops.py, bit-exact
against this module on int32 tables (tests/test_signed_kernels.py).
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.core.hashing import addmod_p31, draw_hash_params, mulmod_p31_16


class CountSketchParams(NamedTuple):
    """Bucket hash params + one CW sign hash per (row, group)."""
    base: sk.SketchParams
    sign_q: jax.Array  # uint32[w, total_chunks]
    sign_r: jax.Array  # uint32[w, n_groups]


class CountSketchState(NamedTuple):
    params: CountSketchParams
    table: jax.Array  # [w, h], float32 or int32


def init_params(spec: sk.SketchSpec, key: jax.Array) -> CountSketchParams:
    kb, kq, kr = jax.random.split(key, 3)
    base = sk.init_params(spec, kb)
    sign_q = draw_hash_params(kq, (spec.width, spec.schema.total_chunks))
    sign_r = draw_hash_params(kr, (spec.width, spec.n_groups))
    return CountSketchParams(base, sign_q, sign_r)


def init_state(spec: sk.SketchSpec, key: jax.Array,
               dtype=jnp.float32) -> CountSketchState:
    params = init_params(spec, key)
    table = jnp.zeros((spec.width, spec.table_size), dtype=dtype)
    return CountSketchState(params, table)


# --------------------------------------------------------------------------
# Signs
# --------------------------------------------------------------------------

def sign_bits(spec: sk.SketchSpec, params: CountSketchParams,
              items: jax.Array) -> jax.Array:
    """Packed cumulative parity bits per (row, item): uint32[w, B].

    Bit L is the XOR of the per-group CW-hash parities of groups 0..L --
    i.e. the sign of the level-L prefix of the key under the shared family
    (the finest/flat sign is the top group's bit).  One pass computes every
    level's sign, the sign half of the ingest cascade.
    """
    chunks = spec.schema.module_chunks(items)  # [B, C]
    w, b = spec.width, chunks.shape[0]
    bits = jnp.zeros((w, b), dtype=jnp.uint32)
    cum = jnp.zeros((w, b), dtype=jnp.uint32)
    for j in range(spec.n_groups):
        acc = jnp.broadcast_to(params.sign_r[:, j][:, None], (w, b))
        acc = acc.astype(jnp.uint32)
        for c in spec.group_chunk_columns(j):
            acc = addmod_p31(acc, mulmod_p31_16(params.sign_q[:, c][:, None],
                                                chunks[None, :, c]))
        cum = cum ^ (acc & jnp.uint32(1))
        bits = bits | (cum << jnp.uint32(j))
    return bits


def signs_from_bits(bits: jax.Array, level: int) -> jax.Array:
    """float32 +-1 signs for one level from the packed cumulative bits."""
    par = (bits >> jnp.uint32(level)) & jnp.uint32(1)
    return 1.0 - 2.0 * par.astype(jnp.float32)


def signs(spec: sk.SketchSpec, params: CountSketchParams,
          items: jax.Array) -> jax.Array:
    """+-1 per (row, item) for the full composite key: float32[w, B]."""
    return signs_from_bits(sign_bits(spec, params, items), spec.n_groups - 1)


def group_sign_parity(spec: sk.SketchSpec, params: CountSketchParams,
                      group: int, values: jax.Array) -> jax.Array:
    """Parity bit of ONE group's sign hash: uint32[w, Q] in {0, 1}.

    ``values``: uint32[Q, len(group modules)].  The sign analogue of
    sk.group_subindex -- the separable child factor of the candidate grid:
    sign(prefix + v) = prefix_sign * (1 - 2 * parity(v)).
    """
    vcols = []
    for mi, mod in enumerate(spec.partition[group]):
        nc = spec.schema.chunk_counts[mod]
        v = values[..., mi].astype(jnp.uint32)
        for c in range(nc):
            vcols.append((v >> jnp.uint32(16 * c)) & jnp.uint32(0xFFFF))
    gchunks = jnp.stack(vcols, axis=-1)                        # [Q, Cg]

    w = spec.width
    acc = jnp.broadcast_to(params.sign_r[:, group][:, None],
                           (w, values.shape[0])).astype(jnp.uint32)
    for ci, c in enumerate(spec.group_chunk_columns(group)):
        acc = addmod_p31(acc, mulmod_p31_16(params.sign_q[:, c][:, None],
                                            gchunks[None, :, ci]))
    return acc & jnp.uint32(1)


# --------------------------------------------------------------------------
# Flat update / query / diagnostics
# --------------------------------------------------------------------------

def add_signed(table: jax.Array, idx: jax.Array,
               signed_vals: jax.Array) -> jax.Array:
    """Scatter-add per-(row, item) signed values (float32[w, B]) into the
    table -- the signed analogue of sk.add_at_indices, whose broadcast
    doesn't apply because the sign differs per row."""
    w, h = table.shape
    flat = (jnp.arange(w, dtype=jnp.uint32)[:, None] * jnp.uint32(h)
            + idx).reshape(-1)
    contrib = signed_vals.reshape(-1).astype(table.dtype)
    return table.reshape(-1).at[flat].add(contrib).reshape(w, h)


def update(spec: sk.SketchSpec, state: CountSketchState, items: jax.Array,
           values: jax.Array) -> CountSketchState:
    """Fold (item, value) pairs: cell[k, h_k(x)] += s_k(x) * v (order-free).

    Values may be real or signed integers (turnstile deletions are fine);
    int32 tables stay bit-exact for |value| < 2^24, matching the kernel."""
    idx = sk.compute_indices(spec, state.params.base, items)   # [w, B]
    s = signs(spec, state.params, items)                       # [w, B]
    table = add_signed(state.table, idx,
                       s * values[None, :].astype(jnp.float32))
    return CountSketchState(state.params, table)


def query_rows(spec: sk.SketchSpec, state: CountSketchState,
               items: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(per-row estimates [w, Q], median [Q]) -- rows enable robustness
    filters (e.g. sign agreement) on top of the median."""
    idx = sk.compute_indices(spec, state.params.base, items)
    s = signs(spec, state.params, items)
    vals = jnp.take_along_axis(state.table, idx.astype(jnp.int32),
                               axis=1).astype(jnp.float32) * s
    return vals, jnp.median(vals, axis=0)


def query(spec: sk.SketchSpec, state: CountSketchState,
          items: jax.Array) -> jax.Array:
    """Unbiased median-of-rows estimate of each item's summed value."""
    return query_rows(spec, state, items)[1]


def l2estimate(table: jax.Array) -> jax.Array:
    """AMS-style L2 estimate: sqrt(median_k sum_j table[k, j]^2).

    Each row's squared norm is an unbiased F2 estimate (cross terms cancel
    in expectation under the +-1 signs); the median over rows gives the
    usual constant-probability multiplicative bound."""
    sq = jnp.sum(jnp.square(table.astype(jnp.float32)), axis=1)
    return jnp.sqrt(jnp.median(sq))


def merge(a: CountSketchState, b: CountSketchState) -> CountSketchState:
    """Cell-wise merge -- exact by linearity (same hash params assumed)."""
    return CountSketchState(params=a.params, table=a.table + b.table)


# --------------------------------------------------------------------------
# Hierarchy: signed tables over the same group-prefix cascade
# --------------------------------------------------------------------------

class CountSketchHierarchy(NamedTuple):
    """One signed table per level, sharing ONE (bucket + sign) hash draw.

    ``params`` is the finest level's draw; level L uses the prefix slices
    (exactly core/hierarchy.py's shared family, extended to the sign hash).
    """
    params: CountSketchParams
    tables: Tuple[jax.Array, ...]   # coarse -> fine, [w, h_L] each


def level_params(hspec: hh.HierarchySpec, params: CountSketchParams,
                 level: int) -> CountSketchParams:
    """Level ``level``'s params as prefix slices of the finest draw."""
    nc = hspec.levels[level].schema.total_chunks
    return CountSketchParams(
        base=hh.level_params(hspec, params.base, level),
        sign_q=params.sign_q[:, :nc],
        sign_r=params.sign_r[:, : level + 1])


def init_hierarchy(hspec: hh.HierarchySpec, key: jax.Array,
                   dtype=jnp.float32) -> CountSketchHierarchy:
    params = init_params(hspec.levels[-1], key)
    tables = tuple(jnp.zeros((s.width, s.table_size), dtype=dtype)
                   for s in hspec.levels)
    return CountSketchHierarchy(params, tables)


def hier_fold_tables(
    hspec: hh.HierarchySpec,
    params: CountSketchParams,
    tables: Tuple[jax.Array, ...],
    items: jax.Array,
    values: jax.Array,
) -> Tuple[jax.Array, ...]:
    """Signed cascade fold: ONE hash pass (buckets + sign bits), every
    level's cells by integer division and its sign by one bit of the packed
    parities.  Jittable with static ``hspec``; shared by hier_update, the
    gradient compressor, and the DP table folds."""
    items = jnp.asarray(items)
    fine = hspec.levels[-1]
    fine_items = hspec.level_items(hspec.n_levels - 1, items)
    idxs = hh.hierarchy_indices(hspec, params.base, items)
    bits = sign_bits(fine, params, fine_items)
    vals = values[None, :].astype(jnp.float32)
    out = []
    for lvl, (table, idx) in enumerate(zip(tables, idxs)):
        s = signs_from_bits(bits, lvl)
        out.append(add_signed(table, idx, s * vals))
    return tuple(out)


def hier_update(hspec: hh.HierarchySpec, state: CountSketchHierarchy,
                items: jax.Array, values: jax.Array) -> CountSketchHierarchy:
    """Fold full keys into every level's signed table (cascade path)."""
    tables = hier_fold_tables(hspec, state.params, state.tables, items,
                              values)
    return CountSketchHierarchy(state.params, tables)


def hier_update_reference(hspec: hh.HierarchySpec,
                          state: CountSketchHierarchy, items: jax.Array,
                          values: jax.Array) -> CountSketchHierarchy:
    """Per-level oracle: L independent flat updates, each re-hashing its
    prefix (and its prefix sign) from scratch -- the parity reference for
    :func:`hier_update` and the fused signed kernel."""
    items = jnp.asarray(items)
    new = []
    for lvl, (spec_l, table) in enumerate(zip(hspec.levels, state.tables)):
        st = CountSketchState(level_params(hspec, state.params, lvl), table)
        new.append(update(spec_l, st, hspec.level_items(lvl, items),
                          values).table)
    return CountSketchHierarchy(state.params, tuple(new))


def hier_merge(a: CountSketchHierarchy,
               b: CountSketchHierarchy) -> CountSketchHierarchy:
    """Cell-wise merge per level -- exact by linearity."""
    return CountSketchHierarchy(
        a.params, tuple(ta + tb for ta, tb in zip(a.tables, b.tables)))


def hier_query(hspec: hh.HierarchySpec, state: CountSketchHierarchy,
               level: int, prefixes: jax.Array) -> jax.Array:
    """Median estimate of each level-``level`` prefix's signed mass: [Q].

    ``prefixes``: uint32[Q, n_modules(levels 0..level)] in group-major
    order.  Jittable with static (hspec, level)."""
    spec_l = hspec.levels[level]
    p = level_params(hspec, state.params, level)
    st = CountSketchState(p, state.tables[level])
    return query(spec_l, st, prefixes)


# --------------------------------------------------------------------------
# Separable signed candidate queries + threshold descent
# --------------------------------------------------------------------------

def candidate_signed_partials(
    hspec: hh.HierarchySpec,
    params: CountSketchParams,
    level: int,
    prefixes: jax.Array,     # uint32[P, n_prefix_modules] (group-major)
    values: jax.Array,       # uint32[C, len(level group modules)]
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Index AND sign factors of the level-``level`` candidate grid.

    Returns (pp, cp, sp, sc): the bucket partials of
    hierarchy.candidate_partials plus float32 +-1 sign partials such that
    child (p, c) of row k lives at cell ``pp[k,p] + cp[k,c]`` with sign
    ``sp[k,p] * sc[k,c]`` -- signs compose multiplicatively because the
    cumulative parity XORs (the separability the mixed radix gives the
    index, the group product gives the sign).  Pure jnp, jittable.
    """
    spec_l = hspec.levels[level]
    lp = level_params(hspec, params, level)
    w = spec_l.width
    r_last = spec_l.ranges[-1]

    if level == 0:
        pp = jnp.zeros((w, prefixes.shape[0]), jnp.uint32)
        sp = jnp.ones((w, prefixes.shape[0]), jnp.float32)
    else:
        prefix_spec = hspec.levels[level - 1]
        prefix_params = level_params(hspec, params, level - 1)
        pp = sk.compute_indices(prefix_spec, prefix_params.base, prefixes)
        pp = pp * jnp.uint32(r_last)
        sp = signs(prefix_spec, prefix_params, prefixes)

    cp = sk.group_subindex(spec_l, lp.base, level, values)
    sc = 1.0 - 2.0 * group_sign_parity(spec_l, lp, level,
                                       values).astype(jnp.float32)
    return pp, cp, sp, sc


def candidate_estimates(
    hspec: hh.HierarchySpec,
    state: CountSketchHierarchy,
    level: int,
    prefixes: np.ndarray,    # uint32[P, n_prefix_modules]
    values: np.ndarray,      # uint32[C, len(level group modules)]
    *,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
    tile_h: int = 512,
    max_batch: Optional[int] = None,
) -> np.ndarray:
    """Median signed estimates for every (prefix x value) child: f32[P, C].

    ``use_kernel=True`` routes the per-row gather through the signed Pallas
    grid kernel (kernels/hier_query.hier_candidate_query_signed); the
    default is the jnp reference.  Both agree bit-for-bit on int32 tables
    (the kernel's two-limb gather only covers int32; other dtypes always
    take the reference path).  ``max_batch`` chunks the prefix axis only,
    like hierarchy.candidate_estimates.
    """
    prefixes = jnp.asarray(np.asarray(prefixes, dtype=np.uint32))
    values = jnp.asarray(np.asarray(values, dtype=np.uint32))
    pp, cp, sp, sc = candidate_signed_partials(hspec, state.params, level,
                                               prefixes, values)
    table = state.tables[level]
    from repro.kernels.hier_query import (
        hier_candidate_query_signed,
        hier_candidate_query_signed_ref,
    )
    if use_kernel and table.dtype == jnp.int32:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        def one(pp_chunk, sp_chunk):
            per_row = hier_candidate_query_signed(
                table, pp_chunk, cp, sp_chunk, sc, tile_h=tile_h,
                interpret=interpret)
            return jnp.median(per_row, axis=0)
    else:
        def one(pp_chunk, sp_chunk):
            per_row = hier_candidate_query_signed_ref(table, pp_chunk, cp,
                                                      sp_chunk, sc)
            return jnp.median(per_row, axis=0)

    p, c = pp.shape[1], cp.shape[1]
    if max_batch is None or p * c <= max_batch:
        return np.asarray(one(pp, sp))
    p_chunk = max(1, max_batch // max(c, 1))
    outs = []
    for s in range(0, p, p_chunk):
        ppc, spc = pp[:, s : s + p_chunk], sp[:, s : s + p_chunk]
        if ppc.shape[1] < p_chunk:
            # pad to the fixed chunk width so one compiled kernel serves
            # every chunk (pad index 0 is always a valid cell; sliced off)
            pad = p_chunk - ppc.shape[1]
            ppc = jnp.pad(ppc, ((0, 0), (0, pad)))
            spc = jnp.pad(spc, ((0, 0), (0, pad)), constant_values=1.0)
        outs.append(np.asarray(one(ppc, spc)))
    return np.concatenate(outs, axis=0)[:p]


def find_heavy_hitters(
    hspec: hh.HierarchySpec,
    state: CountSketchHierarchy,
    threshold: float,
    candidates: Sequence[np.ndarray],
    *,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
    max_batch: int = 1 << 16,
) -> Tuple[np.ndarray, np.ndarray]:
    """All keys whose |median estimate| >= ``threshold`` (signed descent).

    The CM descent prunes on overestimates; the signed descent prunes on
    |median|, which is unbiased per level -- a heavy prefix survives unless
    w/2 of its rows are simultaneously pushed below threshold by collisions
    (probability bounded by the usual median argument at each level).
    Returns (items uint32[K, n_modules] in schema order, float32 estimates
    of the FINEST level) sorted by |estimate| descending.
    """
    if len(candidates) != hspec.n_levels:
        raise ValueError(
            f"need one candidate set per level ({hspec.n_levels}), "
            f"got {len(candidates)}")
    threshold = float(threshold)

    prefixes = np.zeros((1, 0), dtype=np.uint32)
    est = np.zeros((1,), dtype=np.float32)
    for lvl in range(hspec.n_levels):
        cand = np.asarray(candidates[lvl], dtype=np.uint32)
        if cand.ndim != 2 or cand.shape[1] != len(hspec.base.partition[lvl]):
            raise ValueError(
                f"candidates[{lvl}] must be "
                f"[C, {len(hspec.base.partition[lvl])}]")
        if prefixes.shape[0] == 0 or cand.shape[0] == 0:
            n_mods = len(hh.level_modules(hspec.base, hspec.n_levels - 1))
            return (np.zeros((0, n_mods), np.uint32),
                    np.zeros((0,), np.float32))
        grid = candidate_estimates(
            hspec, state, lvl, prefixes, cand, use_kernel=use_kernel,
            interpret=interpret, max_batch=max_batch)
        keep_p, keep_c = np.nonzero(np.abs(grid) >= threshold)
        prefixes = np.concatenate([prefixes[keep_p], cand[keep_c]], axis=1)
        est = grid[keep_p, keep_c]

    order = np.argsort(-np.abs(est), kind="stable")
    return hspec.to_schema_order(prefixes[order]), est[order]
