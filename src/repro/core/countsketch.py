"""Signed Count-Sketch variant of the composite-hash core (beyond paper).

Same partitioned indexing machinery as core/sketch.py, plus a +-1 sign hash
per (row, item).  Unbiased (median) estimates make this the right primitive
for *gradient* frequency/heavy-hitter sketching, where values are real and
cancellation matters -- used by training/grad_compression.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sketch as sk
from repro.core.hashing import addmod_p31, draw_hash_params, mulmod_p31_16


class CountSketchParams(NamedTuple):
    base: sk.SketchParams
    sign_q: jax.Array  # uint32[w, total_chunks]
    sign_r: jax.Array  # uint32[w]


class CountSketchState(NamedTuple):
    params: CountSketchParams
    table: jax.Array  # float32[w, h]


def init_state(spec: sk.SketchSpec, key: jax.Array, dtype=jnp.float32) -> CountSketchState:
    kb, kq, kr = jax.random.split(key, 3)
    base = sk.init_params(spec, kb)
    sign_q = draw_hash_params(kq, (spec.width, spec.schema.total_chunks))
    sign_r = draw_hash_params(kr, (spec.width,))
    table = jnp.zeros((spec.width, spec.table_size), dtype=dtype)
    return CountSketchState(CountSketchParams(base, sign_q, sign_r), table)


def _signs(spec: sk.SketchSpec, params: CountSketchParams, items: jax.Array) -> jax.Array:
    """+-1 per (row, item): independent CW hash over the full chunk vector."""
    chunks = spec.schema.module_chunks(items)  # [B, C]
    w = spec.width
    acc = jnp.broadcast_to(params.sign_r[:, None], (w, chunks.shape[0])).astype(jnp.uint32)
    for c in range(chunks.shape[1]):
        acc = addmod_p31(acc, mulmod_p31_16(params.sign_q[:, c][:, None], chunks[None, :, c]))
    return jnp.where((acc & jnp.uint32(1)) == 0, 1.0, -1.0).astype(jnp.float32)


def update(spec: sk.SketchSpec, state: CountSketchState, items: jax.Array,
           values: jax.Array) -> CountSketchState:
    idx = sk.compute_indices(spec, state.params.base, items)       # [w, B]
    s = _signs(spec, state.params, items)                          # [w, B]
    w, h = state.table.shape
    flat = (jnp.arange(w, dtype=jnp.uint32)[:, None] * jnp.uint32(h) + idx).reshape(-1)
    contrib = (s * values[None, :].astype(jnp.float32)).reshape(-1)
    table = state.table.reshape(-1).at[flat].add(contrib.astype(state.table.dtype)).reshape(w, h)
    return CountSketchState(state.params, table)


def query(spec: sk.SketchSpec, state: CountSketchState, items: jax.Array) -> jax.Array:
    """Unbiased median-of-rows estimate of each item's summed value."""
    return query_rows(spec, state, items)[1]


def query_rows(spec: sk.SketchSpec, state: CountSketchState,
               items: jax.Array):
    """(per-row estimates [w, Q], median [Q]) -- rows enable robustness
    filters (e.g. sign agreement) on top of the median."""
    idx = sk.compute_indices(spec, state.params.base, items)
    s = _signs(spec, state.params, items)
    vals = jnp.take_along_axis(state.table, idx.astype(jnp.int32), axis=1) * s
    return vals, jnp.median(vals, axis=0)
