"""Unified composite-hash sketch family (paper SIII).

Every sketch studied in the paper is one point of a single family::

    SketchSpec = (partition G = {g_1..g_m} of modules, ranges r_1..r_m, width w)
    row index  = sum_j  H_{k,j}(pack(key[g_j])) * stride_j     (mixed radix)

  * Count-Min    : G = {{0..n-1}},        r_1 = h
  * Equal-Sketch : G = {{0},..,{n-1}},    r_j = h^(1/n)
  * MOD-Sketch   : data-dependent G and r (Thm 3 / Algorithm 1)

Update adds +f to one cell per row; query takes the min over rows.  The table
is linear in the stream, hence sketches merge by cell-wise addition -- the
basis of the distributed runtime (core/distributed.py) and of the Pallas
one-hot-matmul update kernel (kernels/).

This module is the *reference* JAX implementation (jnp scatter/gather).  The
performance path lives in kernels/ops.py and is verified against this one.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import (
    P31,
    KeySchema,
    addmod_p31,
    cw_hash,
    cw_hash_np,
    draw_hash_params,
    mulmod_p31_16,
)


# --------------------------------------------------------------------------
# Spec
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Static description of a composite-hash sketch."""
    schema: KeySchema
    partition: Tuple[Tuple[int, ...], ...]  # ordered groups of module indices
    ranges: Tuple[int, ...]                 # hash range per group
    width: int                              # w rows

    def __post_init__(self):
        n = self.schema.modularity
        seen = sorted(i for g in self.partition for i in g)
        if seen != list(range(n)):
            raise ValueError(f"partition {self.partition} does not cover 0..{n-1}")
        if len(self.ranges) != len(self.partition):
            raise ValueError("one range per group required")
        for r in self.ranges:
            if r < 1:
                raise ValueError(f"range {r} < 1")
        if self.width < 1:
            raise ValueError("width >= 1 required")

    @property
    def n_groups(self) -> int:
        return len(self.partition)

    @property
    def table_size(self) -> int:
        """Cells per row: h = prod(ranges)."""
        return int(np.prod([int(r) for r in self.ranges], dtype=np.int64))

    @property
    def strides(self) -> Tuple[int, ...]:
        s, out = 1, []
        for r in reversed(self.ranges):
            out.append(s)
            s *= int(r)
        return tuple(reversed(out))

    def group_chunk_columns(self, j: int) -> Tuple[int, ...]:
        """Columns of the full chunk matrix belonging to group j (ordered)."""
        cols = []
        for mod in self.partition[j]:
            a, b = self.schema.chunk_slice(mod)
            cols.extend(range(a, b))
        return tuple(cols)

    def describe(self) -> str:
        gs = ",".join("{" + "+".join(str(m) for m in g) + "}" for g in self.partition)
        rs = "x".join(str(r) for r in self.ranges)
        return f"[{gs}] ranges={rs} (h={self.table_size}) w={self.width}"


def count_min_spec(schema: KeySchema, h: int, w: int) -> SketchSpec:
    """Paper baseline (1): concatenate all modules, one hash of range h."""
    return SketchSpec(schema, (tuple(range(schema.modularity)),), (int(h),), w)


def _floor_root(x: int, n: int) -> int:
    """max r >= 1 with r**n <= x, exact (float root + integer adjustment)."""
    r = max(1, int(round(x ** (1.0 / n))))
    while r > 1 and r ** n > x:
        r -= 1
    while (r + 1) ** n <= x:
        r += 1
    return r


def equal_ranges(h: int, n: int) -> Tuple[int, ...]:
    """n integer ranges ~ h^(1/n) with ``prod(ranges) <= h`` guaranteed.

    Greedy floor-root split: range j is the floor (n-j)-th root of the
    remaining budget, so the product never exceeds the allocated table size
    (the old round-and-nudge version overshot badly for small h / large n,
    e.g. h=2, n=3 gave 2*2*2 = 4x the budget) while still tracking h from
    below (paper's own integer examples are approximate too, e.g. 848*424
    vs h=360000).  Ranges degrade to 1 when h < 2**n.
    """
    if n < 1:
        raise ValueError("need n >= 1 ranges")
    rem = max(1, int(h))
    ranges = []
    for j in range(n):
        r = _floor_root(rem, n - j)
        ranges.append(r)
        rem //= r
    return tuple(ranges)


def equal_sketch_spec(schema: KeySchema, h: int, w: int) -> SketchSpec:
    """Paper baseline (2) (= TCM / gMatrix / reversible-sketch style)."""
    n = schema.modularity
    return SketchSpec(schema, tuple((i,) for i in range(n)), equal_ranges(h, n), w)


def mod_sketch_spec(
    schema: KeySchema,
    partition: Sequence[Sequence[int]],
    ranges: Sequence[int],
    w: int,
) -> SketchSpec:
    return SketchSpec(
        schema,
        tuple(tuple(int(m) for m in g) for g in partition),
        tuple(int(r) for r in ranges),
        w,
    )


# --------------------------------------------------------------------------
# Params & state
# --------------------------------------------------------------------------

class SketchParams(NamedTuple):
    """Hash parameters: one CW vector hash per (row, group)."""
    q: jax.Array  # uint32[w, total_chunks]
    r: jax.Array  # uint32[w, n_groups]


class SketchState(NamedTuple):
    params: SketchParams
    table: jax.Array  # [w, h]


def init_params(spec: SketchSpec, key: jax.Array) -> SketchParams:
    kq, kr = jax.random.split(key)
    q = draw_hash_params(kq, (spec.width, spec.schema.total_chunks))
    r = draw_hash_params(kr, (spec.width, spec.n_groups))
    return SketchParams(q=q, r=r)


def init_state(spec: SketchSpec, key: jax.Array, dtype=jnp.int32) -> SketchState:
    params = init_params(spec, key)
    table = jnp.zeros((spec.width, spec.table_size), dtype=dtype)
    return SketchState(params=params, table=table)


# --------------------------------------------------------------------------
# Indexing / update / query
# --------------------------------------------------------------------------

def compute_indices(spec: SketchSpec, params: SketchParams, items: jax.Array) -> jax.Array:
    """Cell index per (row, item): uint32[w, B].

    items: uint32[B, n_modules].
    """
    chunks = spec.schema.module_chunks(items)  # [B, C]
    w = spec.width
    idx = jnp.zeros((w, chunks.shape[0]), dtype=jnp.uint32)
    for j, (rng_j, stride_j) in enumerate(zip(spec.ranges, spec.strides)):
        cols = spec.group_chunk_columns(j)
        gchunks = chunks[:, list(cols)]                       # [B, Cj]
        # vector hash per row k: fold over the group's chunks
        acc = jnp.broadcast_to(params.r[:, j][:, None], (w, chunks.shape[0]))
        acc = acc.astype(jnp.uint32)
        for ci, c in enumerate(cols):
            acc = addmod_p31(acc, mulmod_p31_16(params.q[:, c][:, None], gchunks[None, :, ci]))
        hj = acc % jnp.uint32(rng_j)
        idx = idx + hj * jnp.uint32(stride_j)
    return idx


def compute_indices_np(spec: SketchSpec, params: SketchParams, items: np.ndarray) -> np.ndarray:
    """Host oracle for compute_indices (uint64 arithmetic)."""
    chunks = spec.schema.module_chunks_np(np.asarray(items))
    q = np.asarray(params.q)
    r = np.asarray(params.r)
    w = spec.width
    idx = np.zeros((w, chunks.shape[0]), dtype=np.uint64)
    for j, (rng_j, stride_j) in enumerate(zip(spec.ranges, spec.strides)):
        cols = list(spec.group_chunk_columns(j))
        for k in range(w):
            hk = cw_hash_np(chunks[:, cols], q[k, cols], int(r[k, j]))
            idx[k] += (hk.astype(np.uint64) % np.uint64(rng_j)) * np.uint64(stride_j)
    return idx.astype(np.uint32)


def add_at_indices(table: jax.Array, idx: jax.Array,
                   freqs: jax.Array) -> jax.Array:
    """Scatter-add ``freqs`` into ``table`` at per-row cell indices.

    idx: uint32[w, B] (one cell per row per item).  This is the linear-update
    primitive shared by :func:`update` and the hierarchy's cascade path
    (core/hierarchy.py), where the indices are derived once for all levels."""
    w, h = table.shape
    flat = (jnp.arange(w, dtype=jnp.uint32)[:, None] * jnp.uint32(h) + idx).reshape(-1)
    f = jnp.broadcast_to(freqs.astype(table.dtype), (w, freqs.shape[0])).reshape(-1)
    return table.reshape(-1).at[flat].add(f).reshape(w, h)


def update(
    spec: SketchSpec,
    state: SketchState,
    items: jax.Array,
    freqs: jax.Array,
) -> SketchState:
    """Fold a block of (item, freq) pairs into the sketch (order-free)."""
    idx = compute_indices(spec, state.params, items)          # [w, B]
    return SketchState(params=state.params,
                       table=add_at_indices(state.table, idx, freqs))


def query(spec: SketchSpec, state: SketchState, items: jax.Array) -> jax.Array:
    """Count-Min style point query: min over rows (overestimate)."""
    idx = compute_indices(spec, state.params, items)          # [w, B]
    vals = jnp.take_along_axis(state.table, idx.astype(jnp.int32), axis=1)
    return jnp.min(vals, axis=0)


def conservative_fold(table: jax.Array, idx: jax.Array,
                      freqs: jax.Array) -> jax.Array:
    """Estan-Varghese fold with precomputed indices (sequential in B).

    cell_k <- max(cell_k, min_k(cell_k) + f), one item at a time; the min
    couples all w rows so the loop cannot be batched.  Shared by
    :func:`update_conservative` and the hierarchy's cascade path, which
    hashes once and feeds every level's derived indices through this fold."""
    w = table.shape[0]

    def body(b, tbl):
        cells = idx[:, b].astype(jnp.int32)
        cur = tbl[jnp.arange(w), cells]
        est = jnp.min(cur) + freqs[b].astype(tbl.dtype)
        new = jnp.maximum(cur, est)
        return tbl.at[jnp.arange(w), cells].set(new)

    return jax.lax.fori_loop(0, idx.shape[1], body, table)


def update_conservative(
    spec: SketchSpec,
    state: SketchState,
    items: jax.Array,
    freqs: jax.Array,
) -> SketchState:
    """Conservative update (beyond-paper accuracy option; breaks linearity).

    Sequential over the block via fori_loop: cell_k <- max(cell_k, est + f).
    Not mergeable across shards -- excluded from the distributed runtime.
    """
    idx = compute_indices(spec, state.params, items)          # [w, B]
    return SketchState(params=state.params,
                       table=conservative_fold(state.table, idx, freqs))


def check_conservative_freqs(freqs, table_dtype) -> None:
    """Validate a conservative-update frequency block (host-side; shared by
    kernels/ops.KernelSketch and serving.engine.SketchTopKEndpoint).

    f < 0 would make est = min + f <= every cell, a silent no-op; an int
    frequency past the table dtype's range would wrap negative in the cast
    with the same silent outcome.  Both are rejected loudly.
    """
    freqs = np.asarray(freqs)
    if freqs.size == 0:
        return
    if not np.all(freqs >= 0):   # catches f < 0 AND NaN
        raise ValueError(
            "conservative update requires non-negative frequencies "
            "(f < 0 would be a silent no-op; NaN would poison every "
            "touched cell)")
    if (jnp.issubdtype(table_dtype, jnp.integer)
            and freqs.max() > np.iinfo(np.dtype(table_dtype)).max):
        raise ValueError(
            f"per-arrival frequency exceeds the {np.dtype(table_dtype)} "
            "table range (the cast would wrap negative and the update "
            "would silently no-op): use a wider table dtype")


def merge(a: SketchState, b: SketchState) -> SketchState:
    """Cell-wise merge: sketch(A + B) == merge(sketch(A), sketch(B)) exactly."""
    return SketchState(params=a.params, table=a.table + b.table)


def group_subindex(spec: SketchSpec, params: SketchParams, group: int,
                   values: jax.Array) -> jax.Array:
    """Sub-index of ``values`` within ``group``'s hash range: uint32[w, Q].

    ``values``: uint32[Q, len(group modules)] module values for the group.
    This is the per-group factor of the mixed-radix cell address; both the
    marginal query below and the hierarchy's separable candidate queries
    (core/hierarchy.py) are built from it.
    """
    vcols = []
    for mi, mod in enumerate(spec.partition[group]):
        nc = spec.schema.chunk_counts[mod]
        v = values[..., mi].astype(jnp.uint32)
        for c in range(nc):
            vcols.append((v >> jnp.uint32(16 * c)) & jnp.uint32(0xFFFF))
    gchunks = jnp.stack(vcols, axis=-1)                       # [Q, Cg]

    w = spec.width
    acc = jnp.broadcast_to(params.r[:, group][:, None],
                           (w, values.shape[0])).astype(jnp.uint32)
    for ci, c in enumerate(spec.group_chunk_columns(group)):
        acc = addmod_p31(acc, mulmod_p31_16(params.q[:, c][:, None],
                                            gchunks[None, :, ci]))
    return acc % jnp.uint32(spec.ranges[group])


def query_marginal(spec: SketchSpec, state: SketchState, group: int,
                   values: jax.Array) -> jax.Array:
    """Subspace query: estimate O(*,..,value,..,*) -- the total frequency of
    all items whose ``group`` equals ``value`` (e.g. a node's out-degree mass
    for an edge stream).

    This is the structural capability composite hashing buys over Count-Min
    (the gMatrix/TCM motivation the paper cites): the group's sub-index is a
    separate factor of the cell address, so the marginal is the sum of the
    ``h / range_j`` cells sharing that sub-index, min'd over rows.  Count-Min
    would have to enumerate every key.  ``values``: uint32[Q, len(group
    modules)] module values for the queried group.
    """
    w = spec.width
    sub_idx = group_subindex(spec, state.params, group,
                             values).astype(jnp.int32)         # [w, Q]

    # sum the cells sharing this sub-index: reshape the row into the mixed-
    # radix grid, reduce every axis except this group's
    grid = state.table.reshape((w,) + tuple(spec.ranges))
    axes = tuple(1 + j for j in range(spec.n_groups) if j != group)
    per_value = jnp.sum(grid, axis=axes) if axes else grid     # [w, range_g]
    vals = jnp.take_along_axis(per_value, sub_idx, axis=1)     # [w, Q]
    return jnp.min(vals, axis=0)


def cell_std(table: jax.Array) -> jax.Array:
    """Std-dev of all cell values -- the Thm 4/5 selection statistic."""
    return jnp.std(table.astype(jnp.float64 if table.dtype == jnp.int64 else jnp.float32))


# --------------------------------------------------------------------------
# Convenience jit'd entry points (static spec)
# --------------------------------------------------------------------------

import functools


# The jit'd update wrappers donate the TABLE buffer (ingest folds the block
# in place instead of copying the table every call) but deliberately not the
# hash params: params are shared across states, query paths, and merge
# checks, so donating them would invalidate live references (donation is
# effective on CPU too, not just TPU).  Callers must rebind the state to the
# returned value -- every streaming build here does (state = update_jit(...)).

@functools.partial(jax.jit, static_argnums=0, donate_argnums=(1,))
def _update_table_jit(spec: SketchSpec, table, params, items, freqs):
    idx = compute_indices(spec, params, items)
    return add_at_indices(table, idx, freqs)


def update_jit(spec: SketchSpec, state: SketchState, items, freqs) -> SketchState:
    table = _update_table_jit(spec, state.table, state.params, items, freqs)
    return SketchState(params=state.params, table=table)


@functools.partial(jax.jit, static_argnums=0)
def query_jit(spec: SketchSpec, state: SketchState, items) -> jax.Array:
    return query(spec, state, items)


@functools.partial(jax.jit, static_argnums=0, donate_argnums=(1,))
def _update_conservative_table_jit(spec: SketchSpec, table, params,
                                   items, freqs):
    idx = compute_indices(spec, params, items)
    return conservative_fold(table, idx, freqs)


def update_conservative_jit(spec: SketchSpec, state: SketchState,
                            items, freqs) -> SketchState:
    table = _update_conservative_table_jit(spec, state.table, state.params,
                                           items, freqs)
    return SketchState(params=state.params, table=table)


def stream_blocks(items, freqs, block: int):
    """Yield a weighted stream as fixed-size jnp blocks.

    Short tails are zero-padded (zero-frequency items are no-ops under
    ``update``) so a single compiled update serves the whole stream.  This
    is the one block/pad loop shared by every streaming build
    (:func:`build_sketch`, hierarchy.build_hierarchy).
    """
    items = np.asarray(items, dtype=np.uint32)
    freqs = np.asarray(freqs)
    n = items.shape[0]
    for s in range(0, n, block):
        e = min(n, s + block)
        blk_items = items[s:e]
        blk_freqs = freqs[s:e]
        if e - s < block and n > block:
            pad = block - (e - s)
            blk_items = np.pad(blk_items, ((0, pad), (0, 0)))
            blk_freqs = np.pad(blk_freqs, (0, pad))
        yield jnp.asarray(blk_items), jnp.asarray(blk_freqs)


def build_sketch(
    spec: SketchSpec,
    key: jax.Array,
    items: np.ndarray | jax.Array,
    freqs: np.ndarray | jax.Array,
    block: int = 1 << 18,
    dtype=jnp.int32,
) -> SketchState:
    """Build a sketch over a (possibly large) weighted stream, in blocks."""
    state = init_state(spec, key, dtype=dtype)
    for blk_items, blk_freqs in stream_blocks(items, freqs, block):
        state = update_jit(spec, state, blk_items, blk_freqs)
    return state
