"""MOD-Sketch core: composite hashing for data-stream sketches (the paper's
contribution), plus the Count-Min / Equal-Sketch / FCM baselines and the
distributed (mesh-sharded) runtime."""
from repro.core.hashing import KeySchema, P31  # noqa: F401
from repro.core.sketch import (  # noqa: F401
    SketchParams,
    SketchSpec,
    SketchState,
    build_sketch,
    cell_std,
    count_min_spec,
    equal_sketch_spec,
    init_state,
    merge,
    mod_sketch_spec,
    query,
    query_jit,
    update,
    update_jit,
)
from repro.core.range_opt import optimal_ranges_mod2, recursive_ranges, split_range  # noqa: F401
from repro.core.selection import choose_sketch  # noqa: F401
from repro.core.greedy import greedy_config  # noqa: F401
from repro.core.partition import all_partitions, bell_number  # noqa: F401
