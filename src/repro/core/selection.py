"""Sketch selection by cell-value standard deviation (paper Thm 4/5, SIV-B).

Between two equal-size sketches built over the *same uniform sample*, the one
with smaller cell-value standard deviation yields smaller estimation error
with high probability (Cantelli).  Thm 5 shows the sample decision transfers
to the full stream since (sigma^p)^2 = p * sigma^2 under uniform sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.core.range_opt import Aggregate, optimal_ranges_mod2


def sample_cell_std(
    spec: sk.SketchSpec,
    key: jax.Array,
    items: np.ndarray,
    freqs: np.ndarray,
) -> float:
    """Build ``spec`` over the sample and return the cell std statistic."""
    state = sk.build_sketch(spec, key, items, freqs)
    return float(sk.cell_std(state.table))


@dataclasses.dataclass
class SelectionResult:
    choice: str                       # 'count-min' | 'mod-sketch'
    spec: sk.SketchSpec
    sigma: Dict[str, float]
    mod_ranges: Tuple[int, ...]


def choose_sketch(
    items: np.ndarray,
    freqs: np.ndarray,
    schema: KeySchema,
    h: int,
    w: int,
    key: jax.Array,
    agg: Aggregate = "median",
    candidates: Optional[Dict[str, sk.SketchSpec]] = None,
) -> SelectionResult:
    """Paper SIV summary steps (1)-(3) for modularity-2 keys.

    (1) the caller supplies the uniform sample; (2) find optimal MOD ranges
    (a, b) via Thm 3; (3) store the sample in both Count-Min and MOD-Sketch
    and keep the one with smaller cell std.  ``candidates`` may override /
    extend the compared specs (used by Algorithm 1, which reuses this
    criterion to score greedy choices).
    """
    if candidates is None:
        a, b = optimal_ranges_mod2(items, freqs, h, agg)
        candidates = {
            "count-min": sk.count_min_spec(schema, h, w),
            "mod-sketch": sk.mod_sketch_spec(schema, [(0,), (1,)], (a, b), w),
        }
    sigma: Dict[str, float] = {}
    for i, (name, spec) in enumerate(candidates.items()):
        sigma[name] = sample_cell_std(spec, jax.random.fold_in(key, i), items, freqs)
    choice = min(sigma, key=sigma.get)
    spec = candidates[choice]
    mod_ranges = candidates.get("mod-sketch", spec).ranges
    return SelectionResult(choice=choice, spec=spec, sigma=sigma, mod_ranges=mod_ranges)


def migration_gain(
    current: sk.SketchSpec,
    proposed: sk.SketchSpec,
    items: np.ndarray,
    freqs: np.ndarray,
    key: jax.Array,
) -> Tuple[float, float]:
    """Thm 4/5 criterion applied to a hot-migration decision.

    Builds both specs over the SAME weighted sample (the live proxy
    sample from streams/livestats.py in the online setting) and returns
    ``(sigma_current, sigma_proposed)``.  The smaller cell-value standard
    deviation predicts the smaller estimation error with high probability
    (Cantelli), so a migration is worth its double-write window when
    ``sigma_proposed`` undercuts ``sigma_current`` by a real margin --
    serving/autotune.py requires ``sigma_proposed < min_improvement *
    sigma_current`` before triggering one.
    """
    sigma_cur = sample_cell_std(current, jax.random.fold_in(key, 0),
                                items, freqs)
    sigma_new = sample_cell_std(proposed, jax.random.fold_in(key, 1),
                                items, freqs)
    return sigma_cur, sigma_new
