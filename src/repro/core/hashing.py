"""Pairwise-independent modular hashing (paper Eq. 1, generalized).

The paper uses ``H(i) = ((q*i + r) mod P) mod range`` with ``P`` a prime larger
than any key.  Packed modular keys can exceed 61 bits (e.g. modularity-8 IPv4
keys pack to 64 bits), and TPU Pallas has no 64-bit integer lanes, so we use
the standard Carter-Wegman *vector* generalization of the same family:

    H(x) = ((r + sum_c q_c * x_c) mod P) mod range,     P = 2^31 - 1

where ``x_c`` are the 16-bit chunks of the (domain-aware) packed key and
``q_c, r`` are uniform in ``[0, P)``.  This family is strongly universal
(pairwise independent) over distinct chunk vectors, hence over distinct keys,
and degenerates to Eq. 1 exactly for keys smaller than 2^16.  All collision
bounds used by the paper (Thms 1-3) only need pairwise independence, so the
guarantees carry over unchanged.

Everything here is exact uint32 limb arithmetic:

  * products are split so every partial product fits in 32 bits,
  * ``mod P`` uses the Mersenne reduction ``x mod (2^31-1) = (x >> 31) + (x & P)``.

The same functions run under ``jit``, inside Pallas kernel bodies, and on CPU,
bit-identical to the uint64 numpy oracle (`cw_hash_np`).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

P31 = np.uint32((1 << 31) - 1)  # Mersenne prime 2^31 - 1
_MASK16 = np.uint32(0xFFFF)
_MASK15 = np.uint32(0x7FFF)


# --------------------------------------------------------------------------
# uint32 limb arithmetic (jnp; also valid inside Pallas kernel bodies)
# --------------------------------------------------------------------------

def mod_p31(x: jax.Array) -> jax.Array:
    """x (uint32, any value) mod P31, result in [0, P31)."""
    x = x.astype(jnp.uint32)
    s = (x >> jnp.uint32(31)) + (x & P31)
    # s < 2^31 + 1, so at most one conditional subtract; P31 itself maps to 0.
    return jnp.where(s >= P31, s - P31, s)


def mulmod_p31_16(a: jax.Array, x: jax.Array) -> jax.Array:
    """(a * x) mod P31 for a < P31 (31 bits) and x < 2^16, exact in uint32.

    Split ``a = a1*2^16 + a0`` so both partial products fit 32 bits:
      a0*x < 2^32 (exact uint32 product), a1*x < 2^31.
    Then reduce ``a1*x*2^16`` with the Mersenne shift identity.
    """
    a = a.astype(jnp.uint32)
    x = x.astype(jnp.uint32)
    a0 = a & _MASK16
    a1 = a >> jnp.uint32(16)          # < 2^15
    p0 = a0 * x                        # < 2^32, exact
    p1 = a1 * x                        # < 2^31, exact
    # (p1 << 16) mod P31: low 31 bits come from the low 15 bits of p1;
    # the high part is p1 >> 15 (since 2^31 = 1 mod P31).
    lo = (p1 & _MASK15) << jnp.uint32(16)   # < 2^31
    hi = p1 >> jnp.uint32(15)               # < 2^16
    t1 = mod_p31(lo + hi)
    t0 = mod_p31(p0)
    s = t1 + t0                              # < 2*P31 < 2^32
    return jnp.where(s >= P31, s - P31, s)


def addmod_p31(a: jax.Array, b: jax.Array) -> jax.Array:
    """(a + b) mod P31 for a, b < P31."""
    s = a.astype(jnp.uint32) + b.astype(jnp.uint32)
    return jnp.where(s >= P31, s - P31, s)


def cw_hash(chunks: jax.Array, q: jax.Array, r: jax.Array) -> jax.Array:
    """Carter-Wegman vector hash, uint32 limbs.

    chunks: uint32[..., C] with each value < 2^16
    q:      uint32[C]       multipliers  < P31
    r:      uint32[]        offset       < P31
    returns uint32[...] in [0, P31)
    """
    acc = jnp.broadcast_to(r.astype(jnp.uint32), chunks.shape[:-1])
    for c in range(chunks.shape[-1]):
        acc = addmod_p31(acc, mulmod_p31_16(q[c], chunks[..., c]))
    return acc


# --------------------------------------------------------------------------
# numpy uint64 oracle (host side / tests)
# --------------------------------------------------------------------------

def cw_hash_np(chunks: np.ndarray, q: np.ndarray, r: int | np.ndarray) -> np.ndarray:
    """Oracle: same hash with plain uint64 arithmetic.

    q*x < 2^31 * 2^16 = 2^47 per term; <= 64 chunk terms keeps the sum < 2^53,
    far below uint64 overflow, so a single final ``% P`` suffices.
    """
    chunks = chunks.astype(np.uint64)
    q = q.astype(np.uint64)
    acc = np.full(chunks.shape[:-1], np.uint64(r), dtype=np.uint64)
    for c in range(chunks.shape[-1]):
        acc = acc + q[c] * chunks[..., c]
    return (acc % np.uint64(P31)).astype(np.uint32)


# --------------------------------------------------------------------------
# Key schema: module domains -> 16-bit chunk layout
# --------------------------------------------------------------------------

def _chunks_for_domain(domain: int) -> int:
    """Number of 16-bit chunks needed for values in [0, domain)."""
    if domain < 2:
        return 1
    bits = int(domain - 1).bit_length()
    return (bits + 15) // 16


@dataclasses.dataclass(frozen=True)
class KeySchema:
    """Domains of the ordered modules of an item key (paper SIII).

    ``domains[i]`` is the size of module i's value set; module values are
    uint32 in ``[0, domains[i])``.  Packing a *group* of modules is the
    concatenation of each member's fixed-width 16-bit digit vector, which is
    injective given the fixed domains -- the paper's "consider the domains of
    the modules before concatenating them" (SIII-B), in digit form.
    """
    domains: Tuple[int, ...]

    def __post_init__(self):
        if not self.domains:
            raise ValueError("KeySchema needs at least one module")
        for d in self.domains:
            if not (2 <= d <= 1 << 32):
                raise ValueError(f"module domain {d} out of [2, 2^32]")

    @property
    def modularity(self) -> int:
        return len(self.domains)

    @property
    def chunk_counts(self) -> Tuple[int, ...]:
        return tuple(_chunks_for_domain(d) for d in self.domains)

    def module_chunks_np(self, items: np.ndarray) -> np.ndarray:
        """uint32[N, n_modules] -> uint32[N, total_chunks] of 16-bit digits."""
        cols = []
        for m, nc in enumerate(self.chunk_counts):
            v = items[..., m].astype(np.uint64)
            for c in range(nc):
                cols.append(((v >> np.uint64(16 * c)) & np.uint64(0xFFFF)).astype(np.uint32))
        return np.stack(cols, axis=-1)

    def module_chunks(self, items: jax.Array) -> jax.Array:
        """jnp version of :meth:`module_chunks_np` (uint32 in, uint32 out)."""
        cols = []
        for m, nc in enumerate(self.chunk_counts):
            v = items[..., m].astype(jnp.uint32)
            for c in range(nc):
                cols.append((v >> jnp.uint32(16 * c)) & jnp.uint32(0xFFFF))
        return jnp.stack(cols, axis=-1)

    def chunk_slice(self, module: int) -> Tuple[int, int]:
        """(start, stop) of module's chunks in the full chunk vector."""
        start = sum(self.chunk_counts[:module])
        return start, start + self.chunk_counts[module]

    @property
    def total_chunks(self) -> int:
        return sum(self.chunk_counts)


def draw_hash_params(key: jax.Array, shape: Sequence[int]) -> jax.Array:
    """Uniform multipliers/offsets in [0, P31), uint32."""
    v = jax.random.randint(key, tuple(shape), 0, int(P31), dtype=jnp.int32)
    return v.astype(jnp.uint32)


def draw_hash_params_np(rng: np.random.Generator, shape: Sequence[int]) -> np.ndarray:
    return rng.integers(0, int(P31), size=tuple(shape), dtype=np.int64).astype(np.uint32)
