"""Greedy hashing-strategy search for modularity > 2 (paper Algorithm 1, SV-B).

Walk the modules in order.  At the stage of module j the committed config
covers some prefix of modules; the (n-k+1) choices are:

    * hash x_j as its own part, or
    * combine x_j with a remaining module x_r (r > j) -- joining x_r's
      existing group if an earlier stage already grouped it (Fig. 3c).

Each choice is scored by building the induced *partial* sketch over the
uniform sample -- total range h^((#covered)/n), per-part ranges from the
SV-B1 recursive ratio method -- and comparing cell standard deviations
(the SIV-B criterion).  Range-ratio estimates are memoized in a shared
``beta_cache`` and reused across stages (SV-B2).  Total candidates scored:
sum_k (n-k+1) = O(n^2), vs. the Bell number T(n) for the exact search.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.core.partition import canonical
from repro.core.range_opt import Aggregate, BetaCache, recursive_ranges
from repro.core.selection import sample_cell_std


@dataclasses.dataclass
class GreedyTrace:
    """One scored candidate (kept for tests / Fig. 6-9 style reporting)."""
    stage: int
    partition: Tuple[Tuple[int, ...], ...]
    covered: Tuple[int, ...]
    ranges: Tuple[int, ...]
    sigma: float
    chosen: bool


@dataclasses.dataclass
class GreedyResult:
    spec: sk.SketchSpec
    trace: List[GreedyTrace]
    n_candidates: int
    beta_cache_hits: int


def _projected_spec(
    schema: KeySchema,
    groups: Sequence[Sequence[int]],
    covered: Sequence[int],
    ranges: Sequence[int],
    w: int,
) -> Tuple[sk.SketchSpec, List[int]]:
    """Spec over the sub-key of ``covered`` modules (renumbered 0..c-1)."""
    covered = sorted(covered)
    remap = {m: i for i, m in enumerate(covered)}
    sub_schema = KeySchema(domains=tuple(schema.domains[m] for m in covered))
    sub_groups = tuple(tuple(remap[m] for m in g) for g in groups)
    return sk.SketchSpec(sub_schema, sub_groups, tuple(ranges), w), covered


def _score_partition(
    items: np.ndarray,
    freqs: np.ndarray,
    schema: KeySchema,
    groups: Sequence[Sequence[int]],
    total_range: float,
    w: int,
    key: jax.Array,
    agg: Aggregate,
    beta_cache: BetaCache,
) -> Tuple[float, Tuple[int, ...]]:
    groups = canonical(groups)
    covered = sorted(m for g in groups for m in g)
    sub_items = np.ascontiguousarray(items[:, covered])
    # renumber groups into the projected column space for the marginal calc
    remap = {m: i for i, m in enumerate(covered)}
    proj_groups = [tuple(remap[m] for m in g) for g in groups]
    ranges = recursive_ranges(sub_items, freqs, proj_groups, total_range, agg, beta_cache)
    spec, _ = _projected_spec(schema, groups, covered, ranges, w)
    sigma = sample_cell_std(spec, key, sub_items, freqs)
    return sigma, ranges


def greedy_config(
    items: np.ndarray,
    freqs: np.ndarray,
    schema: KeySchema,
    h: int,
    w: int,
    key: jax.Array,
    agg: Aggregate = "median",
) -> GreedyResult:
    """Algorithm 1: greedy composite-hashing strategy for modularity-n keys."""
    n = schema.modularity
    if n < 2:
        raise ValueError("greedy search needs modularity >= 2")

    group_of: Dict[int, int] = {}          # module -> group id
    groups: Dict[int, List[int]] = {}      # group id -> members
    next_gid = 0
    beta_cache: BetaCache = {}
    trace: List[GreedyTrace] = []
    n_candidates = 0
    cache_hits = 0

    for j in range(n):
        if j in group_of:
            continue  # already combined by an earlier stage
        # ------------------------------------------------------ candidates
        # each candidate: (description, groups-after-choice)
        cands: List[Tuple[str, List[List[int]]]] = []
        base = [sorted(members) for members in groups.values()]
        cands.append(("separate", base + [[j]]))
        seen_struct = set()
        for r in range(j + 1, n):
            if r in group_of:
                tgt = sorted(groups[group_of[r]] + [j])
                rest = [sorted(m) for gid, m in groups.items() if gid != group_of[r]]
                struct = canonical(rest + [tgt])
            else:
                struct = canonical(base + [[j, r]])
            if struct in seen_struct:
                continue
            seen_struct.add(struct)
            cands.append((f"merge({j},{r})", [list(g) for g in struct]))

        # ------------------------------------------------------ score
        best = None
        stage_traces = []
        for ci, (_, cand_groups) in enumerate(cands):
            covered = sorted(m for g in cand_groups for m in g)
            total_range = float(h) ** (len(covered) / n)
            before = len(beta_cache)
            sigma, ranges = _score_partition(
                items, freqs, schema, cand_groups, total_range, w,
                jax.random.fold_in(key, 1000 * j + ci), agg, beta_cache,
            )
            n_candidates += 1
            if len(beta_cache) == before and len(cand_groups) > 1:
                cache_hits += 1  # every ratio this candidate needed was cached
            t = GreedyTrace(
                stage=j, partition=canonical(cand_groups), covered=tuple(covered),
                ranges=ranges, sigma=sigma, chosen=False,
            )
            stage_traces.append((sigma, t, cand_groups))
            if best is None or sigma < best[0]:
                best = (sigma, t, cand_groups)

        best[1].chosen = True
        trace.extend(t for _, t, _ in stage_traces)

        # ------------------------------------------------------ commit
        groups = {}
        group_of = {}
        for gi, g in enumerate(canonical(best[2])):
            groups[gi] = list(g)
            for m in g:
                group_of[m] = gi
        next_gid = len(groups)

    # final ranges over the full key with the full budget h
    final_partition = canonical([g for g in groups.values()])
    ranges = recursive_ranges(items, freqs, final_partition, float(h), agg, beta_cache)
    spec = sk.SketchSpec(schema, final_partition, tuple(ranges), w)
    return GreedyResult(spec=spec, trace=trace, n_candidates=n_candidates,
                        beta_cache_hits=cache_hits)
