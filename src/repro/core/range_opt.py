"""Data-dependent hash-range optimization (paper Thm 3 and SV-B1).

Given a uniform sample of the stream, estimate per-item

    alpha = O(x1, *) / O(*, x2)

from the sample marginals, aggregate over sampled occurrences (the paper's
default: frequency-weighted median, SIV-A / Example 1 / Fig. 11), and set the
range ratio ``beta = a/b = 1/alpha_agg`` with ``a*b = h``:

    a = sqrt(h / alpha_agg),    b = sqrt(h * alpha_agg)

(This is the AM-GM optimum of the Thm 2/3 error bound.)

For m > 2 separately-hashed parts, the recursive strategy of SV-B1 peels the
last part: beta_m = a_m / a_{1..m-1} with alpha_m = O(*,..,*,y_m) /
O(y_1..y_{m-1}, *), then recurses on the prefix with budget h / a_m.
Computed alpha aggregates are memoized (``beta_cache``) and reused across
greedy stages (SV-B2 "re-using of range ratio estimation").
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Aggregate = str  # 'median' | 'mean' | 'min' | 'max'

# Clamp on the estimated ratio so degenerate samples can't produce ranges < 2.
_BETA_MIN, _BETA_MAX = 1e-6, 1e6


# --------------------------------------------------------------------------
# Sample marginals
# --------------------------------------------------------------------------

def aggregate_sample(items: np.ndarray, freqs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Collapse a sampled stream to (distinct item, sampled frequency)."""
    items = np.ascontiguousarray(np.asarray(items, dtype=np.uint32))
    freqs = np.asarray(freqs, dtype=np.int64)
    uniq, inv = np.unique(items, axis=0, return_inverse=True)
    agg = np.bincount(inv, weights=freqs.astype(np.float64), minlength=len(uniq))
    return uniq, agg.astype(np.int64)


def marginal_per_item(items: np.ndarray, freqs: np.ndarray, cols: Sequence[int]) -> np.ndarray:
    """For each row, the total sampled frequency of items that agree on ``cols``.

    I.e. O(value-of-cols, *) evaluated at every sampled item.
    """
    sub = np.ascontiguousarray(items[:, list(cols)])
    _, inv = np.unique(sub, axis=0, return_inverse=True)
    sums = np.bincount(inv, weights=freqs.astype(np.float64))
    return sums[inv]


def weighted_median(values: np.ndarray, weights: np.ndarray) -> float:
    """Median of the multiset where value v appears weight(v) times (Ex. 1)."""
    order = np.argsort(values, kind="stable")
    v = np.asarray(values, dtype=np.float64)[order]
    w = np.asarray(weights, dtype=np.float64)[order]
    cw = np.cumsum(w)
    cut = 0.5 * cw[-1]
    return float(v[np.searchsorted(cw, cut)])


def aggregate_alpha(alphas: np.ndarray, freqs: np.ndarray, agg: Aggregate = "median") -> float:
    """Aggregate per-item alphas over sampled occurrences (Fig. 11 variants)."""
    a = np.asarray(alphas, dtype=np.float64)
    f = np.asarray(freqs, dtype=np.float64)
    if agg == "median":
        val = weighted_median(a, f)
    elif agg == "mean":
        val = float(np.sum(a * f) / np.sum(f))
    elif agg == "min":
        val = float(np.min(a))
    elif agg == "max":
        val = float(np.max(a))
    else:
        raise ValueError(f"unknown aggregate {agg!r}")
    return float(np.clip(val, _BETA_MIN, _BETA_MAX))


def estimate_alpha(
    items: np.ndarray,
    freqs: np.ndarray,
    first_cols: Sequence[int],
    second_cols: Sequence[int],
    agg: Aggregate = "median",
) -> float:
    """alpha_agg = aggregate of O(first,*)/O(*,second) over the sample."""
    uniq, f = aggregate_sample(items, freqs)
    m1 = marginal_per_item(uniq, f, first_cols)
    m2 = marginal_per_item(uniq, f, second_cols)
    return aggregate_alpha(m1 / m2, f, agg)


# --------------------------------------------------------------------------
# Range splitting
# --------------------------------------------------------------------------

def split_range(h: float, beta: float) -> Tuple[int, int]:
    """Integer (a, b) with a/b ~ beta and a*b ~ h (Thm 3).

    a = sqrt(h*beta), b = sqrt(h/beta).  Paper example: h = 360000,
    beta = 2 -> (849, 424); the paper itself reports 848 x 424, i.e. integer
    products are approximate by design.
    """
    beta = float(np.clip(beta, _BETA_MIN, _BETA_MAX))
    a = max(2, int(round(math.sqrt(h * beta))))
    b = max(2, int(round(h / a)))
    return a, b


def optimal_ranges_mod2(
    items: np.ndarray,
    freqs: np.ndarray,
    h: int,
    agg: Aggregate = "median",
) -> Tuple[int, int]:
    """Thm 3 end-to-end for modularity-2 keys: sample -> alpha_agg -> (a, b)."""
    alpha = estimate_alpha(items, freqs, [0], [1], agg)
    return split_range(h, 1.0 / alpha)


# --------------------------------------------------------------------------
# Recursive ranges for m separately-hashed parts (SV-B1)
# --------------------------------------------------------------------------

BetaCache = Dict[Tuple[Tuple[int, ...], ...], float]


def _alpha_for_split(
    uniq: np.ndarray,
    f: np.ndarray,
    prefix_groups: Sequence[Sequence[int]],
    last_group: Sequence[int],
    agg: Aggregate,
) -> float:
    prefix_cols = [c for g in prefix_groups for c in g]
    m_last = marginal_per_item(uniq, f, list(last_group))
    m_prefix = marginal_per_item(uniq, f, prefix_cols)
    # alpha_m = O(*,...,*, y_m) / O(y_1..y_{m-1}, *)
    return aggregate_alpha(m_last / m_prefix, f, agg)


def recursive_ranges(
    items: np.ndarray,
    freqs: np.ndarray,
    groups: Sequence[Sequence[int]],
    h: float,
    agg: Aggregate = "median",
    beta_cache: Optional[BetaCache] = None,
) -> Tuple[int, ...]:
    """Optimal ranges a_1..a_m for parts ``groups`` with prod ~ h (SV-B1).

    beta_m = 1/alpha_m gives a_m = sqrt(h * beta_m); recurse on the prefix
    with budget h / a_m until one part remains.  ``beta_cache`` memoizes
    alpha aggregates keyed by the (prefix, last) group structure so greedy
    stages can reuse earlier estimates (SV-B2).
    """
    groups = [tuple(int(c) for c in g) for g in groups]
    uniq, f = aggregate_sample(items, freqs)
    cache: BetaCache = beta_cache if beta_cache is not None else {}

    ranges_rev: List[int] = []
    budget = float(h)
    live = list(groups)
    while len(live) > 1:
        key = tuple(tuple(g) for g in live)
        if key in cache:
            beta_m = cache[key]
        else:
            alpha_m = _alpha_for_split(uniq, f, live[:-1], live[-1], agg)
            beta_m = 1.0 / alpha_m
            cache[key] = beta_m
        a_m, _ = split_range(budget, beta_m)
        a_m = min(a_m, max(2, int(budget // (2 ** (len(live) - 1)))))  # leave >=2 per prefix part
        a_m = max(2, a_m)
        ranges_rev.append(a_m)
        budget = max(2.0, budget / a_m)
        live = live[:-1]
    ranges_rev.append(max(2, int(round(budget))))
    return tuple(reversed(ranges_rev))
