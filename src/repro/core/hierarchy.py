"""Hierarchical heavy-hitter sketches over composite-hash prefixes.

The composite-hash family (core/sketch.py) factors a key's cell address into
per-group sub-indices with mixed-radix strides.  That factorization induces a
natural *prefix hierarchy*: level L sketches the key restricted to module
groups 0..L of the partition, coarsening one group per level.  Because the
frequency of a prefix upper-bounds the frequency of every full key extending
it, a Count-Min estimate at level L that falls below a threshold prunes the
whole subtree -- the classic hierarchical heavy-hitter recursion (Cormode's
dyadic CM / hierarchical count-sketch), lifted from bit prefixes to the
paper's module-group prefixes.

    level 0 : sketch of group g_1                (coarsest marginal)
    level L : sketch of groups g_1..g_{L+1}
    level m-1: sketch of the full composite key  (== the base SketchSpec)

``find_heavy_hitters(threshold)`` descends the hierarchy: at each level it
extends the surviving prefixes by every candidate value of the next group,
estimates all children in one batched query, and keeps those >= threshold.
Overestimation (CM) + prefix monotonicity give *no false negatives* for any
key whose group values appear in the candidate sets; false positives are
bounded by the per-level CM overestimate.

Shared per-group hash family (ingest cascade)
---------------------------------------------
All levels share ONE per-group hash family: :func:`init_hierarchy` draws the
finest level's params once and every level L uses the prefix slices
``q[:, :chunks(g_1..g_{L+1})]`` and ``r[:, :L+1]``.  Independence argument:
each level's row index is the mixed-radix combination of *independent*
per-group CW hashes, which is exactly the composite hash of the base family
restricted to groups 0..L -- two distinct level-L prefixes differ in some
group j <= L, and conditioning on the other groups' hashes leaves H_j
pairwise independent, so every level's row hash remains pairwise independent
over its own key domain and the per-level CM bounds (Thms 1-3) are
unchanged.  What IS given up is independence *between* levels, which no
per-level guarantee uses (the descent's union bound over levels never
needed cross-level independence).

What sharing buys is the ingest cascade: with shared per-group hashes the
level indices nest exactly,

    idx_L(prefix, v) = idx_{L-1}(prefix) * r_L + H_L(v)
    idx_L            = idx_{m-1} // (r_{L+1} * ... * r_{m-1})

so one hash pass over the full key yields every level's cell index by an
integer division (:func:`hierarchy_indices`).  Every ingest surface runs
this cascade; per-level hashing survives only as the bit-exactness oracle
:func:`update_reference`.  Ingest cost per item is ONE hash pass + L fused
table adds instead of the reference's ~L hash passes + L kernel launches;
the Pallas path (kernels/hier_update.py) folds a stream block into all
level tables in a single launch against the level-concatenated padded
table.  The
conservative update gets the same cascade for its index computation and then
runs the per-level sequential folds (the min couples rows, so the folds
themselves stay per level).

Every level's table is linear in the stream, so a hierarchy merges cell-wise
per level and composes with the distributed runtime (core/distributed.py)
exactly like a single sketch: see :func:`merge` and
:func:`sharded_hierarchy_build`.  The same linearity (plus the shared hash
draw) is what lets core/window.py keep a ring of per-epoch hierarchies that
merge, subtract, and decay cell-wise; docs/architecture.md has the full
layer map and the bit-exactness contracts.

The candidate-extension query is the hot path (P prefixes x C child values
per step).  The mixed radix makes it separable: within level L,

    idx(prefix, v) = idx_prefix * r_L  +  H_L(v)        (stride of g_L is 1)

so the batched query needs only P prefix partial indices and C child partial
indices per row, combined on the fly.  The Pallas path
(kernels/hier_query.py) evaluates the full P x C grid in one launch;
:func:`candidate_partials` computes the two factors for it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.hashing import KeySchema


# --------------------------------------------------------------------------
# Spec
# --------------------------------------------------------------------------

def level_modules(base: sk.SketchSpec, level: int) -> Tuple[int, ...]:
    """Module indices (into the base schema) covered by levels 0..level,
    ordered group-major -- the column order of level items."""
    return tuple(m for g in base.partition[: level + 1] for m in g)


def level_spec(base: sk.SketchSpec, level: int) -> sk.SketchSpec:
    """The SketchSpec of one hierarchy level: groups 0..level of the base,
    with modules renumbered consecutively in group-major order."""
    mods = level_modules(base, level)
    schema = KeySchema(domains=tuple(base.schema.domains[m] for m in mods))
    part: List[Tuple[int, ...]] = []
    pos = 0
    for g in base.partition[: level + 1]:
        part.append(tuple(range(pos, pos + len(g))))
        pos += len(g)
    return sk.SketchSpec(schema, tuple(part), base.ranges[: level + 1],
                         base.width)


@dataclasses.dataclass(frozen=True)
class HierarchySpec:
    """A stack of composite-hash sketches over successive group prefixes."""
    base: sk.SketchSpec
    levels: Tuple[sk.SketchSpec, ...]

    @staticmethod
    def from_spec(base: sk.SketchSpec) -> "HierarchySpec":
        return HierarchySpec(
            base=base,
            levels=tuple(level_spec(base, l) for l in range(base.n_groups)),
        )

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    @property
    def table_cells(self) -> int:
        """Total cells across all levels (memory overhead vs the base:
        sum_L prod(r_1..r_L) <= h * r/(r-1) for geometric ranges)."""
        return sum(s.width * s.table_size for s in self.levels)

    @functools.cached_property
    def _level_cols(self) -> Tuple[Tuple[int, ...], ...]:
        """Per-level column tuples for :meth:`level_items`, computed once at
        first use and cached on the (frozen) spec -- the old per-call list
        rebuild sat on the ingest hot path."""
        return tuple(tuple(level_modules(self.base, l))
                     for l in range(self.n_levels))

    @functools.cached_property
    def level_divisors(self) -> Tuple[int, ...]:
        """``idx_L = idx_finest // level_divisors[L]`` -- the suffix range
        products of the mixed radix (cascade identity; divisor of the finest
        level is 1)."""
        divs, d = [], 1
        for r in reversed(self.base.ranges):
            divs.append(d)
            d *= int(r)
        return tuple(reversed(divs))

    def level_items(self, level: int, items: np.ndarray | jax.Array):
        """Select/reorder full-key columns into level ``level``'s layout."""
        return items[:, list(self._level_cols[level])]

    def to_schema_order(self, items: np.ndarray) -> np.ndarray:
        """Group-major full-key columns -> original schema module order."""
        mods = self._level_cols[self.n_levels - 1]
        out = np.empty_like(items)
        for pos, m in enumerate(mods):
            out[:, m] = items[:, pos]
        return out


class HierarchyState(NamedTuple):
    states: Tuple[sk.SketchState, ...]   # one per level, coarse -> fine


def level_params(hspec: HierarchySpec, base_params: sk.SketchParams,
                 level: int) -> sk.SketchParams:
    """Level ``level``'s hash params as prefix slices of the finest level's.

    Group-major layout makes groups 0..level's chunk columns the FIRST
    ``total_chunks`` columns of the finest chunk matrix, so slicing q/r gives
    exactly the same per-group hash functions at every level -- the shared
    family underlying the ingest cascade."""
    nc = hspec.levels[level].schema.total_chunks
    return sk.SketchParams(q=base_params.q[:, :nc],
                           r=base_params.r[:, : level + 1])


def init_hierarchy(hspec: HierarchySpec, key: jax.Array,
                   dtype=jnp.int32) -> HierarchyState:
    """Draw ONE shared per-group hash family and zero tables for all levels.

    Every level's params are prefix slices of the finest level's draw (see
    :func:`level_params` and the module header for the independence
    argument).  All cascade entry points (:func:`update`,
    :func:`hierarchy_indices`, the fused Pallas kernel, the distributed
    folds) rely on this shared-prefix invariant; states built here always
    satisfy it."""
    base_params = sk.init_params(hspec.levels[-1], key)
    states = []
    for l, spec_l in enumerate(hspec.levels):
        states.append(sk.SketchState(
            params=level_params(hspec, base_params, l),
            table=jnp.zeros((spec_l.width, spec_l.table_size), dtype=dtype)))
    return HierarchyState(states=tuple(states))


def params_share_prefix(state: HierarchyState) -> bool:
    """Host-side check of the shared-params invariant (concrete arrays only).

    True iff every level's params are the prefix slices of the finest
    level's -- the precondition of every cascade path.  Used by the kernel
    wrappers when importing externally supplied states; the jit'd hot paths
    assume the invariant (init_hierarchy always establishes it)."""
    fine = state.states[-1].params
    fq, fr = np.asarray(fine.q), np.asarray(fine.r)
    for l, st in enumerate(state.states):
        q, r = np.asarray(st.params.q), np.asarray(st.params.r)
        if q.shape[1] > fq.shape[1] or r.shape[1] != l + 1:
            return False
        if not (np.array_equal(q, fq[:, : q.shape[1]])
                and np.array_equal(r, fr[:, : l + 1])):
            return False
    return True


import weakref

_validated_params = weakref.WeakValueDictionary()  # id(q_fine) -> q_fine


def _require_shared_params(state: HierarchyState, entry: str) -> None:
    """Refuse non-shared-params states on the cascade entry points.

    The cascade derives coarse-level cells from the finest index by
    division, which is garbage for states whose levels were drawn
    independently (the pre-cascade layout) -- silently wrong tables, lost
    no-false-negative guarantee.  Concrete states are validated host-side
    once per distinct finest-params array (params persist across blocks,
    so streaming ingest pays the tiny device read a single time and stays
    async afterwards); traced values cannot be inspected, so jit-embedded
    callers rely on the init_hierarchy invariant, same as the distributed
    folds."""
    q = state.states[-1].params.q
    if isinstance(q, jax.core.Tracer):
        return
    if _validated_params.get(id(q)) is q:
        return
    if not params_share_prefix(state):
        raise ValueError(
            f"{entry} requires the shared per-group hash family (level "
            "params must be prefix slices of the finest level's, as drawn "
            "by init_hierarchy); for independently drawn per-level params "
            "use update_reference")
    try:
        _validated_params[id(q)] = q
    except TypeError:
        pass  # non-weakrefable array type: validate again next call


# --------------------------------------------------------------------------
# Stream ops (linear => mergeable)
# --------------------------------------------------------------------------

def hierarchy_indices(hspec: HierarchySpec, fine_params: sk.SketchParams,
                      items: jax.Array) -> Tuple[jax.Array, ...]:
    """Every level's cell indices from ONE hash pass: tuple of uint32[w, B].

    Computes the finest level's composite index (one CW hash per group,
    exactly ``compute_indices`` of ``hspec.levels[-1]`` on the group-major
    columns) and derives each coarser level by the cascade identity
    ``idx_L = idx_finest // prod(r_{L+1}..r_{m-1})`` -- exact, because the
    dropped remainder is precisely the mixed-radix value of the finer
    groups' sub-indices.  Requires the shared-prefix params invariant
    (:func:`init_hierarchy`)."""
    fine = hspec.levels[-1]
    idx_fine = sk.compute_indices(
        fine, fine_params, hspec.level_items(hspec.n_levels - 1, items))
    out = []
    for div in hspec.level_divisors:
        out.append(idx_fine // jnp.uint32(div) if div > 1 else idx_fine)
    return tuple(out)


def update(hspec: HierarchySpec, state: HierarchyState,
           items: jax.Array, freqs: jax.Array) -> HierarchyState:
    """Fold a block of full keys into every level (items: uint32[B, n]).

    Cascade path: hash once per (row, item), derive all L level indices by
    integer division, then L scatter-adds -- bit-identical to
    :func:`update_reference` under the shared params drawn by
    :func:`init_hierarchy` (enforced by tests/test_hier_update_kernel.py)."""
    _require_shared_params(state, "hierarchy.update")
    items = jnp.asarray(items)
    idxs = hierarchy_indices(hspec, state.states[-1].params, items)
    new = []
    for st_l, idx in zip(state.states, idxs):
        new.append(sk.SketchState(
            params=st_l.params,
            table=sk.add_at_indices(st_l.table, idx, freqs)))
    return HierarchyState(states=tuple(new))


def update_reference(hspec: HierarchySpec, state: HierarchyState,
                     items: jax.Array, freqs: jax.Array) -> HierarchyState:
    """Per-level reference fold: L independent ``sk.update`` calls, each
    re-hashing its prefix from scratch.  The pre-cascade ingest path, kept
    as the parity oracle for :func:`update` and the fused Pallas kernel
    (and as the per-level-launch baseline in the ingest benchmark)."""
    items = jnp.asarray(items)
    new = []
    for lvl, (spec_l, st_l) in enumerate(zip(hspec.levels, state.states)):
        new.append(sk.update(spec_l, st_l, hspec.level_items(lvl, items),
                             freqs))
    return HierarchyState(states=tuple(new))


def update_conservative(hspec: HierarchySpec, state: HierarchyState,
                        items: jax.Array, freqs: jax.Array) -> HierarchyState:
    """Conservative fold into every level (freqs must be non-negative).

    The index computation shares the one-hash-pass cascade with
    :func:`update`; each level then applies the sequential Estan-Varghese
    fold independently (the row-coupling min keeps the folds per level), so
    every level still never underestimates and the heavy-hitter descent's
    no-false-negative argument is unchanged (est(prefix) >= true(prefix) >=
    true(key)).  The resulting tables are NOT linear in the stream: a
    conservatively built hierarchy must not be merged cell-wise (see
    :func:`merge`) or fed through the psum paths of core/distributed.py.
    """
    _require_shared_params(state, "hierarchy.update_conservative")
    items = jnp.asarray(items)
    idxs = hierarchy_indices(hspec, state.states[-1].params, items)
    new = []
    for st_l, idx in zip(state.states, idxs):
        new.append(sk.SketchState(
            params=st_l.params,
            table=sk.conservative_fold(st_l.table, idx, freqs)))
    return HierarchyState(states=tuple(new))


def merge(a: HierarchyState, b: HierarchyState) -> HierarchyState:
    """Cell-wise merge per level -- exact by linearity, same contract as
    core.sketch.merge, so hierarchies shard/merge like single sketches.
    Only valid for hierarchies built with the linear update: conservative
    tables (:func:`update_conservative`) are excluded from cell-wise
    merging, which is why SketchTopKEndpoint.merge_from refuses them."""
    return HierarchyState(states=tuple(
        sk.merge(sa, sb) for sa, sb in zip(a.states, b.states)))


def build_hierarchy(hspec: HierarchySpec, key: jax.Array,
                    items: np.ndarray, freqs: np.ndarray,
                    block: int = 1 << 17, dtype=jnp.int32) -> HierarchyState:
    """Build all levels over a (possibly large) weighted stream, in blocks."""
    state = init_hierarchy(hspec, key, dtype=dtype)
    for blk_items, blk_freqs in sk.stream_blocks(items, freqs, block):
        state = update_jit(hspec, state, blk_items, blk_freqs)
    return state


# The jit'd hierarchy folds donate every level TABLE (ingest folds in place
# instead of copying sum_L w*h_L cells per block) but not the params: the
# shared family is referenced by all levels and the query paths, and
# donation is effective on CPU as well as TPU.  Callers rebind the state to
# the returned value (build_hierarchy, the serving endpoints all do).

@functools.partial(jax.jit, static_argnums=0, donate_argnums=(1,))
def _update_tables_jit(hspec: HierarchySpec, tables, fine_params,
                       items, freqs):
    idxs = hierarchy_indices(hspec, fine_params, items)
    return tuple(sk.add_at_indices(t, idx, freqs)
                 for t, idx in zip(tables, idxs))


def update_jit(hspec: HierarchySpec, state: HierarchyState,
               items, freqs) -> HierarchyState:
    _require_shared_params(state, "hierarchy.update_jit")
    tables = _update_tables_jit(hspec, tuple(st.table for st in state.states),
                                state.states[-1].params, items, freqs)
    return HierarchyState(states=tuple(
        sk.SketchState(params=st.params, table=t)
        for st, t in zip(state.states, tables)))


@functools.partial(jax.jit, static_argnums=0, donate_argnums=(1,))
def _update_conservative_tables_jit(hspec: HierarchySpec, tables,
                                    fine_params, items, freqs):
    idxs = hierarchy_indices(hspec, fine_params, items)
    return tuple(sk.conservative_fold(t, idx, freqs)
                 for t, idx in zip(tables, idxs))


def update_conservative_jit(hspec: HierarchySpec, state: HierarchyState,
                            items, freqs) -> HierarchyState:
    _require_shared_params(state, "hierarchy.update_conservative_jit")
    tables = _update_conservative_tables_jit(
        hspec, tuple(st.table for st in state.states),
        state.states[-1].params, items, freqs)
    return HierarchyState(states=tuple(
        sk.SketchState(params=st.params, table=t)
        for st, t in zip(state.states, tables)))


# --------------------------------------------------------------------------
# Two-phase ingest (the serving engine's double-buffered pipeline)
# --------------------------------------------------------------------------
#
# update_jit fuses the hash cascade and the table fold into one program.
# The async serving engine (serving/sketch_engine.SketchServeEngine) wants
# them SPLIT: the cascade of block k+1 reads only the (never-donated) hash
# params and the block, so it can be dispatched while block k's fold is
# still executing against the donated table buffers -- the fold ping-pongs
# the table buffers (donation rebinds them every call) while the cascade
# runs ahead one block.  Splitting changes nothing numerically:
# stage_indices computes exactly the indices update_jit computes, and
# fold_indices applies exactly its add_at_indices -- the composition is
# bit-identical to update_jit (tests/test_serve_engine.py enforces it).

@functools.partial(jax.jit, static_argnums=0)
def _stage_indices_jit(hspec: HierarchySpec, fine_params, items):
    return hierarchy_indices(hspec, fine_params, items)


def stage_indices(hspec: HierarchySpec, state: HierarchyState,
                  items) -> Tuple[jax.Array, ...]:
    """Pipeline stage A: the hash cascade alone (all levels' cell indices).

    Depends only on the hash params and the block -- never on the tables --
    so it can run while a previous block's fold is in flight."""
    _require_shared_params(state, "hierarchy.stage_indices")
    return _stage_indices_jit(hspec, state.states[-1].params, items)


@functools.partial(jax.jit, donate_argnums=(0,))
def _fold_indices_tables_jit(tables, idxs, freqs):
    return tuple(sk.add_at_indices(t, idx, freqs)
                 for t, idx in zip(tables, idxs))


def fold_indices(state: HierarchyState, idxs: Tuple[jax.Array, ...],
                 freqs) -> HierarchyState:
    """Pipeline stage B: fold pre-computed level indices into the tables.

    Table buffers are donated (same ping-pong as :func:`update_jit`);
    callers rebind the state to the return value.  ``fold_indices(state,
    stage_indices(hspec, state, items), freqs)`` is bit-identical to
    ``update_jit(hspec, state, items, freqs)``."""
    tables = _fold_indices_tables_jit(
        tuple(st.table for st in state.states), idxs, freqs)
    return HierarchyState(states=tuple(
        sk.SketchState(params=st.params, table=t)
        for st, t in zip(state.states, tables)))


def sharded_hierarchy_build(
    hspec: HierarchySpec,
    state: HierarchyState,
    mesh,
    data_axes: Tuple[str, ...],
    items: jax.Array,
    freqs: jax.Array,
    *,
    mode: str = "linear",
) -> HierarchyState:
    """Distributed build: sharded cascade fold + per-level psum (exact).

    One shard_map over ALL levels (core.distributed.sharded_hierarchy_fold):
    each device hashes its stream slice once, derives every level's indices
    by the cascade, scatter-adds into per-level local deltas, and the psum
    merge per level is exact by linearity, just like the flat case.
    ``mode`` exists only to be refused: a conservatively built hierarchy
    (:func:`update_conservative`) has non-linear tables and must never
    enter a psum, so passing mode="conservative" raises instead of
    silently producing a wrong merged hierarchy.
    """
    from repro.core import distributed as dist

    dist.require_linear(mode, "sharded_hierarchy_build")
    items = jnp.asarray(items)
    deltas = dist.sharded_hierarchy_fold(
        hspec, state.states[-1].params, mesh, data_axes, items, freqs,
        table_dtypes=tuple(st.table.dtype for st in state.states))
    return HierarchyState(states=tuple(
        sk.SketchState(params=st.params, table=st.table + d)
        for st, d in zip(state.states, deltas)))


# --------------------------------------------------------------------------
# Separable candidate queries
# --------------------------------------------------------------------------

def candidate_partials(
    hspec: HierarchySpec,
    state: HierarchyState,
    level: int,
    prefixes: jax.Array,     # uint32[P, n_prefix_modules] (group-major)
    values: jax.Array,       # uint32[C, len(level group modules)]
) -> Tuple[jax.Array, jax.Array]:
    """The two factors of the level-``level`` child cell index.

    Returns (pp, cp): uint32[w, P] prefix partials (already scaled by the
    last group's range) and uint32[w, C] child partials, such that the cell
    index of child (p, c) at row k is ``pp[k, p] + cp[k, c]`` -- exactly
    ``compute_indices`` of the level spec on the concatenated key, by the
    mixed-radix stride identity stride_j(level) = stride_j(level-1) * r_L.
    Under the shared per-group family the sliced prefix params ARE level
    ``level - 1``'s params, so the prefix partials equal that level's own
    cell indices (the same nesting the ingest cascade exploits).
    """
    spec_l = hspec.levels[level]
    params = state.states[level].params
    w = spec_l.width
    r_last = spec_l.ranges[-1]

    if level == 0:
        pp = jnp.zeros((w, prefixes.shape[0]), jnp.uint32)
    else:
        prefix_spec = level_spec(hspec.base, level - 1)
        n_pc = prefix_spec.schema.total_chunks
        prefix_params = sk.SketchParams(q=params.q[:, :n_pc],
                                        r=params.r[:, :level])
        pp = sk.compute_indices(prefix_spec, prefix_params, prefixes)
        pp = pp * jnp.uint32(r_last)

    # child partial: the last group's sub-index, stride 1
    cp = sk.group_subindex(spec_l, params, level, values)
    return pp, cp


def candidate_estimates(
    hspec: HierarchySpec,
    state: HierarchyState,
    level: int,
    prefixes: np.ndarray,    # uint32[P, n_prefix_modules]
    values: np.ndarray,      # uint32[C, len(level group modules)]
    *,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
    tile_h: int = 512,
    max_batch: Optional[int] = None,
) -> np.ndarray:
    """CM estimates for every (prefix x candidate-value) child: [P, C].

    ``use_kernel=True`` routes through the Pallas one-launch grid kernel
    (kernels/hier_query.py); the default is the jnp gather reference.  Both
    agree bit-for-bit on int32 tables.  The kernel's two-limb gather only
    covers int32, so other table dtypes (int64 hierarchies) always take
    the dtype-preserving reference path.

    ``max_batch`` bounds the per-call P*C working set: the partial hashes
    are computed ONCE for all prefixes and candidates, then only the
    prefix axis is chunked (the child partials are identical across
    chunks, so rehashing them per chunk would be pure waste).
    """
    prefixes = jnp.asarray(np.asarray(prefixes, dtype=np.uint32))
    values = jnp.asarray(np.asarray(values, dtype=np.uint32))
    pp, cp = candidate_partials(hspec, state, level, prefixes, values)
    table = state.states[level].table
    from repro.kernels.hier_query import (
        hier_candidate_query,
        hier_candidate_query_ref,
    )
    if use_kernel and table.dtype == jnp.int32:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        def one(pp_chunk):
            return hier_candidate_query(table, pp_chunk, cp, tile_h=tile_h,
                                        interpret=interpret)
    else:
        def one(pp_chunk):
            return hier_candidate_query_ref(table, pp_chunk, cp)

    p, c = pp.shape[1], cp.shape[1]
    if max_batch is None or p * c <= max_batch:
        return np.asarray(one(pp))
    p_chunk = max(1, max_batch // max(c, 1))
    outs = []
    for s in range(0, p, p_chunk):
        pc = pp[:, s : s + p_chunk]
        if pc.shape[1] < p_chunk:
            # pad to the fixed chunk width so one compiled kernel serves
            # every chunk (pad index 0 is always a valid cell; sliced off)
            pc = jnp.pad(pc, ((0, 0), (0, p_chunk - pc.shape[1])))
        outs.append(np.asarray(one(pc)))
    return np.concatenate(outs, axis=0)[:p]


# --------------------------------------------------------------------------
# Heavy-hitter descent
# --------------------------------------------------------------------------

def find_heavy_hitters(
    hspec: HierarchySpec,
    state: HierarchyState,
    threshold: float,
    candidates: Sequence[np.ndarray],
    *,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
    max_batch: int = 1 << 16,
) -> Tuple[np.ndarray, np.ndarray]:
    """All keys whose CM estimate is >= ``threshold``.

    candidates[j]: uint32[C_j, len(g_j modules)] -- the value combos to
    consider for group j (e.g. the distinct observed values; see
    streams.heavy_hitters.group_candidates).  Guarantees, conditional on
    every true heavy hitter's group values appearing in the candidate sets:

      * no false negatives: estimates only overestimate, and a prefix's
        mass >= any extension's, so no ancestor of a heavy key is pruned;
      * false positives only from CM collisions at the leaf level, i.e.
        every reported key has true frequency >= threshold - eps*L with the
        usual (h, w) probability.

    Returns (items uint32[K, n_modules] in schema module order, estimates
    int64[K]) sorted by estimate, descending.
    """
    if len(candidates) != hspec.n_levels:
        raise ValueError(
            f"need one candidate set per level ({hspec.n_levels}), "
            f"got {len(candidates)}")
    threshold = int(threshold)

    prefixes = np.zeros((1, 0), dtype=np.uint32)
    est = np.zeros((1,), dtype=np.int64)
    for lvl in range(hspec.n_levels):
        cand = np.asarray(candidates[lvl], dtype=np.uint32)
        if cand.ndim != 2 or cand.shape[1] != len(hspec.base.partition[lvl]):
            raise ValueError(
                f"candidates[{lvl}] must be [C, {len(hspec.base.partition[lvl])}]")
        if prefixes.shape[0] == 0 or cand.shape[0] == 0:
            n_mods = len(level_modules(hspec.base, hspec.n_levels - 1))
            return (np.zeros((0, n_mods), np.uint32),
                    np.zeros((0,), np.int64))
        # batched P x C estimates; candidate_estimates hashes the partials
        # once and chunks the prefix axis to bound the one-hot working set
        grid = candidate_estimates(
            hspec, state, lvl, prefixes, cand, use_kernel=use_kernel,
            interpret=interpret, max_batch=max_batch).astype(np.int64)
        keep_p, keep_c = np.nonzero(grid >= threshold)
        prefixes = np.concatenate(
            [prefixes[keep_p], cand[keep_c]], axis=1)
        est = grid[keep_p, keep_c]

    order = np.argsort(-est, kind="stable")
    return hspec.to_schema_order(prefixes[order]), est[order]


# --------------------------------------------------------------------------
# Batched multi-request descent (Q concurrent queries, one launch per level)
# --------------------------------------------------------------------------

def batched_candidate_estimates(
    hspec: HierarchySpec,
    state: HierarchyState,
    level: int,
    prefix_sets: Sequence[np.ndarray],   # Q arrays uint32[P_q, n_prefix_mods]
    values: np.ndarray,                  # uint32[C, len(level group modules)]
    *,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
    tile_h: int = 512,
    max_batch: Optional[int] = None,
) -> List[np.ndarray]:
    """CM estimate grids for Q concurrent requests at one level: Q x [P_q, C].

    All requests share the level's candidate set but carry their own
    surviving prefix sets.  The prefix partials are hashed ONCE over the
    concatenated prefixes, padded to a common P_max (pad prefix index 0 is
    always a valid cell; the padded rows are sliced off), and the whole
    [Q, P_max, C] request grid is evaluated in a single launch
    (kernels/hier_query.hier_candidate_query_batched) -- Q concurrent
    queries cost one ``pallas_call`` per level instead of Q.  Every
    returned cell is computed lane-independently, so each request's grid
    is bit-identical to its own :func:`candidate_estimates` call.

    ``max_batch`` bounds the Q*P_max*C working set by chunking the request
    axis (the per-request grids are already the unsplittable unit).
    """
    if not prefix_sets:
        return []
    counts = [int(np.asarray(p).shape[0]) for p in prefix_sets]
    if min(counts) == 0:
        raise ValueError("every request must have a non-empty prefix set "
                         "(callers retire empty requests before batching)")
    values = jnp.asarray(np.asarray(values, dtype=np.uint32))
    cat = jnp.asarray(np.concatenate(
        [np.asarray(p, dtype=np.uint32) for p in prefix_sets], axis=0))
    pp_all, cp = candidate_partials(hspec, state, level, cat, values)
    nq, p_max, c = len(counts), max(counts), int(cp.shape[1])

    table = state.states[level].table
    from repro.kernels.hier_query import (
        hier_candidate_query_batched,
        hier_candidate_query_batched_ref,
    )
    if use_kernel and table.dtype == jnp.int32:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        def one(pp3):
            return hier_candidate_query_batched(table, pp3, cp,
                                                tile_h=tile_h,
                                                interpret=interpret)
    else:
        def one(pp3):
            return hier_candidate_query_batched_ref(table, pp3, cp)

    # per-request column blocks, padded to the common P_max
    blocks, off = [], 0
    for n in counts:
        blk = pp_all[:, off : off + n]
        if n < p_max:
            blk = jnp.pad(blk, ((0, 0), (0, p_max - n)))
        blocks.append(blk)
        off += n
    pp3 = jnp.stack(blocks, axis=1)                  # [w, Q, P_max]

    if max_batch is None or nq * p_max * c <= max_batch:
        grids = np.asarray(one(pp3))
    else:
        q_chunk = max(1, max_batch // max(p_max * c, 1))
        outs = []
        for s in range(0, nq, q_chunk):
            qc = pp3[:, s : s + q_chunk]
            if qc.shape[1] < q_chunk:
                # pad to the fixed chunk so one compiled kernel serves
                # every chunk (pad prefix 0 is a valid cell; sliced off)
                qc = jnp.pad(qc, ((0, 0), (0, q_chunk - qc.shape[1]),
                                  (0, 0)))
            outs.append(np.asarray(one(qc)))
        grids = np.concatenate(outs, axis=0)[:nq]
    return [grids[i, : counts[i], :] for i in range(nq)]


def batched_find_heavy_hitters(
    hspec: HierarchySpec,
    state: HierarchyState,
    thresholds: Sequence[float],
    candidates: Sequence[np.ndarray],
    *,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
    max_batch: int = 1 << 16,
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Q concurrent heavy-hitter descents sharing one set of launches.

    Request q receives exactly ``find_heavy_hitters(..., thresholds[q],
    candidates)`` -- bit-identical, enforced by tests/test_serve_engine.py
    -- but the per-level candidate grids of ALL still-active requests are
    evaluated together (:func:`batched_candidate_estimates`), so the
    device sees one P x C x Q launch per level instead of Q separate
    P x C launches.  Requests prune independently; a request whose prefix
    set empties retires early with the empty result, same as the serial
    descent.
    """
    if len(candidates) != hspec.n_levels:
        raise ValueError(
            f"need one candidate set per level ({hspec.n_levels}), "
            f"got {len(candidates)}")
    thrs = [int(t) for t in thresholds]
    nq = len(thrs)
    n_mods = len(level_modules(hspec.base, hspec.n_levels - 1))
    empty = (np.zeros((0, n_mods), np.uint32), np.zeros((0,), np.int64))

    prefixes = [np.zeros((1, 0), dtype=np.uint32) for _ in range(nq)]
    est = [np.zeros((1,), dtype=np.int64) for _ in range(nq)]
    done: List[Optional[Tuple[np.ndarray, np.ndarray]]] = [None] * nq
    for lvl in range(hspec.n_levels):
        active = [q for q in range(nq) if done[q] is None]
        if not active:
            break
        cand = np.asarray(candidates[lvl], dtype=np.uint32)
        if cand.ndim != 2 or cand.shape[1] != len(hspec.base.partition[lvl]):
            raise ValueError(
                f"candidates[{lvl}] must be [C, {len(hspec.base.partition[lvl])}]")
        for q in active:
            if prefixes[q].shape[0] == 0 or cand.shape[0] == 0:
                done[q] = empty
        active = [q for q in active if done[q] is None]
        if not active:
            break
        grids = batched_candidate_estimates(
            hspec, state, lvl, [prefixes[q] for q in active], cand,
            use_kernel=use_kernel, interpret=interpret, max_batch=max_batch)
        for q, grid in zip(active, grids):
            grid = grid.astype(np.int64)
            keep_p, keep_c = np.nonzero(grid >= thrs[q])
            prefixes[q] = np.concatenate(
                [prefixes[q][keep_p], cand[keep_c]], axis=1)
            est[q] = grid[keep_p, keep_c]

    out: List[Tuple[np.ndarray, np.ndarray]] = []
    for q in range(nq):
        if done[q] is not None:
            out.append(done[q])
            continue
        order = np.argsort(-est[q], kind="stable")
        out.append((hspec.to_schema_order(prefixes[q][order]), est[q][order]))
    return out
