from repro.streams.synthetic import (  # noqa: F401
    Stream,
    ipv4_stream,
    reinterpret_modularity,
    telecom_stream,
    zipf_graph_stream,
)
from repro.streams.heavy_hitters import (  # noqa: F401
    HHWorkload,
    exact_heavy_hitters,
    group_candidates,
    ngram_hh_workload,
    zipf_hh_workload,
)
from repro.streams.stats import (  # noqa: F401
    average_relative_error,
    degree_stats,
    exact_f2,
    exact_marginals,
    observed_error,
    sketch_f2_upper,
)
from repro.streams.dstream import (  # noqa: F401
    Batch,
    BatchReport,
    DStreamHarness,
    ExactWindowCounter,
    drifting_batches,
    skew_flip_batches,
    timestamped_batches,
)
from repro.streams.livestats import (  # noqa: F401
    LiveStats,
    collect_live_stats,
    group_marginal_mass,
    propose_spec,
)
