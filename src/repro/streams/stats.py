"""Exact stream statistics: ground truth + evaluation metrics.

``observed_error`` is the paper's SVI-A4 aggregate metric;
``average_relative_error`` / ``exact_f2`` / ``sketch_f2_upper`` are the
live-accuracy metrics the batched streaming harness (streams/dstream.py)
reports per batch against exact windowed ground truth.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def observed_error(est: np.ndarray, true: np.ndarray) -> float:
    """SVI-A4: sum_i |est_i - true_i| / sum_i true_i over queried items."""
    est = np.asarray(est, dtype=np.float64)
    true = np.asarray(true, dtype=np.float64)
    return float(np.abs(est - true).sum() / max(float(true.sum()), 1.0))


def average_relative_error(est: np.ndarray, true: np.ndarray) -> float:
    """Mean per-item relative error: mean_i |est_i - true_i| / true_i.

    The DStream-style live metric (per-key, unlike the mass-weighted
    ``observed_error``): heavy and light queried keys count equally, so a
    sketch that nails the head but garbles the queried tail is penalized.
    Zero-truth rows contribute |est| per unit (denominator floored at 1)
    instead of dividing by zero.  Empty query sets score 0.
    """
    est = np.asarray(est, dtype=np.float64)
    true = np.asarray(true, dtype=np.float64)
    if est.shape != true.shape:
        raise ValueError(f"est/true shape mismatch: {est.shape} vs {true.shape}")
    if est.size == 0:
        return 0.0
    return float(np.mean(np.abs(est - true) / np.maximum(true, 1.0)))


def exact_f2(freqs: np.ndarray) -> float:
    """Second frequency moment of a compressed stream: sum_i f_i**2."""
    f = np.asarray(freqs, dtype=np.float64)
    return float(np.dot(f, f))


def sketch_f2_upper(table: np.ndarray) -> float:
    """F2 upper bound from a linear Count-Min table: min over rows of the
    row's sum of squared cells.

    Each cell holds the sum of its colliding keys' frequencies, so a row's
    sum of squares is F2 plus non-negative cross terms -- an overestimate
    for every row; the min is the tightest.  (Unbiased F2 needs sign
    hashes -- Count-Sketch / AMS -- which this table family does not carry;
    the bound still tracks F2 well at the usual loads and is what the
    streaming harness reports.)  Only meaningful for linearly built
    tables: conservative cells under-count collisions, voiding the
    row-wise >= F2 argument.
    """
    t = np.asarray(table, dtype=np.float64)
    if t.ndim != 2:
        raise ValueError(f"table must be [w, h], got shape {t.shape}")
    return float(np.min(np.sum(t * t, axis=1)))


def hierarchy_point_estimates(hspec, state, query_items: np.ndarray) -> np.ndarray:
    """CM point estimates for schema-ordered keys from a hierarchy's finest level.

    The shared scoring primitive of the DStream harness
    (streams/dstream.py) and the autotune launcher
    (launch/serve.run_sketch_autotune): map the schema-ordered query rows
    to the finest level's module order (``hspec.level_items`` -- identity
    only when the partition happens to be in schema order) and point-query
    that level's table.  Returns float64 estimates, one per query row.
    """
    import jax.numpy as jnp

    from repro.core import sketch as sk

    fine = hspec.levels[-1]
    level_items = hspec.level_items(
        hspec.n_levels - 1, np.asarray(query_items, dtype=np.uint32))
    est = sk.query(fine, state.states[-1],
                   jnp.asarray(np.ascontiguousarray(level_items)))
    return np.asarray(est, dtype=np.float64)


def topk_point_are(hspec, state, query_items: np.ndarray,
                   true_freqs: np.ndarray) -> float:
    """ARE of a hierarchy's point estimates over a fixed query set.

    ``average_relative_error(estimates, truth)`` with the estimates drawn
    by :func:`hierarchy_point_estimates` -- the twin-endpoint scoring the
    autotune launcher prints (auto-tuned vs frozen-spec endpoint on the
    same window) and the per-batch top-k ARE of the streaming harness.
    """
    est = hierarchy_point_estimates(hspec, state, query_items)
    return average_relative_error(est, np.asarray(true_freqs,
                                                  dtype=np.float64))


def exact_marginals(items: np.ndarray, freqs: np.ndarray, cols: Sequence[int]) -> np.ndarray:
    """O(value(cols), *) at every item row, from the full stream."""
    sub = np.ascontiguousarray(items[:, list(cols)])
    _, inv = np.unique(sub, axis=0, return_inverse=True)
    sums = np.bincount(inv, weights=np.asarray(freqs, dtype=np.float64))
    return sums[inv]


def degree_stats(items: np.ndarray, freqs: np.ndarray) -> dict:
    """Source/target distinct counts + marginal skew (paper Table III)."""
    n_src = len(np.unique(items[:, 0]))
    n_tgt = len(np.unique(items[:, 1]))
    o1 = exact_marginals(items, freqs, [0])
    o2 = exact_marginals(items, freqs, [1])
    return {
        "n_sources": n_src,
        "n_targets": n_tgt,
        "alpha_median": float(np.median(o1 / o2)),
        "total": int(np.asarray(freqs).sum()),
        "max_freq": int(np.asarray(freqs).max()),
        "distinct": len(items),
    }
