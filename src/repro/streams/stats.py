"""Exact stream statistics: ground truth + the paper's evaluation metric."""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def observed_error(est: np.ndarray, true: np.ndarray) -> float:
    """SVI-A4: sum_i |est_i - true_i| / sum_i true_i over queried items."""
    est = np.asarray(est, dtype=np.float64)
    true = np.asarray(true, dtype=np.float64)
    return float(np.abs(est - true).sum() / max(float(true.sum()), 1.0))


def exact_marginals(items: np.ndarray, freqs: np.ndarray, cols: Sequence[int]) -> np.ndarray:
    """O(value(cols), *) at every item row, from the full stream."""
    sub = np.ascontiguousarray(items[:, list(cols)])
    _, inv = np.unique(sub, axis=0, return_inverse=True)
    sums = np.bincount(inv, weights=np.asarray(freqs, dtype=np.float64))
    return sums[inv]


def degree_stats(items: np.ndarray, freqs: np.ndarray) -> dict:
    """Source/target distinct counts + marginal skew (paper Table III)."""
    n_src = len(np.unique(items[:, 0]))
    n_tgt = len(np.unique(items[:, 1]))
    o1 = exact_marginals(items, freqs, [0])
    o2 = exact_marginals(items, freqs, [1])
    return {
        "n_sources": n_src,
        "n_targets": n_tgt,
        "alpha_median": float(np.median(o1 / o2)),
        "total": int(np.asarray(freqs).sum()),
        "max_freq": int(np.asarray(freqs).max()),
        "distinct": len(items),
    }
