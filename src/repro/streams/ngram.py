"""Token streams as modular-key streams (the LM-framework integration).

A training corpus is the fastest stream a cluster sees.  An n-gram is a key
of modularity n over the vocabulary domain -- a bigram ⟨prev, next⟩ is
structurally a directed graph edge, the paper's flagship example.  These
helpers turn token batches into (items, freqs) blocks consumable by the
sketch runtime, so MOD-Sketch tracks corpus n-gram statistics *during
training* with O(w*h) memory and exact psum mergeability across the mesh.

Also here: (expert, token-bucket) pair extraction for MoE routing telemetry
-- a modularity-2 key stream with strongly asymmetric marginals (few experts,
many buckets), i.e. precisely the alpha != 1 regime Thm 3 optimizes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import KeySchema


def ngram_schema(vocab_size: int, n: int) -> KeySchema:
    return KeySchema(domains=(int(vocab_size),) * n)


def ngram_items(tokens: jax.Array, n: int) -> jax.Array:
    """uint32[B, T] token ids -> uint32[B*(T-n+1), n] n-gram keys.

    jnp implementation so it runs inside the jitted train step; windows that
    straddle sequence boundaries are excluded by construction (per-row
    windows only).
    """
    if n < 1:
        raise ValueError("n >= 1")
    b, t = tokens.shape
    if t < n:
        raise ValueError(f"sequence length {t} < n {n}")
    cols = [tokens[:, i : t - n + 1 + i] for i in range(n)]
    grams = jnp.stack(cols, axis=-1)            # [B, T-n+1, n]
    return grams.reshape(-1, n).astype(jnp.uint32)


def ngram_items_np(tokens: np.ndarray, n: int) -> np.ndarray:
    b, t = tokens.shape
    cols = [tokens[:, i : t - n + 1 + i] for i in range(n)]
    return np.stack(cols, axis=-1).reshape(-1, n).astype(np.uint32)


def moe_routing_items(
    token_ids: jax.Array,      # int32[N] flattened tokens
    expert_ids: jax.Array,     # int32[N, top_k] chosen experts
    n_buckets: int = 4096,
) -> jax.Array:
    """(expert, token-bucket) pairs: uint32[N*top_k, 2].

    Token ids are bucketed (id mod n_buckets) to bound the second module's
    domain; expert domain is tiny => alpha = O(expert,*)/O(*,bucket) >> 1,
    so the Thm-3 optimizer allocates b >> a, exactly the asymmetric-range
    case the paper motivates.
    """
    n, k = expert_ids.shape
    tok = jnp.broadcast_to(token_ids[:, None], (n, k)).reshape(-1)
    exp = expert_ids.reshape(-1)
    bucket = (tok % jnp.int32(n_buckets)).astype(jnp.uint32)
    return jnp.stack([exp.astype(jnp.uint32), bucket], axis=-1)


def routing_schema(n_experts: int, n_buckets: int = 4096) -> KeySchema:
    return KeySchema(domains=(int(n_experts), int(n_buckets)))
