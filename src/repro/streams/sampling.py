"""Uniform stream sampling (paper SIV: "sample a small portion, 2~4%").

Two modes:
  * ``Stream.sample`` (synthetic.py): Binomial per-item thinning of a
    compressed stream -- the exact distribution of a uniform occurrence
    sample of the flat stream.
  * :class:`BernoulliSampler` here: online single-pass thinning for flat
    arrival blocks (what the training-loop integration uses).
  * :class:`ReservoirSampler`: fixed-budget variant (weighted reservoir,
    A-ES) when the stream length is unknown and memory is the constraint.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np


class BernoulliSampler:
    """Keep each stream occurrence independently with probability p."""

    def __init__(self, p: float, seed: int = 0):
        if not (0.0 < p <= 1.0):
            raise ValueError("p in (0, 1] required")
        self.p = float(p)
        self.rng = np.random.default_rng(seed)
        self._items: List[np.ndarray] = []
        self._freqs: List[np.ndarray] = []

    def offer(self, items: np.ndarray, freqs: Optional[np.ndarray] = None) -> None:
        items = np.asarray(items, dtype=np.uint32)
        if freqs is None:
            freqs = np.ones(items.shape[0], dtype=np.int64)
        kept = self.rng.binomial(np.asarray(freqs, dtype=np.int64), self.p)
        mask = kept > 0
        if mask.any():
            self._items.append(items[mask])
            self._freqs.append(kept[mask])

    def sample(self) -> Tuple[np.ndarray, np.ndarray]:
        if not self._items:
            return np.zeros((0, 1), dtype=np.uint32), np.zeros((0,), dtype=np.int64)
        return np.concatenate(self._items, axis=0), np.concatenate(self._freqs)


class ReservoirSampler:
    """Weighted reservoir (Efraimidis-Spirakis A-ES) of stream occurrences."""

    def __init__(self, capacity: int, seed: int = 0):
        self.capacity = int(capacity)
        self.rng = np.random.default_rng(seed)
        self._keys: Optional[np.ndarray] = None   # float64 priorities
        self._items: Optional[np.ndarray] = None
        self._freqs: Optional[np.ndarray] = None

    def offer(self, items: np.ndarray, freqs: Optional[np.ndarray] = None) -> None:
        items = np.asarray(items, dtype=np.uint32)
        if freqs is None:
            freqs = np.ones(items.shape[0], dtype=np.int64)
        freqs = np.asarray(freqs, dtype=np.float64)
        pri = self.rng.random(items.shape[0]) ** (1.0 / np.maximum(freqs, 1e-12))
        if self._keys is None:
            self._keys, self._items, self._freqs = pri, items, freqs.astype(np.int64)
        else:
            self._keys = np.concatenate([self._keys, pri])
            self._items = np.concatenate([self._items, items], axis=0)
            self._freqs = np.concatenate([self._freqs, freqs.astype(np.int64)])
        if len(self._keys) > self.capacity:
            top = np.argpartition(-self._keys, self.capacity)[: self.capacity]
            self._keys = self._keys[top]
            self._items = self._items[top]
            self._freqs = self._freqs[top]

    def sample(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._items is None:
            return np.zeros((0, 1), dtype=np.uint32), np.zeros((0,), dtype=np.int64)
        return self._items, self._freqs
