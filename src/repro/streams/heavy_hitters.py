"""Heavy-hitter workloads + exact ground truth for the hierarchy subsystem.

Two workload families feed core/hierarchy.py:

  * ``zipf_hh_workload`` -- the Twitter/CAIDA-like edge streams already used
    for point queries, re-cut as threshold reporting: which edges carry at
    least a phi-fraction of the stream?
  * ``ngram_hh_workload`` -- the LM-framework angle: which n-grams dominate
    a token stream?  (An n-gram key is modularity-n over the vocabulary; the
    hierarchy prunes by (n-1)-gram prefix mass.)

Both return a :class:`HHWorkload` bundling the stream, a threshold, the
exact answer (for tests/benchmarks), and per-group candidate sets -- the
value combos the descent may extend prefixes with.  Candidates from
``group_candidates`` are the distinct observed group values, which makes
the no-false-negative guarantee unconditional on these streams.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.hashing import KeySchema
from repro.core.sketch import SketchSpec
from repro.streams.ngram import ngram_items_np, ngram_schema
from repro.streams.synthetic import Stream, zipf_graph_stream


def exact_heavy_hitters(
    items: np.ndarray, freqs: np.ndarray, threshold: float
) -> Tuple[np.ndarray, np.ndarray]:
    """Ground truth: distinct keys with total frequency >= threshold,
    sorted by frequency descending."""
    uniq, inv = np.unique(np.asarray(items), axis=0, return_inverse=True)
    tot = np.bincount(inv, weights=np.asarray(freqs, dtype=np.float64))
    keep = tot >= threshold
    uniq, tot = uniq[keep], tot[keep].astype(np.int64)
    order = np.argsort(-tot, kind="stable")
    return uniq[order], tot[order]


def group_candidates(spec: SketchSpec, items: np.ndarray) -> List[np.ndarray]:
    """Distinct observed value-combos per partition group, in group order.

    candidates[j]: uint32[C_j, len(g_j)] -- exactly the shape
    core.hierarchy.find_heavy_hitters expects.  Using observed values keeps
    the candidate sets exact (every true heavy hitter is reachable).
    """
    items = np.asarray(items, dtype=np.uint32)
    return [np.unique(items[:, list(g)], axis=0) for g in spec.partition]


@dataclasses.dataclass
class HHWorkload:
    """A stream plus everything a heavy-hitter evaluation needs."""
    stream: Stream
    threshold: int
    exact_items: np.ndarray    # uint32[K, n_modules], schema order
    exact_freqs: np.ndarray    # int64[K]

    def candidates(self, spec: SketchSpec) -> List[np.ndarray]:
        return group_candidates(spec, self.stream.items)


def zipf_hh_workload(
    phi: float = 0.002,
    n_src: int = 2_000,
    n_tgt: int = 4_000,
    n_edges: int = 20_000,
    n_occurrences: int = 100_000,
    s: float = 1.1,
    seed: int = 0,
) -> HHWorkload:
    """Edge stream with Zipf(s) marginals; report edges >= phi * L."""
    stream = zipf_graph_stream(n_src=n_src, n_tgt=n_tgt, n_edges=n_edges,
                               n_occurrences=n_occurrences, s_src=s, s_tgt=s,
                               seed=seed, name=f"zipf-hh(s={s})")
    threshold = max(1, int(phi * stream.total))
    ei, ef = exact_heavy_hitters(stream.items, stream.freqs, threshold)
    return HHWorkload(stream=stream, threshold=threshold,
                      exact_items=ei, exact_freqs=ef)


def ngram_hh_workload(
    vocab_size: int = 512,
    n: int = 2,
    n_sequences: int = 64,
    seq_len: int = 256,
    phi: float = 0.002,
    s: float = 1.2,
    seed: int = 0,
) -> HHWorkload:
    """Token n-gram stream: Zipf(s) unigram marginal, report heavy n-grams.

    The compressed stream's keys are modularity-n over [0, vocab_size); a
    hierarchy over the per-token partition prunes by prefix (n-1)-gram mass.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64) ** (-s)
    p = ranks / ranks.sum()
    toks = rng.choice(vocab_size, size=(n_sequences, seq_len), p=p)
    grams = ngram_items_np(toks.astype(np.uint32), n)
    uniq, inv = np.unique(grams, axis=0, return_inverse=True)
    freqs = np.bincount(inv).astype(np.int64)
    stream = Stream(schema=ngram_schema(vocab_size, n), items=uniq,
                    freqs=freqs, name=f"{n}gram-hh(V={vocab_size})")
    threshold = max(1, int(phi * stream.total))
    ei, ef = exact_heavy_hitters(stream.items, stream.freqs, threshold)
    return HHWorkload(stream=stream, threshold=threshold,
                      exact_items=ei, exact_freqs=ef)
