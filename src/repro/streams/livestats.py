"""Live stream statistics from serving-endpoint state (no extra passes).

The offline strategy search (core/greedy.py, core/range_opt.py) consumes a
uniform weighted sample of the stream.  A production endpoint has no such
sample lying around -- but it DOES maintain, for free:

  * per-group **space-saving pools** (core/summary.py): every group value
    carrying more than total/m of the stream's weight is in its pool, with
    a count that upper-bounds its true weight;
  * per-level **hierarchy tables** (core/hierarchy.py): the level-L table
    holds the mass of every group-prefix, and ``sk.query_marginal`` reads
    any single group's marginal mass straight off the finest table.

``collect_live_stats`` combines the two into a :class:`LiveStats` bundle:
the heavy-hitter descent (pools supply candidate values, level tables
supply prefix mass) yields a weighted *proxy sample* of the stream's head
-- joint keys with their sketch estimates -- plus per-group marginal-skew
summaries.  ``propose_spec`` feeds the proxy sample into the existing
greedy search to re-draw the composite strategy online.

The proxy sample is head-biased by construction (it holds the estimated
top-K keys, not a uniform thinning), which is the right bias for the
range-ratio estimates: the paper's alpha aggregates are frequency-weighted
(SIV-A), so the head dominates them on the skewed streams this matters
for.  When the keyspace is small enough that the pools are under capacity
and the tables collision-free, the proxy sample IS the exact compressed
stream and the re-search is exactly the offline search
(tests/test_selection_greedy.py enforces this parity).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.core import sketch as sk
from repro.core.hashing import KeySchema


@dataclasses.dataclass
class LiveStats:
    """Stream statistics derived from an endpoint's sketch + pool state.

    ``items``/``freqs`` are the weighted proxy sample (schema module
    order, sketch estimates as weights) that feeds the greedy re-search;
    ``group_values``/``group_mass`` are the raw per-group heavy values
    from the pools with their marginal masses read off the level tables.
    """
    schema: KeySchema
    items: np.ndarray                 # uint32[K, n_modules], schema order
    freqs: np.ndarray                 # int64[K] sketch estimates (>= true)
    total: int                        # endpoint's ingested stream mass
    group_values: List[np.ndarray]    # per partition group: uint32[C_j, |g_j|]
    group_mass: List[np.ndarray]      # per group: int64[C_j] marginal mass

    @property
    def coverage(self) -> float:
        """Estimated stream-mass fraction the proxy sample accounts for.

        Can exceed 1.0 under heavy collisions (estimates overcount)."""
        if self.total <= 0:
            return 0.0
        return float(self.freqs.sum() / self.total)

    def group_skew(self, j: int) -> float:
        """Top-value mass fraction of group j's marginal (1.0 = one value
        carries everything; ~C/total... -> uniform).  The per-module skew
        signal that makes re-tuning worthwhile when it drifts."""
        if self.total <= 0 or len(self.group_mass[j]) == 0:
            return 0.0
        return float(self.group_mass[j].max() / self.total)

    def describe(self) -> str:
        gs = " ".join(
            f"g{j}:C={len(v)},skew={self.group_skew(j):.3f}"
            for j, v in enumerate(self.group_values))
        return (f"live-stats: {len(self.items)} proxy keys "
                f"({self.coverage:.2f} of {self.total} mass) {gs}")


def group_marginal_mass(endpoint, j: int, values: np.ndarray) -> np.ndarray:
    """Marginal mass O(*,..,value_of_group_j,..,*) for each value, read off
    the endpoint's level tables.

    Group 0's marginal IS the level-0 table (the coarsest prefix sketch);
    any other group's marginal comes from ``sk.query_marginal`` on the
    finest level, summing the cells that share the group's sub-index --
    the structural capability composite hashing buys over Count-Min.
    """
    values = np.asarray(values, dtype=np.uint32)
    if values.shape[0] == 0:
        return np.zeros((0,), dtype=np.int64)
    state = endpoint.state
    if callable(state):      # ShardedTopKService exposes state() as a method
        state = state()
    hspec = endpoint.hspec
    if j == 0:
        est = sk.query(hspec.levels[0], state.states[0],
                       np.ascontiguousarray(values))
    else:
        est = sk.query_marginal(hspec.levels[-1], state.states[-1], j,
                                np.ascontiguousarray(values))
    return np.asarray(est, dtype=np.int64)


def collect_live_stats(endpoint, *, k: int = 512,
                       min_threshold: Optional[int] = None) -> LiveStats:
    """Derive :class:`LiveStats` from a serving endpoint's live state.

    ``endpoint`` is anything with the SketchTopKEndpoint query surface
    (``hspec``, ``state``/``state()``, ``candidates()``, ``topk``,
    ``total``) -- the sharded service qualifies.  ``k`` bounds the proxy
    sample (the estimated top-k keys); ``min_threshold`` floors the
    descent exactly as in ``topk`` (pass 1 to force exhaustive descent on
    small keyspaces).

    No stream pass happens here: everything is read from the pools (heavy
    group values) and the level tables (prefix / marginal mass).
    """
    items, est = endpoint.topk(int(k), min_threshold=min_threshold)
    items = np.asarray(items, dtype=np.uint32)
    est = np.asarray(est, dtype=np.int64)

    group_values, group_mass = [], []
    for j, vals in enumerate(endpoint.candidates()):
        vals = np.asarray(vals, dtype=np.uint32)
        group_values.append(vals)
        group_mass.append(group_marginal_mass(endpoint, j, vals))

    return LiveStats(
        schema=endpoint.hspec.base.schema,
        items=items, freqs=est, total=int(endpoint.total),
        group_values=group_values, group_mass=group_mass)


def propose_spec(stats: LiveStats, h: int, w: int, key: jax.Array,
                 agg: str = "median", partition=None):
    """Re-run the strategy search over the live proxy sample.

    With ``partition=None`` this is the full greedy re-search (paper
    Algorithm 1): partition AND per-group ranges are re-drawn with prod ~
    h, width w.  Passing a ``partition`` (usually the endpoint's current
    one) keeps the group structure -- and with it the hierarchy's descent
    levels -- and re-optimizes only the per-group ranges via the SIV-A
    alpha-ratio rule (core.range_opt.recursive_ranges), the knob that
    actually tracks per-module skew drift: when a narrow hot module goes
    wide, its optimal range grows at the expense of the others.

    Returns a :class:`repro.core.greedy.GreedyResult` either way (the
    range-only path with an empty trace), so callers read ``.spec``
    uniformly.  Whether the proposal is worth a hot migration is the
    caller's call -- serving/autotune.py compares cell-std sigmas
    (core.selection.migration_gain) before pulling the trigger.
    """
    from repro.core.greedy import GreedyResult, greedy_config
    from repro.core.range_opt import recursive_ranges

    if stats.items.shape[0] < 2:
        raise ValueError(
            "propose_spec needs at least 2 proxy keys; the endpoint has "
            "not seen enough distinct stream mass to re-tune from")
    if partition is not None:
        ranges = recursive_ranges(stats.items, stats.freqs, partition,
                                  float(h), agg)
        spec = sk.SketchSpec(stats.schema, tuple(tuple(g) for g in partition),
                             tuple(int(r) for r in ranges), int(w))
        return GreedyResult(spec=spec, trace=[],
                            n_candidates=len(partition), beta_cache_hits=0)
    return greedy_config(stats.items, stats.freqs, stats.schema,
                         int(h), int(w), key, agg=agg)
