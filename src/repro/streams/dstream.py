"""DStream-style batched streaming harness with live accuracy tracking.

Feeds a stream of TIMESTAMPED batches through a windowed heavy-hitter
service (serving/windowed_topk.py), advancing the service's epoch clock
from the timestamps, and after every batch scores the service against
exact windowed ground truth maintained alongside:

  * average relative error (streams.stats.average_relative_error) over the
    window's exact top-k keys,
  * heavy-hitter recall/precision at a phi-fraction threshold of the
    window mass,
  * F2: the exact second moment of the window vs the sketch's row-min
    upper bound (streams.stats.sketch_f2_upper), as relative error.

This is the single-device answer to the Spark-cluster style discretized-
stream evaluation loops (batch -> update sketch -> compare against exact
counts -> report ARE/F2): the exact counter here is a ring of per-epoch
dicts that expires with the service, so ground truth and sketch always
describe the SAME window.  The harness can also thin the stream through a
BernoulliSampler (streams/sampling.py) on the side -- the paper's 2-4%
uniform sample, kept live for offline range re-tuning -- without touching
the ground truth.

``benchmarks/window_bench.py`` drives this harness over a drifting stream
to produce the decay-vs-tumbling-vs-landmark accuracy rows of
BENCH_WINDOW.json; tests/test_window.py runs it small for invariants.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.streams.sampling import BernoulliSampler
from repro.streams.stats import (
    average_relative_error,
    exact_f2,
    hierarchy_point_estimates,
    sketch_f2_upper,
)


# --------------------------------------------------------------------------
# Exact windowed ground truth
# --------------------------------------------------------------------------

class ExactWindowCounter:
    """Ring of per-epoch exact counters mirroring the service's window.

    Same epoch semantics as core/window.py: tumbling drops expired epochs,
    landmark folds them into a retired counter, decay weights epoch age a
    by decay**a (applied at read time over the live ring -- exact, no
    accumulating float drift).  Memory is O(distinct keys in the window),
    which is the price of ground truth and why it lives in the evaluation
    harness, not the serving path.
    """

    def __init__(self, n_epochs: int, mode: str = "tumbling",
                 decay: float = 1.0):
        if mode not in ("tumbling", "landmark", "decay"):
            raise ValueError(f"unknown mode {mode!r}")
        self.n_epochs = int(n_epochs)
        self.mode = mode
        self.decay = float(decay)
        self._ring: List[Counter] = [Counter() for _ in range(self.n_epochs)]
        self._retired: Counter = Counter()
        self._head = 0
        self._epoch = 0

    def ingest(self, items: np.ndarray, freqs: np.ndarray) -> None:
        c = self._ring[self._head]
        for row, f in zip(np.asarray(items).tolist(),
                          np.asarray(freqs).tolist()):
            if f:
                c[tuple(row)] += f

    def advance(self) -> None:
        self._head = (self._head + 1) % self.n_epochs
        if self.mode == "landmark":
            self._retired.update(self._ring[self._head])
        self._ring[self._head] = Counter()
        self._epoch += 1

    def window_counts(self) -> Dict[tuple, float]:
        """Exact key -> (possibly decay-weighted) frequency of the window."""
        n_live = min(self._epoch + 1, self.n_epochs)
        out: Dict[tuple, float] = dict(self._retired) \
            if self.mode == "landmark" else {}
        for a in reversed(range(n_live)):            # oldest -> newest
            slot = (self._head - a) % self.n_epochs
            wgt = self.decay ** a if self.mode == "decay" else 1.0
            for k, f in self._ring[slot].items():
                out[k] = out.get(k, 0.0) + wgt * f
        return out


# --------------------------------------------------------------------------
# Timestamped batches
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Batch:
    """One discretized-stream arrival: a weighted key block at a time."""
    t: int                    # epoch timestamp (non-decreasing)
    items: np.ndarray         # uint32[B, n_modules]
    freqs: np.ndarray         # int64[B]


def timestamped_batches(items: np.ndarray, freqs: np.ndarray,
                        n_batches: int, batches_per_epoch: int = 1,
                        ) -> Iterator[Batch]:
    """Cut a compressed stream into ``n_batches`` equal arrival batches,
    ``batches_per_epoch`` of them per epoch tick."""
    items = np.asarray(items, dtype=np.uint32)
    freqs = np.asarray(freqs)
    edges = np.linspace(0, items.shape[0], n_batches + 1).astype(int)
    for b, (s, e) in enumerate(zip(edges[:-1], edges[1:])):
        yield Batch(t=b // batches_per_epoch, items=items[s:e],
                    freqs=freqs[s:e])


def drifting_batches(schema_domains: Tuple[int, int], n_batches: int,
                     rows_per_batch: int, *, batches_per_epoch: int = 1,
                     drift_every: int = 4, n_keys: int = 2_000,
                     s: float = 1.2, seed: int = 0) -> Iterator[Batch]:
    """Zipf key stream whose popularity RANKING is re-permuted every
    ``drift_every`` epochs -- the workload where "since boot" and "last
    hour" genuinely disagree, used by the window benchmark's accuracy
    sweep.  Keys are 2-module (edge-like) over ``schema_domains``."""
    rng = np.random.default_rng(seed)
    keys = np.stack([
        rng.choice(schema_domains[0], size=n_keys, replace=False),
        rng.choice(schema_domains[1], size=n_keys, replace=False),
    ], axis=1).astype(np.uint32)
    p = np.arange(1, n_keys + 1, dtype=np.float64) ** (-s)
    p /= p.sum()
    perm = rng.permutation(n_keys)
    for b in range(n_batches):
        epoch = b // batches_per_epoch
        if b and b % (drift_every * batches_per_epoch) == 0:
            perm = rng.permutation(n_keys)       # new heavy set
        draws = rng.choice(n_keys, size=rows_per_batch, p=p)
        picked = keys[perm[draws]]
        uniq, inv = np.unique(picked, axis=0, return_inverse=True)
        yield Batch(t=epoch, items=uniq,
                    freqs=np.bincount(inv).astype(np.int64))


def skew_flip_batches(schema_domains: Tuple[int, int], n_batches: int,
                      rows_per_batch: int, *, batches_per_epoch: int = 1,
                      flip_after: Optional[int] = None, narrow: int = 8,
                      wide: int = 1_024, s: float = 1.4,
                      seed: int = 0) -> Iterator[Batch]:
    """Two-module stream whose per-MODULE marginal skew flips mid-stream.

    Unlike :func:`drifting_batches` (which re-permutes the joint ranking
    but keeps each module's marginal shape), this drifts the statistic the
    composite-hash strategy is actually tuned to: before the flip, module
    0's marginal is concentrated on ``narrow`` hot values (zipf ``s``)
    while module 1 is near-uniform over ``wide`` values; after batch
    ``flip_after`` (default: halfway) the roles swap.  Modules are drawn
    independently, so the optimal per-group ranges (a, b) under the
    paper's alpha-ratio rule flip with them -- a spec tuned on the first
    phase is measurably stale on the second, which is what the online
    auto-tuner (serving/autotune.py) exists to catch.
    """
    if flip_after is None:
        flip_after = n_batches // 2
    rng = np.random.default_rng(seed)
    d0, d1 = schema_domains
    narrow = min(narrow, d0, d1)
    vals0 = rng.choice(d0, size=min(wide, d0), replace=False).astype(np.uint32)
    vals1 = rng.choice(d1, size=min(wide, d1), replace=False).astype(np.uint32)

    def _marginal_p(n_vals: int, skewed: bool) -> np.ndarray:
        if skewed:
            p = np.zeros(n_vals, dtype=np.float64)
            p[:narrow] = np.arange(1, narrow + 1, dtype=np.float64) ** (-s)
        else:
            p = np.ones(n_vals, dtype=np.float64)
        return p / p.sum()

    for b in range(n_batches):
        hot0 = b < flip_after                  # module 0 skewed first phase
        c0 = rng.choice(len(vals0), size=rows_per_batch,
                        p=_marginal_p(len(vals0), skewed=hot0))
        c1 = rng.choice(len(vals1), size=rows_per_batch,
                        p=_marginal_p(len(vals1), skewed=not hot0))
        picked = np.stack([vals0[c0], vals1[c1]], axis=1)
        uniq, inv = np.unique(picked, axis=0, return_inverse=True)
        yield Batch(t=b // batches_per_epoch, items=uniq,
                    freqs=np.bincount(inv).astype(np.int64))


# --------------------------------------------------------------------------
# Harness
# --------------------------------------------------------------------------

@dataclasses.dataclass
class BatchReport:
    """Live accuracy of the service after one batch, vs exact window truth."""
    batch: int
    epoch: int
    window_total: float       # exact (decay-weighted) window mass
    window_distinct: int
    are_topk: float           # ARE over the exact top-k window keys
    recall: float             # heavy hitters found / exact heavy hitters
    precision: float          # exact among reported heavy hitters
    f2_exact: float
    f2_est: float             # sketch row-min upper bound
    f2_rel_err: float         # (f2_est - f2_exact) / f2_exact  (>= 0 linear)


class DStreamHarness:
    """Drive a WindowedTopKService over timestamped batches, scoring live.

    ``k`` sizes the ARE query set (the window's exact top-k); ``phi``
    sets the heavy-hitter threshold as a fraction of the exact window
    mass.  ``sample_p`` optionally maintains a Bernoulli-thinned side
    sample of everything ingested (``.sample()``), the paper's uniform
    stream sample kept warm for offline strategy re-tuning.
    """

    def __init__(self, service, *, k: int = 32, phi: float = 0.01,
                 sample_p: Optional[float] = None, sample_seed: int = 0):
        self.service = service
        self.k = int(k)
        self.phi = float(phi)
        self.exact = ExactWindowCounter(
            service.wspec.n_epochs, mode=service.wspec.mode,
            decay=service.wspec.decay)
        self.sampler = (BernoulliSampler(sample_p, seed=sample_seed)
                        if sample_p else None)
        self.reports: List[BatchReport] = []
        self._batch = 0
        self._clock = 0

    def sample(self) -> Tuple[np.ndarray, np.ndarray]:
        if self.sampler is None:
            raise ValueError("harness built without sample_p")
        return self.sampler.sample()

    def step(self, batch: Batch) -> BatchReport:
        """Ingest one batch (advancing epochs to its timestamp), then score."""
        if batch.t < self._clock:
            raise ValueError(
                f"batch timestamps must be non-decreasing (got {batch.t} "
                f"after {self._clock})")
        while self._clock < batch.t:
            self.service.advance()
            self.exact.advance()
            self._clock += 1
        self.service.ingest(batch.items, batch.freqs)
        self.exact.ingest(batch.items, batch.freqs)
        if self.sampler is not None:
            self.sampler.offer(batch.items, batch.freqs)
        report = self._score()
        self.reports.append(report)
        self._batch += 1
        return report

    def run(self, batches: Iterable[Batch]) -> List[BatchReport]:
        for batch in batches:
            self.step(batch)
        return self.reports

    def _score(self) -> BatchReport:
        truth = self.exact.window_counts()
        total = float(sum(truth.values()))
        ranked = sorted(truth.items(), key=lambda kv: (-kv[1], kv[0]))

        # ARE over the exact top-k window keys (point queries against the
        # merged window state -- the descent is not needed for scoring)
        top = ranked[: self.k]
        if top:
            qi = np.asarray([k for k, _ in top], dtype=np.uint32)
            qt = np.asarray([f for _, f in top], dtype=np.float64)
            est = hierarchy_point_estimates(
                self.service.hspec, self.service.state(), qi)
            are = average_relative_error(est, qt)
        else:
            are = 0.0

        # heavy hitters at phi * window mass
        thr = max(1, int(self.phi * total))
        exact_hh = {k for k, f in truth.items() if f >= thr}
        got_items, _ = self.service.heavy_hitters(thr)
        got_hh = {tuple(r) for r in got_items.tolist()}
        recall = (len(exact_hh & got_hh) / len(exact_hh)) if exact_hh else 1.0
        precision = (len(exact_hh & got_hh) / len(got_hh)) if got_hh else 1.0

        # F2 of the window: exact vs the finest level's row-min bound
        f2 = exact_f2(np.asarray(list(truth.values())))
        finest = np.asarray(self.service.state().states[-1].table)
        f2_est = sketch_f2_upper(finest)
        f2_err = (f2_est - f2) / f2 if f2 > 0 else 0.0

        return BatchReport(
            batch=self._batch, epoch=self._clock, window_total=total,
            window_distinct=len(truth), are_topk=are, recall=recall,
            precision=precision, f2_exact=f2, f2_est=f2_est,
            f2_rel_err=f2_err)

