"""Synthetic stream generators matched to the paper's datasets (SVI-A1).

The raw Twitter/CAIDA traces are not redistributable offline, so we generate
streams with the same *structure*: modular keys, Zipf-skewed frequencies, and
asymmetric module marginals.  Calibration targets (Tables II/III):

  * Twitter  (mod 2): #targets ~ 3.1x #sources, max freq ~ 17K, L ~ 151M
  * IPv4-1   (mod 2): #sources ~ 10.9x #targets (7.23M vs 0.67M), L ~ 6.2G
  * IPv4#4 / IPv4#8: the same pairs viewed as 16-bit / 8-bit words

Scales are configurable so benchmarks run on one CPU core; structure (skew
direction and modularity) is what the paper's claims depend on.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.hashing import KeySchema


@dataclasses.dataclass
class Stream:
    """A weighted (compressed) stream: distinct items + frequencies.

    A p-fraction *uniform occurrence sample* of the flat stream is drawn per
    item as Binomial(freq, p) -- exactly the distribution a uniform sample of
    the expanded stream would have (see :meth:`sample`).
    """
    schema: KeySchema
    items: np.ndarray       # uint32[N, n_modules], distinct
    freqs: np.ndarray       # int64[N]
    name: str = "stream"

    @property
    def total(self) -> int:
        return int(self.freqs.sum())

    def sample(self, fraction: float, rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
        """Uniform sample of stream occurrences (paper's 2-4% sample)."""
        cnt = rng.binomial(self.freqs.astype(np.int64), fraction)
        keep = cnt > 0
        return self.items[keep], cnt[keep]

    def top_k_queries(self, k: int) -> Tuple[np.ndarray, np.ndarray]:
        idx = np.argsort(-self.freqs)[:k]
        return self.items[idx], self.freqs[idx]

    def random_k_queries(self, k: int, rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
        idx = rng.choice(len(self.freqs), size=min(k, len(self.freqs)), replace=False)
        return self.items[idx], self.freqs[idx]


def _zipf_values(n_distinct: int, n_draws: int, s: float, rng: np.random.Generator) -> np.ndarray:
    """n_draws values in [0, n_distinct) with Zipf(s) head-heavy skew."""
    ranks = np.arange(1, n_distinct + 1, dtype=np.float64)
    p = ranks ** (-s)
    p /= p.sum()
    return rng.choice(n_distinct, size=n_draws, p=p)


def zipf_graph_stream(
    n_src: int = 20_000,
    n_tgt: int = 60_000,
    n_edges: int = 200_000,
    n_occurrences: int = 2_000_000,
    s_src: float = 1.1,
    s_tgt: float = 1.1,
    seed: int = 0,
    name: str = "twitter-like",
) -> Stream:
    """Directed-edge stream with asymmetric node marginals (Twitter-like).

    Node ids are randomly embedded in [0, 2^32) so hashing sees realistic
    key magnitudes.  With n_tgt > n_src the per-item alpha = O(src,*)/O(*,tgt)
    is typically > 1 => optimal b > a, matching the paper's Twitter finding.
    """
    rng = np.random.default_rng(seed)
    src = _zipf_values(n_src, n_edges, s_src, rng)
    tgt = _zipf_values(n_tgt, n_edges, s_tgt, rng)
    # random id embedding
    src_ids = rng.choice(np.uint32(0xFFFFFFFF), size=n_src, replace=False).astype(np.uint32)
    tgt_ids = rng.choice(np.uint32(0xFFFFFFFF), size=n_tgt, replace=False).astype(np.uint32)
    edges = np.stack([src_ids[src], tgt_ids[tgt]], axis=1)
    uniq, inv = np.unique(edges, axis=0, return_counts=False, return_inverse=True)
    # Zipf edge frequencies on top of edge multiplicity
    mult = np.bincount(inv)
    f = mult.astype(np.float64)
    f = f / f.sum()
    freqs = rng.multinomial(n_occurrences, f).astype(np.int64)
    keep = freqs > 0
    schema = KeySchema(domains=(1 << 32, 1 << 32))
    return Stream(schema=schema, items=uniq[keep].astype(np.uint32), freqs=freqs[keep], name=name)


def ipv4_stream(
    n_src_hosts: int = 40_000,
    n_tgt_hosts: int = 4_000,
    n_pairs: int = 150_000,
    n_occurrences: int = 3_000_000,
    s: float = 1.2,
    seed: int = 1,
    name: str = "ipv4-like",
) -> Stream:
    """(src_ip, dst_ip) pair stream; #sources >> #targets like CAIDA probing."""
    rng = np.random.default_rng(seed)
    src_hosts = rng.integers(0, 1 << 32, size=n_src_hosts, dtype=np.uint64).astype(np.uint32)
    tgt_hosts = rng.integers(0, 1 << 32, size=n_tgt_hosts, dtype=np.uint64).astype(np.uint32)
    src = src_hosts[_zipf_values(n_src_hosts, n_pairs, s, rng)]
    tgt = tgt_hosts[_zipf_values(n_tgt_hosts, n_pairs, 0.8, rng)]
    pairs = np.stack([src, tgt], axis=1)
    uniq, inv = np.unique(pairs, axis=0, return_inverse=True)
    mult = np.bincount(inv).astype(np.float64)
    freqs = rng.multinomial(n_occurrences, mult / mult.sum()).astype(np.int64)
    keep = freqs > 0
    schema = KeySchema(domains=(1 << 32, 1 << 32))
    return Stream(schema=schema, items=uniq[keep].astype(np.uint32), freqs=freqs[keep], name=name)


def reinterpret_modularity(stream: Stream, words: int) -> Stream:
    """View a modularity-2 (two 32-bit modules) stream at higher modularity.

    words=4: 16-bit words (IPv4#4 analogue); words=8: 8-bit words (IPv4#8).
    This mirrors how the paper derives #4/#8 datasets from the same trace.
    """
    if stream.schema.domains != (1 << 32, 1 << 32):
        raise ValueError("expects a two x 32-bit stream")
    if words not in (4, 8):
        raise ValueError("words must be 4 or 8")
    bits = 64 // words
    mask = (1 << bits) - 1
    packed = (stream.items[:, 0].astype(np.uint64) << np.uint64(32)) | stream.items[:, 1].astype(np.uint64)
    cols = [((packed >> np.uint64(bits * (words - 1 - i))) & np.uint64(mask)).astype(np.uint32)
            for i in range(words)]
    items = np.stack(cols, axis=1)
    schema = KeySchema(domains=(1 << bits,) * words)
    return Stream(schema=schema, items=items, freqs=stream.freqs.copy(),
                  name=f"{stream.name}#{words}")


def telecom_stream(
    n_users: int = 30_000,
    n_calls: int = 120_000,
    seed: int = 3,
) -> Stream:
    """(caller, callee, duration_s) stream -- the paper's SIII example of
    arbitrary positive per-tuple counts (seconds of conversation)."""
    rng = np.random.default_rng(seed)
    a = _zipf_values(n_users, n_calls, 1.05, rng).astype(np.uint32)
    b = rng.integers(0, n_users, size=n_calls, dtype=np.int64).astype(np.uint32)
    pairs = np.stack([a, b], axis=1)
    uniq, inv = np.unique(pairs, axis=0, return_inverse=True)
    dur = rng.exponential(180.0, size=n_calls).astype(np.int64) + 1
    freqs = np.bincount(inv, weights=dur.astype(np.float64)).astype(np.int64)
    schema = KeySchema(domains=(n_users, n_users))
    return Stream(schema=schema, items=uniq, freqs=freqs, name="telecom-like")
