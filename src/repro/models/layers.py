"""Shared neural-net layers (pure functions over param pytrees)."""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def make_norm_params(cfg: ModelConfig, d: int) -> Dict[str, jax.Array]:
    p = {"scale": jnp.ones((d,), cfg.activation_dtype)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.activation_dtype)
    return p


def apply_norm(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, n_heads, head_dim]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                        # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP variants
# --------------------------------------------------------------------------

def make_mlp_params(cfg: ModelConfig, key, d: int, f: int) -> Dict[str, jax.Array]:
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 3)
    p: Dict[str, jax.Array] = {}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[0], d, f, dt)
        p["w_in"] = dense_init(ks[1], d, f, dt)
    else:
        p["w_in"] = dense_init(ks[1], d, f, dt)
    p["w_out"] = dense_init(ks[2], f, d, dt)
    if cfg.use_bias:
        p["b_in"] = jnp.zeros((f,), dt)
        p["b_out"] = jnp.zeros((d,), dt)
    return p


def apply_mlp(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
    elif cfg.mlp_type == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_in"])
    else:
        h = x @ p["w_in"]
        if "b_in" in p:
            h = h + p["b_in"]
        h = jax.nn.gelu(h, approximate=True)
    y = h @ p["w_out"]
    if "b_out" in p:
        y = y + p["b_out"]
    return y


# --------------------------------------------------------------------------
# misc
# --------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float) -> jax.Array:
    return (jnp.tanh(x / cap) * cap).astype(x.dtype)
