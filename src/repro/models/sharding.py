"""Sharding rules: param/optimizer/batch/cache PartitionSpecs per mesh.

Layout (DESIGN.md S5): 2-D sharding -- tensor-parallel over ``model``
(attention heads, FFN hidden, vocab, MoE expert FFN, SSD heads) x
FSDP/ZeRO-3-style over the data axes (``data`` or ``("pod","data")``) on the
other big dimension.  Every rule is path+rank based over the real param
tree, so it applies uniformly to the stacked-block layout (leading
``n_blocks`` dim -> spec prepended with None).

Decode caches: batch over data axes and *sequence over model* -- decode
attention is then sequence-parallel (flash-decoding style: partial softmax
stats psum over ``model``); ``long_500k`` (batch=1) shards the sequence over
every axis.  SSM decode caches shard SSD heads over ``model``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.training.optimizer import Moment8

PyTree = Any


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """(data_axes, model_axis) for single-pod / multi-pod meshes."""
    names = mesh.axis_names
    if names[-1] != "model":
        raise ValueError(f"expected trailing 'model' axis, got {names}")
    return tuple(names[:-1]), "model"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _param_rule(path: str, ndim: int, dp, mp) -> P:
    """PartitionSpec for one (unstacked) parameter leaf."""
    leaf = path.rsplit("/", 1)[-1]
    if leaf in ("embed",):
        return P(mp, dp)                       # (V, D): vocab TP, d FSDP
    if leaf == "lm_head":
        return P(dp, mp)                       # (D, V)
    if leaf in ("wq", "wk", "wv", "w_gate", "w_in", "in_proj"):
        return P(dp, mp)                       # (D, out): out TP
    if leaf in ("wo", "w_out", "out_proj"):
        return P(mp, dp)                       # (in, D): in TP
    if leaf == "router":
        return P(dp, None)                     # (D, E): experts replicated
    if leaf in ("bq", "bk", "bv", "b_in"):
        return P(mp)
    if leaf in ("bo", "b_out"):
        return P(None)
    if leaf == "conv_w":
        return P(None, mp)                     # (K, C)
    if leaf == "conv_b":
        return P(mp)
    if leaf == "norm_scale":
        return P(mp)                           # (d_inner,) SSD gated norm
    if leaf in ("dt_bias", "a_log", "d_skip"):
        return P(None)                         # tiny per-head vectors
    if leaf in ("scale", "bias"):
        return P(None)                         # layer norms
    return P(*([None] * ndim))


def _moe_rule(path: str, ndim: int, dp, mp, mode: str = "2d") -> Optional[P]:
    """Expert-stacked leaves: (E, D, F) / (E, F, D).

    mode "2d": D over data axes, F over model (TPxFSDP; contraction dims
    sharded -> partial-sum ARs of the [G,E,C,F] intermediates in the
    grouped-dispatch path).  mode "f_allaxes": F over ALL axes, D unsharded
    -- contraction over D is local, the F-psum over model is the only
    reduction, and the FSDP memory share is preserved (SPerf cell A iter 4).
    """
    leaf = path.rsplit("/", 1)[-1]
    if "moe" not in path:
        return None
    axes_all = (dp if isinstance(dp, tuple) else (dp,)) + (mp,)
    if leaf in ("w_gate", "w_in"):
        return P(None, None, axes_all) if mode == "f_allaxes" else P(None, dp, mp)
    if leaf == "w_out":
        return P(None, axes_all, None) if mode == "f_allaxes" else P(None, mp, dp)
    return None


def param_pspec(path: str, ndim: int, dp, mp, stacked: bool,
                moe_mode: str = "2d") -> P:
    """Spec for a leaf; ``stacked`` leaves get a leading None (block dim)."""
    inner_ndim = ndim - 1 if stacked else ndim
    rule = _moe_rule(path, inner_ndim, dp, mp, moe_mode) \
        or _param_rule(path, inner_ndim, dp, mp)
    parts = list(rule) + [None] * (inner_ndim - len(rule))
    if stacked:
        parts = [None] + parts
    return P(*parts)


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes from dims they do not divide (jit requires exact
    divisibility of input shardings).  Axes are dropped from the right of a
    dim's axis tuple until the remaining product divides the dim."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, parts):
        if ax is None:
            out.append(None)
            continue
        axes = list(ax) if isinstance(ax, tuple) else [ax]
        while axes:
            prod = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % prod == 0:
                break
            axes.pop()
        out.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


def sanitize_specs(specs: PyTree, shapes: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s, x: sanitize_spec(s, x.shape, mesh), specs, shapes,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_specs(cfg: ModelConfig, params_shape: PyTree, mesh: Mesh) -> PyTree:
    """PartitionSpec pytree matching the param tree (from eval_shape)."""
    dp_axes, mp = mesh_axes(mesh)
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def spec_of(path, leaf):
        ps = _path_str(path)
        leafname = ps.rsplit("/", 1)[-1]
        # vocab-carrying leaves: preference chain (odd vocab sizes fall back
        # to sharding d_model on the model axis rather than dropping TP)
        if leafname == "embed":
            chain = (P(mp, dp), P(None, mp), P(None, dp))
        elif leafname == "lm_head":
            chain = (P(dp, mp), P(mp, None), P(dp, None))
        else:
            chain = None
        if chain is not None:
            for cand in chain:
                if sanitize_spec(cand, leaf.shape, mesh) == cand:
                    return cand
            return sanitize_spec(chain[0], leaf.shape, mesh)
        stacked = ps.startswith("blocks") or ps.startswith("enc_blocks")
        return sanitize_spec(param_pspec(ps, leaf.ndim, dp, mp, stacked,
                                         getattr(cfg, "moe_weight_shard", "2d")),
                             leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_of, params_shape)


def opt_state_specs(cfg: ModelConfig, opt_shape: PyTree, pspecs: PyTree,
                    mesh: Mesh) -> PyTree:
    """Optimizer-state specs mirror the param specs (incl. Moment8 leaves).

    Moment8.q has the param's shape; Moment8.scale has the same rank (last
    dim / 128) so the same spec applies to both.
    """
    def expand(ps, leaf):
        if isinstance(leaf, Moment8):
            return Moment8(q=sanitize_spec(ps, leaf.q.shape, mesh),
                           scale=sanitize_spec(ps, leaf.scale.shape, mesh))
        return sanitize_spec(ps, leaf.shape, mesh)

    return {
        "m": jax.tree.map(expand, pspecs, opt_shape["m"],
                          is_leaf=lambda x: isinstance(x, Moment8)),
        "v": jax.tree.map(expand, pspecs, opt_shape["v"],
                          is_leaf=lambda x: isinstance(x, Moment8)),
        "step": P(),
    }


def batch_specs(cfg: ModelConfig, mesh: Mesh, with_embeds: bool):
    dp_axes, _ = mesh_axes(mesh)
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    tokens = P(dp, None)
    if not with_embeds:
        return {"tokens": tokens}
    return {"tokens": tokens, "embeds": P(dp, None, None)}


def cache_specs(cfg: ModelConfig, cache_shape: PyTree, mesh: Mesh,
                batch: int) -> PyTree:
    """Decode-cache specs (stacked leading n_blocks dim on every leaf)."""
    dp_axes, mp = mesh_axes(mesh)
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    n_data = int(np.prod([mesh.shape[a] for a in dp_axes]))
    batch_sharded = batch >= n_data

    def spec_of(path, leaf):
        ps = _path_str(path)
        leafname = ps.rsplit("/", 1)[-1]
        if leafname in ("k", "v", "cross_k", "cross_v"):
            # (blocks, B, S, kv, hd)
            if batch_sharded:
                return P(None, dp, mp, None, None)
            return P(None, None, (*dp_axes, mp), None, None)
        if leafname == "ssm":
            # (blocks, B, H, N, P)
            if batch_sharded:
                return P(None, dp, mp, None, None)
            return P(None, None, mp, None, None)
        if leafname == "conv":
            # (blocks, B, K-1, C)
            if batch_sharded:
                return P(None, dp, None, mp)
            return P(None, None, None, mp)
        return P(*([None] * leaf.ndim))

    def spec_of_safe(path, leaf):
        return sanitize_spec(spec_of(path, leaf), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_of_safe, cache_shape)


def to_shardings(mesh: Mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
