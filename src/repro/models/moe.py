"""Mixture-of-Experts FFN: top-k routing with capacity-based gather dispatch.

Dispatch is sort-based (argsort by expert id -> capacity buckets -> gather),
so expert compute is a single batched einsum of shape [E, C, *] with
C = T * top_k * capacity_factor / E -- i.e. the compiled FLOPs equal the
*active* expert compute (correct 6*N_active*D roofline accounting), unlike a
dense all-experts evaluation.  Overflowing tokens are dropped (standard
capacity semantics) and their combine weight is zero.

Telemetry: returns the (expert, token-bucket) modularity-2 key stream for
the MOD-Sketch routing monitor (streams/ngram.py; DESIGN.md S2) -- few
experts x many buckets is exactly the asymmetric-marginal regime of Thm 3.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.shard_ctx import DP, MP, constrain


def make_moe_params(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_in": (jax.random.normal(ks[1], (e, d, f), jnp.float32) / jnp.sqrt(d)).astype(dt),
        "w_out": (jax.random.normal(ks[2], (e, f, d), jnp.float32) / jnp.sqrt(f)).astype(dt),
    }
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(ks[3], (e, d, f), jnp.float32) / jnp.sqrt(d)).astype(dt)
    return p


def _dispatch_groups(cfg: ModelConfig, t: int) -> int:
    """#independent dispatch groups for moe_dispatch='local'.

    One group per DP shard (from the active mesh context): capacity is
    computed per shard and the scatter/gather becomes a batched (vmapped)
    scatter GSPMD can partition on the group dim -- no cross-shard
    activation collectives in the dispatch (SPerf collective-term fix).
    Slightly higher drop variance than global capacity (per-group
    imbalance); measured in EXPERIMENTS.md.
    """
    if cfg.moe_dispatch != "local":
        return 1
    from repro.models.shard_ctx import current_mesh
    mesh = current_mesh()
    if mesh is None:
        return 1
    g = 1
    for name in mesh.axis_names:
        if name != "model":
            g *= mesh.shape[name]
    while g > 1 and t % g:
        g //= 2
    return max(1, g)


def apply_moe(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,            # [B, S, D]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k

    if cfg.moe_dispatch == "ep_shardmap":
        from repro.models.shard_ctx import current_mesh
        if current_mesh() is not None:
            return _shardmap_dispatch(cfg, p, x)

    xt = x.reshape(t, d)

    gate_logits = (xt.astype(jnp.float32)) @ p["router"]               # [T, E]
    weights, experts = jax.lax.top_k(jax.nn.softmax(gate_logits, -1), k)  # [T,k]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    groups = _dispatch_groups(cfg, t)
    if groups > 1:
        out, aux = _grouped_dispatch(cfg, p, xt.reshape(groups, t // groups, d),
                                     experts.reshape(groups, t // groups, k),
                                     weights.reshape(groups, t // groups, k))
        me = jnp.mean(jax.nn.softmax(gate_logits, -1), axis=0)
        ce = jnp.mean(jax.nn.one_hot(experts[:, 0], e), axis=0)
        aux["lb_loss"] = e * jnp.sum(me * ce)
        aux["expert_choice"] = experts
        return out.reshape(b, s, d), aux

    # ---- capacity-bucketed dispatch -----------------------------------
    # Small token counts (decode steps, smoke tests) run dropless: cap = T*k
    # guarantees no overflow whatever the routing; large T uses the standard
    # capacity formula (overflowing tokens dropped, weight 0).
    if t * k <= 4096:
        cap = t * k
    else:
        cap = int(max(1, round(t * k * cfg.capacity_factor / e)))
    flat_expert = experts.reshape(-1)                                   # [T*k]
    order = jnp.argsort(flat_expert)                                    # stable
    sorted_expert = flat_expert[order]
    # position of each routed slot within its expert's bucket
    slot_in_expert = jnp.arange(t * k) - jnp.searchsorted(
        sorted_expert, sorted_expert, side="left")
    keep = slot_in_expert < cap
    token_of = order // k                                               # [T*k]
    dest = jnp.where(keep, sorted_expert * cap + slot_in_expert, 0)     # [T*k]

    # gather tokens into [E*C, D]: kept slots have unique dests, so a masked
    # scatter-add == set, and the buffer stays shardable (no overflow row)
    upd = jnp.where(keep[:, None], xt[token_of], 0)
    buf = jnp.zeros((e * cap, d), x.dtype).at[dest].add(upd)
    xe = constrain(buf.reshape(e, cap, d), None, DP, None)

    # ---- expert FFN: [E, C, D] x [E, D, F] ------------------------------
    if "w_gate" in p:
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
        h = constrain(h, None, DP, MP)
    else:
        h = constrain(jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["w_in"])),
                      None, DP, MP)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"]).reshape(e * cap, d)

    # ---- combine back ---------------------------------------------------
    gathered = jnp.where(keep[:, None], ye[dest], 0.0)
    wcomb = (weights.reshape(-1)[order] * keep).astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[token_of].add(gathered * wcomb[:, None])

    # ---- aux: load-balancing loss + routing telemetry -------------------
    me = jnp.mean(jax.nn.softmax(gate_logits, -1), axis=0)               # [E]
    ce = jnp.mean(jax.nn.one_hot(experts[:, 0], e), axis=0)
    aux = {
        "lb_loss": e * jnp.sum(me * ce),
        "expert_choice": experts,                                        # [T, k]
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.reshape(b, s, d), aux


def _grouped_dispatch(cfg: ModelConfig, p, xg, eg, wg):
    """Per-group capacity dispatch, vmapped over the group (DP-shard) dim.

    xg: [G, Tl, D], eg: [G, Tl, k], wg: [G, Tl, k].  The vmapped scatter /
    gather lower to batched scatter ops that GSPMD partitions along G, so
    dispatch traffic stays shard-local.
    """
    g_, tl, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(max(1, round(tl * k * cfg.capacity_factor / e)))
    if tl * k <= 4096:
        cap = tl * k

    # NOTE (SPerf iteration 3, refuted): forcing the expert weights to
    # (None, None, MP) here to avoid dp-sharded contractions made XLA
    # replicate the expert einsums instead (t_compute x13, t_coll x3.8 at
    # mixtral train_4k).  Reverted; the partial-sum ARs are cheaper.
    w_in, w_out = p["w_in"], p["w_out"]
    w_gate = p.get("w_gate")

    def one_group(xt, experts, weights):
        flat_expert = experts.reshape(-1)
        order = jnp.argsort(flat_expert)
        sorted_expert = flat_expert[order]
        slot = jnp.arange(tl * k) - jnp.searchsorted(sorted_expert,
                                                     sorted_expert, "left")
        keep = slot < cap
        token_of = order // k
        dest = jnp.where(keep, sorted_expert * cap + slot, 0)
        upd = jnp.where(keep[:, None], xt[token_of], 0)
        buf = jnp.zeros((e * cap, d), xt.dtype).at[dest].add(upd)
        xe = buf.reshape(e, cap, d)
        if w_gate is not None:
            act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
            h = act(jnp.einsum("ecd,edf->ecf", xe, w_gate)) * \
                jnp.einsum("ecd,edf->ecf", xe, w_in)
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, w_in))
        ye = jnp.einsum("ecf,efd->ecd", h, w_out).reshape(e * cap, d)
        gathered = jnp.where(keep[:, None], ye[dest], 0.0)
        wcomb = (weights.reshape(-1)[order] * keep).astype(xt.dtype)
        out = jnp.zeros((tl, d), xt.dtype).at[token_of].add(
            gathered * wcomb[:, None])
        return out, jnp.mean(keep.astype(jnp.float32))

    xg = constrain(xg, DP, None, None)
    out, kept = jax.vmap(one_group)(xg, eg, wg)
    out = constrain(out, DP, None, None)
    return out.reshape(g_ * tl, d), {"dropped_frac": 1.0 - jnp.mean(kept)}


# --------------------------------------------------------------------------
# shard_map expert compute (SPerf cell A, iteration 5)
# --------------------------------------------------------------------------

def _shardmap_dispatch(cfg: ModelConfig, p, x: jax.Array):
    """Explicit-collective MoE: the program structure GSPMD cannot find.

    Iterations 2-4 (EXPERIMENTS SPerf) showed that with token groups on the
    data axes and expert weights D-sharded on them, the partitioner always
    resolves the einsum conflict by partial-sum all-reducing the [E,C,F]
    intermediates (TBs/step).  Under shard_map WE choose the loser:

      1. all-gather the expert weights' D-shard over the data axes
         (~100s of MB per layer -- the cheap side),
      2. dispatch and contract entirely locally (tokens stay in their
         shard; each model column computes its F-slice of every expert),
      3. one psum over "model" combines the F-slices (the only big
         collective: ~|tokens_local| * D per layer).

    Capacity is per data shard (same semantics as moe_dispatch="local").
    """
    from jax.sharding import PartitionSpec as P
    from repro.models.shard_ctx import current_mesh

    mesh = current_mesh()
    dp_axes = tuple(n for n in mesh.axis_names if n != "model")
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    e, k = cfg.n_experts, cfg.top_k
    has_gate = "w_gate" in p

    def local_fn(router, wg, wi, wo, xl):
        bl, sl, d = xl.shape
        tl = bl * sl
        xt = xl.reshape(tl, d)
        gates = jax.nn.softmax(xt.astype(jnp.float32) @ router, -1)
        weights, experts = jax.lax.top_k(gates, k)
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

        cap = tl * k if tl * k <= 4096 else int(
            max(1, round(tl * k * cfg.capacity_factor / e)))
        flat_expert = experts.reshape(-1)
        order = jnp.argsort(flat_expert)
        sorted_expert = flat_expert[order]
        slot = jnp.arange(tl * k) - jnp.searchsorted(sorted_expert,
                                                     sorted_expert, "left")
        keep = slot < cap
        token_of = order // k
        dest = jnp.where(keep, sorted_expert * cap + slot, 0)
        upd = jnp.where(keep[:, None], xt[token_of], 0)
        xe = jnp.zeros((e * cap, d), xt.dtype).at[dest].add(upd)
        xe = xe.reshape(e, cap, d)

        # weights arrive D-sharded over the data axes: gather D explicitly
        wi_f = jax.lax.all_gather(wi, dp_axes, axis=1, tiled=True)
        wo_f = jax.lax.all_gather(wo, dp_axes, axis=2, tiled=True)
        if has_gate:
            wg_f = jax.lax.all_gather(wg, dp_axes, axis=1, tiled=True)
            act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
            h = act(jnp.einsum("ecd,edf->ecf", xe, wg_f)) * \
                jnp.einsum("ecd,edf->ecf", xe, wi_f)
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, wi_f))
        ye = jnp.einsum("ecf,efd->ecd", h, wo_f).reshape(e * cap, d)

        gathered = jnp.where(keep[:, None], ye[dest], 0.0)
        wcomb = (weights.reshape(-1)[order] * keep).astype(xt.dtype)
        out = jnp.zeros((tl, d), xt.dtype).at[token_of].add(
            gathered * wcomb[:, None])
        # each model column held an F-slice: combine the partial outputs
        out = jax.lax.psum(out, "model")

        me = jnp.mean(gates, axis=0)
        ce = jnp.mean(jax.nn.one_hot(experts[:, 0], e), axis=0)
        lb = jax.lax.pmean(e * jnp.sum(me * ce), dp_axes)
        drop = jax.lax.pmean(1.0 - jnp.mean(keep.astype(jnp.float32)),
                             dp_axes)
        return out.reshape(bl, sl, d), lb, drop

    w_spec = P(None, dp, "model")
    wo_spec = P(None, "model", dp)
    args = [p["router"], p.get("w_gate", p["w_in"]), p["w_in"], p["w_out"], x]
    out, lb, drop = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(None, None), w_spec, w_spec, wo_spec, P(dp, None, None)),
        out_specs=(P(dp, None, None), P(), P()),
        check_vma=False,
    )(*args)
    aux = {"lb_loss": lb, "dropped_frac": drop,
           "expert_choice": jnp.zeros((1, k), jnp.int32)}
    return out, aux
