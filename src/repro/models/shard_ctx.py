"""Activation-sharding context: `constrain(x, ...)` hints inside model code.

Model code is mesh-agnostic; the launcher activates a mesh context and the
layers drop `with_sharding_constraint` pins at the few places where XLA's
propagation would otherwise lose the batch sharding (embedding lookup with a
non-divisible vocab, logits contraction, MoE dispatch buffers).  Tokens:

    DP   -- the data-parallel axes ("data" or ("pod","data"))
    MP   -- the model axis
    None -- unsharded dim

Constraints are divisibility-sanitized against the actual dim (an axis that
does not divide the dim is dropped), so the same model code lowers on any
mesh -- and is a no-op outside a context (single-device tests).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP = "__dp__"
MP = "__mp__"

_state = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh):
    """Enable constraint emission during tracing/lowering."""
    names = mesh.axis_names
    dp = tuple(names[:-1])
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, dp, names[-1])
    try:
        yield
    finally:
        _state.ctx = prev


def current_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def constrain(x: jax.Array, *tokens) -> jax.Array:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, dp, mp = ctx
    parts = []
    for dim, tok in zip(x.shape, tokens):
        if tok == DP:
            axes = list(dp)
        elif tok == MP:
            axes = [mp]
        elif tok is None:
            parts.append(None)
            continue
        else:
            axes = [tok] if isinstance(tok, str) else list(tok)
        while axes:
            prod = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % prod == 0:
                break
            axes.pop()
        parts.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    parts += [None] * (x.ndim - len(parts))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
