"""Layer-stack assembly: init + forward + prefill + decode for all families.

The stack is a ``lax.scan`` over ``n_blocks`` stacked parameter blocks, each
block holding ``cfg.block_period`` heterogeneously-typed sublayers with a
*static* per-position kind (attn/mamba, mlp/moe, local/global window) --
this keeps the HLO proportional to one block at any depth (compile-time at
512 devices) and gives remat a natural boundary.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.shard_ctx import DP, MP, constrain
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    embed_init,
    dense_init,
    make_mlp_params,
    make_norm_params,
    softcap,
)

Params = Dict[str, Any]


# ==========================================================================
# init
# ==========================================================================

def _make_layer_params(cfg: ModelConfig, key, i: int, *, cross: bool = False) -> Params:
    """Params for sublayer position i of a block."""
    ks = jax.random.split(key, 6)
    kind = cfg.layer_kind(i)
    p: Params = {"norm1": make_norm_params(cfg, cfg.d_model)}
    if kind == "attn":
        p["attn"] = attn.make_attn_params(cfg, ks[0])
    else:
        p["ssm"] = ssm_mod.make_ssm_params(cfg, ks[0])
    if cross:
        p["norm_cross"] = make_norm_params(cfg, cfg.d_model)
        p["cross"] = attn.make_attn_params(cfg, ks[1], cross=True)
    if cfg.d_ff and not cfg.parallel_block:
        p["norm2"] = make_norm_params(cfg, cfg.d_model)
    if cfg.layer_is_moe(i):
        p["moe"] = moe_mod.make_moe_params(cfg, ks[2])
    elif cfg.d_ff:
        p["mlp"] = make_mlp_params(cfg, ks[3], cfg.d_model, cfg.d_ff)
    if cfg.post_block_norm:
        p["post_attn_norm"] = make_norm_params(cfg, cfg.d_model)
        if cfg.d_ff:
            p["post_ff_norm"] = make_norm_params(cfg, cfg.d_model)
    return p


def _make_block_params(cfg: ModelConfig, key, *, cross: bool = False) -> Params:
    ks = jax.random.split(key, cfg.block_period)
    return {f"layer_{i}": _make_layer_params(cfg, ks[i], i, cross=cross)
            for i in range(cfg.block_period)}


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    block_keys = jax.random.split(ks[0], cfg.n_blocks)
    params: Params = {
        "embed": embed_init(ks[1], cfg.padded_vocab, cfg.d_model,
                            cfg.activation_dtype),
        "blocks": jax.vmap(lambda k: _make_block_params(
            cfg, k, cross=bool(cfg.n_enc_layers)))(block_keys),
        "final_norm": make_norm_params(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.padded_vocab,
                                       cfg.activation_dtype)
    if cfg.n_enc_layers:
        enc_keys = jax.random.split(ks[3], cfg.n_enc_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: {"layer_0": _make_layer_params(cfg, k, 0)})(enc_keys)
        params["enc_final_norm"] = make_norm_params(cfg, cfg.d_model)
    return params


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ==========================================================================
# forward building blocks
# ==========================================================================

def _apply_layer_train(cfg: ModelConfig, lp: Params, x: jax.Array,
                       positions: jax.Array, i: int,
                       enc: Optional[jax.Array], aux: Dict[str, jax.Array],
                       causal: bool = True) -> jax.Array:
    kind = cfg.layer_kind(i)
    h = apply_norm(cfg, lp["norm1"], x)
    if kind == "attn":
        mix = attn.self_attention(cfg, lp["attn"], h, positions,
                                  cfg.layer_window(i), causal=causal)
    else:
        mix = ssm_mod.ssm_forward(cfg, lp["ssm"], h)
    if cfg.post_block_norm:
        mix = apply_norm(cfg, lp["post_attn_norm"], mix)

    if cfg.parallel_block and "mlp" in lp:
        x = x + mix + apply_mlp(cfg, lp["mlp"], h)
        return x
    x = x + mix

    if enc is not None and "cross" in lp:
        hc = apply_norm(cfg, lp["norm_cross"], x)
        x = x + attn.cross_attention(cfg, lp["cross"], hc, enc)

    if "moe" in lp:
        h2 = apply_norm(cfg, lp["norm2"], x)
        y, moe_aux = moe_mod.apply_moe(cfg, lp["moe"], h2)
        aux["lb_loss"] = aux.get("lb_loss", 0.0) + moe_aux["lb_loss"]
        aux["dropped_frac"] = aux.get("dropped_frac", 0.0) + moe_aux["dropped_frac"]
        if cfg.post_block_norm:
            y = apply_norm(cfg, lp["post_ff_norm"], y)
        x = x + y
    elif "mlp" in lp:
        h2 = apply_norm(cfg, lp["norm2"], x)
        y = apply_mlp(cfg, lp["mlp"], h2)
        if cfg.post_block_norm:
            y = apply_norm(cfg, lp["post_ff_norm"], y)
        x = x + y
    return x


def _stack_forward(cfg: ModelConfig, blocks: Params, x: jax.Array,
                   positions: jax.Array, enc: Optional[jax.Array] = None,
                   causal: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """scan over stacked blocks; returns (hidden, summed aux)."""

    def block_fn(carry, bp):
        h = constrain(carry, DP, None, None)
        aux: Dict[str, jax.Array] = {}
        for i in range(cfg.block_period):
            h = _apply_layer_train(cfg, bp[f"layer_{i}"], h, positions, i,
                                   enc, aux, causal=causal)
            h = constrain(h, DP, None, None)
        ys = {
            "lb_loss": jnp.asarray(aux.get("lb_loss", 0.0), jnp.float32),
            "dropped_frac": jnp.asarray(aux.get("dropped_frac", 0.0), jnp.float32),
        }
        return h, ys

    if cfg.remat:
        block_fn = jax.checkpoint(
            block_fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, ys = jax.lax.scan(block_fn, x, blocks)
    return x, {k: jnp.sum(v) for k, v in ys.items()}


def _logits(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if logits.ndim == 3:
        logits = constrain(logits, DP, None, MP)
    else:
        logits = constrain(logits, DP, MP)
    if cfg.padded_vocab != cfg.vocab_size:
        # padded vocab rows exist only for TP divisibility: mask them out of
        # every softmax/argmax downstream
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    if cfg.logit_softcap:
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits.astype(jnp.float32)


def _embed(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    x = constrain(x, DP, None, None)
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    return x


def _encode(cfg: ModelConfig, params: Params, embeds: jax.Array) -> jax.Array:
    pos = jnp.arange(embeds.shape[1])
    h, _ = _stack_forward(cfg, params["enc_blocks"], embeds, pos, causal=False)
    return apply_norm(cfg, params["enc_final_norm"], h)


# ==========================================================================
# public entry points
# ==========================================================================

def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,                 # int32[B, S_text]
    embeds: Optional[jax.Array] = None,  # [B, F, D] frontend stub prefix
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Training/prefill forward -> (logits [B, S_total, V], aux)."""
    x, aux = hidden_forward(cfg, params, tokens, embeds=embeds)
    return _logits(cfg, params, x), aux


def hidden_forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Forward up to the final norm (no unembedding)."""
    x = _embed(cfg, params, tokens)
    enc = None
    if cfg.n_enc_layers:
        enc = _encode(cfg, params, embeds)
    elif embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    x, aux = _stack_forward(cfg, params["blocks"], x, positions, enc=enc)
    return apply_norm(cfg, params["final_norm"], x), aux


def loss_fn(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    embeds: Optional[jax.Array] = None,
    lb_coef: float = 0.01,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross-entropy (text positions only) + MoE aux loss.

    With ``cfg.loss_chunk > 0`` the [B, S, V] logit tensor never
    materializes: a rematerialized scan computes per-chunk logits ->
    log-softmax -> NLL and discards them (SPerf memory-term optimization).
    """
    hidden, aux = hidden_forward(cfg, params, tokens, embeds=embeds)
    n_prefix = hidden.shape[1] - tokens.shape[1]
    hx = hidden[:, n_prefix : n_prefix + tokens.shape[1] - 1, :]  # predictors
    tgt = tokens[:, 1:]

    if cfg.loss_chunk and hx.shape[1] > cfg.loss_chunk:
        ck = cfg.loss_chunk
        n_tok = hx.shape[1]
        pad = (-n_tok) % ck                     # pad to a chunk multiple;
        if pad:                                 # padded positions are masked
            hx = jnp.pad(hx, ((0, 0), (0, pad), (0, 0)))
            tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
        valid = (jnp.arange(hx.shape[1]) < n_tok)
        nc = hx.shape[1] // ck
        hc = hx.reshape(hx.shape[0], nc, ck, hx.shape[-1])
        tc = tgt.reshape(tgt.shape[0], nc, ck)
        vc = valid.reshape(nc, ck)

        def chunk_nll(args):
            h_c, t_c, v_c = args                         # [B,ck,D], [B,ck], [ck]
            logits = _logits(cfg, params, h_c)
            lp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(lp, t_c[..., None], axis=-1)[..., 0]
            return jnp.sum(nll * v_c[None, :])

        def scan_body(acc, args):
            return acc + jax.checkpoint(chunk_nll)(args), None

        total_nll, _ = jax.lax.scan(
            scan_body, jnp.float32(0.0),
            (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(tc, 1, 0), vc))
        ce = total_nll / (hx.shape[0] * n_tok)
    else:
        logits = _logits(cfg, params, hx)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        nll = constrain(nll, DP, None)
        ce = jnp.mean(nll)
    total = ce + lb_coef * aux.get("lb_loss", 0.0)
    metrics = {"ce": ce, **aux}
    return total, metrics


# --------------------------------------------------------------------------
# caches: stacked per block, mirrors the block structure
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0) -> Params:
    """Decode cache, stacked over n_blocks (scan xs/ys structure)."""

    def one_block():
        c: Params = {}
        for i in range(cfg.block_period):
            kind = cfg.layer_kind(i)
            if kind == "attn":
                c[f"layer_{i}"] = attn.init_kv_cache(cfg, batch, max_len)
            else:
                c[f"layer_{i}"] = ssm_mod.init_ssm_cache(cfg, batch)
            if cfg.n_enc_layers:
                hd = cfg.resolved_head_dim
                c[f"layer_{i}"]["cross_k"] = jnp.zeros(
                    (batch, enc_len, cfg.n_kv_heads, hd), cfg.activation_dtype)
                c[f"layer_{i}"]["cross_v"] = jnp.zeros(
                    (batch, enc_len, cfg.n_kv_heads, hd), cfg.activation_dtype)
        return c

    blk = one_block()
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_blocks,) + x.shape), blk)


def decode_step(
    cfg: ModelConfig,
    params: Params,
    cache: Params,
    tokens_last: jax.Array,     # int32[B, 1]
    pos: jax.Array,             # int32[] position of the new token
) -> Tuple[jax.Array, Params]:
    """One serve step: next-token logits + updated cache."""
    x = _embed(cfg, params, tokens_last)

    def block_fn(carry, xs):
        h = carry
        bp, bc = xs
        new_bc: Params = {}
        for i in range(cfg.block_period):
            lp, lc = bp[f"layer_{i}"], bc[f"layer_{i}"]
            kind = cfg.layer_kind(i)
            hn = apply_norm(cfg, lp["norm1"], h)
            if kind == "attn":
                mix, upd = attn.decode_self_attention(
                    cfg, lp["attn"], {"k": lc["k"], "v": lc["v"]}, hn, pos,
                    cfg.layer_window(i))
                new_lc = dict(lc)
                new_lc.update(upd)
            else:
                mix, upd = ssm_mod.ssm_decode(cfg, lp["ssm"], lc, hn)
                new_lc = dict(lc)
                new_lc.update(upd)
            if cfg.post_block_norm:
                mix = apply_norm(cfg, lp["post_attn_norm"], mix)
            if cfg.parallel_block and "mlp" in lp:
                h = h + mix + apply_mlp(cfg, lp["mlp"], hn)
                new_bc[f"layer_{i}"] = new_lc
                continue
            h = h + mix
            if "cross" in lp and "cross_k" in lc:
                hc = apply_norm(cfg, lp["norm_cross"], h)
                h = h + _decode_cross(cfg, lp["cross"], hc, lc)
            if "moe" in lp:
                h2 = apply_norm(cfg, lp["norm2"], h)
                y, _ = moe_mod.apply_moe(cfg, lp["moe"], h2)
                if cfg.post_block_norm:
                    y = apply_norm(cfg, lp["post_ff_norm"], y)
                h = h + y
            elif "mlp" in lp:
                h2 = apply_norm(cfg, lp["norm2"], h)
                y = apply_mlp(cfg, lp["mlp"], h2)
                if cfg.post_block_norm:
                    y = apply_norm(cfg, lp["post_ff_norm"], y)
                h = h + y
            new_bc[f"layer_{i}"] = new_lc
        return h, new_bc

    x, new_cache = jax.lax.scan(block_fn, x, (params["blocks"], cache))
    x = apply_norm(cfg, params["final_norm"], x)
    return _logits(cfg, params, x), new_cache


def _decode_cross(cfg: ModelConfig, p: Params, x: jax.Array, lc: Params) -> jax.Array:
    """Cross-attention for one decode token using precomputed enc K/V."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, 1, cfg.n_heads, hd)
    if "bq" in p:
        q = q + p["bq"].reshape(1, 1, cfg.n_heads, hd)
    k = attn._expand_kv(cfg, lc["cross_k"])
    v = attn._expand_kv(cfg, lc["cross_v"])
    mask = jnp.ones((1, 1, 1, k.shape[1]), bool)
    out = attn._attend(cfg, q, k, v, mask).reshape(b, 1, -1) @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,                 # int32[B, S]
    embeds: Optional[jax.Array] = None,
    max_len: Optional[int] = None,
) -> Tuple[jax.Array, Params]:
    """Process a prompt; return (last-position logits [B, V], filled cache).

    The cache is sized ``max_len`` (>= S) so subsequent decode_steps append.
    """
    x = _embed(cfg, params, tokens)
    enc = None
    if cfg.n_enc_layers:
        enc = _encode(cfg, params, embeds)
    elif embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    ml = max_len or s
    positions = jnp.arange(s)

    def block_fn(carry, bp):
        h = carry
        caches: Params = {}
        aux: Dict[str, jax.Array] = {}
        for i in range(cfg.block_period):
            lp = bp[f"layer_{i}"]
            kind = cfg.layer_kind(i)
            hn = apply_norm(cfg, lp["norm1"], h)
            lcache: Params = {}
            if kind == "attn":
                qh, kh, vh = attn._project_qkv(cfg, lp["attn"], hn)
                qh = attn.apply_rope(qh, positions, cfg.rope_theta)
                kh = attn.apply_rope(kh, positions, cfg.rope_theta)
                pad = ml - s
                lcache["k"] = jnp.pad(kh, ((0, 0), (0, pad), (0, 0), (0, 0)))
                lcache["v"] = jnp.pad(vh, ((0, 0), (0, pad), (0, 0), (0, 0)))
                kf = attn._expand_kv(cfg, kh)
                vf = attn._expand_kv(cfg, vh)
                if s > cfg.attn_chunk_threshold:
                    mix = attn._blockwise_causal(cfg, qh, kf, vf, cfg.layer_window(i))
                else:
                    mask = attn._causal_mask(s, s, jnp.int32(0), cfg.layer_window(i))
                    mix = attn._attend(cfg, qh, kf, vf, mask)
                mix = mix.reshape(b, s, -1) @ lp["attn"]["wo"]
                if "bo" in lp["attn"]:
                    mix = mix + lp["attn"]["bo"]
            else:
                mix, st = ssm_mod.ssm_forward(cfg, lp["ssm"], hn, return_state=True)
                lcache.update(st)
            if cfg.post_block_norm:
                mix = apply_norm(cfg, lp["post_attn_norm"], mix)
            if cfg.parallel_block and "mlp" in lp:
                h = h + mix + apply_mlp(cfg, lp["mlp"], hn)
                caches[f"layer_{i}"] = lcache
                continue
            h = h + mix
            if enc is not None and "cross" in lp:
                hc = apply_norm(cfg, lp["norm_cross"], h)
                h = h + attn.cross_attention(cfg, lp["cross"], hc, enc)
                hd = cfg.resolved_head_dim
                ksrc = (enc @ lp["cross"]["wk"]).reshape(b, -1, cfg.n_kv_heads, hd)
                vsrc = (enc @ lp["cross"]["wv"]).reshape(b, -1, cfg.n_kv_heads, hd)
                if "bk" in lp["cross"]:
                    ksrc = ksrc + lp["cross"]["bk"].reshape(1, 1, cfg.n_kv_heads, hd)
                    vsrc = vsrc + lp["cross"]["bv"].reshape(1, 1, cfg.n_kv_heads, hd)
                lcache["cross_k"] = ksrc
                lcache["cross_v"] = vsrc
            if "moe" in lp:
                h2 = apply_norm(cfg, lp["norm2"], h)
                y, moe_aux = moe_mod.apply_moe(cfg, lp["moe"], h2)
                if cfg.post_block_norm:
                    y = apply_norm(cfg, lp["post_ff_norm"], y)
                h = h + y
            elif "mlp" in lp:
                h2 = apply_norm(cfg, lp["norm2"], h)
                y = apply_mlp(cfg, lp["mlp"], h2)
                if cfg.post_block_norm:
                    y = apply_norm(cfg, lp["post_ff_norm"], y)
                h = h + y
            caches[f"layer_{i}"] = lcache
        return h, caches

    x, cache = jax.lax.scan(block_fn, x, params["blocks"])
    x = apply_norm(cfg, params["final_norm"], x)
    return _logits(cfg, params, x[:, -1, :]), cache
