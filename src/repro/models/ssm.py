"""Mamba2 (SSD, state-space duality) mixer -- TPU-native chunked form.

The sequence is processed in chunks of Q tokens inside one ``lax.scan``
carrying the inter-chunk SSM state H in [B, heads, N, P]:

  * intra-chunk: the quadratic "attention-like" branch -- masked decay
    matrix L composed with C.B^T, contracted on the MXU,
  * inter-chunk: the linear recurrence H' = decay * H + B^T.(dt*x).

Streaming the chunks through the scan (rather than materializing all
[B, nc, H, Q, Q] decay blocks at once) keeps the per-step working set at
[B, H, Q, Q] -- the VMEM-conscious formulation (DESIGN.md S4).  Exponentials
and cumulative sums run in fp32; contractions accumulate in fp32.

Decode is the O(1) recurrence: conv ring-state + per-token state update --
what makes ssm/hybrid archs eligible for the long_500k cell.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.shard_ctx import DP, MP, constrain


def make_ssm_params(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    d = cfg.d_model
    din = cfg.ssm_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_ch = din + 2 * n                      # conv over [x, B, C]
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z (din), x (din), B (n), C (n), dt (h)]
        "in_proj": dense_init(ks[0], d, 2 * din + 2 * n + h, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((din,), dt),
        "out_proj": dense_init(ks[4], din, d, dt),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    din, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :din]
    x = proj[..., din : 2 * din]
    bmat = proj[..., 2 * din : 2 * din + n]
    cmat = proj[..., 2 * din + n : 2 * din + 2 * n]
    dt_raw = proj[..., 2 * din + 2 * n :]
    return z, x, bmat, cmat, dt_raw


def _causal_conv(p: Dict[str, jax.Array], u: jax.Array) -> jax.Array:
    """Depthwise causal conv, width K: u [B, S, C] -> [B, S, C]."""
    k = p["conv_w"].shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):
        out = out + pad[:, i : i + u.shape[1], :] * p["conv_w"][i]
    return out + p["conv_b"]


def _ssd_chunk_scan(cfg: ModelConfig, x, dtv, bmat, cmat, a, d_skip, h0):
    """Chunked SSD.  x:[B,S,H,P] dtv:[B,S,H] bmat/cmat:[B,S,N] a:[H].

    Returns (y [B,S,H,P], h_final [B,H,N,P]).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(cfg.ssm_chunk, s)
    s_orig = s
    if s % q:
        # pad the tail with dt = 0 entries: exp(0) decay leaves the state
        # untouched and dt-weighted contributions vanish -- exact padding.
        pad = q - s % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // q

    xc = x.reshape(b, nc, q, h, p)
    dtc = dtv.reshape(b, nc, q, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)

    def step(hstate, inputs):
        x_c, dt_c, b_c, c_c = inputs          # [B,q,h,p] [B,q,h] [B,q,n] [B,q,n]
        da = dt_c * a                          # [B,q,h] (a < 0)
        cs = jnp.cumsum(da, axis=1)            # [B,q,h]
        # intra-chunk: masked decay L[i,j] = exp(cs_i - cs_j), i >= j.
        # Mask BEFORE exp: for i < j the diff is positive and exp overflows,
        # and inf in the untaken where-branch still poisons the backward pass.
        diff = cs[:, :, None, :] - cs[:, None, :, :]          # [B,i,j,h]
        mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, :, :, None]
        ldecay = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)
        cb = jnp.einsum("bin,bjn->bij", c_c, b_c)             # [B,i,j]
        m = cb[..., None] * ldecay                            # [B,i,j,h]
        y_diag = jnp.einsum("bijh,bjh,bjhp->bihp", m, dt_c,
                            x_c.astype(jnp.float32))
        # contribution of the carried state
        y_off = jnp.einsum("bin,bhnp->bihp", c_c, hstate) * \
            jnp.exp(cs)[..., None]                            # [B,i,h,p]
        # next state
        decay_to_end = jnp.exp(cs[:, -1:, :] - cs)            # [B,j,h]
        s_c = jnp.einsum("bjn,bjh,bjhp->bhnp", b_c, dt_c * decay_to_end,
                         x_c.astype(jnp.float32))
        h_next = jnp.exp(cs[:, -1, :])[:, :, None, None] * hstate + s_c
        y = y_diag + y_off + d_skip[None, None, :, None] * x_c.astype(jnp.float32)
        return h_next, y.astype(x_c.dtype)

    inputs = (
        jnp.moveaxis(xc, 1, 0),
        jnp.moveaxis(dtc, 1, 0),
        jnp.moveaxis(bc, 1, 0),
        jnp.moveaxis(cc, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)[:, :s_orig]
    return y, h_final


def ssm_forward(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    u: jax.Array,                 # [B, S, D]
    h0: jax.Array | None = None,  # [B, H, N, P] initial state
    return_state: bool = False,
):
    b, s, _ = u.shape
    din, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim

    proj = u @ p["in_proj"]
    z, x, bmat, cmat, dt_raw = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([x, bmat, cmat], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(p, conv_in))
    x = constrain(conv_out[..., :din].reshape(b, s, h, pdim), DP, None, MP, None)
    bmat = conv_out[..., din : din + n]
    cmat = conv_out[..., din + n :]
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    if h0 is None:
        h0 = jnp.zeros((b, h, n, pdim), jnp.float32)
    y, h_final = _ssd_chunk_scan(cfg, x, dtv, bmat, cmat, a, p["d_skip"], h0)
    y = y.reshape(b, s, din)

    # gated RMSNorm + out projection
    g = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    g = (g.astype(jnp.float32) * jax.lax.rsqrt(ms + cfg.norm_eps)).astype(u.dtype)
    out = (g * p["norm_scale"]) @ p["out_proj"]
    if return_state:
        # conv ring state: last (K-1) channels-in inputs
        k = cfg.ssm_conv
        tail = jnp.concatenate(
            [jnp.zeros((b, max(0, k - 1 - s), conv_in.shape[-1]), conv_in.dtype),
             conv_in[:, max(0, s - (k - 1)):, :]], axis=1)
        return out, {"ssm": h_final, "conv": tail}
    return out


# --------------------------------------------------------------------------
# O(1) decode
# --------------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    din, n = cfg.ssm_inner, cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, din + 2 * n),
                          cfg.activation_dtype),
    }


def ssm_decode(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    cache: Dict[str, jax.Array],
    u: jax.Array,                 # [B, 1, D]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b = u.shape[0]
    din, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim

    proj = u[:, 0] @ p["in_proj"]                              # [B, *]
    z, x, bmat, cmat, dt_raw = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([x, bmat, cmat], axis=-1)        # [B, C]
    window = jnp.concatenate([cache["conv"], conv_in[:, None, :]], axis=1)  # [B,K,C]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(u.dtype)
    x = conv_out[:, :din].reshape(b, h, pdim)
    bmat = conv_out[:, din : din + n].astype(jnp.float32)      # [B, N]
    cmat = conv_out[:, din + n :].astype(jnp.float32)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B, H]
    a = -jnp.exp(p["a_log"])

    decay = jnp.exp(dtv * a)                                    # [B, H]
    hs = cache["ssm"] * decay[:, :, None, None] + \
        jnp.einsum("bn,bh,bhp->bhnp", bmat, dtv, x.astype(jnp.float32))
    y = jnp.einsum("bn,bhnp->bhp", cmat, hs) + \
        p["d_skip"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, din)

    g = y.astype(u.dtype) * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    g = (g.astype(jnp.float32) * jax.lax.rsqrt(ms + cfg.norm_eps)).astype(u.dtype)
    out = ((g * p["norm_scale"]) @ p["out_proj"])[:, None, :]
    new_cache = {"ssm": hs, "conv": window[:, 1:, :]}
    return out, new_cache
