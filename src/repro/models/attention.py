"""GQA/MQA attention: full, sliding-window, blockwise (long-seq), and
single-token decode against a KV cache.

Blockwise attention chunks the query axis with ``lax.scan`` (flash-style
memory profile: the [B,H,S,S] logit tensor never materializes, only
[B,H,Cq,S]); it is numerically identical to the dense path (same softmax,
fp32 accumulation) and switches on automatically above
``cfg.attn_chunk_threshold``.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, softcap
from repro.models.shard_ctx import DP, MP, constrain


def make_attn_params(cfg: ModelConfig, key, *, cross: bool = False) -> Dict[str, jax.Array]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dt = cfg.activation_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bo"] = jnp.zeros((d,), dt)
    return p


def _project_qkv(cfg: ModelConfig, p, x: jax.Array, kv_x: Optional[jax.Array] = None):
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    kv_src = x if kv_x is None else kv_x
    q = x @ p["wq"]
    k = kv_src @ p["wk"]
    v = kv_src @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(b, x.shape[1], cfg.n_heads, hd), DP, None, MP, None)
    k = constrain(k.reshape(b, kv_src.shape[1], cfg.n_kv_heads, hd), DP, None, MP, None)
    v = constrain(v.reshape(b, kv_src.shape[1], cfg.n_kv_heads, hd), DP, None, MP, None)
    return q, k, v


def _expand_kv(cfg: ModelConfig, k: jax.Array) -> jax.Array:
    """[B, S, n_kv, hd] -> [B, S, n_heads, hd] by repeating each kv head."""
    rep = cfg.n_heads // cfg.n_kv_heads
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def _attend(cfg: ModelConfig, q, k, v, mask) -> jax.Array:
    """q: [B,Sq,H,hd], k/v: [B,Sk,H,hd], mask: [B or 1, 1, Sq, Sk] bool."""
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if cfg.attn_softcap:
        logits = jnp.tanh(logits / cfg.attn_softcap) * cfg.attn_softcap
    logits = jnp.where(mask, logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _causal_mask(sq: int, sk: int, q_offset, window: int) -> jax.Array:
    """bool[1, 1, Sq, Sk]: causal (+ sliding window if window > 0)."""
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window:
        m = m & (kpos > qpos - window)
    return m[None, None]


def self_attention(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,                # [B, S, D]
    positions: jax.Array,        # [B, S] or [S]
    window: int,
    causal: bool = True,
) -> jax.Array:
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k = _expand_kv(cfg, k)
    v = _expand_kv(cfg, v)

    if causal and s > cfg.attn_chunk_threshold:
        out = _blockwise_causal(cfg, q, k, v, window)
    else:
        if causal:
            mask = _causal_mask(s, s, jnp.int32(0), window)
        else:
            mask = jnp.ones((1, 1, s, s), bool)
        out = _attend(cfg, q, k, v, mask)
    out = out.reshape(b, s, -1) @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out


def _blockwise_causal(cfg: ModelConfig, q, k, v, window: int) -> jax.Array:
    """Query-chunked causal attention (flash-style memory profile)."""
    b, s, h, hd = q.shape
    cq = min(cfg.attn_chunk, s)
    n_chunks = s // cq
    assert s % cq == 0, f"seq {s} % chunk {cq} != 0"
    qc = q.reshape(b, n_chunks, cq, h, hd)

    def step(_, ci):
        qi = qc[:, ci]                                        # [B, Cq, H, hd]
        offset = ci * cq
        mask = _causal_mask(cq, s, offset, window)            # [1,1,Cq,S]
        return None, _attend(cfg, qi, k, v, mask)

    _, chunks = jax.lax.scan(step, None, jnp.arange(n_chunks))
    # chunks: [n_chunks, B, Cq, H, hd] -> [B, S, H, hd]
    return jnp.moveaxis(chunks, 0, 1).reshape(b, s, h, hd)


def cross_attention(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,            # [B, Sq, D] decoder states
    enc: jax.Array,          # [B, Sk, D] encoder output
) -> jax.Array:
    b, sq, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, kv_x=enc)
    k = _expand_kv(cfg, k)
    v = _expand_kv(cfg, v)
    mask = jnp.ones((1, 1, sq, k.shape[1]), bool)
    out = _attend(cfg, q, k, v, mask).reshape(b, sq, -1) @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out


# --------------------------------------------------------------------------
# decode (single new token against a KV cache)
# --------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, jax.Array]:
    hd = cfg.resolved_head_dim
    dt = cfg.activation_dtype
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
    }


def decode_self_attention(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    cache: Dict[str, jax.Array],
    x: jax.Array,              # [B, 1, D] the new token's hidden state
    pos: jax.Array,            # int32[] or [B] current position
    window: int,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(cfg, p, x)
    posb = jnp.broadcast_to(jnp.atleast_1d(pos), (b,))[:, None]   # [B,1]
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)
    cache_k = _scatter_time(cache["k"], k_new, pos)
    cache_v = _scatter_time(cache["v"], v_new, pos)
    k = _expand_kv(cfg, cache_k)
    v = _expand_kv(cfg, cache_v)
    s = k.shape[1]
    kpos = jnp.arange(s)[None, None, None, :]
    mask = kpos <= posb[:, None, None, :]
    if window:
        mask = mask & (kpos > posb[:, None, None, :] - window)
    out = _attend(cfg, q, k, v, mask).reshape(b, 1, -1) @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out, {"k": cache_k, "v": cache_v}


def _scatter_time(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write the [B, 1, ...] slice at time `pos` (same pos for the batch)."""
    return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype),
                                               pos, axis=1)
