"""Durability & recovery: snapshots, WAL replay, fault injection, remesh.

Contracts under test (serving/recovery.py + serving/faults.py + the
state_dict surfaces grown across serving/* and kernels/ops.py):

  * **state_dict round trips** -- endpoint (linear/conservative/kernel),
    windowed, sharded, and KernelSketch (linear/conservative/signed)
    restore bit-identically: tables, pools, totals, clocks, and top-k
    output all match the snapshotted object, and keep matching after
    further shared ingest;
  * **checkpoint integrity** -- per-array CRC32 catches byte flips
    (CheckpointCorruptionError), AsyncCheckpointer surfaces worker
    exceptions instead of dropping failed writes, transient save failures
    are retried;
  * **WAL semantics** -- ordered replay, torn-tail truncation at reopen,
    duplicate records applied exactly once, gaps refused loudly,
    rotation + pruning bounded by the oldest retained snapshot;
  * **kill-and-recover bit-exactness** (the acceptance matrix) -- for
    endpoint/windowed surfaces and linear/conservative modes, an injected
    crash mid-stream followed by recover() + resumed ingest yields
    tables, pools, totals, and topk output bit-identical to an
    uninterrupted run, including the corrupted-snapshot fallback case;
    the sharded legs (kill/recover + N->M remesh) run on forced
    multi-device CPU meshes in subprocesses;
  * **crash-consistent migration** -- abort_migration() rolls back with
    no double-write residue; a checkpoint mid-warmup refuses with an
    error that names the way out.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import sketch as sk
from repro.serving.faults import (
    FaultPlan,
    ServingSupervisor,
    corrupt_checkpoint_array,
    drop_wal_record,
    duplicate_wal_record,
)
from repro.serving.recovery import (
    BlockLog,
    DurableSketchEngine,
    WALGapError,
    recover,
)
from repro.serving.sketch_engine import SketchServeEngine, SketchTopKEndpoint
from repro.serving.windowed_topk import WindowedTopKService
from repro.streams import zipf_hh_workload
from repro.training import checkpoint as ckpt

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_DEVICES = int(os.environ.get("REPRO_TEST_DEVICES", "8"))

KEY = jax.random.PRNGKey(0)


def _run(code: str, devices: int = _DEVICES) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def _stream(seed=1):
    return zipf_hh_workload(n_src=100, n_tgt=200, n_edges=800,
                            n_occurrences=4_000, seed=seed).stream


def _spec(stream, ranges=(32, 32), w=4):
    return sk.mod_sketch_spec(stream.schema, [(0,), (1,)], ranges, w)


def _blocks(stream, size=50):
    it, fr = stream.items, stream.freqs
    return [(it[s:s + size], fr[s:s + size])
            for s in range(0, it.shape[0], size)]


def _assert_same_endpoint(a, b):
    assert a.total == b.total
    for sa, sb in zip(a.state.states, b.state.states):
        assert np.array_equal(np.asarray(sa.table), np.asarray(sb.table))
    for pa, pb in zip(a.candidates(), b.candidates()):
        assert np.array_equal(pa, pb)      # order included: descent order


# --------------------------------------------------------------------------
# state_dict round trips
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs", [
    {}, {"mode": "conservative"}, {"use_update_kernel": True}],
    ids=["linear", "conservative", "kernel"])
def test_endpoint_state_roundtrip_bitwise(kwargs):
    stream = _stream()
    spec = _spec(stream)
    a = SketchTopKEndpoint(spec, KEY, **kwargs)
    blocks = _blocks(stream)
    for it, fr in blocks[:5]:
        a.ingest(it, fr)
    b = SketchTopKEndpoint(spec, KEY, **kwargs)
    b.load_state_dict(a.state_dict())
    _assert_same_endpoint(a, b)
    # the restored endpoint keeps tracking bitwise under further ingest
    for it, fr in blocks[5:]:
        a.ingest(it, fr)
        b.ingest(it, fr)
    _assert_same_endpoint(a, b)
    ia, ea = a.topk(8)
    ib, eb = b.topk(8)
    assert np.array_equal(ia, ib) and np.array_equal(ea, eb)


def test_endpoint_state_fingerprint_mismatch_refused():
    stream = _stream()
    a = SketchTopKEndpoint(_spec(stream), KEY)
    other = SketchTopKEndpoint(_spec(stream, ranges=(16, 64)), KEY)
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        other.load_state_dict(a.state_dict())


@pytest.mark.parametrize("mode", ["tumbling", "landmark", "decay"])
def test_windowed_state_roundtrip_mid_window(mode):
    stream = _stream()
    spec = _spec(stream)
    kw = dict(n_epochs=3, window_mode=mode)
    if mode == "decay":
        kw["decay"] = 0.5
    a = WindowedTopKService(spec, KEY, **kw)
    blocks = _blocks(stream)
    for n, (it, fr) in enumerate(blocks[:6]):
        a.ingest(it, fr)
        if n % 2 == 1:
            a.advance()
    b = WindowedTopKService(spec, KEY, **kw)
    b.load_state_dict(a.state_dict())
    assert b.epoch == a.epoch and b.total == a.total
    # keep streaming both through an expiry boundary
    for it, fr in blocks[6:]:
        a.ingest(it, fr)
        b.ingest(it, fr)
    a.advance()
    b.advance()
    ia, ea = a.topk(8)
    ib, eb = b.topk(8)
    assert np.array_equal(ia, ib) and np.array_equal(ea, eb)


@pytest.mark.parametrize("mode", ["linear", "conservative", "signed"])
def test_kernel_sketch_state_roundtrip_all_modes(mode):
    from repro.kernels.ops import KernelSketch

    stream = _stream()
    spec = _spec(stream)
    blocks = _blocks(stream)
    a = KernelSketch(spec, KEY, mode=mode, block_b=64)
    for it, fr in blocks[:4]:
        a.update(it, fr)
    b = KernelSketch(spec, KEY, mode=mode, block_b=64)
    b.load_state_dict(a.state_dict())
    assert np.array_equal(np.asarray(a.table), np.asarray(b.table))
    for it, fr in blocks[4:]:         # conservative: order-dependent, same order
        a.update(it, fr)
        b.update(it, fr)
    assert np.array_equal(np.asarray(a.table), np.asarray(b.table))
    q = stream.items[:64]
    assert np.array_equal(a.query(q), b.query(q))


def test_kernel_sketch_state_mode_mismatch_refused():
    from repro.kernels.ops import KernelSketch

    stream = _stream()
    spec = _spec(stream)
    a = KernelSketch(spec, KEY, mode="signed")
    b = KernelSketch(spec, KEY, mode="linear")
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        b.load_state_dict(a.state_dict())


# --------------------------------------------------------------------------
# checkpoint layer: CRC, async error surfacing, retry
# --------------------------------------------------------------------------

def test_checkpoint_crc_catches_byte_flip(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"t": {"x": np.arange(32, dtype=np.int64)}})
    # restore_trees verifies and passes on intact data
    step, trees = ckpt.restore_trees(d)
    assert step == 1 and np.array_equal(trees["t"]["x"], np.arange(32))
    # flip a byte inside the archive, manifest untouched
    path = os.path.join(d, "step_00000001", "proc00_shard000.npz")
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    arrays["t::x"] = arrays["t::x"] + 1
    np.savez(path, **arrays)
    with pytest.raises(ckpt.CheckpointCorruptionError, match="CRC mismatch"):
        ckpt.restore_trees(d)
    # verify=False loads anyway (forensics escape hatch)
    _, trees = ckpt.restore_trees(d, verify=False)
    assert trees["t"]["x"][0] == 1


def test_async_checkpointer_surfaces_worker_error(tmp_path, monkeypatch):
    calls = []

    def boom(*a, **k):
        calls.append(1)
        raise OSError("disk on fire")

    monkeypatch.setattr(ckpt, "save", boom)
    w = ckpt.AsyncCheckpointer(str(tmp_path / "ck"), retries=0)
    w.submit(1, {"t": {"x": np.zeros(4)}})
    with pytest.raises(OSError, match="disk on fire"):
        w.wait()
    # ...and submit() itself surfaces a failed PRIOR write, not drops it
    w.submit(2, {"t": {"x": np.zeros(4)}})
    with pytest.raises(OSError, match="disk on fire"):
        w.submit(3, {"t": {"x": np.zeros(4)}})


def test_async_checkpointer_retries_transient_failure(tmp_path, monkeypatch):
    real_save = ckpt.save
    attempts = []

    def flaky(*a, **k):
        attempts.append(1)
        if len(attempts) == 1:
            raise OSError("transient")
        return real_save(*a, **k)

    monkeypatch.setattr(ckpt, "save", flaky)
    w = ckpt.AsyncCheckpointer(str(tmp_path / "ck"), retries=2,
                               backoff=0.001)
    w.submit(1, {"t": {"x": np.arange(4)}})
    w.wait()                               # retried, no raise
    assert len(attempts) == 2
    step, trees = ckpt.restore_trees(str(tmp_path / "ck"))
    assert step == 1 and np.array_equal(trees["t"]["x"], np.arange(4))


# --------------------------------------------------------------------------
# WAL semantics
# --------------------------------------------------------------------------

def test_wal_roundtrip_and_reopen(tmp_path):
    d = str(tmp_path)
    log = BlockLog(d)
    items = np.arange(12, dtype=np.uint32).reshape(6, 2)
    freqs = np.array([1, 2, 3, 4, 5, 6], dtype=np.int64)
    log.append_block(items, freqs)
    log.append_advance()
    log.append_block(items[:2], freqs[:2].astype(np.float32))
    log.close()
    log2 = BlockLog(d)
    recs = log2.records(0)
    assert [r.kind for r in recs] == ["block", "advance", "block"]
    assert np.array_equal(recs[0].items, items)
    assert np.array_equal(recs[0].freqs, freqs)
    assert recs[2].freqs.dtype == np.float32   # dtype preserved bitwise
    assert log2.next_seq == 3                  # numbering continues


def test_wal_truncates_torn_tail(tmp_path):
    d = str(tmp_path)
    log = BlockLog(d)
    items = np.ones((4, 2), dtype=np.uint32)
    freqs = np.ones(4, dtype=np.int64)
    log.append_block(items, freqs)
    log.append_block(items, freqs)
    log.close()
    # crash mid-append: chop bytes off the tail of the last segment
    seg = sorted(os.listdir(os.path.join(d, "wal")))[-1]
    path = os.path.join(d, "wal", seg)
    size = os.path.getsize(path)
    with open(path, "ab") as f:
        f.truncate(size - 7)
    log2 = BlockLog(d)                         # reopen truncates the tear
    recs = log2.records(0)
    assert len(recs) == 1 and recs[0].seq == 0
    assert log2.next_seq == 1                  # seq 1 was never durable
    log2.append_block(items, freqs)            # and is cleanly re-appended
    assert [r.seq for r in log2.records(0)] == [0, 1]


def test_wal_duplicate_skipped_gap_refused(tmp_path):
    d = str(tmp_path)
    log = BlockLog(d)
    for i in range(4):
        log.append_block(np.full((2, 2), i, dtype=np.uint32),
                         np.ones(2, dtype=np.int64))
    log.close()
    duplicate_wal_record(d, 2)
    recs = BlockLog(d).records(0)
    assert [r.seq for r in recs] == [0, 1, 2, 3]   # applied exactly once
    drop_wal_record(d, 1)
    with pytest.raises(WALGapError, match="missing"):
        BlockLog(d).records(0)


def test_wal_reopen_after_tail_duplicate_keeps_next_seq(tmp_path):
    # A survived-retry duplicate sits at the TAIL with a stale lower seq;
    # reopening must resume at max(seq)+1, not regress the cursor (which
    # would make new appends reuse live seqs and be dropped as duplicates).
    d = str(tmp_path)
    log = BlockLog(d)
    for i in range(4):
        log.append_block(np.full((2, 2), i, dtype=np.uint32),
                         np.ones(2, dtype=np.int64))
    log.close()
    duplicate_wal_record(d, 1)
    log2 = BlockLog(d)
    assert log2.next_seq == 4
    log2.append_block(np.full((2, 2), 9, dtype=np.uint32),
                      np.ones(2, dtype=np.int64))
    recs = log2.records(0)
    assert [r.seq for r in recs] == [0, 1, 2, 3, 4]
    assert np.array_equal(recs[-1].items, np.full((2, 2), 9,
                                                  dtype=np.uint32))
    log2.close()


def test_empty_block_advances_wal_seq_and_supervisor_cursor(tmp_path):
    # Every op maps 1:1 onto a WAL seq, empties included -- otherwise the
    # supervisor's cursor (next_seq) never passes an empty-block op.
    stream = _stream()
    spec = _spec(stream)
    blocks = _blocks(stream)[:4]
    empty = (blocks[0][0][:0], blocks[0][1][:0])
    ops = [("block", *blocks[0]), ("block", *empty),
           ("block", *blocks[1]), ("block", *blocks[2]),
           ("block", *blocks[3])]
    ref = SketchTopKEndpoint(spec, KEY)
    for _, it, fr in ops:
        ref.ingest(it, fr)
    sup = ServingSupervisor(str(tmp_path),
                            lambda: SketchTopKEndpoint(spec, KEY),
                            snapshot_every=2)
    eng, rep = sup.run(ops, FaultPlan(crash_after_ops=3, max_crashes=1))
    assert rep.crashes == 1
    assert eng.log.next_seq == len(ops)
    eng.drain()
    _assert_same_endpoint(ref, eng.backend)
    eng.close()


def test_wal_rotate_and_prune_respects_retained_snapshots(tmp_path):
    stream = _stream()
    spec = _spec(stream)
    eng = DurableSketchEngine(
        SketchServeEngine(SketchTopKEndpoint(spec, KEY)), str(tmp_path),
        keep_snapshots=2)
    blocks = _blocks(stream)
    wal_dir = os.path.join(str(tmp_path), "wal")
    for it, fr in blocks[:2]:
        eng.ingest(it, fr)
    eng.snapshot()
    # one snapshot retained: nothing pruned (its corruption must leave a
    # full-replay path)
    assert len(os.listdir(wal_dir)) >= 2
    for it, fr in blocks[2:4]:
        eng.ingest(it, fr)
    eng.snapshot()
    for it, fr in blocks[4:]:
        eng.ingest(it, fr)
    eng.snapshot()
    # keep_last=2 retains steps {4, 6}; segments below step 4 are pruned
    segs = sorted(os.listdir(wal_dir))
    assert int(segs[0].split("_")[1].split(".")[0]) >= 2
    eng.close()
    # and recovery still works from what remains
    eng2, rep = recover(str(tmp_path), lambda: SketchTopKEndpoint(spec, KEY))
    ref = SketchTopKEndpoint(spec, KEY)
    for it, fr in blocks:
        ref.ingest(it, fr)
    _assert_same_endpoint(ref, eng2.backend)


# --------------------------------------------------------------------------
# kill-and-recover bit-exactness (the acceptance matrix, single-device legs)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs", [
    {}, {"mode": "conservative"}, {"use_update_kernel": True}],
    ids=["linear", "conservative", "kernel"])
def test_kill_recover_endpoint_bitwise(tmp_path, kwargs):
    stream = _stream()
    spec = _spec(stream)
    blocks = _blocks(stream)
    ops = [("block", it, fr) for it, fr in blocks]
    ref = SketchTopKEndpoint(spec, KEY, **kwargs)
    for it, fr in blocks:
        ref.ingest(it, fr)

    sup = ServingSupervisor(str(tmp_path),
                            lambda: SketchTopKEndpoint(spec, KEY, **kwargs),
                            snapshot_every=3)
    eng, rep = sup.run(ops, FaultPlan(crash_after_ops=4, max_crashes=1))
    assert rep.crashes == 1
    assert rep.recoveries[-1].restored_step is not None
    eng.drain()                    # fold the pipelined block before peeking
    _assert_same_endpoint(ref, eng.backend)
    ri, re_ = ref.topk(10)
    ei, ee = eng.topk(10)
    assert np.array_equal(ri, ei) and np.array_equal(re_, ee)


def test_kill_recover_windowed_mid_window_bitwise(tmp_path):
    stream = _stream()
    spec = _spec(stream)
    ops = []
    for n, (it, fr) in enumerate(_blocks(stream)):
        ops.append(("block", it, fr))
        if n % 3 == 2:
            ops.append(("advance",))
    ref = WindowedTopKService(spec, KEY, n_epochs=3)
    for op in ops:
        ref.ingest(op[1], op[2]) if op[0] == "block" else ref.advance()

    sup = ServingSupervisor(str(tmp_path),
                            lambda: WindowedTopKService(spec, KEY, n_epochs=3),
                            snapshot_every=4)
    eng, rep = sup.run(ops, FaultPlan(crash_after_ops=5, max_crashes=1))
    assert rep.crashes == 1
    assert eng.backend.epoch == ref.epoch
    assert eng.backend.total == ref.total
    ri, re_ = ref.topk(10)
    ei, ee = eng.topk(10)
    assert np.array_equal(ri, ei) and np.array_equal(re_, ee)


def test_kill_recover_corrupted_snapshot_falls_back(tmp_path):
    stream = _stream()
    spec = _spec(stream)
    blocks = _blocks(stream)
    ops = [("block", it, fr) for it, fr in blocks]
    ref = SketchTopKEndpoint(spec, KEY)
    for it, fr in blocks:
        ref.ingest(it, fr)

    sup = ServingSupervisor(str(tmp_path),
                            lambda: SketchTopKEndpoint(spec, KEY),
                            snapshot_every=2)
    plan = FaultPlan(crash_after_ops=3, max_crashes=1,
                     corrupt_newest_snapshot=True)
    eng, rep = sup.run(ops, plan)
    last = rep.recoveries[-1]
    assert last.corrupted_steps, "the corrupted snapshot must be detected"
    eng.drain()
    _assert_same_endpoint(ref, eng.backend)
    ri, re_ = ref.topk(10)
    ei, ee = eng.topk(10)
    assert np.array_equal(ri, ei) and np.array_equal(re_, ee)


def test_repeated_crashes_until_max_restarts(tmp_path):
    stream = _stream()
    spec = _spec(stream)
    ops = [("block", it, fr) for it, fr in _blocks(stream)]
    sup = ServingSupervisor(str(tmp_path),
                            lambda: SketchTopKEndpoint(spec, KEY),
                            snapshot_every=2, max_restarts=1)
    from repro.serving.faults import InjectedCrash

    with pytest.raises(InjectedCrash):
        sup.run(ops, FaultPlan(crash_after_ops=1, max_crashes=10))


def test_engine_watermark_survives_recovery(tmp_path):
    stream = _stream()
    spec = _spec(stream)
    blocks = _blocks(stream)
    eng = DurableSketchEngine(
        SketchServeEngine(SketchTopKEndpoint(spec, KEY)), str(tmp_path))
    for it, fr in blocks[:3]:
        eng.ingest(it, fr)
    eng.snapshot()
    mass = eng.engine.ingested_mass
    assert mass == sum(int(fr.sum()) for _, fr in blocks[:3])
    eng.close()
    eng2, rep = recover(str(tmp_path), lambda: SketchTopKEndpoint(spec, KEY))
    assert eng2.engine.ingested_mass == mass
    assert rep.replayed_blocks == 0        # everything was in the snapshot


def test_recover_empty_directory_starts_fresh(tmp_path):
    stream = _stream()
    spec = _spec(stream)
    eng, rep = recover(str(tmp_path), lambda: SketchTopKEndpoint(spec, KEY))
    assert rep.restored_step is None and rep.replayed_blocks == 0
    it, fr = _blocks(stream)[0]
    eng.ingest(it, fr)
    eng.drain()
    assert eng.backend.total == int(fr.sum())


# --------------------------------------------------------------------------
# crash-consistent migration (satellite)
# --------------------------------------------------------------------------

def test_abort_migration_leaves_no_residue():
    stream = _stream()
    spec = _spec(stream)
    new_spec = _spec(stream, ranges=(16, 64))
    blocks = _blocks(stream)
    ref = SketchTopKEndpoint(spec, KEY)      # never migrates
    ep = SketchTopKEndpoint(spec, KEY)
    for it, fr in blocks[:3]:
        ref.ingest(it, fr)
        ep.ingest(it, fr)
    ep.begin_migration(new_spec, jax.random.PRNGKey(9), warmup=1 << 40)
    for it, fr in blocks[3:5]:               # double-write window open
        ref.ingest(it, fr)
        ep.ingest(it, fr)
    assert ep.migrating
    ep.abort_migration()
    assert not ep.migrating
    _assert_same_endpoint(ref, ep)           # active surface untouched
    for it, fr in blocks[5:]:                # and stays bitwise thereafter
        ref.ingest(it, fr)
        ep.ingest(it, fr)
    _assert_same_endpoint(ref, ep)
    ep.abort_migration()                     # aborting twice is a no-op


def test_checkpoint_mid_warmup_refuses_with_clear_error():
    stream = _stream()
    spec = _spec(stream)
    ep = SketchTopKEndpoint(spec, KEY)
    it, fr = _blocks(stream)[0]
    ep.ingest(it, fr)
    ep.begin_migration(_spec(stream, ranges=(16, 64)), jax.random.PRNGKey(9),
                       warmup=1 << 40)
    with pytest.raises(ValueError, match="abort_migration"):
        ep.state_dict()
    ep.abort_migration()
    ep.state_dict()                          # fine after rollback


# --------------------------------------------------------------------------
# sharded legs: kill/recover + N->M remesh (forced multi-device subprocess)
# --------------------------------------------------------------------------

def test_sharded_remesh_grow_shrink_bitwise():
    print(_run("""
        import jax, numpy as np
        from repro.core import sketch as sk
        from repro.serving.sharded_topk import ShardedTopKService
        from repro.streams import zipf_hh_workload

        wl = zipf_hh_workload(n_occurrences=20_000, n_edges=4_000, seed=3)
        spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (64, 64), 3)
        key = jax.random.PRNGKey(7)
        items, freqs = wl.stream.items, wl.stream.freqs
        blocks = [(items[s:s+500], freqs[s:s+500])
                  for s in range(0, len(items), 500)]
        half = len(blocks) // 2
        assert jax.device_count() >= 4, jax.device_count()
        mesh2 = jax.make_mesh((2,), ("data",))
        mesh4 = jax.make_mesh((4,), ("data",))

        ref = ShardedTopKService(spec, key, mesh2, sync_every=2)
        for it, fr in blocks: ref.ingest(it, fr)
        ri, re = ref.topk(10)
        rt = [np.asarray(st.table) for st in ref.state().states]

        for src, dst in [(mesh2, mesh4), (mesh4, mesh2)]:
            svc = ShardedTopKService(spec, key, src, sync_every=2)
            for it, fr in blocks[:half]: svc.ingest(it, fr)
            svc.remesh(dst)
            # post-remesh queries answer immediately (no drain)
            for it, fr in blocks[half:]: svc.ingest(it, fr)
            ei, ee = svc.topk(10)
            assert np.array_equal(ri, ei) and np.array_equal(re, ee)
            for a, st in zip(rt, svc.state().states):
                assert np.array_equal(a, np.asarray(st.table))
        print("remesh 2->4 and 4->2 bit-exact")
    """))


def test_sharded_snapshot_restores_across_shard_counts():
    print(_run("""
        import jax, numpy as np
        from repro.core import sketch as sk
        from repro.serving.sharded_topk import ShardedTopKService
        from repro.streams import zipf_hh_workload

        wl = zipf_hh_workload(n_occurrences=20_000, n_edges=4_000, seed=3)
        spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (64, 64), 3)
        key = jax.random.PRNGKey(7)
        items, freqs = wl.stream.items, wl.stream.freqs
        blocks = [(items[s:s+500], freqs[s:s+500])
                  for s in range(0, len(items), 500)]
        half = len(blocks) // 2
        mesh2 = jax.make_mesh((2,), ("data",))
        mesh4 = jax.make_mesh((4,), ("data",))

        ref = ShardedTopKService(spec, key, mesh2, sync_every=2)
        for it, fr in blocks: ref.ingest(it, fr)
        ri, re = ref.topk(10)

        src = ShardedTopKService(spec, key, mesh4, sync_every=2)
        for it, fr in blocks[:half]: src.ingest(it, fr)
        sd = src.state_dict()
        # 4-shard snapshot restored into a 2-shard service: pools fold
        for dst_mesh, n in [(mesh4, 4), (mesh2, 2)]:
            dst = ShardedTopKService(spec, key, dst_mesh, sync_every=2)
            dst.load_state_dict(sd)
            assert dst.n_shards == n
            for it, fr in blocks[half:]: dst.ingest(it, fr)
            ei, ee = dst.topk(10)
            assert np.array_equal(ri, ei) and np.array_equal(re, ee)
        print("sharded snapshot restores at 4 and 2 shards, bit-exact")
    """))


def test_sharded_kill_recover_bitwise():
    print(_run("""
        import tempfile, jax, numpy as np
        from repro.core import sketch as sk
        from repro.serving.sharded_topk import ShardedTopKService
        from repro.serving.faults import ServingSupervisor, FaultPlan
        from repro.streams import zipf_hh_workload

        wl = zipf_hh_workload(n_occurrences=20_000, n_edges=4_000, seed=3)
        spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (64, 64), 3)
        key = jax.random.PRNGKey(7)
        items, freqs = wl.stream.items, wl.stream.freqs
        ops = [("block", items[s:s+500], freqs[s:s+500])
               for s in range(0, len(items), 500)]
        mesh = jax.make_mesh((min(4, jax.device_count()),), ("data",))

        def factory():
            return ShardedTopKService(spec, key, mesh, sync_every=2)

        ref = factory()
        for _, it, fr in ops: ref.ingest(it, fr)
        ri, re = ref.topk(10)

        with tempfile.TemporaryDirectory() as d:
            sup = ServingSupervisor(d, factory, snapshot_every=3)
            eng, rep = sup.run(ops, FaultPlan(crash_after_ops=4,
                                              max_crashes=1))
            assert rep.crashes == 1
            ei, ee = eng.topk(10)
            assert np.array_equal(ri, ei) and np.array_equal(re, ee)
            assert eng.backend.total == ref.total
            for a, b in zip(ref.state().states, eng.backend.state().states):
                assert np.array_equal(np.asarray(a.table),
                                      np.asarray(b.table))
        print("sharded kill/recover bit-exact")
    """))
