"""The async sketch-serving engine: pipeline, staleness, batched descent.

Contracts under test (serving/sketch_engine.py + the split serving stack):

  * **pipeline bit-identity** -- engine ingest through the staged
    stage_indices/fold_indices pipeline leaves tables, totals, and pools
    bit-identical to direct synchronous endpoint ingest; the two-phase
    split itself equals update_jit at the hierarchy level;
  * **staleness-0 parity** -- engine queries with ``max_staleness=0`` are
    bit-identical to the synchronous surfaces (endpoint, sharded service,
    windowed service) fed the same stream;
  * **staleness semantics** -- unbounded staleness freezes the snapshot
    until an explicit sync; a finite bound triggers refresh exactly when
    exceeded; ``advance()`` invalidates the snapshot outright;
  * **batched descent bit-identity** -- batched_find_heavy_hitters equals
    per-request find_heavy_hitters (ref and kernel paths), and the
    engine's submit/flush answers equal the serial topk/heavy_hitters
    calls -- same items, same estimates, same tie order;
  * **one engine protocol** -- both the model stack's SlotScheduler and
    the sketch engine satisfy serving/protocol.ServeEngineProtocol, and
    the pre-split ``repro.serving.engine`` import surface still works;
  * **integration points** -- the AutoTuner ticks on sync and its
    migration runs through the engine without wedging the pipeline.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.serving.protocol import ServeEngineProtocol
from repro.serving.sketch_engine import (
    SketchQuery,
    SketchServeEngine,
    SketchTopKEndpoint,
)
from repro.streams import zipf_hh_workload


def _stream(seed=1):
    return zipf_hh_workload(n_src=100, n_tgt=200, n_edges=800,
                            n_occurrences=4_000, seed=seed).stream


def _blocks(stream, size=100):
    it, fr = stream.items, stream.freqs
    return [(it[s:s + size], fr[s:s + size])
            for s in range(0, it.shape[0], size)]


def _spec(stream, ranges=(32, 32), w=4):
    return sk.mod_sketch_spec(stream.schema, [(0,), (1,)], ranges, w)


KEY = jax.random.PRNGKey(0)


# -- two-phase ingest == fused update (hierarchy level) ---------------------

def test_stage_fold_equals_update_jit():
    stream = _stream()
    spec = _spec(stream)
    hspec = hh.HierarchySpec.from_spec(spec)
    a = hh.init_hierarchy(hspec, KEY)
    b = hh.init_hierarchy(hspec, KEY)
    for items, freqs in _blocks(stream, 128)[:6]:
        items = jnp.asarray(np.asarray(items, np.uint32))
        freqs = jnp.asarray(np.asarray(freqs))
        a = hh.update_jit(hspec, a, items, freqs)
        b = hh.fold_indices(b, hh.stage_indices(hspec, b, items), freqs)
    for sa, sb in zip(a.states, b.states):
        assert np.array_equal(np.asarray(sa.table), np.asarray(sb.table))


def test_stage_block_refused_off_the_plain_linear_path():
    stream = _stream()
    spec = _spec(stream)
    cons = SketchTopKEndpoint(spec, KEY, mode="conservative")
    with pytest.raises(ValueError, match="plain linear"):
        cons.stage_block(stream.items[:8], stream.freqs[:8])
    krn = SketchTopKEndpoint(spec, KEY, use_update_kernel=True)
    with pytest.raises(ValueError, match="plain linear"):
        krn.stage_block(stream.items[:8], stream.freqs[:8])


# -- pipelined engine ingest == synchronous endpoint ingest -----------------

def test_engine_pipeline_bitwise_equals_direct_ingest():
    stream = _stream()
    spec = _spec(stream)
    ref = SketchTopKEndpoint(spec, KEY)
    ep = SketchTopKEndpoint(spec, KEY)
    eng = SketchServeEngine(ep, max_staleness=None)
    for items, freqs in _blocks(stream):
        ref.ingest(items, freqs)
        eng.ingest(items, freqs)
    eng.drain()
    assert ep.total == ref.total
    for sa, sb in zip(ref.state.states, ep.state.states):
        assert np.array_equal(np.asarray(sa.table), np.asarray(sb.table))
    for pa, pb in zip(ref.candidates(), ep.candidates()):
        assert np.array_equal(np.sort(pa, axis=0), np.sort(pb, axis=0))


def test_engine_staleness0_parity_with_endpoint():
    stream = _stream()
    spec = _spec(stream)
    ref = SketchTopKEndpoint(spec, KEY)
    eng = SketchServeEngine(SketchTopKEndpoint(spec, KEY), max_staleness=0)
    for items, freqs in _blocks(stream):
        ref.ingest(items, freqs)
        eng.ingest(items, freqs)
        # query mid-stream too: parity must hold at every point
    ri, re = ref.topk(10)
    ei, ee = eng.topk(10)
    assert np.array_equal(ri, ei) and np.array_equal(re, ee)
    rh = ref.heavy_hitters(50)
    eh = eng.heavy_hitters(50)
    assert np.array_equal(rh[0], eh[0]) and np.array_equal(rh[1], eh[1])


def test_engine_staleness0_parity_with_sharded_service():
    from repro.serving.sharded_topk import ShardedTopKService

    stream = _stream()
    spec = _spec(stream)
    mesh = jax.make_mesh((1,), ("data",))
    ref = ShardedTopKService(spec, KEY, mesh, sync_every=1)
    svc = ShardedTopKService(spec, KEY, mesh, sync_every=None)
    eng = SketchServeEngine(svc, max_staleness=0, shard_sync_every=3)
    for items, freqs in _blocks(stream):
        ref.ingest(items, freqs)
        eng.ingest(items, freqs)
    ri, re = ref.topk(8)
    ei, ee = eng.topk(8)
    assert np.array_equal(ri, ei) and np.array_equal(re, ee)


def test_engine_staleness0_parity_with_windowed_service():
    from repro.serving.windowed_topk import WindowedTopKService

    stream = _stream()
    spec = _spec(stream)
    ref = WindowedTopKService(spec, KEY, n_epochs=3)
    svc = WindowedTopKService(spec, KEY, n_epochs=3)
    eng = SketchServeEngine(svc, max_staleness=0)
    for i, (items, freqs) in enumerate(_blocks(stream)):
        ref.ingest(items, freqs)
        eng.ingest(items, freqs)
        if i % 3 == 2:
            ref.advance()
            eng.advance()
    ri, re = ref.topk(8)
    ei, ee = eng.topk(8)
    assert np.array_equal(ri, ei) and np.array_equal(re, ee)


# -- staleness semantics ----------------------------------------------------

def test_unbounded_staleness_serves_frozen_snapshot_until_sync():
    stream = _stream()
    spec = _spec(stream)
    blocks = _blocks(stream)
    eng = SketchServeEngine(SketchTopKEndpoint(spec, KEY),
                            max_staleness=None)
    for items, freqs in blocks[:5]:
        eng.ingest(items, freqs)
    eng.sync()
    at_sync = eng.topk(8)
    for items, freqs in blocks[5:10]:
        eng.ingest(items, freqs)
    # snapshot is frozen: post-sync ingest is invisible to queries
    assert eng.staleness == sum(int(np.asarray(f).sum())
                                for _, f in blocks[5:10])
    stale = eng.topk(8)
    assert np.array_equal(at_sync[0], stale[0])
    assert np.array_equal(at_sync[1], stale[1])
    eng.sync()
    assert eng.staleness == 0


def test_bounded_staleness_refreshes_exactly_when_exceeded():
    stream = _stream()
    spec = _spec(stream)
    blocks = _blocks(stream)
    mass0 = int(np.asarray(blocks[0][1]).sum())
    # bound big enough to tolerate block 0, exceeded by block 0+1
    eng = SketchServeEngine(SketchTopKEndpoint(spec, KEY),
                            max_staleness=mass0)
    eng.ingest(*blocks[0])
    snap_before = eng._fresh_snapshot()
    assert snap_before.mass == 0          # within bound: no refresh
    eng.ingest(*blocks[1])
    snap_after = eng._fresh_snapshot()    # bound exceeded: refreshed
    assert snap_after.mass == eng._mass
    assert eng.staleness == 0


def test_advance_invalidates_snapshot_without_mass():
    from repro.serving.windowed_topk import WindowedTopKService

    stream = _stream()
    spec = _spec(stream)
    blocks = _blocks(stream)
    svc = WindowedTopKService(spec, KEY, n_epochs=2)
    eng = SketchServeEngine(svc, max_staleness=None)
    for items, freqs in blocks[:6]:
        eng.ingest(items, freqs)
    eng.sync()
    before = eng.topk(6)
    eng.advance()                          # no stream mass moves, yet ...
    eng.advance()                          # ... the whole window expired
    after = eng.topk(6)                    # snapshot must have refreshed
    ref = WindowedTopKService(spec, KEY, n_epochs=2)
    for items, freqs in blocks[:6]:
        ref.ingest(items, freqs)
    ref.advance()
    ref.advance()
    ri, re = ref.topk(6)
    assert np.array_equal(after[0], ri) and np.array_equal(after[1], re)
    # and the pre-advance answer reflected the live window
    assert not (len(before[1]) == len(after[1])
                and np.array_equal(before[1], after[1]))


# -- batched descent bit-identity -------------------------------------------

def _built_endpoint(use_kernel=False):
    stream = _stream(seed=5)
    spec = _spec(stream, ranges=(16, 64))
    ep = SketchTopKEndpoint(spec, KEY, use_kernel=use_kernel)
    for items, freqs in _blocks(stream, 256):
        ep.ingest(items, freqs)
    return ep


@pytest.mark.parametrize("use_kernel", [False, True])
def test_batched_find_heavy_hitters_bitwise_equals_serial(use_kernel):
    ep = _built_endpoint(use_kernel)
    cands = ep.candidates()
    thresholds = [1, 10, 50, 200, ep.total + 1]
    batched = hh.batched_find_heavy_hitters(
        ep.hspec, ep.state, thresholds, cands, use_kernel=use_kernel)
    for thr, (bi, be) in zip(thresholds, batched):
        si, se = hh.find_heavy_hitters(ep.hspec, ep.state, thr, cands,
                                       use_kernel=use_kernel)
        assert np.array_equal(bi, si), f"items diverge at threshold {thr}"
        assert np.array_equal(be, se), f"estimates diverge at threshold {thr}"


def test_batched_request_chunking_is_bit_neutral():
    ep = _built_endpoint()
    cands = ep.candidates()
    thresholds = [1, 5, 25, 125, 625]
    full = hh.batched_find_heavy_hitters(
        ep.hspec, ep.state, thresholds, cands)
    # max_batch small enough to force request-axis chunking + padding
    chunked = hh.batched_find_heavy_hitters(
        ep.hspec, ep.state, thresholds, cands, max_batch=64)
    for (fi, fe), (ci, ce) in zip(full, chunked):
        assert np.array_equal(fi, ci) and np.array_equal(fe, ce)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_engine_flush_equals_serial_queries(use_kernel):
    ep = _built_endpoint(use_kernel)
    eng = SketchServeEngine(ep, max_staleness=0)
    r_top5 = eng.submit_topk(5)
    r_top20 = eng.submit_topk(20)
    r_hh = eng.submit_heavy_hitters(40)
    r_floor = eng.submit_topk(4, min_threshold=1)
    done = eng.flush()
    assert done == [r_top5, r_top20, r_hh, r_floor]
    assert all(r.done for r in done)
    for r, serial in [
        (r_top5, ep.topk(5)),
        (r_top20, ep.topk(20)),
        (r_hh, ep.heavy_hitters(40)),
        (r_floor, ep.topk(4, min_threshold=1)),
    ]:
        assert np.array_equal(r.items, serial[0])
        assert np.array_equal(r.est, serial[1])


def test_engine_flush_floor_above_total_returns_empty():
    ep = _built_endpoint()
    eng = SketchServeEngine(ep, max_staleness=0)
    r = eng.submit_topk(3, min_threshold=ep.total * 2)
    eng.flush()
    si, se = ep.topk(3, min_threshold=ep.total * 2)
    assert np.array_equal(r.items, si) and np.array_equal(r.est, se)
    assert r.est.shape == (0,)


def test_submit_rejects_unknown_kind():
    eng = SketchServeEngine(_built_endpoint(), max_staleness=0)
    with pytest.raises(ValueError, match="kind"):
        eng.submit(SketchQuery(rid=-1, kind="range"))
    assert eng.flush() == []


# -- the split serving stack ------------------------------------------------

def test_engine_protocol_spans_both_stacks():
    from repro.serving.model_engine import SlotScheduler

    eng = SketchServeEngine(_built_endpoint(), max_staleness=0)
    assert isinstance(eng, ServeEngineProtocol)
    assert isinstance(SlotScheduler.__new__(SlotScheduler),
                      ServeEngineProtocol)


def test_presplit_import_surface_still_works():
    from repro.serving import engine as legacy

    for name in ("Request", "ServeConfig", "ServeEngine", "SlotScheduler",
                 "SketchTopKEndpoint"):
        assert hasattr(legacy, name), f"shim lost {name}"
    from repro.serving.model_engine import ServeEngine
    assert legacy.ServeEngine is ServeEngine
    assert legacy.SketchTopKEndpoint is SketchTopKEndpoint


# -- integration points: tuner + migration through the engine ---------------

def test_tuner_ticks_on_sync_and_migration_runs_through_engine():
    from repro.serving.autotune import AutoTuner

    stream = _stream(seed=7)
    # deliberately lopsided ranges so a ranges re-search has room to win
    spec = _spec(stream, ranges=(2, 512))
    ep = SketchTopKEndpoint(spec, KEY)
    tuner = AutoTuner(ep, jax.random.fold_in(KEY, 1), retune_every=1_000,
                      warmup=500, min_threshold=1, search="ranges")
    eng = SketchServeEngine(ep, max_staleness=0, tuner=tuner)
    for items, freqs in _blocks(stream):
        eng.ingest(items, freqs)
        eng.sync()
    assert tuner.decisions, "tuner never ticked through engine.sync()"
    # pipeline + queries keep working across whatever the tuner decided
    items, est = eng.topk(5)
    assert est.shape[0] <= 5
    if any(d.migrated for d in tuner.decisions):
        assert not ep.migrating or ep.migration_progress < 1.0
