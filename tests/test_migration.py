"""Hot spec migration correctness: the double-write / cutover contract.

The contract under test (serving/migration.py):

  * **bit-identity** -- post-cutover, the migrated endpoint/service is
    bit-identical (tables, totals, topk output) to a fresh one built on
    the new spec from the same key and fed exactly the
    post-warmup-start stream;
  * **no false negatives across the window** -- at every point of a
    drifting stream, before / during / after the warmup window,
    ``heavy_hitters(T)`` reports every key whose exact count within the
    endpoint's serving window is >= T (the window is the whole stream
    until cutover, the post-migration-start suffix after);
  * **top-k continuity** -- ``topk`` keeps answering mid-warmup and
    post-cutover, with estimates that upper-bound the window-exact
    counts of every reported key;
  * **shard invariance composes** -- a ShardedTopKService migration is
    bit-identical across 1/2/4 shards (subprocess harness with forced
    host devices, pattern from tests/test_sharded_topk.py);
  * **refusals** -- conservative endpoints cannot begin a migration;
    ``merge_from`` / ``to_sharded`` / a second ``begin_migration`` are
    refused mid-warmup; SpecMigration rejects a non-empty successor.

Property tests randomize the warmup split, stream kind (zipf edges /
token bigrams) and seed through the _propcheck shim (hypothesis when
available, deterministic examples otherwise).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from _propcheck import given, settings, st

from repro.core import sketch as sk
from repro.serving.engine import SketchTopKEndpoint
from repro.serving.migration import SpecMigration, require_not_migrating
from repro.streams import ngram_hh_workload, zipf_hh_workload

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_DEVICES = int(os.environ.get("REPRO_TEST_DEVICES", "8"))


def _run(code: str, devices: int = _DEVICES) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def _workload(kind: str, seed: int):
    if kind == "zipf":
        wl = zipf_hh_workload(n_src=200, n_tgt=400, n_edges=1_500,
                              n_occurrences=8_000, seed=seed)
    else:
        wl = ngram_hh_workload(vocab_size=64, n=2, n_sequences=8,
                               seq_len=128, seed=seed)
    return wl.stream


def _drifted_blocks(stream, n_blocks: int, seed: int):
    """Cut the compressed stream into blocks with a drifting composition:
    block b is drawn from a rotated slice of the key set, so the heavy
    set of the late stream differs from the early stream."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(stream.items.shape[0])
    items, freqs = stream.items[order], stream.freqs[order]
    edges = np.linspace(0, items.shape[0], n_blocks + 1).astype(int)
    return [(items[s:e], freqs[s:e]) for s, e in zip(edges[:-1], edges[1:])]


def _exact(counts_items, counts_freqs):
    uniq, inv = np.unique(np.concatenate(counts_items, axis=0), axis=0,
                          return_inverse=True)
    tot = np.zeros(len(uniq), dtype=np.int64)
    np.add.at(tot, inv, np.concatenate(counts_freqs))
    return uniq, tot


# --------------------------------------------------------------------------
# Acceptance: migrated endpoint == fresh endpoint on the new spec, bitwise
# --------------------------------------------------------------------------

def test_migrated_endpoint_bitwise_equals_fresh():
    stream = _workload("zipf", seed=3)
    spec_old = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (64, 16), 4)
    spec_new = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (16, 64), 4)
    key = jax.random.PRNGKey(0)
    mig_key = jax.random.fold_in(key, 7)
    items, freqs = stream.items, stream.freqs
    n = items.shape[0]
    cut1, cut2 = n // 3, 2 * n // 3
    warm = int(freqs[cut1:cut2].sum())

    ep = SketchTopKEndpoint(spec_old, key)
    ep.ingest(items[:cut1], freqs[:cut1])
    ep.begin_migration(spec_new, mig_key, warmup=warm)
    assert ep.migrating and ep.migration_progress == 0.0
    ep.ingest(items[cut1:cut2], freqs[cut1:cut2])   # hits warmup exactly
    assert not ep.migrating and ep.migration_progress == 1.0
    ep.ingest(items[cut2:], freqs[cut2:])

    fresh = SketchTopKEndpoint(spec_new, mig_key)
    fresh.ingest(items[cut1:cut2], freqs[cut1:cut2])
    fresh.ingest(items[cut2:], freqs[cut2:])

    assert ep.total == fresh.total
    assert ep.hspec == fresh.hspec
    for a, b in zip(ep.state.states, fresh.state.states):
        assert np.array_equal(np.asarray(a.table), np.asarray(b.table))
        assert np.array_equal(np.asarray(a.params.q), np.asarray(b.params.q))
    ia, fa = ep.topk(16)
    ib, fb = fresh.topk(16)
    assert np.array_equal(ia, ib)
    assert np.array_equal(fa, fb)


def test_migration_across_partition_change():
    """Cutover to a spec with a DIFFERENT partition (greedy may combine
    groups): hierarchy depth changes under the endpoint, queries survive."""
    stream = _workload("zipf", seed=5)
    spec_old = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (32, 32), 4)
    spec_new = sk.mod_sketch_spec(stream.schema, [(0, 1)], (1024,), 4)
    key = jax.random.PRNGKey(2)
    items, freqs = stream.items, stream.freqs
    half = items.shape[0] // 2

    ep = SketchTopKEndpoint(spec_old, key)
    ep.ingest(items[:half], freqs[:half])
    assert ep.hspec.n_levels == 2
    ep.begin_migration(spec_new, key, warmup=1)
    ep.ingest(items[half:], freqs[half:])
    assert not ep.migrating
    assert ep.hspec.n_levels == 1
    ti, tf = ep.topk(8)
    uniq, tot = _exact([items[half:]], [freqs[half:]])
    exact = {tuple(r): t for r, t in zip(uniq.tolist(), tot.tolist())}
    for row, est in zip(ti.tolist(), tf.tolist()):
        assert est >= exact[tuple(row)]     # linear tables overcount only


# --------------------------------------------------------------------------
# Property: no false negatives + top-k continuity across the whole window
# --------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=5),
       st.integers(min_value=1, max_value=3),
       st.sampled_from(["zipf", "ngram"]))
def test_no_false_negatives_through_migration(seed, warm_blocks, kind):
    """At every block boundary -- pre-warmup, mid-warmup, post-cutover --
    heavy_hitters(T) reports every key exactly >= T within the serving
    window (whole stream before cutover, post-migration suffix after)."""
    stream = _workload(kind, seed)
    m = stream.schema.modularity
    groups = [(j,) for j in range(m)]
    spec_old = sk.mod_sketch_spec(stream.schema, groups, (32,) * m, 4)
    spec_new = sk.mod_sketch_spec(stream.schema, groups,
                                  (16,) + (64,) * (m - 1), 4)
    key = jax.random.PRNGKey(100 + seed)
    blocks = _drifted_blocks(stream, 6, seed)
    start_at = 2                      # begin migration after 2 blocks
    warm = int(sum(int(f.sum()) for _, f in
                   blocks[start_at:start_at + warm_blocks]))

    ep = SketchTopKEndpoint(spec_old, key)
    window = []                       # blocks the serving tables have seen
    for b, (bi, bf) in enumerate(blocks):
        if b == start_at:
            ep.begin_migration(spec_new, jax.random.fold_in(key, 1),
                               warmup=warm)
        was_migrating = ep.migrating
        ep.ingest(bi, bf)
        if was_migrating and not ep.migrating:
            window = []               # cutover: window restarts at the
            window_from = start_at    # first double-written block
            window = [blocks[i] for i in range(window_from, b + 1)]
        else:
            window.append((bi, bf))

        uniq, tot = _exact([w[0] for w in window], [w[1] for w in window])
        threshold = max(2, int(tot.max()) // 2)
        hh_items, hh_est = ep.heavy_hitters(threshold)
        got = {tuple(r) for r in hh_items.tolist()}
        exact_hh = {tuple(r) for r, t in zip(uniq.tolist(), tot.tolist())
                    if t >= threshold}
        assert exact_hh <= got, (
            f"false negatives at block {b} (migrating={ep.migrating}): "
            f"{sorted(exact_hh - got)[:4]}")

        # top-k continuity: answers exist and upper-bound window-exact
        ti, tf = ep.topk(8, min_threshold=1)
        assert len(ti) == min(8, len(uniq))
        exact_map = {tuple(r): t for r, t in zip(uniq.tolist(), tot.tolist())}
        for row, est in zip(ti.tolist(), tf.tolist()):
            assert est >= exact_map.get(tuple(row), 0)
    assert not ep.migrating           # warmup fits inside the stream


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=3))
def test_migration_progress_monotone(seed):
    stream = _workload("zipf", seed)
    spec = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (32, 32), 4)
    ep = SketchTopKEndpoint(spec, jax.random.PRNGKey(seed))
    blocks = _drifted_blocks(stream, 8, seed)
    ep.begin_migration(spec, jax.random.PRNGKey(seed + 1),
                       warmup=int(stream.freqs.sum()))
    last = 0.0
    for bi, bf in blocks:
        ep.ingest(bi, bf)
        assert ep.migration_progress >= last
        last = ep.migration_progress
    assert not ep.migrating and last == 1.0   # full stream == warmup mass


# --------------------------------------------------------------------------
# Sharded service: migration is shard-count invariant, bitwise
# --------------------------------------------------------------------------

def test_sharded_migration_shard_invariant():
    print(_run("""
        import jax, numpy as np
        from repro.core import sketch as sk
        from repro.serving.sharded_topk import ShardedTopKService
        from repro.streams import zipf_hh_workload

        key = jax.random.PRNGKey(0)
        wl = zipf_hh_workload(n_occurrences=40_000, n_edges=6_000, seed=3)
        spec_old = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)],
                                      (64, 16), 4)
        spec_new = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)],
                                      (16, 64), 4)
        items, freqs = wl.stream.items, wl.stream.freqs
        n = items.shape[0]; cut1, cut2 = n // 3, 2 * n // 3
        mig_key = jax.random.fold_in(key, 7)
        warm = int(freqs[cut1:cut2].sum())

        counts = [c for c in (1, 2, 4) if c <= jax.device_count()]
        assert counts[-1] >= 2
        results = {}
        for c in counts:
            mesh = jax.make_mesh((c,), ("data",))
            svc = ShardedTopKService(spec_old, key, mesh)
            svc.ingest(items[:cut1], freqs[:cut1])
            svc.begin_migration(spec_new, mig_key, warmup=warm)
            assert svc.migrating
            svc.ingest(items[cut1:cut2], freqs[cut1:cut2])
            assert not svc.migrating
            svc.ingest(items[cut2:], freqs[cut2:])
            ti, tf = svc.topk(16)
            results[c] = (ti, tf, svc.total,
                          [np.asarray(s.table) for s in svc.state().states])
        for c in counts[1:]:
            assert np.array_equal(results[counts[0]][0], results[c][0])
            assert np.array_equal(results[counts[0]][1], results[c][1])
            assert results[counts[0]][2] == results[c][2]
            for ta, tb in zip(results[counts[0]][3], results[c][3]):
                assert np.array_equal(ta, tb)

        # migrated == fresh service on the new spec, post-warmup stream
        mesh = jax.make_mesh((counts[-1],), ("data",))
        fresh = ShardedTopKService(spec_new, mig_key, mesh)
        fresh.ingest(items[cut1:cut2], freqs[cut1:cut2])
        fresh.ingest(items[cut2:], freqs[cut2:])
        fi, ff = fresh.topk(16)
        assert np.array_equal(results[counts[-1]][0], fi)
        assert np.array_equal(results[counts[-1]][1], ff)
        print("sharded migration invariant over", counts, "shards; "
              "migrated == fresh")
    """))


# --------------------------------------------------------------------------
# Refusal paths
# --------------------------------------------------------------------------

def _small_specs():
    stream = _workload("zipf", 1)
    old = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (32, 32), 4)
    new = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (16, 64), 4)
    return stream, old, new


def test_conservative_endpoint_refuses_migration():
    stream, old, new = _small_specs()
    ep = SketchTopKEndpoint(old, jax.random.PRNGKey(0), mode="conservative")
    ep.ingest(stream.items, stream.freqs)
    with pytest.raises(ValueError, match="linear"):
        ep.begin_migration(new, jax.random.PRNGKey(1), warmup=1)


def test_mid_warmup_merge_and_shard_refused():
    stream, old, new = _small_specs()
    key = jax.random.PRNGKey(0)
    ep = SketchTopKEndpoint(old, key)
    ep.ingest(stream.items, stream.freqs)
    ep.begin_migration(new, jax.random.PRNGKey(1), warmup=1 << 40)
    other = SketchTopKEndpoint(old, key)
    with pytest.raises(ValueError, match="migration"):
        ep.merge_from(other)
    with pytest.raises(ValueError, match="migration"):
        other.merge_from(ep)          # source side mid-warmup: also refused
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="migration"):
        ep.to_sharded(mesh)
    with pytest.raises(ValueError, match="already in flight"):
        ep.begin_migration(new, jax.random.PRNGKey(2), warmup=1)


def test_spec_migration_holder_invariants():
    stream, old, _ = _small_specs()
    ep = SketchTopKEndpoint(old, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="warmup"):
        SpecMigration(ep, warmup=0)
    ep.ingest(stream.items, stream.freqs)
    with pytest.raises(ValueError, match="start empty"):
        SpecMigration(ep, warmup=10)  # non-empty successor refused
    require_not_migrating(None, "anything")   # no-op without a migration
    with pytest.raises(ValueError, match="warmup window"):
        require_not_migrating(
            SpecMigration(SketchTopKEndpoint(old, jax.random.PRNGKey(1)),
                          warmup=10), "entry")
