"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.kernels import ref
from repro.kernels.hashes import make_plan
from repro.kernels.ops import KernelSketch
from repro.kernels.sketch_query import sketch_query_pallas
from repro.kernels.sketch_update import padded_table_size, sketch_update_pallas

CASES = [
    # (domains, partition, ranges, w, tile_h, B)
    (((1 << 32), (1 << 32)), [(0, 1)], (1000,), 1, 256, 64),
    (((1 << 32), (1 << 32)), [(0,), (1,)], (48, 90), 4, 512, 128),
    ((256,) * 4, [(0,), (1,), (2,), (3,)], (8, 8, 8, 8), 5, 512, 200),
    ((256,) * 4, [(0, 2), (1, 3)], (64, 64), 3, 1024, 100),
    (((1 << 16), (1 << 16)), [(0,), (1,)], (100, 41), 2, 128, 333),
]


@pytest.mark.parametrize("domains,part,ranges,w,tile_h,b", CASES)
def test_update_kernel_matches_oracle_int32(domains, part, ranges, w, tile_h, b):
    rng = np.random.default_rng(hash((w, tile_h, b)) % 2**32)
    schema = KeySchema(domains=domains)
    spec = sk.mod_sketch_spec(schema, part, ranges, w)
    plan = make_plan(spec)
    params = sk.init_params(spec, jax.random.PRNGKey(0))
    items = np.stack([rng.integers(0, d, b, dtype=np.uint64).astype(np.uint32)
                      for d in domains], axis=1)
    freqs = rng.integers(1, 1 << 14, size=(b,)).astype(np.int32)
    chunks = schema.module_chunks(jnp.asarray(items))
    h_pad = padded_table_size(spec.table_size, tile_h)
    t0 = jnp.zeros((w, h_pad), jnp.int32)
    # oracle first: the pallas wrapper DONATES its table arg, so t0 is
    # consumed by the kernel call
    want = ref.sketch_update_ref(plan, t0, chunks, jnp.asarray(freqs),
                                 params.q, params.r)
    got = sketch_update_pallas(plan, t0, chunks, jnp.asarray(freqs),
                               params.q, params.r, tile_h=tile_h,
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("domains,part,ranges,w,tile_h,b", CASES[:3])
def test_update_kernel_matches_oracle_float32(domains, part, ranges, w, tile_h, b):
    rng = np.random.default_rng(0)
    schema = KeySchema(domains=domains)
    spec = sk.mod_sketch_spec(schema, part, ranges, w)
    plan = make_plan(spec)
    params = sk.init_params(spec, jax.random.PRNGKey(1))
    items = np.stack([rng.integers(0, d, b, dtype=np.uint64).astype(np.uint32)
                      for d in domains], axis=1)
    vals = rng.standard_normal(b).astype(np.float32)
    chunks = schema.module_chunks(jnp.asarray(items))
    h_pad = padded_table_size(spec.table_size, tile_h)
    t0 = jnp.zeros((w, h_pad), jnp.float32)
    want = ref.sketch_update_ref(plan, t0, chunks, jnp.asarray(vals),
                                 params.q, params.r)
    got = sketch_update_pallas(plan, t0, chunks, jnp.asarray(vals),
                               params.q, params.r, tile_h=tile_h,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("domains,part,ranges,w,tile_h,b", CASES)
def test_query_kernel_matches_oracle(domains, part, ranges, w, tile_h, b):
    rng = np.random.default_rng(42)
    schema = KeySchema(domains=domains)
    spec = sk.mod_sketch_spec(schema, part, ranges, w)
    plan = make_plan(spec)
    params = sk.init_params(spec, jax.random.PRNGKey(2))
    items = np.stack([rng.integers(0, d, b, dtype=np.uint64).astype(np.uint32)
                      for d in domains], axis=1)
    freqs = rng.integers(1, 1000, size=(b,)).astype(np.int32)
    chunks = schema.module_chunks(jnp.asarray(items))
    h_pad = padded_table_size(spec.table_size, tile_h)
    table = ref.sketch_update_ref(plan, jnp.zeros((w, h_pad), jnp.int32),
                                  chunks, jnp.asarray(freqs), params.q,
                                  params.r)
    got = sketch_query_pallas(plan, table, chunks[:61], params.q, params.r,
                              tile_h=tile_h, interpret=True)
    want = ref.sketch_query_ref(plan, table, chunks[:61], params.q, params.r)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_sketch_end_to_end_matches_core_path():
    rng = np.random.default_rng(5)
    schema = KeySchema(domains=(1 << 32, 1 << 32))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (100, 41), 4)
    ks = KernelSketch(spec, jax.random.PRNGKey(0), tile_h=512, block_b=128)
    items = rng.integers(0, 1 << 32, size=(1000, 2), dtype=np.uint64).astype(np.uint32)
    freqs = rng.integers(1, 100, size=(1000,)).astype(np.int32)
    ks.update(items, freqs)
    core = sk.SketchState(params=ks.params,
                          table=jnp.zeros((4, spec.table_size), jnp.int32))
    core = sk.update_jit(spec, core, jnp.asarray(items), jnp.asarray(freqs))
    np.testing.assert_array_equal(np.asarray(ks.state().table),
                                  np.asarray(core.table))
    np.testing.assert_array_equal(
        ks.query(items[:77]),
        np.asarray(sk.query_jit(spec, core, jnp.asarray(items[:77]))))


def test_kernel_rejects_oversized_frequency():
    schema = KeySchema(domains=(1 << 16, 1 << 16))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (32, 32), 2)
    ks = KernelSketch(spec, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="2\\^24"):
        ks.update(np.zeros((4, 2), np.uint32),
                  np.full((4,), 1 << 25, np.int64))
