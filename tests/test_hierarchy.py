"""Hierarchical heavy-hitter subsystem: recovery guarantees, kernel/reference
parity, level-spec structure, merge linearity, serving endpoint."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.kernels.hier_query import hier_candidate_query, hier_candidate_query_ref
from repro.serving.engine import SketchTopKEndpoint
from repro.streams import (
    exact_heavy_hitters,
    group_candidates,
    ngram_hh_workload,
    zipf_hh_workload,
)


def _build(wl, ranges=(256, 256), w=4, key=0):
    base = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], ranges, w)
    hspec = hh.HierarchySpec.from_spec(base)
    state = hh.build_hierarchy(hspec, jax.random.PRNGKey(key),
                               wl.stream.items, wl.stream.freqs)
    return base, hspec, state


def test_level_spec_structure():
    schema = KeySchema(domains=(1 << 32, 256, 1000))
    base = sk.mod_sketch_spec(schema, [(1, 2), (0,)], (128, 512), 3)
    hspec = hh.HierarchySpec.from_spec(base)
    assert hspec.n_levels == 2
    # coarse level: only group 0's modules, renumbered consecutively
    assert hspec.levels[0].schema.domains == (256, 1000)
    assert hspec.levels[0].ranges == (128,)
    # top level covers the full key (group-major module order) and has the
    # base's table size; candidate strides nest (stride identity)
    assert hspec.levels[1].schema.domains == (256, 1000, 1 << 32)
    assert hspec.levels[1].table_size == base.table_size
    assert hspec.levels[1].strides[0] == hspec.levels[0].strides[0] * 512
    # schema-order round trip
    items = np.arange(12, dtype=np.uint32).reshape(4, 3)
    reordered = np.asarray(hspec.level_items(1, items))
    assert (hspec.to_schema_order(reordered) == items).all()


def test_zipf_recovery_no_false_negatives():
    """Acceptance: 10^5-occurrence zipf(1.1) stream, every item with true
    frequency >= threshold recovered; false positives within the CM
    overestimate slack."""
    wl = zipf_hh_workload(phi=0.002, n_occurrences=100_000, s=1.1, seed=0)
    base, hspec, state = _build(wl)
    got_items, got_est = hh.find_heavy_hitters(
        hspec, state, wl.threshold, wl.candidates(base))

    exact = {tuple(r) for r in wl.exact_items.tolist()}
    got = {tuple(r) for r in got_items.tolist()}
    assert exact <= got, f"false negatives: {exact - got}"

    # false positives: each reported key's true frequency must be within
    # the leaf-level CM slack eps*L of the threshold.  The slack constant
    # accounts for the max-over-candidates selection effect (thousands of
    # keys reach the leaf, so the worst overestimate governs, not the
    # per-key bound) and for the shared per-group family: leaf-colliding
    # keys collide at every ancestor too, so ancestor levels cannot prune
    # leaf-collision false positives (the leaf bound itself is unchanged).
    uniq, inv = np.unique(wl.stream.items, axis=0, return_inverse=True)
    tot = np.bincount(inv, weights=wl.stream.freqs.astype(np.float64))
    true_of = {tuple(k): int(v) for k, v in zip(uniq.tolist(), tot)}
    eps_l = 32.0 / base.table_size * wl.stream.total
    for t in got:
        assert true_of[t] >= wl.threshold - eps_l, (t, true_of[t])
    # estimates are CM overestimates of the truth
    for t, e in zip(got_items.tolist(), got_est.tolist()):
        assert e >= true_of[tuple(t)]


def test_ngram_recovery():
    wl = ngram_hh_workload(vocab_size=512, n=2, phi=0.003, seed=1)
    base, hspec, state = _build(wl, ranges=(128, 128))
    got_items, _ = hh.find_heavy_hitters(
        hspec, state, wl.threshold, wl.candidates(base))
    exact = {tuple(r) for r in wl.exact_items.tolist()}
    got = {tuple(r) for r in got_items.tolist()}
    assert exact <= got


def test_kernel_matches_reference_exactly():
    """Acceptance: the Pallas candidate kernel is bit-identical to the jnp
    reference on int32 tables -- both raw (random partials) and end-to-end
    through the descent."""
    rng = np.random.default_rng(0)
    w, h, p, c = 3, 1000, 17, 29  # h deliberately not a tile multiple
    table = jnp.asarray(rng.integers(0, 1 << 20, (w, h)).astype(np.int32))
    cp = rng.integers(0, 64, (w, c)).astype(np.uint32)
    pp = (rng.integers(0, h // 64, (w, p)) * 64).astype(np.uint32)
    got = hier_candidate_query(table, jnp.asarray(pp), jnp.asarray(cp),
                               tile_h=256, interpret=True)
    want = hier_candidate_query_ref(table, jnp.asarray(pp), jnp.asarray(cp))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    wl = zipf_hh_workload(phi=0.004, n_occurrences=50_000, n_edges=5_000)
    base, hspec, state = _build(wl)
    cands = wl.candidates(base)
    ri, re = hh.find_heavy_hitters(hspec, state, wl.threshold, cands,
                                   use_kernel=False)
    ki, ke = hh.find_heavy_hitters(hspec, state, wl.threshold, cands,
                                   use_kernel=True)
    np.testing.assert_array_equal(ri, ki)
    np.testing.assert_array_equal(re, ke)


def test_candidate_separability_equals_direct_query():
    """pp + cp must reproduce compute_indices of the level spec exactly:
    the grid estimates equal a flat sk.query over the materialized children."""
    wl = zipf_hh_workload(phi=0.01, n_occurrences=20_000, n_edges=3_000)
    base, hspec, state = _build(wl, ranges=(64, 64), w=3)
    prefixes = np.unique(wl.stream.items[:, 0])[:40][:, None]
    values = np.unique(wl.stream.items[:, 1])[:50][:, None]
    grid = hh.candidate_estimates(hspec, state, 1, prefixes, values)
    children = np.concatenate(
        [np.repeat(prefixes, len(values), 0),
         np.tile(values, (len(prefixes), 1))], axis=1)
    direct = np.asarray(sk.query(hspec.levels[1], state.states[1],
                                 jnp.asarray(children)))
    np.testing.assert_array_equal(grid.reshape(-1), direct)


def test_hierarchy_merge_linear():
    wl = zipf_hh_workload(phi=0.01, n_occurrences=20_000, n_edges=3_000)
    base = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (64, 64), 3)
    hspec = hh.HierarchySpec.from_spec(base)
    key = jax.random.PRNGKey(2)
    items, freqs = wl.stream.items, wl.stream.freqs
    half = len(items) // 2
    a = hh.build_hierarchy(hspec, key, items[:half], freqs[:half])
    b = hh.build_hierarchy(hspec, key, items[half:], freqs[half:])
    whole = hh.build_hierarchy(hspec, key, items, freqs)
    merged = hh.merge(a, b)
    for m, w_ in zip(merged.states, whole.states):
        np.testing.assert_array_equal(np.asarray(m.table),
                                      np.asarray(w_.table))


def test_three_module_hierarchy_with_joint_group():
    """Multi-module group at level 0 + 2-chunk module at level 1."""
    schema = KeySchema(domains=(1 << 32, 256, 1000))
    base = sk.mod_sketch_spec(schema, [(1, 2), (0,)], (512, 512), 3)
    hspec = hh.HierarchySpec.from_spec(base)
    rng = np.random.default_rng(3)
    n = 300
    items = np.stack(
        [rng.integers(0, 1 << 32, n, dtype=np.uint64).astype(np.uint32),
         rng.integers(0, 256, n).astype(np.uint32),
         rng.integers(0, 1000, n).astype(np.uint32)], axis=1)
    freqs = (rng.pareto(1.5, n) * 30 + 1).astype(np.int64)
    state = hh.build_hierarchy(hspec, jax.random.PRNGKey(4), items, freqs)
    exact_i, _ = exact_heavy_hitters(items, freqs, 200)
    exact = {tuple(r) for r in exact_i.tolist()}
    cands = group_candidates(base, items)
    gi, _ = hh.find_heavy_hitters(hspec, state, 200, cands,
                                  max_batch=1 << 14)
    got = {tuple(r) for r in gi.tolist()}
    assert exact <= got
    # returned columns are in schema module order
    if len(gi):
        assert (gi[:, 1] < 256).all() and (gi[:, 2] < 1000).all()


def test_find_heavy_hitters_validates_candidates():
    wl = zipf_hh_workload(phi=0.01, n_occurrences=10_000, n_edges=2_000)
    base, hspec, state = _build(wl, ranges=(32, 32), w=2)
    with pytest.raises(ValueError, match="one candidate set per level"):
        hh.find_heavy_hitters(hspec, state, 10,
                              [np.zeros((1, 1), np.uint32)])
    with pytest.raises(ValueError, match="candidates\\[0\\]"):
        hh.find_heavy_hitters(hspec, state, 10,
                              [np.zeros((1, 2), np.uint32)] * 2)


def test_kernel_rejects_non_int32_tables():
    """The Pallas two-limb gather only covers int32; other dtypes must be
    refused loudly (the descent then takes the dtype-preserving reference
    path -- exercised under x64 below)."""
    from repro.kernels.hier_query import hier_candidate_query
    with pytest.raises(ValueError, match="int32 tables only"):
        hier_candidate_query(jnp.zeros((2, 64), jnp.float32),
                             jnp.zeros((2, 1), jnp.uint32),
                             jnp.zeros((2, 1), jnp.uint32))


def test_int64_tables_route_to_dtype_preserving_path():
    """use_kernel on an int64 hierarchy must not wrap counts through the
    kernel's int32 limb split: the query silently takes the reference path
    and keeps exact 64-bit estimates.  int64 tables only exist under
    jax_enable_x64, so this runs in a subprocess."""
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import sketch as sk, hierarchy as hh
        from repro.core.hashing import KeySchema
        from repro.streams import group_candidates
        schema = KeySchema(domains=(1 << 16, 1 << 16))
        base = sk.mod_sketch_spec(schema, [(0,), (1,)], (16, 16), 2)
        hspec = hh.HierarchySpec.from_spec(base)
        items = np.array([[7, 9]], np.uint32)
        freqs = np.array([1 << 33], np.int64)
        state = hh.build_hierarchy(hspec, jax.random.PRNGKey(0), items,
                                   freqs, dtype=jnp.int64)
        assert state.states[0].table.dtype == jnp.int64
        cands = group_candidates(base, items)
        for uk in (False, True):
            gi, ge = hh.find_heavy_hitters(hspec, state, 1 << 33, cands,
                                           use_kernel=uk)
            assert gi.tolist() == [[7, 9]], (uk, gi)
            assert int(ge[0]) >= 1 << 33, (uk, ge)
        print("int64 ok")
    """)], capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "int64 ok" in out.stdout


def test_endpoint_pool_admission_space_saving():
    """Space-saving admission (core/summary.py): heavy group values enter
    the candidate pools regardless of arrival order -- early heavies
    survive a flood of light values, and late heavies evict light entries
    instead of being dropped at the cap (the old append-only behaviour)."""
    schema = KeySchema(domains=(1 << 32, 1 << 32))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (16, 16), 2)
    ep = SketchTopKEndpoint(spec, jax.random.PRNGKey(0),
                            max_candidates_per_group=8)
    big = np.full((6, 2), 0xFFFF0000, np.uint32) + np.arange(6, dtype=np.uint32)[:, None]
    ep.ingest(big, np.full(6, 100, np.int64))
    # flood with light values: heavies must not be evicted
    small = np.arange(40, dtype=np.uint32).reshape(20, 2)
    ep.ingest(small, np.ones(20, np.int64))
    for j, cand in enumerate(ep.candidates()):
        assert len(ep._pools[j]) == 8  # at capacity
        assert {int(v) for v in big[:, j]} <= {int(v) for v in cand[:, 0]}
    items, _ = ep.heavy_hitters(100)
    got = {tuple(r) for r in items.tolist()}
    assert {tuple(r) for r in big.tolist()} <= got

    # reverse order: pools full of light values, then late-arriving heavies
    ep2 = SketchTopKEndpoint(spec, jax.random.PRNGKey(0),
                             max_candidates_per_group=8)
    ep2.ingest(small, np.ones(20, np.int64))
    late = np.full((4, 2), 0xAAAA0000, np.uint32) + np.arange(4, dtype=np.uint32)[:, None]
    ep2.ingest(late, np.full(4, 500, np.int64))
    items2, est2 = ep2.heavy_hitters(400)
    got2 = {tuple(r) for r in items2.tolist()}
    assert {tuple(r) for r in late.tolist()} <= got2  # old code dropped these

    # merged shards keep heavy values from both sides within the cap
    ep.merge_from(ep2)
    items3, _ = ep.heavy_hitters(400)
    got3 = {tuple(r) for r in items3.tolist()}
    assert {tuple(r) for r in late.tolist()} <= got3


def test_topk_endpoint_ranks_head():
    wl = zipf_hh_workload(phi=0.002, n_occurrences=50_000, seed=5)
    spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (256, 256), 4)
    ep = SketchTopKEndpoint(spec, jax.random.PRNGKey(0))
    ep.ingest(wl.stream.items, wl.stream.freqs)
    assert ep.total == wl.stream.total
    items, est = ep.topk(5)
    assert items.shape == (5, 2)
    # the true heaviest key must be reported first (estimates only inflate)
    assert tuple(items[0]) == tuple(wl.exact_items[0])
    assert est[0] >= wl.exact_freqs[0]
