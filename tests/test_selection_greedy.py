"""Thm 4/5 sigma-selection and Algorithm 1 greedy search."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch as sk
from repro.core.greedy import greedy_config
from repro.core.exhaustive import exhaustive_config, observed_error
from repro.core.selection import choose_sketch
from repro.streams import ipv4_stream, reinterpret_modularity, zipf_graph_stream


def _error_of(spec, stream, key, k=300):
    state = sk.build_sketch(spec, key, stream.items, stream.freqs)
    qi, qf = stream.top_k_queries(k)
    est = np.asarray(sk.query_jit(spec, state, jnp.asarray(qi)))
    return observed_error(est, qf)


def test_selection_picks_lower_error_sketch():
    """The sigma criterion (Thm 4/5) must agree with actual observed error."""
    stream = ipv4_stream(n_src_hosts=20_000, n_tgt_hosts=2_000, n_pairs=80_000,
                         n_occurrences=1_500_000, seed=4)
    rng = np.random.default_rng(0)
    s_items, s_freqs = stream.sample(0.03, rng)
    h, w = 4096, 5
    key = jax.random.PRNGKey(1)
    res = choose_sketch(s_items, s_freqs, stream.schema, h, w, key)
    errs = {
        "count-min": _error_of(sk.count_min_spec(stream.schema, h, w), stream, key),
        "mod-sketch": _error_of(
            sk.mod_sketch_spec(stream.schema, [(0,), (1,)],
                               res.mod_ranges, w), stream, key),
    }
    assert res.choice == min(errs, key=errs.get)


def test_selection_sigma_sample_invariance():
    """Thm 5: the sigma ordering is stable across sample rates."""
    stream = ipv4_stream(n_src_hosts=10_000, n_tgt_hosts=1_000, n_pairs=50_000,
                         n_occurrences=800_000, seed=9)
    rng = np.random.default_rng(1)
    key = jax.random.PRNGKey(7)
    choices = []
    for frac in (0.02, 0.04, 0.08):
        s_items, s_freqs = stream.sample(frac, rng)
        res = choose_sketch(s_items, s_freqs, stream.schema, 4096, 5, key)
        choices.append(res.choice)
    assert len(set(choices)) == 1


def test_greedy_candidate_count_quadratic():
    """Algorithm 1 scores at most sum_j (n-j+1) = O(n^2) candidates,
    far below T(n) (paper SV-B2)."""
    base = ipv4_stream(n_src_hosts=3000, n_tgt_hosts=400, n_pairs=20_000,
                       n_occurrences=200_000, seed=2)
    stream = reinterpret_modularity(base, 4)
    rng = np.random.default_rng(0)
    s_items, s_freqs = stream.sample(0.05, rng)
    res = greedy_config(s_items, s_freqs, stream.schema, 4096, 4,
                        jax.random.PRNGKey(0))
    n = 4
    assert res.n_candidates <= sum(n - j for j in range(n)) + n  # <= O(n^2)
    assert res.n_candidates < 15  # T(4) = 15: strictly fewer than exhaustive
    assert sum(1 for t in res.trace if t.chosen) >= 1
    # final spec covers all modules with valid ranges
    assert sorted(m for g in res.spec.partition for m in g) == list(range(n))


def test_greedy_beats_equal_sketch_mod4():
    base = ipv4_stream(n_src_hosts=8000, n_tgt_hosts=800, n_pairs=60_000,
                       n_occurrences=1_000_000, seed=0)
    stream = reinterpret_modularity(base, 4)
    rng = np.random.default_rng(0)
    s_items, s_freqs = stream.sample(0.03, rng)
    h, w = 4096, 5
    key = jax.random.PRNGKey(11)
    res = greedy_config(s_items, s_freqs, stream.schema, h, w, key)
    err_greedy = _error_of(res.spec, stream, key)
    err_equal = _error_of(sk.equal_sketch_spec(stream.schema, h, w), stream, key)
    assert err_greedy < err_equal


def test_exhaustive_refuses_large_modularity():
    stream = reinterpret_modularity(
        ipv4_stream(n_src_hosts=100, n_tgt_hosts=50, n_pairs=500,
                    n_occurrences=2000, seed=1), 8)
    with pytest.raises(ValueError, match="100 hours"):
        exhaustive_config(stream.items, stream.freqs, stream.schema, 256, 3,
                          jax.random.PRNGKey(0))


def test_exhaustive_at_least_as_good_as_greedy_mod3():
    rng = np.random.default_rng(5)
    src = rng.integers(0, 30, size=30_000).astype(np.uint32)
    mid = rng.integers(0, 300, size=30_000).astype(np.uint32)
    tgt = rng.integers(0, 3000, size=30_000).astype(np.uint32)
    items = np.stack([src, mid, tgt], axis=1)
    from repro.core.hashing import KeySchema
    from repro.streams.synthetic import Stream
    uniq, inv = np.unique(items, axis=0, return_inverse=True)
    freqs = np.bincount(inv).astype(np.int64)
    stream = Stream(schema=KeySchema(domains=(32, 512, 4096)), items=uniq,
                    freqs=freqs)
    s_items, s_freqs = stream.sample(0.1, rng)
    key = jax.random.PRNGKey(3)
    ex = exhaustive_config(s_items, s_freqs, stream.schema, 1024, 4, key)
    gr = greedy_config(s_items, s_freqs, stream.schema, 1024, 4, key)
    err_ex = _error_of(ex.spec, stream, key)
    err_gr = _error_of(gr.spec, stream, key)
    assert err_ex <= err_gr * 1.35 + 0.02   # greedy close to exhaustive


# --------------------------------------------------------------------------
# Live-stats faithfulness: the online re-search equals the offline search
# when the proxy sample is exact (streams/livestats.py contract)
# --------------------------------------------------------------------------

def _small_keyspace_endpoint(seed):
    """Keyspace engineered so the endpoint's live state is lossless: pools
    far under capacity (every group value admitted) and level tables so
    sparse that no key pair collides in all rows -- the proxy sample from
    the descent is then the exact compressed stream."""
    from repro.core.hashing import KeySchema
    from repro.serving.engine import SketchTopKEndpoint

    rng = np.random.default_rng(seed)
    n = 3000
    src = rng.integers(0, 4, size=n).astype(np.uint32)
    mid = ((src * 2 + rng.integers(0, 3, size=n)) % 8).astype(np.uint32)
    tgt = (rng.zipf(1.6, size=n) % 12).astype(np.uint32)
    uniq, inv = np.unique(np.stack([src, mid, tgt], axis=1), axis=0,
                          return_inverse=True)
    freqs = np.bincount(inv).astype(np.int64)
    schema = KeySchema(domains=(4, 8, 16))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,), (2,)], (16, 16, 16), 5)
    ep = SketchTopKEndpoint(spec, jax.random.PRNGKey(0))
    ep.ingest(uniq, freqs)
    return ep, schema, uniq, freqs


@pytest.mark.parametrize("seed", [7, 11])
def test_live_stats_proxy_sample_is_exact_on_small_keyspace(seed):
    from repro.streams import collect_live_stats, exact_marginals

    ep, schema, uniq, freqs = _small_keyspace_endpoint(seed)
    stats = collect_live_stats(ep, k=len(uniq) + 32, min_threshold=1)
    exact = {tuple(r): f for r, f in zip(uniq.tolist(), freqs.tolist())}
    got = {tuple(r): f for r, f in
           zip(stats.items.tolist(), stats.freqs.tolist())}
    assert got == exact               # no phantom keys, no inflated counts
    assert stats.total == int(freqs.sum())
    assert abs(stats.coverage - 1.0) < 1e-9
    # per-group marginal mass off the level tables == exact marginals
    for j in range(schema.modularity):
        per_row = exact_marginals(uniq, freqs, [j])  # O(v_j, *) per row
        exact_m = {int(v): int(m) for v, m in
                   zip(uniq[:, j].tolist(), per_row.tolist())}
        live = {int(v): int(m) for v, m in
                zip(stats.group_values[j][:, 0].tolist(),
                    stats.group_mass[j].tolist())}
        assert live == exact_m


@pytest.mark.parametrize("seed", [7, 11])
def test_live_propose_spec_matches_offline_greedy_and_exhaustive(seed):
    """With an exact proxy sample, the online re-search IS the offline
    search: propose_spec == greedy_config bitwise (partition + ranges),
    and at a budget where greedy finds the optimum it also equals
    exhaustive_config."""
    from repro.streams import collect_live_stats, propose_spec

    ep, schema, uniq, freqs = _small_keyspace_endpoint(seed)
    stats = collect_live_stats(ep, k=len(uniq) + 32, min_threshold=1)
    key = jax.random.PRNGKey(3)
    for h in (64, 256):
        live = propose_spec(stats, h, 4, key)
        off = greedy_config(uniq, freqs, schema, h, 4, key)
        assert live.spec.partition == off.spec.partition
        assert live.spec.ranges == off.spec.ranges
        assert live.spec.width == off.spec.width
    ex = exhaustive_config(uniq, freqs, schema, 64, 4, key)
    live64 = propose_spec(stats, 64, 4, key)
    assert live64.spec.partition == ex.spec.partition
    assert live64.spec.ranges == ex.spec.ranges


def test_live_propose_spec_range_only_matches_recursive_ranges():
    from repro.core.range_opt import recursive_ranges
    from repro.streams import collect_live_stats, propose_spec

    ep, schema, uniq, freqs = _small_keyspace_endpoint(7)
    stats = collect_live_stats(ep, k=len(uniq) + 32, min_threshold=1)
    part = ((0,), (1,), (2,))
    live = propose_spec(stats, 256, 4, jax.random.PRNGKey(3), partition=part)
    assert live.spec.partition == part
    assert live.spec.ranges == recursive_ranges(uniq, freqs, part, 256.0)
