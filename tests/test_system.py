"""End-to-end behaviour: the full MOD-Sketch pipeline of paper SIV/SV.

sample 2-4% -> estimate alpha (weighted median) -> Thm-3 ranges ->
Thm-4/5 selection vs Count-Min -> build on the full stream -> frequency
queries.  Asserts the paper's qualitative claims on the calibrated stream
(heavy-overload regime, DESIGN.md S4 changed-assumptions note)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch as sk
from repro.core.greedy import greedy_config
from repro.core.selection import choose_sketch
from repro.streams import (
    observed_error,
    reinterpret_modularity,
    zipf_graph_stream,
)


@pytest.fixture(scope="module")
def overload_stream():
    # distinct/h overload ~ 20x, mild skew: the paper's Twitter-like regime
    return zipf_graph_stream(n_src=20_000, n_tgt=60_000, n_edges=400_000,
                             n_occurrences=2_000_000, s_src=0.7, s_tgt=0.7,
                             seed=0)


def _err(spec, stream, key, queries):
    state = sk.build_sketch(spec, key, stream.items, stream.freqs)
    qi, qf = queries
    est = np.asarray(sk.query_jit(spec, state, jnp.asarray(qi)))
    return observed_error(est, qf)


def test_full_pipeline_mod2(overload_stream):
    stream = overload_stream
    rng = np.random.default_rng(0)
    h, w = 4096, 5
    key = jax.random.PRNGKey(0)

    # (1) sample 2%  (2) optimal (a,b)  (3) sigma-selection
    s_items, s_freqs = stream.sample(0.02, rng)
    res = choose_sketch(s_items, s_freqs, stream.schema, h, w, key)
    assert res.choice in ("count-min", "mod-sketch")
    a, b = res.mod_ranges
    assert 0.5 * h <= a * b <= 1.5 * h

    # paper claim (SVI-B): MOD beats Equal-Sketch on skewed modular streams;
    # random-k queries in the overload regime also beat Count-Min
    queries = stream.random_k_queries(500, rng)
    err_mod = _err(sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (a, b), w),
                   stream, key, queries)
    err_eq = _err(sk.equal_sketch_spec(stream.schema, h, w), stream, key,
                  queries)
    err_cm = _err(sk.count_min_spec(stream.schema, h, w), stream, key,
                  queries)
    assert err_mod <= err_eq * 1.02
    assert err_mod <= err_cm * 1.02

    # the selected sketch is never materially worse than either candidate
    err_sel = _err(res.spec, stream, key, queries)
    assert err_sel <= max(err_mod, err_cm) * 1.02


def test_full_pipeline_mod4():
    """SV: greedy composite hashing at modularity 4 beats Equal-Sketch."""
    stream = reinterpret_modularity(
        zipf_graph_stream(n_src=10_000, n_tgt=1_000, n_edges=100_000,
                          n_occurrences=1_000_000, seed=3), 4)
    rng = np.random.default_rng(1)
    s_items, s_freqs = stream.sample(0.03, rng)
    h, w = 4096, 5
    key = jax.random.PRNGKey(1)
    res = greedy_config(s_items, s_freqs, stream.schema, h, w, key)
    queries = stream.top_k_queries(400)
    err_mod = _err(res.spec, stream, key, queries)
    err_eq = _err(sk.equal_sketch_spec(stream.schema, h, w), stream, key,
                  queries)
    assert err_mod < err_eq


def test_error_decreases_with_h(overload_stream):
    """Fig. 4/5 trend: larger range h => smaller observed error."""
    stream = overload_stream
    rng = np.random.default_rng(2)
    key = jax.random.PRNGKey(2)
    queries = stream.top_k_queries(300)
    errs = []
    for h in (1024, 4096, 16384):
        s_items, s_freqs = stream.sample(0.02, rng)
        from repro.core.range_opt import optimal_ranges_mod2
        a, b = optimal_ranges_mod2(s_items, s_freqs, h)
        errs.append(_err(sk.mod_sketch_spec(stream.schema, [(0,), (1,)],
                                            (a, b), 5), stream, key, queries))
    assert errs[0] > errs[1] > errs[2]


def test_error_decreases_with_w(overload_stream):
    """Thm 2: more hash functions (w) tightens the min-estimate."""
    stream = overload_stream
    key = jax.random.PRNGKey(3)
    queries = stream.top_k_queries(300)
    errs = [
        _err(sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (64, 64), w),
             stream, key, queries)
        for w in (1, 3, 6)
    ]
    assert errs[0] >= errs[1] >= errs[2]
