"""serving/kv_cache.py: sizing and slot-reuse helpers for decode caches.

Covers the three helpers against real reduced configs across cache
families: attention KV (gemma-7b), SSM conv/state (mamba2-130m), and the
encoder-decoder cross-attention entries (seamless-m4t-medium):

  * ``cache_bytes`` counts every leaf exactly (size * itemsize) and is
    linear in the batch axis;
  * ``new_cache`` builds the stacked per-block structure with the right
    shapes, and only encoder-decoder configs get cross_k/cross_v entries
    sized by ``frontend_len``;
  * ``reset_slots`` zeroes exactly the finished slots' rows on every
    batch-carrying leaf, preserving other slots, shapes, and dtypes.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.serving.kv_cache import cache_bytes, new_cache, reset_slots


def _leaves(cache):
    return jax.tree.leaves(cache)


def test_cache_bytes_counts_every_leaf():
    cfg = get_reduced("gemma-7b")
    cache = new_cache(cfg, batch=2, max_len=16)
    manual = sum(int(np.asarray(x).size) * np.asarray(x).dtype.itemsize
                 for x in _leaves(cache))
    assert cache_bytes(cache) == manual > 0


def test_cache_bytes_linear_in_batch():
    cfg = get_reduced("gemma-7b")
    b1 = cache_bytes(new_cache(cfg, batch=1, max_len=16))
    b3 = cache_bytes(new_cache(cfg, batch=3, max_len=16))
    assert b3 == 3 * b1


def test_new_cache_attention_shapes():
    cfg = get_reduced("gemma-7b")
    batch, max_len = 2, 16
    cache = new_cache(cfg, batch, max_len)
    hd = cfg.resolved_head_dim
    k = cache["layer_0"]["k"]
    assert k.shape == (cfg.n_blocks, batch, max_len, cfg.n_kv_heads, hd)
    assert k.dtype == cfg.activation_dtype
    # decoder-only config: no cross-attention entries anywhere
    assert all("cross_k" not in blk for blk in cache.values())


def test_new_cache_ssm_entries():
    cfg = get_reduced("mamba2-130m")
    cache = new_cache(cfg, batch=2, max_len=16)
    kinds = {cfg.layer_kind(i) for i in range(cfg.block_period)}
    assert kinds != {"attn"}, "mamba config must have non-attention layers"
    ssm_layers = [blk for blk in cache.values() if "ssm" in blk]
    assert ssm_layers, "mamba config must produce SSM cache entries"
    st = ssm_layers[0]["ssm"]
    assert st.shape[1] == 2            # batch axis after the n_blocks stack
    assert st.dtype == jnp.float32     # SSM state accumulates in f32


def test_new_cache_encoder_decoder_cross_entries():
    cfg = get_reduced("seamless-m4t-medium")
    assert cfg.n_enc_layers > 0
    batch = 2
    cache = new_cache(cfg, batch, max_len=16)
    ck = cache["layer_0"]["cross_k"]
    # cross K/V are sized by the encoder output length = frontend_len
    assert ck.shape == (cfg.n_blocks, batch, cfg.frontend_len,
                        cfg.n_kv_heads, cfg.resolved_head_dim)


def test_reset_slots_zeroes_only_finished_rows():
    cfg = get_reduced("gemma-7b")
    batch = 3
    cache = new_cache(cfg, batch, max_len=8)
    filled = jax.tree.map(lambda x: jnp.ones_like(x), cache)
    mask = np.array([True, False, True])
    out = reset_slots(filled, mask)
    for before, after in zip(_leaves(filled), _leaves(out)):
        assert after.shape == before.shape
        assert after.dtype == before.dtype
        a = np.asarray(after)
        assert np.all(a[:, 0] == 0) and np.all(a[:, 2] == 0)
        assert np.all(a[:, 1] == 1)


def test_reset_slots_all_false_is_identity():
    cfg = get_reduced("mamba2-130m")
    cache = jax.tree.map(lambda x: jnp.ones_like(x),
                         new_cache(cfg, batch=2, max_len=8))
    out = reset_slots(cache, np.array([False, False]))
    for before, after in zip(_leaves(cache), _leaves(out)):
        assert np.array_equal(np.asarray(before), np.asarray(after))
