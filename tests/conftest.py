"""Suite-wide fixtures: fixed PRNG seed, slow marker for kernel sweeps."""
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running kernel sweeps; deselect with -m 'not slow'")


@pytest.fixture(autouse=True)
def _fixed_global_seed():
    """Pin numpy's legacy global PRNG so tests that forget to pass a seeded
    Generator stay reproducible (jax keys and default_rng(seed) calls are
    already explicit everywhere)."""
    np.random.seed(0)
    yield
