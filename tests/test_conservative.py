"""Conservative-mode invariants across the stack: point-wise tighter than
linear, never underestimates, and excluded from every cell-wise-merge
surface (KernelSketch.merge/state, endpoint merge_from / sharding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.kernels.ops import KernelSketch
from repro.serving.engine import SketchTopKEndpoint

_SCHEMA = KeySchema(domains=(1 << 32, 1 << 32))


def _zipfish_stream(rng, n, n_keys=400):
    ranks = rng.zipf(1.3, size=n).clip(max=n_keys) - 1
    keys = rng.integers(0, 1 << 32, size=(n_keys, 2),
                        dtype=np.uint64).astype(np.uint32)
    items = keys[ranks]
    freqs = rng.integers(1, 20, size=n).astype(np.int32)
    return items, freqs


def _true_freqs(items, freqs):
    packed = items[:, 0].astype(np.uint64) << np.uint64(32) | items[:, 1]
    uniq, inv = np.unique(packed, return_inverse=True)
    return np.bincount(inv, weights=freqs.astype(np.float64))[inv]


def test_kernel_conservative_pointwise_leq_linear_and_overestimates():
    """est_true <= est_conservative <= est_linear, point-wise, same params."""
    spec = sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (40, 40), 3)
    rng = np.random.default_rng(0)
    items, freqs = _zipfish_stream(rng, 3000)
    lin = KernelSketch(spec, jax.random.PRNGKey(5), tile_h=256, block_b=256,
                       interpret=True)
    cons = KernelSketch(spec, jax.random.PRNGKey(5), tile_h=256, block_b=256,
                        interpret=True, mode="conservative")
    lin.update(items, freqs)
    cons.update(items, freqs)
    # same key => same hash params => same cells; conservative writes
    # max(cur, min+f) <= cur+f, so the table (hence every query) dominates
    assert (cons.table_view() <= lin.table_view()).all()

    q = items[rng.choice(len(items), 200, replace=False)]
    e_lin, e_cons = lin.query(q), cons.query(q)
    assert (e_cons <= e_lin).all()
    # never underestimates (queried keys all appear in the stream)
    tmap = {tuple(it): t for it, t in zip(items, _true_freqs(items, freqs))}
    want = np.array([tmap[tuple(r)] for r in q])
    assert (e_cons >= want - 1e-9).all()


def test_conservative_kernel_sketch_refuses_merge_surfaces():
    spec = sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (16, 16), 2)
    key = jax.random.PRNGKey(0)
    cons = KernelSketch(spec, key, tile_h=128, block_b=64, interpret=True,
                        mode="conservative")
    lin = KernelSketch(spec, key, tile_h=128, block_b=64, interpret=True)
    with pytest.raises(ValueError, match="not linear"):
        cons.merge(lin)
    with pytest.raises(ValueError, match="not linear"):
        lin.merge(cons)
    with pytest.raises(ValueError, match="cell-wise merge"):
        cons.state()
    assert cons.table_view().shape == (2, spec.table_size)  # inspection ok
    with pytest.raises(ValueError, match="mode"):
        KernelSketch(spec, key, mode="bogus")


def test_linear_kernel_sketch_merge_is_exact():
    """Positive control: linear merge == building on the whole stream."""
    spec = sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (40, 40), 3)
    rng = np.random.default_rng(3)
    items, freqs = _zipfish_stream(rng, 1000)
    key = jax.random.PRNGKey(1)
    mk = lambda: KernelSketch(spec, key, tile_h=256, block_b=128,
                              interpret=True)
    a, b, whole = mk(), mk(), mk()
    a.update(items[:500], freqs[:500])
    b.update(items[500:], freqs[500:])
    whole.update(items, freqs)
    a.merge(b)
    np.testing.assert_array_equal(a.table_view(), whole.table_view())
    # mismatched params are rejected, not silently summed
    other = KernelSketch(spec, jax.random.PRNGKey(2), tile_h=256,
                         block_b=128, interpret=True)
    with pytest.raises(ValueError, match="hash params"):
        a.merge(other)
    # mismatched table dtypes would silently promote int32 counts to f32
    fother = KernelSketch(spec, key, tile_h=256, block_b=128,
                          dtype=jnp.float32, interpret=True)
    with pytest.raises(ValueError, match="dtype"):
        a.merge(fother)


def test_hierarchy_conservative_tables_dominated_by_linear():
    base = sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (32, 32), 2)
    hspec = hh.HierarchySpec.from_spec(base)
    rng = np.random.default_rng(4)
    items, freqs = _zipfish_stream(rng, 2000)
    key = jax.random.PRNGKey(2)
    lin = hh.init_hierarchy(hspec, key)
    cons = hh.init_hierarchy(hspec, key)
    lin = hh.update_jit(hspec, lin, jnp.asarray(items), jnp.asarray(freqs))
    cons = hh.update_conservative_jit(hspec, cons, jnp.asarray(items),
                                      jnp.asarray(freqs))
    for sl, sc in zip(lin.states, cons.states):
        assert (np.asarray(sc.table) <= np.asarray(sl.table)).all()
        assert np.asarray(sc.table).sum() > 0


def test_endpoint_conservative_is_single_shard():
    """Acceptance: the serving endpoint rejects conservative mode when
    sharded (merge_from, both directions) but serves top-k normally."""
    spec = sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (64, 64), 3)
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(5)
    items, freqs = _zipfish_stream(rng, 2000)

    cons = SketchTopKEndpoint(spec, key, mode="conservative")
    lin = SketchTopKEndpoint(spec, key)
    cons.ingest(items, freqs)
    lin.ingest(items, freqs)
    with pytest.raises(ValueError, match="linear endpoints"):
        cons.merge_from(lin)
    with pytest.raises(ValueError, match="linear endpoints"):
        lin.merge_from(cons)
    with pytest.raises(ValueError, match="non-negative"):
        cons.ingest(items[:4], np.array([1, -1, 1, 1]))
    with pytest.raises(ValueError, match="table range"):
        cons.ingest(items[:4], np.full(4, 1 << 31, np.int64))
    with pytest.raises(ValueError, match="mode"):
        SketchTopKEndpoint(spec, key, mode="nope")

    ti, te = cons.topk(5, min_threshold=1)
    li, le = lin.topk(5, min_threshold=1)
    assert ti.shape == (5, 2)
    # conservative estimates of the reported head never exceed linear's
    assert te.sum() <= le.sum()
    # and the true heaviest key is still ranked first
    tf = _true_freqs(items, freqs)
    top_true = items[np.argmax(tf)]
    assert tuple(ti[0]) == tuple(top_true)
