"""streams/stats.py + streams/sampling.py: the evaluation-side helpers.

These feed the live-accuracy harness (streams/dstream.py) and the paper's
sampling pipeline, so their edge cases (empty query sets, zero truth,
capacity boundaries) must be pinned down, not just the happy path.
"""
import numpy as np
import pytest

import jax

from repro.core import sketch as sk
from repro.streams import (
    average_relative_error,
    degree_stats,
    exact_f2,
    exact_marginals,
    observed_error,
    sketch_f2_upper,
    zipf_graph_stream,
)
from repro.streams.sampling import BernoulliSampler, ReservoirSampler


# -- error metrics ----------------------------------------------------------

def test_observed_error_mass_weighted():
    est = np.array([12.0, 5.0, 3.0])
    true = np.array([10.0, 5.0, 5.0])
    assert observed_error(est, true) == pytest.approx(4.0 / 20.0)
    assert observed_error(true, true) == 0.0


def test_average_relative_error_per_key():
    est = np.array([12.0, 5.0, 3.0])
    true = np.array([10.0, 5.0, 6.0])
    # mean(0.2, 0.0, 0.5): each key counts equally, unlike observed_error
    assert average_relative_error(est, true) == pytest.approx(0.7 / 3.0)


def test_average_relative_error_edge_cases():
    assert average_relative_error(np.array([]), np.array([])) == 0.0
    # zero-truth rows floor the denominator at 1 instead of dividing by 0
    assert average_relative_error(np.array([3.0]),
                                  np.array([0.0])) == pytest.approx(3.0)
    with pytest.raises(ValueError, match="shape"):
        average_relative_error(np.array([1.0, 2.0]), np.array([1.0]))


def test_exact_f2():
    assert exact_f2(np.array([3, 4])) == 25.0
    assert exact_f2(np.array([])) == 0.0


def test_sketch_f2_upper_manual_table():
    # row 0: keys 3 and 4 collide in one cell -> (3+4)^2 + 0 = 49
    # row 1: they land apart -> 9 + 16 = 25 = exact F2; min picks row 1
    table = np.array([[7.0, 0.0], [3.0, 4.0]])
    assert sketch_f2_upper(table) == 25.0
    with pytest.raises(ValueError, match="w, h"):
        sketch_f2_upper(np.zeros(8))


def test_sketch_f2_upper_bounds_exact_f2():
    """Row-min of sum-of-squares >= F2 on a real linearly built table."""
    stream = zipf_graph_stream(n_src=200, n_tgt=300, n_edges=1_500,
                               n_occurrences=10_000, seed=5)
    spec = sk.count_min_spec(stream.schema, 256, 3)
    state = sk.build_sketch(spec, jax.random.PRNGKey(0),
                            stream.items, stream.freqs)
    f2 = exact_f2(stream.freqs)
    assert sketch_f2_upper(np.asarray(state.table)) >= f2 > 0.0


# -- exact ground-truth helpers --------------------------------------------

def test_exact_marginals():
    items = np.array([[1, 10], [1, 20], [2, 10]], dtype=np.uint32)
    freqs = np.array([5, 7, 2])
    # marginal over module 0: key 1 carries 12, key 2 carries 2
    assert exact_marginals(items, freqs, [0]).tolist() == [12.0, 12.0, 2.0]
    # full-key marginal is the frequency itself
    assert exact_marginals(items, freqs, [0, 1]).tolist() == [5.0, 7.0, 2.0]


def test_degree_stats():
    items = np.array([[1, 10], [1, 20], [2, 10]], dtype=np.uint32)
    freqs = np.array([5, 7, 2])
    stats = degree_stats(items, freqs)
    assert stats["n_sources"] == 2
    assert stats["n_targets"] == 2
    assert stats["total"] == 14
    assert stats["max_freq"] == 7
    assert stats["distinct"] == 3


# -- Bernoulli thinning -----------------------------------------------------

def test_bernoulli_sampler_validates_p():
    with pytest.raises(ValueError, match="p in"):
        BernoulliSampler(0.0)
    with pytest.raises(ValueError, match="p in"):
        BernoulliSampler(1.5)


def test_bernoulli_sampler_p1_keeps_everything():
    s = BernoulliSampler(1.0)
    items = np.array([[1, 2], [3, 4]], dtype=np.uint32)
    freqs = np.array([5, 7])
    s.offer(items, freqs)
    got_items, got_freqs = s.sample()
    assert np.array_equal(got_items, items)
    assert np.array_equal(got_freqs, freqs)


def test_bernoulli_sampler_thins_mass():
    s = BernoulliSampler(0.1, seed=1)
    items = np.arange(2_000, dtype=np.uint32).reshape(-1, 2)
    freqs = np.full(1_000, 50)
    s.offer(items, freqs)
    _, got_freqs = s.sample()
    kept = got_freqs.sum()
    assert 0 < kept < freqs.sum()
    # binomial mean 5000, sd ~67: a seeded draw sits well inside 10 sd
    assert abs(kept - 5_000) < 670


def test_bernoulli_sampler_empty():
    got_items, got_freqs = BernoulliSampler(0.5).sample()
    assert got_items.shape[0] == 0 and got_freqs.shape == (0,)


# -- weighted reservoir -----------------------------------------------------

def test_reservoir_under_capacity_keeps_everything():
    r = ReservoirSampler(capacity=10)
    items = np.array([[1, 2], [3, 4]], dtype=np.uint32)
    freqs = np.array([5, 7])
    r.offer(items, freqs)
    got_items, got_freqs = r.sample()
    order = np.argsort(got_items[:, 0])
    assert np.array_equal(got_items[order], items)
    assert np.array_equal(got_freqs[order], freqs)


def test_reservoir_respects_capacity():
    r = ReservoirSampler(capacity=16, seed=2)
    for start in range(0, 300, 100):
        items = np.arange(2 * start, 2 * (start + 100),
                          dtype=np.uint32).reshape(-1, 2)
        r.offer(items, np.ones(100, dtype=np.int64))
    got_items, got_freqs = r.sample()
    assert got_items.shape == (16, 2)
    assert got_freqs.shape == (16,)


def test_reservoir_weight_bias():
    """A-ES priorities u**(1/w): one overwhelming weight survives any
    seeded draw against a sea of weight-1 rows."""
    r = ReservoirSampler(capacity=8, seed=3)
    light = np.arange(400, dtype=np.uint32).reshape(-1, 2)
    r.offer(light, np.ones(200, dtype=np.int64))
    heavy = np.array([[9999, 9999]], dtype=np.uint32)
    r.offer(heavy, np.array([10_000]))
    got_items, _ = r.sample()
    assert (9999, 9999) in {tuple(row) for row in got_items.tolist()}


def test_reservoir_empty():
    got_items, got_freqs = ReservoirSampler(capacity=4).sample()
    assert got_items.shape[0] == 0 and got_freqs.shape == (0,)


# -- hierarchy point scoring (the shared twin-scoring helper) ---------------

def _built_hierarchy(partition, seed=11):
    from repro.serving.sketch_engine import SketchTopKEndpoint
    from repro.streams import zipf_hh_workload

    stream = zipf_hh_workload(n_src=80, n_tgt=160, n_edges=600,
                              n_occurrences=3_000, seed=seed).stream
    spec = sk.mod_sketch_spec(stream.schema, partition, (16, 16), 4)
    ep = SketchTopKEndpoint(spec, jax.random.PRNGKey(0))
    ep.ingest(stream.items, stream.freqs)
    return stream, ep


def test_hierarchy_point_estimates_match_direct_finest_query():
    import jax.numpy as jnp

    from repro.streams.stats import hierarchy_point_estimates

    stream, ep = _built_hierarchy([(0,), (1,)])
    q = stream.items[:32]
    got = hierarchy_point_estimates(ep.hspec, ep.state, q)
    level_items = ep.hspec.level_items(
        ep.hspec.n_levels - 1, np.asarray(q, np.uint32))
    want = np.asarray(sk.query(
        ep.hspec.levels[-1], ep.state.states[-1],
        jnp.asarray(np.ascontiguousarray(level_items))), dtype=np.float64)
    assert np.array_equal(got, want)


def test_hierarchy_point_estimates_respect_module_order():
    """A partition out of schema order must be remapped, not queried raw."""
    from repro.streams.stats import hierarchy_point_estimates

    stream, ep = _built_hierarchy([(1,), (0,)])
    q = stream.items[:64]
    est = hierarchy_point_estimates(ep.hspec, ep.state, q)
    # CM never under-estimates: only true with the correct column mapping
    truth = {}
    for row, f in zip(stream.items.tolist(), stream.freqs.tolist()):
        truth[tuple(row)] = truth.get(tuple(row), 0) + int(f)
    true = np.array([truth[tuple(r)] for r in q.tolist()], dtype=np.float64)
    assert np.all(est >= true)


def test_topk_point_are_arg_order():
    """ARE must be relative to the TRUE frequencies (est, true order)."""
    from repro.streams.stats import (hierarchy_point_estimates,
                                     topk_point_are)

    stream, ep = _built_hierarchy([(0,), (1,)])
    q = stream.items[:32]
    truth = {}
    for row, f in zip(stream.items.tolist(), stream.freqs.tolist()):
        truth[tuple(row)] = truth.get(tuple(row), 0) + int(f)
    true = np.array([truth[tuple(r)] for r in q.tolist()], dtype=np.float64)
    est = hierarchy_point_estimates(ep.hspec, ep.state, q)
    want = average_relative_error(est, true)
    assert topk_point_are(ep.hspec, ep.state, q, true) == pytest.approx(want)
