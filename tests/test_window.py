"""Windowed hierarchies + windowed serving: bit-exactness and expiry.

The contracts under test (docs/architecture.md, "Window vs recompute"):

  * merged window tables are bit-identical to a hierarchy rebuilt from
    scratch over exactly the live epochs' blocks, for all three modes
    (decay compares against a reference replaying the identical Horner
    recurrence, so even the float tables match bitwise);
  * the incremental running window sum (add on ingest, subtract on
    expiry) equals the lazy re-sum, tables and top-k;
  * a landmark window is the since-boot endpoint, bit for bit;
  * the descent keeps its no-false-negative guarantee across epoch
    expiry (property-checked over zipf and ngram streams);
  * conservative tables are refused at every windowed entry point;
  * merge_from composes aligned windowed shards exactly and refuses
    mismatched specs/clocks.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import given, settings, st
from repro.core import sketch as sk
from repro.core import window as win
from repro.serving.engine import SketchTopKEndpoint
from repro.serving.windowed_topk import WindowedTopKService
from repro.streams import (
    DStreamHarness,
    ExactWindowCounter,
    ngram_hh_workload,
    timestamped_batches,
    zipf_hh_workload,
)

KEY = jax.random.PRNGKey(7)


@functools.lru_cache(maxsize=None)
def _workload(which: str):
    if which == "zipf":
        wl = zipf_hh_workload(n_src=500, n_tgt=800, n_edges=3_000,
                              n_occurrences=20_000, seed=3)
    else:
        wl = ngram_hh_workload(vocab_size=128, n=2, n_sequences=16,
                               seq_len=128, seed=3)
    spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (32, 32), 3)
    return wl, spec


def _epoch_blocks(stream, n_epochs: int):
    """Cut the compressed stream into one block per epoch."""
    edges = np.linspace(0, len(stream.items), n_epochs + 1).astype(int)
    return [(stream.items[s:e], stream.freqs[s:e])
            for s, e in zip(edges[:-1], edges[1:])]


def _drive(wspec, blocks, *, dtype=None):
    """Raw core/window.py loop: one block per epoch, advance between."""
    state = win.init_window(wspec, KEY, dtype=dtype)
    for b, (it, fr) in enumerate(blocks):
        if b:
            state = win.advance_window(wspec, state)
        state = win.window_update(wspec, state, it, fr)
    return state


def _tables(hier_state):
    return [np.asarray(s.table) for s in hier_state.states]


# -- merged window vs recompute-from-scratch oracle ------------------------

@pytest.mark.parametrize("mode,decay", [("tumbling", 1.0),
                                        ("landmark", 1.0),
                                        ("decay", 0.5)])
def test_merged_window_bitexact_vs_reference(mode, decay):
    wl, spec = _workload("zipf")
    n_epochs, total_epochs = 3, 7
    wspec = win.WindowSpec(base=spec, n_epochs=n_epochs, mode=mode,
                           decay=decay)
    blocks = _epoch_blocks(wl.stream, total_epochs)
    state = _drive(wspec, blocks)
    assert state.epoch == total_epochs - 1
    # live = the last n_epochs epochs (landmark keeps everything)
    live = blocks if mode == "landmark" else blocks[-n_epochs:]
    ref = win.reference_window_state(wspec, KEY, live)
    got, want = _tables(win.merged_state(wspec, state)), _tables(ref)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.dtype == w.dtype
        assert np.array_equal(g, w)   # bitwise, floats included


def test_merged_window_before_ring_wraps():
    """Fewer epochs than the ring holds: only ever-used slots count
    (Horner weights depend on the number of folded terms)."""
    wl, spec = _workload("zipf")
    wspec = win.WindowSpec(base=spec, n_epochs=5, mode="decay", decay=0.25)
    blocks = _epoch_blocks(wl.stream, 2)
    state = _drive(wspec, blocks)
    assert win.live_slots(wspec, state) == (0, 1)
    ref = win.reference_window_state(wspec, KEY, blocks)
    for g, w in zip(_tables(win.merged_state(wspec, state)), _tables(ref)):
        assert np.array_equal(g, w)


# -- incremental running sum vs lazy resum ---------------------------------

def test_incremental_service_equals_lazy():
    wl, spec = _workload("zipf")
    svc_inc = WindowedTopKService(spec, KEY, n_epochs=3, incremental=True)
    svc_lazy = WindowedTopKService(spec, KEY, n_epochs=3, incremental=False)
    for b, (it, fr) in enumerate(_epoch_blocks(wl.stream, 7)):
        if b:
            svc_inc.advance()
            svc_lazy.advance()
        svc_inc.ingest(it, fr)
        svc_lazy.ingest(it, fr)
    for g, w in zip(_tables(svc_inc.state()), _tables(svc_lazy.state())):
        assert np.array_equal(g, w)
    items_i, est_i = svc_inc.topk(10)
    items_l, est_l = svc_lazy.topk(10)
    assert np.array_equal(items_i, items_l)
    assert np.array_equal(est_i, est_l)


def test_decay_service_forces_lazy_merge():
    _, spec = _workload("zipf")
    svc = WindowedTopKService(spec, KEY, n_epochs=3, window_mode="decay",
                              decay=0.5, incremental=True)
    assert not svc.incremental   # no cheap incremental form under decay


# -- landmark == since-boot endpoint ---------------------------------------

def test_landmark_window_is_since_boot_endpoint():
    wl, spec = _workload("zipf")
    svc = WindowedTopKService(spec, KEY, n_epochs=3, window_mode="landmark")
    endpoint = SketchTopKEndpoint(spec, KEY)
    for b, (it, fr) in enumerate(_epoch_blocks(wl.stream, 7)):
        if b:
            svc.advance()
        svc.ingest(it, fr)
        endpoint.ingest(it, fr)
    assert svc.total == endpoint.total
    for g, w in zip(_tables(svc.state()), _tables(endpoint.state)):
        assert np.array_equal(g, w)
    items_s, est_s = svc.topk(10)
    items_e, est_e = endpoint.topk(10)
    # identical tables => identical per-key estimates; equal-estimate ties
    # may order differently (the two surfaces' candidate pools iterate in
    # different orders), so compare as key -> estimate maps
    assert np.array_equal(np.sort(est_s), np.sort(est_e))
    assert ({tuple(k): int(e) for k, e in zip(items_s.tolist(), est_s)}
            == {tuple(k): int(e) for k, e in zip(items_e.tolist(), est_e)})


# -- no false negatives across epoch expiry --------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4), st.sampled_from(["zipf", "ngram"]))
def test_no_false_negative_across_expiry(n_epochs, which):
    """Every exact heavy hitter of the LIVE window is reported, even after
    the ring has expired as many epochs as it holds: expired epochs take
    their candidate pools with them, but every live key sits in a live
    pool and CM estimates of the live window never under-count."""
    wl, spec = _workload(which)
    svc = WindowedTopKService(spec, KEY, n_epochs=n_epochs)
    blocks = _epoch_blocks(wl.stream, 2 * n_epochs)
    for b, (it, fr) in enumerate(blocks):
        if b:
            svc.advance()
        svc.ingest(it, fr)
    live_it = np.concatenate([b[0] for b in blocks[-n_epochs:]], axis=0)
    live_fr = np.concatenate([b[1] for b in blocks[-n_epochs:]])
    uniq, inv = np.unique(live_it, axis=0, return_inverse=True)
    tot = np.bincount(inv, weights=live_fr.astype(np.float64))
    threshold = max(2, int(0.005 * tot.sum()))
    exact = {tuple(r) for r, f in zip(uniq.tolist(), tot) if f >= threshold}
    got_items, got_est = svc.heavy_hitters(threshold)
    got = {tuple(r) for r in got_items.tolist()}
    assert exact <= got, f"false negatives: {sorted(exact - got)[:5]}"
    assert np.all(got_est >= threshold)


def test_expired_keys_leave_the_candidate_sets():
    """A key seen ONLY in expired epochs cannot re-enter the descent."""
    _, spec = _workload("zipf")
    svc = WindowedTopKService(spec, KEY, n_epochs=2)
    dead = np.array([[7, 9]], dtype=np.uint32)
    svc.ingest(dead, np.array([1000]))
    for _ in range(2):                      # expire the epoch that saw it
        svc.advance()
        svc.ingest(np.array([[1, 2], [3, 4]], dtype=np.uint32),
                   np.array([5, 6]))
    for cand in svc.candidates():
        assert not any(tuple(r) in {(7,), (9,)} for r in cand.tolist())
    items, _ = svc.heavy_hitters(1)
    assert (7, 9) not in {tuple(r) for r in items.tolist()}


# -- conservative refusal ---------------------------------------------------

def test_windowed_surfaces_refuse_conservative():
    _, spec = _workload("zipf")
    wspec = win.WindowSpec(base=spec, n_epochs=2)
    with pytest.raises(ValueError, match="linear"):
        win.init_window(wspec, KEY, mode="conservative")
    state = win.init_window(wspec, KEY)
    with pytest.raises(ValueError, match="linear"):
        win.window_update(wspec, state, np.zeros((1, 2), np.uint32),
                          np.ones(1), mode="conservative")
    with pytest.raises(ValueError, match="linear"):
        WindowedTopKService(spec, KEY, n_epochs=2, mode="conservative")


def test_window_spec_validation():
    _, spec = _workload("zipf")
    with pytest.raises(ValueError, match="n_epochs"):
        win.WindowSpec(base=spec, n_epochs=0)
    with pytest.raises(ValueError, match="mode"):
        win.WindowSpec(base=spec, n_epochs=2, mode="sliding")
    with pytest.raises(ValueError, match="decay"):
        win.WindowSpec(base=spec, n_epochs=2, mode="decay", decay=0.0)
    with pytest.raises(ValueError, match="float"):
        win.init_window(win.WindowSpec(base=spec, n_epochs=2, mode="decay",
                                       decay=0.5), KEY, dtype=jnp.int32)


# -- windowed sharding (merge_from) ----------------------------------------

def test_merge_from_equals_single_service():
    wl, spec = _workload("zipf")
    single = WindowedTopKService(spec, KEY, n_epochs=3)
    shard_a = WindowedTopKService(spec, KEY, n_epochs=3)
    shard_b = WindowedTopKService(spec, KEY, n_epochs=3)
    for b, (it, fr) in enumerate(_epoch_blocks(wl.stream, 5)):
        if b:
            for s in (single, shard_a, shard_b):
                s.advance()
        half = len(it) // 2
        single.ingest(it, fr)
        shard_a.ingest(it[:half], fr[:half])
        shard_b.ingest(it[half:], fr[half:])
    shard_a.merge_from(shard_b)
    assert shard_a.total == single.total
    for g, w in zip(_tables(shard_a.state()), _tables(single.state())):
        assert np.array_equal(g, w)
    items_m, est_m = shard_a.topk(10)
    items_s, est_s = single.topk(10)
    assert np.array_equal(items_m, items_s)
    assert np.array_equal(est_m, est_s)


def test_merge_from_refuses_mismatches():
    _, spec = _workload("zipf")
    a = WindowedTopKService(spec, KEY, n_epochs=3)
    with pytest.raises(ValueError, match="WindowSpec"):
        a.merge_from(WindowedTopKService(spec, KEY, n_epochs=4))
    drifted = WindowedTopKService(spec, KEY, n_epochs=3)
    drifted.advance()
    with pytest.raises(ValueError, match="aligned"):
        a.merge_from(drifted)
    other_key = WindowedTopKService(spec, jax.random.PRNGKey(99), n_epochs=3)
    with pytest.raises(ValueError, match="hash params"):
        a.merge_from(other_key)


# -- streaming harness ------------------------------------------------------

def test_dstream_harness_reports():
    wl, spec = _workload("zipf")
    svc = WindowedTopKService(spec, KEY, n_epochs=2)
    harness = DStreamHarness(svc, k=16, phi=0.005, sample_p=0.5)
    reports = harness.run(timestamped_batches(
        wl.stream.items, wl.stream.freqs, n_batches=6, batches_per_epoch=2))
    assert len(reports) == 6
    assert [r.epoch for r in reports] == [0, 0, 1, 1, 2, 2]
    for r in reports:
        assert r.recall == 1.0          # exact-candidate pools, CM >= true
        assert 0.0 < r.precision <= 1.0
        assert r.are_topk >= 0.0
        assert r.f2_est >= r.f2_exact > 0.0   # row-min bound from above
        assert r.f2_rel_err >= 0.0
        assert r.window_total > 0
    s_items, s_freqs = harness.sample()
    assert s_items.shape[1] == wl.stream.items.shape[1]
    assert 0 < s_freqs.sum() <= wl.stream.total


def test_dstream_harness_rejects_time_travel():
    from repro.streams import Batch
    _, spec = _workload("zipf")
    harness = DStreamHarness(WindowedTopKService(spec, KEY, n_epochs=2))
    harness.step(Batch(t=2, items=np.array([[1, 2]], np.uint32),
                       freqs=np.array([1])))
    with pytest.raises(ValueError, match="non-decreasing"):
        harness.step(Batch(t=1, items=np.array([[1, 2]], np.uint32),
                           freqs=np.array([1])))


def test_exact_window_counter_decay_weighting():
    c = ExactWindowCounter(n_epochs=3, mode="decay", decay=0.5)
    c.ingest(np.array([[1, 1]], np.uint32), np.array([8]))
    c.advance()
    c.ingest(np.array([[1, 1], [2, 2]], np.uint32), np.array([4, 2]))
    c.advance()
    c.ingest(np.array([[2, 2]], np.uint32), np.array([6]))
    # ages: 2, 1, 0 -> weights 0.25, 0.5, 1.0
    assert c.window_counts() == {(1, 1): 8 * 0.25 + 4 * 0.5,
                                 (2, 2): 2 * 0.5 + 6 * 1.0}
