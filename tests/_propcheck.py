"""Property-test shim: hypothesis when installed, deterministic fallback
otherwise.

The tier-1 suite must collect and run in containers without the
``hypothesis`` package (this image bakes only the jax_pallas toolchain).
Instead of ``pytest.importorskip`` silently dropping the property tests,
this module re-exports ``given / settings / st`` from hypothesis when it is
importable and otherwise substitutes a minimal deterministic runner:

  * each ``@given`` test runs on a fixed number of examples drawn from a
    seeded PRNG (same values every run, no shrinking, no database);
  * the first two examples pin every strategy to its lower/upper boundary,
    so the classic edge cases (0, max, first/last choice) are always hit;
  * only the strategies this repo uses are implemented
    (``st.integers``, ``st.sampled_from``).

Tests import ``from _propcheck import given, settings, st`` and are
oblivious to which implementation they got.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _N_EXAMPLES = 30
    _SEED = 0x5EED

    class _Strategy:
        def __init__(self, low, high, draw):
            self.low = low          # boundary example 0
            self.high = high        # boundary example 1
            self._draw = draw       # rng -> value

        def example(self, i: int, rng: np.random.Generator):
            if i == 0:
                return self.low
            if i == 1:
                return self.high
            return self._draw(rng)

    class st:  # noqa: N801 - mimics hypothesis.strategies module
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                min_value, max_value,
                lambda rng: int(rng.integers(min_value, max_value,
                                             endpoint=True)))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            elements = list(elements)
            return _Strategy(
                elements[0], elements[-1],
                lambda rng: elements[rng.integers(len(elements))])

    def given(*strategies):
        def decorate(fn):
            # no functools.wraps: the zero-arg signature must be visible to
            # pytest, else the example parameters look like fixtures
            def run():
                rng = np.random.default_rng(_SEED)
                for i in range(_N_EXAMPLES):
                    fn(*(s.example(i, rng) for s in strategies))
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return decorate

    def settings(**_kw):
        def decorate(fn):
            return fn
        return decorate
