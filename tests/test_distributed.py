"""Distributed sketch + small-mesh dry-run (subprocess: own device count).

These spawn a fresh interpreter with XLA_FLAGS host-device overrides so the
main test process keeps its single-device view (per the dry-run contract).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_sharded_sketch_build_equals_serial():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import sketch as sk, distributed as dist
        from repro.core.hashing import KeySchema

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        schema = KeySchema(domains=(1 << 20, 1 << 20))
        spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (32, 64), 4)
        params = sk.init_params(spec, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        items = rng.integers(0, 1 << 20, size=(4096, 2), dtype=np.int64).astype(np.uint32)
        freqs = rng.integers(1, 9, size=(4096,)).astype(np.int32)

        merged = dist.sharded_build(spec, params, mesh, ("data",),
                                    jnp.asarray(items), jnp.asarray(freqs))
        serial = sk.update_jit(spec, sk.SketchState(params=params,
            table=jnp.zeros((4, spec.table_size), jnp.int32)),
            jnp.asarray(items), jnp.asarray(freqs))
        assert (np.asarray(merged) == np.asarray(serial.table)).all(), "merge mismatch"

        # row-sharded query: w=4 rows over model axis of size 2
        tbl = jax.device_put(serial.table, NamedSharding(mesh, P("model")))
        est = dist.row_sharded_query(spec, mesh, "model", params, tbl,
                                     jnp.asarray(items[:64]))
        want = sk.query_jit(spec, serial, jnp.asarray(items[:64]))
        assert (np.asarray(est) == np.asarray(want)).all(), "query mismatch"
        print("distributed sketch OK")
    """))


def test_lazy_local_tables_merge():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import sketch as sk, distributed as dist
        from repro.core.hashing import KeySchema

        mesh = jax.make_mesh((8,), ("data",))
        schema = KeySchema(domains=(4096, 4096))
        spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (16, 16), 3)
        params = sk.init_params(spec, jax.random.PRNGKey(1))
        rng = np.random.default_rng(1)
        local = jnp.zeros((8, 3, spec.table_size), jnp.int32)
        all_items, all_freqs = [], []
        for step in range(3):
            items = rng.integers(0, 4096, size=(1024, 2)).astype(np.uint32)
            freqs = np.ones(1024, np.int32)
            local = dist.lazy_local_update(spec, mesh, ("data",), local,
                params, jnp.asarray(items), jnp.asarray(freqs))
            all_items.append(items); all_freqs.append(freqs)
        merged = dist.merge_local_tables(mesh, ("data",), local)
        serial = sk.update_jit(spec, sk.SketchState(params=params,
            table=jnp.zeros((3, spec.table_size), jnp.int32)),
            jnp.asarray(np.concatenate(all_items)),
            jnp.asarray(np.concatenate(all_freqs)))
        assert (np.asarray(merged) == np.asarray(serial.table)).all()
        print("lazy merge OK")
    """))


def test_small_mesh_dryrun_train_and_decode():
    """The dry-run machinery on a small (2,2,2) pod mesh with reduced archs:
    lowering + compile + loop-aware roofline must succeed end to end."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        import dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import hlo_analysis as ha
        from repro.configs import get_reduced
        from repro.launch import specs as sp
        from repro.models import sharding as shd, shard_ctx, transformer as tfm
        from repro.training import train_loop as tl

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        for arch in ("gemma2-9b", "mixtral-8x22b", "mamba2-130m"):
            cfg = get_reduced(arch)
            tcfg = tl.TrainConfig()
            state_sds = sp.train_state_specs(cfg, tcfg)
            batch_sds = sp.batch_input_specs(cfg, 8, 64)
            pspecs = shd.param_specs(cfg, state_sds["params"], mesh)
            state_specs = {
                "params": pspecs,
                "opt": shd.opt_state_specs(cfg, state_sds["opt"], pspecs, mesh),
                "sketch_params": jax.tree.map(lambda _: P(), state_sds["sketch_params"]),
                "sketch_table": P(),
            }
            bspecs = shd.sanitize_specs(shd.batch_specs(cfg, mesh, False),
                                        batch_sds, mesh)
            fn = jax.jit(tl.make_train_step(cfg, tcfg),
                         in_shardings=(shd.to_shardings(mesh, state_specs),
                                       shd.to_shardings(mesh, bspecs)),
                         out_shardings=(shd.to_shardings(mesh, state_specs), None),
                         donate_argnums=(0,))
            with shard_ctx.activation_sharding(mesh):
                compiled = fn.lower(state_sds, batch_sds).compile()
            cost = ha.analyze(compiled.as_text())
            assert cost.flops > 0, arch
            print(arch, "train ok: flops %.2e wire %.2e" % (cost.flops, cost.coll_wire_bytes))

            # decode step
            params_sds = jax.eval_shape(lambda k: tfm.init_params(cfg, k),
                                        jax.random.PRNGKey(0))
            din = sp.decode_input_specs(cfg, 8, 128)
            cspecs = shd.cache_specs(cfg, din["cache"], mesh, 8)
            fn2 = jax.jit(lambda p, c, t, pos: tfm.decode_step(cfg, p, c, t, pos),
                          in_shardings=(shd.to_shardings(mesh, shd.param_specs(cfg, params_sds, mesh)),
                                        shd.to_shardings(mesh, cspecs),
                                        NamedSharding(mesh, P(("pod","data"), None)),
                                        NamedSharding(mesh, P())),
                          out_shardings=(None, shd.to_shardings(mesh, cspecs)),
                          donate_argnums=(1,))
            with shard_ctx.activation_sharding(mesh):
                c2 = fn2.lower(params_sds, din["cache"], din["tokens_last"], din["pos"]).compile()
            print(arch, "decode ok")
        print("small-mesh dryrun OK")
    """, devices=8))


def test_moe_local_dispatch_matches_global_when_dropless():
    print(_run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models import moe as moe_mod, shard_ctx
        cfg = get_reduced("mixtral-8x22b")
        p = moe_mod.make_moe_params(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32).astype(cfg.activation_dtype)
        y_global, _ = moe_mod.apply_moe(cfg, p, x)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg_l = dataclasses.replace(cfg, moe_dispatch="local")
        with shard_ctx.activation_sharding(mesh):
            y_local, aux = jax.jit(
                lambda p, x: moe_mod.apply_moe(cfg_l, p, x))(p, x)
        np.testing.assert_allclose(np.asarray(y_global, np.float32),
                                   np.asarray(y_local, np.float32),
                                   rtol=5e-2, atol=5e-2)
        assert float(aux["dropped_frac"]) == 0.0
        print("moe local dispatch numerics OK")
    """))


def test_moe_ep_shardmap_matches_global_when_dropless():
    print(_run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models import moe as moe_mod, shard_ctx
        cfg = get_reduced("mixtral-8x22b")
        p = moe_mod.make_moe_params(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32).astype(cfg.activation_dtype)
        y_global, _ = moe_mod.apply_moe(cfg, p, x)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg_ep = dataclasses.replace(cfg, moe_dispatch="ep_shardmap")
        with shard_ctx.activation_sharding(mesh):
            y_ep, aux = jax.jit(
                lambda p, x: moe_mod.apply_moe(cfg_ep, p, x))(p, x)
        np.testing.assert_allclose(np.asarray(y_global, np.float32),
                                   np.asarray(y_ep, np.float32),
                                   rtol=5e-2, atol=5e-2)
        assert float(aux["dropped_frac"]) == 0.0
        print("moe ep_shardmap numerics OK")
    """))


def test_elastic_remesh():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training.fault_tolerance import elastic_remesh

        big = jax.make_mesh((8,), ("data",))
        small = jax.make_mesh((4,), ("data",))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(big, P("data", None)))
        y = elastic_remesh({"x": x}, small, lambda leaf: P("data", None))
        assert np.asarray(y["x"]).shape == (8, 8)
        assert len(y["x"].sharding.mesh.devices.flatten()) == 4
        print("elastic remesh OK")
    """))
