"""FCM / FMOD (paper SVI-E) and the signed Count-Sketch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import countsketch as cs
from repro.core import sketch as sk
from repro.core.fcm import FCM, MisraGries, fcm_spec, fmod_spec, pack_keys
from repro.core.range_opt import optimal_ranges_mod2
from repro.streams import ipv4_stream, observed_error


def test_misra_gries_guarantee():
    """MG undercount is bounded by L/k; true heavy hitters survive."""
    rng = np.random.default_rng(0)
    k = 16
    mg = MisraGries(k)
    # one heavy key + uniform noise
    heavy = np.full(5000, 7, dtype=np.uint64)
    noise = rng.integers(100, 10_000, size=20_000).astype(np.uint64)
    keys = np.concatenate([heavy, noise])
    rng.shuffle(keys)
    for s in range(0, len(keys), 1000):
        blk = keys[s : s + 1000]
        mg.offer(blk, np.ones(len(blk), np.int64))
    hh = mg.heavy_hitters()
    assert 7 in hh
    L = len(keys)
    assert hh[7] >= 5000 - L / k - 1
    assert len(hh) <= k


def test_fcm_and_fmod_beat_count_min_on_skewed_stream():
    """Fig. 10 ordering: FMOD <= FCM <= Count-Min observed error.

    Evaluated in the paper's regime (heavy overload, tail queries) where
    composite indexing helps -- the same regime dependence as plain
    MOD-vs-CM (EXPERIMENTS.md SRepro, Fig 4 row).
    """
    from repro.streams import zipf_graph_stream
    stream = zipf_graph_stream(n_src=20_000, n_tgt=60_000, n_edges=300_000,
                               n_occurrences=1_500_000, s_src=0.7, s_tgt=0.7,
                               seed=1)
    h, w = 2048, 6
    rng = np.random.default_rng(0)
    s_items, s_freqs = stream.sample(0.03, rng)
    a, b = optimal_ranges_mod2(s_items, s_freqs, h)
    key = jax.random.PRNGKey(0)

    cm_state = sk.build_sketch(sk.count_min_spec(stream.schema, h, w), key,
                               stream.items, stream.freqs)
    fcm = FCM(fcm_spec(stream.schema, h, w, mg_k=512), key)
    fmod = FCM(fmod_spec(stream.schema, [(0,), (1,)], (a, b), w, mg_k=512), key)
    for s in range(0, len(stream.items), 1 << 15):
        blk_i = stream.items[s : s + (1 << 15)]
        blk_f = stream.freqs[s : s + (1 << 15)]
        fcm.update(blk_i, blk_f)
        fmod.update(blk_i, blk_f)

    qi, qf = stream.random_k_queries(500, rng)
    err_cm = observed_error(
        np.asarray(sk.query_jit(sk.count_min_spec(stream.schema, h, w),
                                cm_state, jnp.asarray(qi))), qf)
    err_fcm = observed_error(fcm.query(qi), qf)
    err_fmod = observed_error(fmod.query(qi), qf)
    # frequency-aware hashing reduces error; composite indexing on top of it
    # reduces it further (exact margins are data-dependent)
    assert err_fcm <= err_cm * 1.05
    assert err_fmod <= err_fcm * 1.05


def test_pack_keys_injective():
    from repro.core.hashing import KeySchema
    schema = KeySchema(domains=(100, 100))
    items = np.array([[1, 12], [11, 2], [0, 0], [99, 99]], dtype=np.uint32)
    packed = pack_keys(schema, items)
    assert len(np.unique(packed)) == 4   # the paper's (1,12) vs (11,2) case


# --------------------------------------------------------------------------
# Count-Sketch (signed; gradient-compression primitive)
# --------------------------------------------------------------------------

def test_countsketch_exact_when_sparse():
    from repro.core.hashing import KeySchema
    schema = KeySchema(domains=(1 << 16, 1 << 16))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (64, 64), 5)
    state = cs.init_state(spec, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    items = rng.integers(0, 1 << 16, size=(10, 2), dtype=np.uint64).astype(np.uint32)
    items = np.unique(items, axis=0)
    vals = rng.standard_normal(len(items)).astype(np.float32)
    state = cs.update(spec, state, jnp.asarray(items), jnp.asarray(vals))
    est = np.asarray(cs.query(spec, state, jnp.asarray(items)))
    np.testing.assert_allclose(est, vals, rtol=1e-4, atol=1e-4)


def test_countsketch_unbiased_under_load():
    from repro.core.hashing import KeySchema
    schema = KeySchema(domains=(1 << 16, 1 << 16))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (32, 32), 7)
    rng = np.random.default_rng(1)
    items = rng.integers(0, 1 << 16, size=(5000, 2), dtype=np.uint64).astype(np.uint32)
    items = np.unique(items, axis=0)
    vals = rng.standard_normal(len(items)).astype(np.float32)
    errs = []
    for trial in range(5):
        state = cs.init_state(spec, jax.random.PRNGKey(trial))
        state = cs.update(spec, state, jnp.asarray(items), jnp.asarray(vals))
        est = np.asarray(cs.query(spec, state, jnp.asarray(items[:500])))
        errs.append(np.mean(est - vals[:500]))
    assert abs(np.mean(errs)) < 0.1       # unbiased within noise


def test_countsketch_linearity_and_merge():
    """psum/merge semantics: table(A) + table(B) == table(A ++ B) exactly,
    and turnstile deletions cancel (fold a stream, fold its negation,
    recover zero)."""
    from repro.core.hashing import KeySchema
    schema = KeySchema(domains=(1 << 16, 1 << 16))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (32, 32), 5)
    params = cs.init_params(spec, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    items = rng.integers(0, 1 << 16, size=(800, 2),
                         dtype=np.uint64).astype(np.uint32)
    vals = rng.integers(-100, 100, size=800).astype(np.int32)

    def fold(it, v):
        st = cs.CountSketchState(
            params, jnp.zeros((spec.width, spec.table_size), jnp.int32))
        return cs.update(spec, st, jnp.asarray(it), jnp.asarray(v))

    whole = fold(items, vals)
    merged = cs.merge(fold(items[:300], vals[:300]),
                      fold(items[300:], vals[300:]))
    np.testing.assert_array_equal(np.asarray(whole.table),
                                  np.asarray(merged.table))
    cancelled = cs.merge(whole, fold(items, -vals))
    assert not np.asarray(cancelled.table).any()


def test_countsketch_l2estimate_bounds():
    """AMS row norms: sqrt(median_k ||row_k||^2) tracks ||v||_2 within the
    usual constant-probability multiplicative band."""
    from repro.core.hashing import KeySchema
    schema = KeySchema(domains=(1 << 16, 1 << 16))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (32, 32), 7)
    rng = np.random.default_rng(3)
    items = rng.integers(0, 1 << 16, size=(4000, 2),
                         dtype=np.uint64).astype(np.uint32)
    items = np.unique(items, axis=0)
    vals = rng.standard_normal(len(items)).astype(np.float32)
    true_l2 = float(np.linalg.norm(vals))
    within = 0
    for trial in range(5):
        state = cs.init_state(spec, jax.random.PRNGKey(100 + trial))
        state = cs.update(spec, state, jnp.asarray(items), jnp.asarray(vals))
        est = float(cs.l2estimate(state.table))
        if 0.7 * true_l2 <= est <= 1.4 * true_l2:
            within += 1
    assert within >= 4, within


def test_countsketch_hier_descent_no_false_negatives():
    """Median threshold descent: every planted heavy key whose |value|
    clears 2x the threshold is returned, at every level of the cascade
    (coarse-level pruning must not drop a heavy child)."""
    from repro.core.hashing import KeySchema
    from repro.core.hierarchy import HierarchySpec
    schema = KeySchema(domains=(1 << 16, 1 << 16))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (64, 64), 5)
    hspec = HierarchySpec.from_spec(spec)
    rng = np.random.default_rng(4)
    noise_items = rng.integers(0, 1 << 16, size=(3000, 2),
                               dtype=np.uint64).astype(np.uint32)
    noise_vals = rng.standard_normal(3000).astype(np.float32)
    heavy_items = np.unique(
        rng.integers(0, 1 << 16, size=(12, 2),
                     dtype=np.uint64).astype(np.uint32), axis=0)
    heavy_vals = np.where(np.arange(len(heavy_items)) % 2 == 0,
                          50.0, -50.0).astype(np.float32)

    hier = cs.init_hierarchy(hspec, jax.random.PRNGKey(5))
    hier = cs.hier_update(hspec, hier, jnp.asarray(noise_items),
                          jnp.asarray(noise_vals))
    hier = cs.hier_update(hspec, hier, jnp.asarray(heavy_items),
                          jnp.asarray(heavy_vals))

    all_items = np.concatenate([noise_items, heavy_items])
    cands = [np.unique(all_items[:, :1], axis=0),
             np.unique(all_items[:, 1:], axis=0)]
    found, est = cs.find_heavy_hitters(hspec, hier, 25.0, cands)
    fs = {tuple(x) for x in found}
    for it, v in zip(heavy_items, heavy_vals):
        assert tuple(it) in fs, (it, v)
    # signed estimates at the found heavy keys carry the right sign
    lookup = {tuple(i): e for i, e in zip(map(tuple, found), est)}
    for it, v in zip(heavy_items, heavy_vals):
        assert np.sign(lookup[tuple(it)]) == np.sign(v)
