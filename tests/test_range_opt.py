"""Thm 3 range optimization: paper Example 1, split formulas, recursion."""
import numpy as np
import pytest

from repro.core.range_opt import (
    aggregate_alpha,
    aggregate_sample,
    estimate_alpha,
    marginal_per_item,
    optimal_ranges_mod2,
    recursive_ranges,
    split_range,
    weighted_median,
)


def test_paper_example_1_exact():
    """Items (1,2):13, (1,3):5, (2,3):7 -> alpha_agg = 18/13 (SIV-A Ex. 1)."""
    items = np.array([[1, 2], [1, 3], [2, 3]], dtype=np.uint32)
    freqs = np.array([13, 5, 7], dtype=np.int64)
    uniq, f = aggregate_sample(items, freqs)
    m1 = marginal_per_item(uniq, f, [0])
    m2 = marginal_per_item(uniq, f, [1])
    alphas = {tuple(i): a for i, a in zip(uniq.tolist(), (m1 / m2).tolist())}
    assert alphas[(1, 2)] == pytest.approx(18 / 13)
    assert alphas[(1, 3)] == pytest.approx(18 / 12)
    assert alphas[(2, 3)] == pytest.approx(7 / 12)
    agg = estimate_alpha(items, freqs, [0], [1], agg="median")
    assert agg == pytest.approx(18 / 13)


def test_paper_split_example():
    """h=360000, O(*,x2) = 2*O(x1,*) => beta=2 => a~848, b~424 (SIV-A)."""
    a, b = split_range(360_000, 2.0)
    assert abs(a - 849) <= 1 and abs(b - 424) <= 1
    assert abs(a / b - 2.0) < 0.02
    assert abs(a * b - 360_000) / 360_000 < 0.01


def test_weighted_median():
    v = np.array([7 / 12, 18 / 13, 18 / 12])
    w = np.array([7.0, 13.0, 5.0])
    assert weighted_median(v, w) == pytest.approx(18 / 13)


def test_aggregates():
    a = np.array([1.0, 2.0, 4.0])
    f = np.array([1.0, 1.0, 1.0])
    assert aggregate_alpha(a, f, "min") == 1.0
    assert aggregate_alpha(a, f, "max") == 4.0
    assert aggregate_alpha(a, f, "mean") == pytest.approx(7 / 3)
    with pytest.raises(ValueError):
        aggregate_alpha(a, f, "mode")


def test_recursive_ranges_product_near_h():
    rng = np.random.default_rng(0)
    items = rng.integers(0, 256, size=(5000, 4)).astype(np.uint32)
    freqs = rng.integers(1, 20, size=(5000,)).astype(np.int64)
    for groups in ([[0], [1], [2], [3]], [[0, 1], [2], [3]], [[0, 2], [1, 3]]):
        ranges = recursive_ranges(items, freqs, groups, 4096.0)
        assert len(ranges) == len(groups)
        prod = float(np.prod(ranges))
        assert 0.4 * 4096 <= prod <= 2.5 * 4096
        assert all(r >= 2 for r in ranges)


def test_beta_direction_tracks_skew():
    """Heavier first-module marginals (alpha > 1) must give a < b (Thm 3)."""
    rng = np.random.default_rng(1)
    # few sources, many targets: O(x1,*) large, alpha > 1 -> beta < 1 -> a < b
    src = rng.integers(0, 20, size=20_000).astype(np.uint32)
    tgt = rng.integers(0, 5000, size=20_000).astype(np.uint32)
    items = np.stack([src, tgt], axis=1)
    freqs = np.ones(20_000, dtype=np.int64)
    a, b = optimal_ranges_mod2(items, freqs, 4096)
    assert a < b
    # flipped skew flips the ranges
    a2, b2 = optimal_ranges_mod2(items[:, ::-1].copy(), freqs, 4096)
    assert a2 > b2


def test_beta_cache_reuse():
    rng = np.random.default_rng(2)
    items = rng.integers(0, 64, size=(2000, 3)).astype(np.uint32)
    freqs = np.ones(2000, dtype=np.int64)
    cache = {}
    r1 = recursive_ranges(items, freqs, [[0], [1], [2]], 512.0, "median", cache)
    n_entries = len(cache)
    assert n_entries >= 1
    r2 = recursive_ranges(items, freqs, [[0], [1], [2]], 512.0, "median", cache)
    assert r1 == r2
    assert len(cache) == n_entries          # all hits, nothing recomputed
