"""Loop-aware HLO cost analysis: exactness on known programs."""
import os
import subprocess
import sys
import textwrap

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_scan_flops_counted_with_trip_count():
    _run("""
        import jax, jax.numpy as jnp
        from repro import hlo_analysis as ha
        d, T, B = 128, 12, 32
        w = jax.ShapeDtypeStruct((T, d, d), jnp.float32)
        x = jax.ShapeDtypeStruct((B, d), jnp.float32)
        def f(w, x):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            y, _ = jax.lax.scan(body, x, w)
            return jnp.sum(y)
        c = jax.jit(jax.grad(f)).lower(w, x).compile()
        cost = ha.analyze(c.as_text())
        # fwd T + bwd 2T matmuls of 2*B*d*d flops each
        want = 3 * T * 2 * B * d * d
        assert 0.9 * want <= cost.flops <= 1.3 * want, (cost.flops, want)

        # XLA's own analysis misses the trip count (documents why ours exists)
        xla = c.cost_analysis()
        if isinstance(xla, (list, tuple)): xla = xla[0]
        assert xla["flops"] < 0.5 * want
    """)


def test_collectives_inside_loops_are_scaled():
    _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import hlo_analysis as ha
        mesh = jax.make_mesh((8,), ("data",))
        T, d = 10, 64
        w = jax.ShapeDtypeStruct((T, d, d), jnp.float32)
        x = jax.ShapeDtypeStruct((32, d), jnp.float32)
        def f(w, x):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            y, _ = jax.lax.scan(body, x, w)
            return jnp.sum(y)
        g = jax.jit(jax.grad(f),
                    in_shardings=(NamedSharding(mesh, P(None)),
                                  NamedSharding(mesh, P("data"))))
        cost = ha.analyze(g.lower(w, x).compile().as_text())
        ar = cost.coll_counts.get("all-reduce", 0)
        assert ar >= T, f"expected >= {T} loop-scaled all-reduces, got {ar}"
    """)


def test_parse_robust_to_tuple_results_and_comments():
    from repro import hlo_analysis as ha
    text = """\
HloModule m

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4] get-tuple-element(%p), index=1
  %y = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,4]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[4,4]) tuple(%z, %a)
  %w = (s32[], /*index=1*/f32[4,4]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[4,4] get-tuple-element(%w), index=1
}
"""
    cost = ha.analyze(text)
    # 7 trips x dot(4x4,4x4) = 896 MXU flops + 7 loop-counter adds (1 each)
    assert 896 <= cost.flops <= 896 + 8, cost.flops
