"""Sketch family invariants: overestimation, linearity, bounds (Thm 1/2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import sketch as sk
from repro.core.hashing import KeySchema


def _true_freqs(items, freqs):
    packed = items[:, 0].astype(np.uint64) << np.uint64(32) | items[:, 1]
    uniq, inv = np.unique(packed, return_inverse=True)
    return np.bincount(inv, weights=freqs.astype(np.float64))[inv]


@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 3, 5]),
       st.sampled_from([(256,), (16, 16), (4, 8, 8)]))
@settings(max_examples=15, deadline=None)
def test_never_underestimates(seed, w, ranges):
    rng = np.random.default_rng(seed)
    schema = KeySchema(domains=(1 << 32, 1 << 32))
    part = [(0, 1)] if len(ranges) == 1 else (
        [(0,), (1,)] if len(ranges) == 2 else [(0,), (1,), (0,)])
    if len(ranges) == 3:  # partition must cover each module exactly once
        part = [(0,), (1,)]
        ranges = (ranges[0] * ranges[1], ranges[2])
    spec = sk.mod_sketch_spec(schema, part, ranges, w)
    items = rng.integers(0, 1 << 32, size=(300, 2), dtype=np.uint64).astype(np.uint32)
    freqs = rng.integers(1, 50, size=(300,)).astype(np.int32)
    st_ = sk.build_sketch(spec, jax.random.PRNGKey(seed % 997), items, freqs)
    est = np.asarray(sk.query_jit(spec, st_, jnp.asarray(items)))
    assert (est >= _true_freqs(items, freqs) - 1e-9).all()


def test_count_min_equals_single_group_mod():
    """CM is the m=1 point of the family: identical spec, identical table."""
    schema = KeySchema(domains=(1 << 32, 1 << 32))
    assert sk.count_min_spec(schema, 1024, 3) == sk.mod_sketch_spec(
        schema, [(0, 1)], (1024,), 3)


def test_merge_linearity_exact():
    rng = np.random.default_rng(7)
    schema = KeySchema(domains=(10_000, 10_000))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (64, 64), 4)
    key = jax.random.PRNGKey(0)
    items = rng.integers(0, 10_000, size=(1000, 2)).astype(np.uint32)
    freqs = rng.integers(1, 9, size=(1000,)).astype(np.int32)
    a = sk.update_jit(spec, sk.init_state(spec, key), jnp.asarray(items[:500]),
                      jnp.asarray(freqs[:500]))
    b = sk.update_jit(spec, sk.init_state(spec, key), jnp.asarray(items[500:]),
                      jnp.asarray(freqs[500:]))
    ab = sk.update_jit(spec, sk.init_state(spec, key), jnp.asarray(items),
                       jnp.asarray(freqs))
    assert (np.asarray(sk.merge(a, b).table) == np.asarray(ab.table)).all()


def test_thm1_error_bound_holds_statistically():
    """Count-Min: est <= true + eps*L w.p. >= 1 - (1/(h*eps))^w (Thm 1)."""
    rng = np.random.default_rng(3)
    schema = KeySchema(domains=(1 << 20, 1 << 20))
    h, w = 2048, 4
    spec = sk.count_min_spec(schema, h, w)
    items = rng.integers(0, 1 << 20, size=(20_000, 2), dtype=np.uint64).astype(np.uint32)
    freqs = np.ones(20_000, dtype=np.int32)
    state = sk.build_sketch(spec, jax.random.PRNGKey(5), items, freqs)
    est = np.asarray(sk.query_jit(spec, state, jnp.asarray(items[:2000])))
    true = _true_freqs(items, freqs)[:2000]
    L = freqs.sum()
    eps = 4.0 / h  # > e/h, so the bound probability is strong
    viol = np.mean(est > true + eps * L)
    assert viol <= (1.0 / (h * eps)) ** w + 0.01


def test_conservative_update_tighter_but_still_overestimates():
    rng = np.random.default_rng(11)
    schema = KeySchema(domains=(4096, 4096))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (32, 32), 3)
    items = rng.integers(0, 4096, size=(2000, 2)).astype(np.uint32)
    freqs = np.ones(2000, dtype=np.int32)
    key = jax.random.PRNGKey(2)
    plain = sk.update_jit(spec, sk.init_state(spec, key), jnp.asarray(items),
                          jnp.asarray(freqs))
    cons = sk.update_conservative(spec, sk.init_state(spec, key),
                                  jnp.asarray(items), jnp.asarray(freqs))
    true = _true_freqs(items, freqs)
    e_plain = np.asarray(sk.query_jit(spec, plain, jnp.asarray(items)))
    e_cons = np.asarray(sk.query(spec, cons, jnp.asarray(items)))
    assert (e_cons >= true - 1e-9).all()
    assert e_cons.sum() <= e_plain.sum()
    assert (e_cons <= e_plain + 1e-9).all()


def test_equal_ranges_respects_space_budget():
    """Regression: the round-and-nudge split overshot the budget badly for
    small h / large n (h=2, n=3 gave prod=8, 4x the allocation).  The
    floor-root split must stay within h everywhere while still tracking it
    from below."""
    for n in (1, 2, 3, 4):
        for h in list(range(2, 70)) + [127, 128, 1000, 1024, 4096, 360000]:
            ranges = sk.equal_ranges(h, n)
            prod = int(np.prod(np.asarray(ranges, dtype=np.int64)))
            assert len(ranges) == n and min(ranges) >= 1
            assert prod <= h, (h, n, ranges)
            assert prod >= max(1, h // 4), (h, n, ranges)  # not degenerate
    # the motivating case: within budget now (was 8 = 4x over)
    assert int(np.prod(sk.equal_ranges(2, 3))) <= 2
    # the well-conditioned points used across the suite are unchanged
    assert sk.equal_ranges(1100, 2) == (33, 33)
    assert sk.equal_ranges(4096, 2) == (64, 64)
    assert sk.equal_ranges(4096, 4) == (8, 8, 8, 8)
    # a spec built from any grid point is valid (ranges >= 1 covers h < 2^n)
    spec = sk.equal_sketch_spec(KeySchema(domains=(4, 4, 4)), 2, 3)
    assert spec.table_size <= 2


def test_spec_validation():
    schema = KeySchema(domains=(100, 100))
    with pytest.raises(ValueError):
        sk.SketchSpec(schema, ((0,),), (10,), 3)          # missing module
    with pytest.raises(ValueError):
        sk.SketchSpec(schema, ((0,), (1,), (0,)), (10, 10, 10), 3)  # dup
    with pytest.raises(ValueError):
        sk.SketchSpec(schema, ((0,), (1,)), (10,), 3)     # range arity


def test_marginal_queries():
    """Composite hashing answers subspace queries (gMatrix/TCM capability):
    O(x1, *) = min over rows of the sum of cells sharing x1's sub-index."""
    rng = np.random.default_rng(13)
    schema = KeySchema(domains=(1 << 20, 1 << 20))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (128, 64), 5)
    src = rng.integers(0, 50, size=5000).astype(np.uint32) * 7919
    tgt = rng.integers(0, 1 << 20, size=5000, dtype=np.int64).astype(np.uint32)
    items = np.stack([src, tgt], axis=1)
    freqs = rng.integers(1, 10, size=5000).astype(np.int32)
    st = sk.build_sketch(spec, jax.random.PRNGKey(0), items, freqs)

    uniq_src = np.unique(src)
    est = np.asarray(sk.query_marginal(spec, st, 0,
                                       jnp.asarray(uniq_src.reshape(-1, 1))))
    true = np.array([freqs[src == s].sum() for s in uniq_src])
    assert (est >= true - 1e-6).all()          # marginal overestimate
    # ranking quality: estimates correlate strongly with true marginals
    corr = np.corrcoef(est, true)[0, 1]
    assert corr > 0.9, corr


def test_strides_mixed_radix():
    schema = KeySchema(domains=(100, 100, 100))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,), (2,)], (5, 7, 11), 2)
    assert spec.strides == (77, 11, 1)
    assert spec.table_size == 385
