"""Stream generators, sampling, n-gram extraction, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as tfm
from repro.serving.engine import Request, ServeConfig, ServeEngine, SlotScheduler
from repro.streams import (
    degree_stats,
    ipv4_stream,
    reinterpret_modularity,
    telecom_stream,
    zipf_graph_stream,
)
from repro.streams.ngram import moe_routing_items, ngram_items, ngram_items_np
from repro.streams.sampling import BernoulliSampler, ReservoirSampler


def test_stream_marginal_asymmetry_directions():
    tw = zipf_graph_stream(n_src=2000, n_tgt=6000, n_edges=30_000,
                           n_occurrences=200_000, seed=0)
    st = degree_stats(tw.items, tw.freqs)
    assert st["n_targets"] > st["n_sources"]          # Twitter-like (Table III)
    ip = ipv4_stream(n_src_hosts=8000, n_tgt_hosts=800, n_pairs=30_000,
                     n_occurrences=200_000, seed=0)
    st2 = degree_stats(ip.items, ip.freqs)
    assert st2["n_sources"] > st2["n_targets"]        # CAIDA-like


def test_sample_is_uniform_thinning():
    s = telecom_stream(n_users=2000, n_calls=20_000, seed=1)
    rng = np.random.default_rng(0)
    items, freqs = s.sample(0.05, rng)
    assert freqs.sum() == pytest.approx(0.05 * s.total, rel=0.1)
    assert (freqs >= 1).all()


def test_reinterpret_modularity_preserves_mass():
    base = ipv4_stream(n_src_hosts=500, n_tgt_hosts=100, n_pairs=3000,
                       n_occurrences=30_000, seed=2)
    for w in (4, 8):
        v = reinterpret_modularity(base, w)
        assert v.schema.modularity == w
        assert v.total == base.total
        assert len(v.items) == len(base.items)
        assert (v.items < (1 << (64 // w))).all()


def test_ngram_items():
    toks = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.uint32)
    bi = np.asarray(ngram_items(toks, 2))
    assert bi.shape == (6, 2)
    assert [1, 2] in bi.tolist() and [7, 8] in bi.tolist()
    assert [4, 5] not in bi.tolist()                  # no cross-row windows
    tri = ngram_items_np(np.asarray(toks), 3)
    assert tri.shape == (4, 3)


def test_moe_routing_items_schema():
    toks = jnp.arange(10, dtype=jnp.int32)
    experts = jnp.stack([jnp.zeros(10, jnp.int32), jnp.ones(10, jnp.int32)],
                        axis=1)
    items = np.asarray(moe_routing_items(toks, experts, n_buckets=8))
    assert items.shape == (20, 2)
    assert set(items[:, 0].tolist()) == {0, 1}
    assert items[:, 1].max() < 8


def test_samplers():
    bs = BernoulliSampler(0.5, seed=0)
    bs.offer(np.arange(1000, dtype=np.uint32).reshape(-1, 1))
    items, freqs = bs.sample()
    assert 300 < freqs.sum() < 700
    rs = ReservoirSampler(100, seed=0)
    rs.offer(np.arange(5000, dtype=np.uint32).reshape(-1, 1))
    items, _ = rs.sample()
    assert len(items) == 100


def test_serve_engine_greedy_deterministic():
    cfg = get_reduced("gemma-7b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_len=48))
    prompts = np.tile(np.arange(16, dtype=np.int32), (2, 1))
    a = eng.generate(prompts, 8)
    b = eng.generate(prompts, 8)
    assert a.shape == (2, 8)
    np.testing.assert_array_equal(a, b)
    # identical prompts -> identical continuations
    np.testing.assert_array_equal(a[0], a[1])


def test_slot_scheduler_completes_all():
    cfg = get_reduced("starcoder2-7b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_len=40))
    sched = SlotScheduler(eng, n_slots=3)
    rng = np.random.default_rng(0)
    for rid in range(7):
        sched.submit(Request(rid=rid,
                             prompt=rng.integers(0, cfg.vocab_size, 12,
                                                 ).astype(np.int32),
                             max_new=5))
    done = sched.run()
    assert len(done) == 7
    assert all(len(r.out) == 5 for r in done)


def test_serve_engine_generation_loop_horizon_consistent():
    """Greedy decode is a deterministic loop: a longer horizon extends the
    shorter one token-for-token (the cache/position bookkeeping does not
    depend on max_new_tokens)."""
    cfg = get_reduced("gemma-7b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_len=48))
    prompts = np.tile(np.arange(12, dtype=np.int32), (2, 1))
    short = eng.generate(prompts, 3)
    long = eng.generate(prompts, 9)
    np.testing.assert_array_equal(short, long[:, :3])
    assert long.shape == (2, 9)
    assert long.dtype == np.int32
    assert np.all((long >= 0) & (long < cfg.vocab_size))


def test_serve_engine_temperature_vs_greedy_sampling():
    cfg = get_reduced("gemma-7b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.tile(np.arange(10, dtype=np.int32), (2, 1))

    # same seed -> bit-identical stochastic generations
    hot_a = ServeEngine(cfg, params, ServeConfig(max_len=40, temperature=1.0),
                        seed=3)
    hot_b = ServeEngine(cfg, params, ServeConfig(max_len=40, temperature=1.0),
                        seed=3)
    a = hot_a.generate(prompts, 8)
    np.testing.assert_array_equal(a, hot_b.generate(prompts, 8))

    # the sampling key advances per token: a second call must not replay
    b = hot_a.generate(prompts, 8)
    assert not np.array_equal(a, b)

    # greedy path ignores the key entirely: repeat calls are identical
    cold = ServeEngine(cfg, params, ServeConfig(max_len=40, temperature=0.0),
                       seed=3)
    g1 = cold.generate(prompts, 8)
    np.testing.assert_array_equal(g1, cold.generate(prompts, 8))


def test_slot_scheduler_reuses_slots_mixed_requests():
    """More requests than slots, mixed prompt lengths and horizons: every
    request completes with exactly its own max_new tokens, rids intact,
    cohort order preserved (FIFO admission)."""
    cfg = get_reduced("starcoder2-7b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64))
    sched = SlotScheduler(eng, n_slots=2)
    rng = np.random.default_rng(1)
    spec = [(0, 12, 4), (1, 16, 6), (2, 12, 2), (3, 20, 5), (4, 14, 3)]
    for rid, plen, max_new in spec:
        sched.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=max_new))
    done = sched.run()
    assert [r.rid for r in done] == [0, 1, 2, 3, 4]   # FIFO cohorts of 2
    assert all(r.done for r in done)
    assert [len(r.out) for r in done] == [4, 6, 2, 5, 3]
    assert sched.queue == []
    # slots turned over: 3 cohorts ran through 2 slots
    assert len(done) > sched.n_slots
