"""Property-style SketchSpec invariants (runs with or without hypothesis
via the _propcheck shim): index ranges, stride consistency, merge
sub-additivity."""
import jax
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, st

from repro.core import sketch as sk
from repro.core.hashing import KeySchema

_CONFIGS = [
    # (domains, partition, ranges)
    (((1 << 32), (1 << 32)), ((0, 1),), (1000,)),
    (((1 << 32), (1 << 32)), ((0,), (1,)), (48, 90)),
    ((4096, 256, 1000), ((0,), (1, 2)), (37, 91)),
    ((256,) * 4, ((0, 2), (1, 3)), (64, 63)),
    ((65536, 65536, 65536), ((0,), (1,), (2,)), (11, 13, 17)),
]


@given(st.integers(0, 2**31 - 1), st.sampled_from(_CONFIGS),
       st.sampled_from([1, 2, 5]))
@settings(max_examples=20, deadline=None)
def test_row_indices_always_in_table(seed, config, w):
    """Mixed-radix cell index in [0, table_size) for arbitrary keys, and the
    jnp limb path agrees with the uint64 numpy oracle bit-for-bit."""
    domains, part, ranges = config
    spec = sk.SketchSpec(KeySchema(domains=domains), part, ranges, w)
    rng = np.random.default_rng(seed)
    params = sk.init_params(spec, jax.random.PRNGKey(seed % 9973))
    items = np.stack(
        [rng.integers(0, d, 128, dtype=np.uint64).astype(np.uint32)
         for d in domains], axis=1)
    idx_np = sk.compute_indices_np(spec, params, items)
    idx_jx = np.asarray(sk.compute_indices(spec, params, jnp.asarray(items)))
    assert idx_np.shape == (w, 128)
    assert (idx_np < spec.table_size).all()
    np.testing.assert_array_equal(idx_np, idx_jx)


@given(st.sampled_from(_CONFIGS))
@settings(max_examples=10, deadline=None)
def test_strides_consistent_with_ranges(config):
    """strides[j] == prod(ranges[j+1:]) and table_size == prod(ranges):
    the mixed radix is exactly the row-major layout of the range grid."""
    domains, part, ranges = config
    spec = sk.SketchSpec(KeySchema(domains=domains), part, ranges, 2)
    m = len(ranges)
    for j in range(m):
        assert spec.strides[j] == int(np.prod(ranges[j + 1:], dtype=np.int64))
    assert spec.table_size == int(np.prod(ranges, dtype=np.int64))
    # strides decrease and the largest addressable cell fits the table
    top = sum((r - 1) * s for r, s in zip(ranges, spec.strides))
    assert top == spec.table_size - 1


@given(st.integers(0, 2**31 - 1), st.sampled_from(_CONFIGS))
@settings(max_examples=10, deadline=None)
def test_merge_linearity_for_nonnegative_streams(seed, config):
    """Merge linearity, elementwise for non-negative streams: the merged
    table is exactly the cell-wise sum, hence query(merge(a, b)) >=
    query(a) + query(b) (min of sums dominates sum of mins) and the merged
    estimate still upper-bounds the combined true frequency."""
    domains, part, ranges = config
    spec = sk.SketchSpec(KeySchema(domains=domains), part, ranges, 3)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed % 7919)
    items = np.stack(
        [rng.integers(0, d, 400, dtype=np.uint64).astype(np.uint32)
         for d in domains], axis=1)
    freqs = rng.integers(1, 100, size=400).astype(np.int32)
    a = sk.update_jit(spec, sk.init_state(spec, key),
                      jnp.asarray(items[:200]), jnp.asarray(freqs[:200]))
    b = sk.update_jit(spec, sk.init_state(spec, key),
                      jnp.asarray(items[200:]), jnp.asarray(freqs[200:]))
    ab = sk.merge(a, b)
    pick = rng.choice(400, 64, replace=False)
    q = jnp.asarray(items[pick])
    est_ab = np.asarray(sk.query(spec, ab, q))
    est_a = np.asarray(sk.query(spec, a, q))
    est_b = np.asarray(sk.query(spec, b, q))
    assert (est_ab >= est_a + est_b).all()
    # the merged table is the exact cell-wise sum ...
    np.testing.assert_array_equal(
        np.asarray(ab.table), np.asarray(a.table) + np.asarray(b.table))
    # ... so the merged estimate still never underestimates the true
    # combined frequency
    packed = [tuple(r) for r in items.tolist()]
    true = {t: 0 for t in packed}
    for t, f in zip(packed, freqs.tolist()):
        true[t] += f
    want = np.array([true[packed[i]] for i in pick])
    assert (est_ab >= want).all()
