"""Training-side fault tolerance: Supervisor, StragglerMonitor, elastic_remesh.

The serving-side recovery matrix lives in tests/test_recovery.py; this file
covers the training loop's pieces from training/fault_tolerance.py:

  * Supervisor restart-and-replay with a sketch table in the step state --
    an injected step failure restores the latest checkpoint and replays,
    and because the data order is keyed by step number the final state is
    bit-identical to an uninterrupted run;
  * restart budget: exceeding ``max_restarts`` re-raises instead of
    looping forever, and ``restart_backoff`` actually sleeps between
    restarts (exponentially);
  * StragglerMonitor flags an injected slow host and un-flags it once its
    EWMA recovers;
  * elastic_remesh re-lays live sharded state onto a smaller/larger mesh
    with values intact (multi-device leg runs in a subprocess on a forced
    CPU mesh).
"""
import os
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np
import pytest

from repro.core import sketch as sk
from repro.kernels.ops import KernelSketch
from repro.streams import zipf_hh_workload
from repro.training.fault_tolerance import (
    StragglerMonitor,
    Supervisor,
    elastic_remesh,
)

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_DEVICES = int(os.environ.get("REPRO_TEST_DEVICES", "8"))


def _run(code: str, devices: int = _DEVICES) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def _sketch_step_setup():
    """A step loop whose state is a KernelSketch table: step i folds block i.

    Data order is keyed by the step number, so replay after a restore
    consumes identical blocks -- the exactly-once contract under test.
    The kernel fold donates its input buffer, so each run gets a FRESH
    init via the returned factory (a shared init array would be deleted
    by the first run's first step).
    """
    import jax.numpy as jnp

    stream = zipf_hh_workload(n_src=100, n_tgt=200, n_edges=800,
                              n_occurrences=4_000, seed=1).stream
    spec = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (32, 32), 4)
    ks = KernelSketch(spec, jax.random.PRNGKey(0), block_b=64)
    init_table = np.asarray(ks.table)
    blocks = [(stream.items[s:s + 50], stream.freqs[s:s + 50])
              for s in range(0, stream.items.shape[0], 50)]

    def step_fn(i, state):
        it, fr = blocks[i % len(blocks)]
        ks.table = jnp.asarray(state["table"])
        ks.update(it, fr)
        return {"table": ks.table, "step_no": np.asarray(i + 1)}

    def make_init():
        return {"table": jnp.array(init_table), "step_no": np.asarray(0)}

    return step_fn, make_init, len(blocks)


def test_supervisor_restart_and_replay_bitwise(tmp_path):
    step_fn, make_init, n = _sketch_step_setup()
    _, ref_state = Supervisor(str(tmp_path / "ref"), save_every=3,
                              ).run(make_init(), step_fn, 0, n)

    boom = {"armed": True}

    def flaky(i, state):
        if i == 5 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected device loss")
        return step_fn(i, state)

    sup = Supervisor(str(tmp_path / "ckpt"), save_every=3)
    step, state = sup.run(make_init(), flaky, 0, n)
    assert step == n and sup.restarts == 1
    assert np.array_equal(np.asarray(state["table"]),
                          np.asarray(ref_state["table"]))
    assert int(state["step_no"]) == n


def test_supervisor_max_restarts_exceeded(tmp_path):
    step_fn, make_init, n = _sketch_step_setup()

    def always_fails(i, state):
        raise RuntimeError("persistent failure")

    sup = Supervisor(str(tmp_path), save_every=3, max_restarts=2)
    with pytest.raises(RuntimeError, match="max_restarts"):
        sup.run(make_init(), always_fails, 0, n)
    assert sup.restarts == 3                 # 2 allowed + the fatal one


def test_supervisor_restart_backoff_sleeps(tmp_path):
    step_fn, make_init, n = _sketch_step_setup()
    boom = {"left": 2}

    def flaky(i, state):
        if boom["left"]:
            boom["left"] -= 1
            raise RuntimeError("injected")
        return step_fn(i, state)

    sup = Supervisor(str(tmp_path), save_every=100, max_restarts=3,
                     restart_backoff=0.05)
    t0 = time.perf_counter()
    sup.run(make_init(), flaky, 0, 3)
    # backoff 0.05 * (1 + 2) = 0.15s floor across the two restarts
    assert time.perf_counter() - t0 >= 0.15
    assert sup.restarts == 2


def test_straggler_monitor_flags_and_recovers():
    mon = StragglerMonitor(threshold=2.0, ewma=0.5)
    # warm: four hosts at ~10ms
    for step in range(3):
        mon.record(step, {h: 0.010 for h in range(4)})
    assert mon.reports[-1].stragglers == []
    # host 2 degrades to 100ms: EWMA crosses 2x median within a few steps
    for step in range(3, 8):
        times = {h: 0.010 for h in range(4)}
        times[2] = 0.100
        rep = mon.record(step, times)
    assert rep.stragglers == [2]
    # and heals once the host speeds back up
    for step in range(8, 20):
        rep = mon.record(step, {h: 0.010 for h in range(4)})
    assert rep.stragglers == []


def test_elastic_remesh_single_device_roundtrip():
    # 1->1 remesh is the degenerate leg runnable on any host: values and
    # structure survive the device_put relayout
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P

    state = {"table": jax.numpy.arange(64, dtype=jax.numpy.int32).reshape(8, 8),
             "count": jax.numpy.asarray(7)}
    out = elastic_remesh(state, mesh, lambda x: P())
    assert np.array_equal(np.asarray(out["table"]),
                          np.asarray(state["table"]))
    assert int(out["count"]) == 7


def test_elastic_remesh_multi_device_shrink_grow():
    print(_run("""
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training.fault_tolerance import elastic_remesh

        assert jax.device_count() >= 8, jax.device_count()
        mesh8 = jax.make_mesh((8,), ("data",))
        mesh4 = jax.make_mesh((4,), ("data",))   # lost half the fleet
        x = jax.device_put(
            jax.numpy.arange(1024, dtype=jax.numpy.float32).reshape(8, 128),
            NamedSharding(mesh8, P("data")))
        state = {"table": x, "step": jax.numpy.asarray(11)}

        down = elastic_remesh(state, mesh4,
                              lambda v: P("data") if v.ndim == 2 else P())
        assert down["table"].sharding.mesh == mesh4
        assert np.array_equal(np.asarray(down["table"]), np.asarray(x))
        assert int(down["step"]) == 11

        up = elastic_remesh(down, mesh8,
                            lambda v: P("data") if v.ndim == 2 else P())
        assert up["table"].sharding.mesh == mesh8
        assert np.array_equal(np.asarray(up["table"]), np.asarray(x))
        print("elastic remesh 8->4->8 values intact")
    """))
