"""Hash family: limb arithmetic vs uint64 oracle, range, independence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core.hashing import (
    P31,
    KeySchema,
    cw_hash,
    cw_hash_np,
    draw_hash_params_np,
    mod_p31,
    mulmod_p31_16,
)


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=200, deadline=None)
def test_mod_p31_matches_int(x):
    got = int(mod_p31(jnp.uint32(x)))
    assert got == x % int(P31)


@given(st.integers(0, int(P31) - 1), st.integers(0, 2**16 - 1))
@settings(max_examples=200, deadline=None)
def test_mulmod_matches_int(a, x):
    got = int(mulmod_p31_16(jnp.uint32(a), jnp.uint32(x)))
    assert got == (a * x) % int(P31)


@given(st.integers(0, 2**63 - 1), st.integers(1, 12))
@settings(max_examples=50, deadline=None)
def test_cw_hash_limb_equals_uint64_oracle(seed, n_chunks):
    rng = np.random.default_rng(seed % 2**32)
    chunks = rng.integers(0, 1 << 16, size=(64, n_chunks)).astype(np.uint32)
    q = draw_hash_params_np(rng, (n_chunks,))
    r = int(draw_hash_params_np(rng, (1,))[0])
    expect = cw_hash_np(chunks, q, r)
    got = np.asarray(cw_hash(jnp.asarray(chunks), jnp.asarray(q), jnp.uint32(r)))
    assert (expect == got).all()


def test_hash_uniformity_and_independence():
    """Pairwise collision rate over a range ~ 1/range (CW guarantee)."""
    rng = np.random.default_rng(0)
    n, h = 4000, 256
    chunks = rng.integers(0, 1 << 16, size=(n, 2)).astype(np.uint32)
    chunks = np.unique(chunks, axis=0)
    rates = []
    for trial in range(20):
        q = draw_hash_params_np(rng, (2,))
        r = int(draw_hash_params_np(rng, (1,))[0])
        hv = cw_hash_np(chunks, q, r) % h
        # collision count among random pairs
        i = rng.integers(0, len(chunks), 4000)
        j = rng.integers(0, len(chunks), 4000)
        mask = i != j
        rates.append(np.mean(hv[i[mask]] == hv[j[mask]]))
    assert abs(np.mean(rates) - 1.0 / h) < 0.5 / h


def test_schema_chunking_injective():
    schema = KeySchema(domains=(1 << 32, 1000, 256))
    assert schema.chunk_counts == (2, 1, 1)
    assert schema.total_chunks == 4
    rng = np.random.default_rng(1)
    items = np.stack([
        rng.integers(0, 1 << 32, 500, dtype=np.uint64).astype(np.uint32),
        rng.integers(0, 1000, 500).astype(np.uint32),
        rng.integers(0, 256, 500).astype(np.uint32),
    ], axis=1)
    chunks = schema.module_chunks_np(items)
    # distinct items -> distinct chunk vectors
    assert len(np.unique(chunks, axis=0)) == len(np.unique(items, axis=0))
    # jnp path identical
    got = np.asarray(schema.module_chunks(jnp.asarray(items)))
    assert (got == chunks).all()


def test_schema_validation():
    with pytest.raises(ValueError):
        KeySchema(domains=())
    with pytest.raises(ValueError):
        KeySchema(domains=(1,))
