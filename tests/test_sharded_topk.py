"""Sharded heavy-hitter serving: shard-invariance harness + properties.

The multi-device tests spawn a fresh interpreter with an XLA host-device
override (pattern from tests/test_distributed.py) so the main test process
keeps its single-device view.  The forced device count defaults to 8 and
can be lowered via REPRO_TEST_DEVICES (the CI device-count matrix leg sets
it); shard-count sweeps adapt to whatever is available.

Single-device properties (merge algebra, shard merges via hh.merge, the
conservative-mode refusals) run in-process so they are part of tier-1.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from _propcheck import given, settings, st

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_DEVICES = int(os.environ.get("REPRO_TEST_DEVICES", "8"))


def _run(code: str, devices: int = _DEVICES) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


# --------------------------------------------------------------------------
# Shard-invariance harness (acceptance): 1/2/4/8 shards, any stream split,
# bit-identical level tables and identical heavy_hitters / topk output.
# --------------------------------------------------------------------------

def test_shard_invariance_tables_and_topk():
    print(_run(f"""
        import jax, numpy as np
        from repro.core import sketch as sk, hierarchy as hh
        from repro.serving.sharded_topk import ShardedTopKService
        from repro.streams import zipf_hh_workload

        wl = zipf_hh_workload(n_occurrences=60_000, n_edges=8_000, seed=3)
        spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)],
                                  (128, 128), 3)
        key = jax.random.PRNGKey(7)
        items, freqs = wl.stream.items, wl.stream.freqs
        counts = [c for c in (1, 2, 4, 8) if c <= jax.device_count()]
        assert counts[-1] >= 2, f"need >= 2 devices, got {{counts}}"

        ref = None
        for ci, c in enumerate(counts):
            mesh = jax.make_mesh((c,), ("data",))
            svc = ShardedTopKService(spec, key, mesh, sync_every=2)
            # a different split of the same stream for every shard count
            edges = np.linspace(0, len(items), ci + 3).astype(int)
            for s, e in zip(edges[:-1], edges[1:]):
                svc.ingest(items[s:e], freqs[s:e])
            svc.sync()
            assert svc.total == wl.stream.total
            tables = [np.asarray(st.table) for st in svc.state().states]
            hh_i, hh_e = svc.heavy_hitters(wl.threshold)
            tk_i, tk_e = svc.topk(10)
            if ref is None:
                ref = (tables, hh_i, hh_e, tk_i, tk_e)
            else:
                for a, b in zip(ref[0], tables):
                    assert (a == b).all(), f"level table mismatch at {{c}}"
                assert np.array_equal(ref[1], hh_i)
                assert np.array_equal(ref[2], hh_e)
                assert np.array_equal(ref[3], tk_i)
                assert np.array_equal(ref[4], tk_e)

        # same shard count, two different splits: also identical
        mesh = jax.make_mesh((counts[-1],), ("data",))
        svc2 = ShardedTopKService(spec, key, mesh, sync_every=1)
        svc2.ingest(items[:100], freqs[:100])
        svc2.ingest(items[100:], freqs[100:])
        for a, b in zip(ref[0],
                        [np.asarray(st.table) for st in svc2.state().states]):
            assert (a == b).all()
        tk2_i, tk2_e = svc2.topk(10)
        assert np.array_equal(ref[3], tk2_i) and np.array_equal(ref[4], tk2_e)

        # the merged tables equal the single-device build bit-for-bit, and
        # no true heavy hitter is lost (exact ground truth)
        hspec = hh.HierarchySpec.from_spec(spec)
        want = hh.build_hierarchy(hspec, key, items, freqs)
        for a, w in zip(ref[0], want.states):
            assert (a == np.asarray(w.table)).all()
        exact = {{tuple(r) for r in wl.exact_items.tolist()}}
        got = {{tuple(r) for r in ref[1].tolist()}}
        assert exact <= got, exact - got
        print("shard invariance OK", counts)
    """))


def test_sharded_service_sync_cadence_and_kernel_descent():
    """Lazy accumulation across many blocks between syncs must equal
    synchronous per-block syncing, and the Pallas candidate kernel must
    agree with the reference descent on the merged tables."""
    print(_run("""
        import jax, numpy as np
        from repro.core import sketch as sk
        from repro.serving.sharded_topk import ShardedTopKService
        from repro.streams import zipf_hh_workload

        wl = zipf_hh_workload(n_occurrences=30_000, n_edges=4_000, seed=9)
        spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (64, 64), 3)
        key = jax.random.PRNGKey(1)
        items, freqs = wl.stream.items, wl.stream.freqs
        c = min(4, jax.device_count())
        mesh = jax.make_mesh((c,), ("data",))

        lazy = ShardedTopKService(spec, key, mesh, sync_every=None)
        sync = ShardedTopKService(spec, key, mesh, sync_every=1)
        edges = np.linspace(0, len(items), 6).astype(int)
        for s, e in zip(edges[:-1], edges[1:]):
            lazy.ingest(items[s:e], freqs[s:e])
            sync.ingest(items[s:e], freqs[s:e])
        assert lazy._dirty and not sync._dirty
        for a, b in zip(lazy.state().states, sync.state().states):
            assert (np.asarray(a.table) == np.asarray(b.table)).all()

        krn = ShardedTopKService(spec, key, mesh, use_kernel=True)
        krn.ingest(items, freqs)
        ri, re = lazy.heavy_hitters(wl.threshold)
        ki, ke = krn.heavy_hitters(wl.threshold)
        assert np.array_equal(ri, ki) and np.array_equal(re, ke)
        print("sync cadence + kernel descent OK")
    """))


def test_endpoint_to_sharded_continuation():
    """Promoting a single-shard endpoint carries tables/pools/total over,
    and continued sharded ingest matches one endpoint fed the full stream."""
    print(_run("""
        import jax, numpy as np
        from repro.core import sketch as sk
        from repro.serving.engine import SketchTopKEndpoint
        from repro.streams import zipf_hh_workload

        wl = zipf_hh_workload(n_occurrences=20_000, n_edges=3_000, seed=1)
        spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (64, 64), 3)
        key = jax.random.PRNGKey(0)
        half = len(wl.stream.items) // 2

        ep = SketchTopKEndpoint(spec, key)
        ep.ingest(wl.stream.items[:half], wl.stream.freqs[:half])
        mesh = jax.make_mesh((min(4, jax.device_count()),), ("data",))
        svc = ep.to_sharded(mesh)
        svc.ingest(wl.stream.items[half:], wl.stream.freqs[half:])

        whole = SketchTopKEndpoint(spec, key)
        whole.ingest(wl.stream.items, wl.stream.freqs)
        assert svc.total == whole.total
        for a, b in zip(svc.state().states, whole.state.states):
            assert (np.asarray(a.table) == np.asarray(b.table)).all()
        # same tables + same candidate *sets* => same estimates
        ti, te = svc.topk(5)
        wi, we = whole.topk(5)
        assert np.array_equal(te, we)
        assert {tuple(r) for r in ti.tolist()} \\
            == {tuple(r) for r in wi.tolist()}
        print("to_sharded continuation OK")
    """))


# --------------------------------------------------------------------------
# Property tests (single device, tier-1): merge algebra + shard merges
# --------------------------------------------------------------------------

def _tiny_hierarchy(seed: int, n_items: int = 200):
    """A small 2-level hierarchy plus a random weighted stream."""
    from repro.core import hierarchy as hh
    from repro.core import sketch as sk
    from repro.core.hashing import KeySchema

    rng = np.random.default_rng(seed)
    schema = KeySchema(domains=(1 << 16, 1 << 16))
    base = sk.mod_sketch_spec(schema, [(0,), (1,)], (16, 32), 3)
    hspec = hh.HierarchySpec.from_spec(base)
    items = rng.integers(0, 1 << 12, size=(n_items, 2)).astype(np.uint32)
    freqs = rng.integers(1, 50, size=n_items).astype(np.int64)
    return hspec, items, freqs


def _assert_states_equal(a, b):
    for sa, sb in zip(a.states, b.states):
        np.testing.assert_array_equal(np.asarray(sa.table),
                                      np.asarray(sb.table))


@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 3, 4]))
@settings(max_examples=10, deadline=None)
def test_hierarchy_merge_commutative_associative(seed, n_parts):
    """Cell-wise hierarchy merge is commutative and associative, and
    folding any shard split of a stream reproduces the unsharded build."""
    from repro.core import hierarchy as hh

    hspec, items, freqs = _tiny_hierarchy(seed)
    key = jax.random.PRNGKey(seed % (1 << 30))
    bounds = np.linspace(0, len(items), n_parts + 1).astype(int)
    parts = [hh.build_hierarchy(hspec, key, items[s:e], freqs[s:e])
             for s, e in zip(bounds[:-1], bounds[1:])]

    _assert_states_equal(hh.merge(parts[0], parts[1]),
                         hh.merge(parts[1], parts[0]))
    if n_parts >= 3:
        _assert_states_equal(
            hh.merge(hh.merge(parts[0], parts[1]), parts[2]),
            hh.merge(parts[0], hh.merge(parts[1], parts[2])))

    folded = parts[0]
    for p in parts[1:]:
        folded = hh.merge(folded, p)
    _assert_states_equal(folded,
                         hh.build_hierarchy(hspec, key, items, freqs))


@given(st.integers(0, 5), st.sampled_from([2, 4]),
       st.sampled_from(["zipf", "ngram"]))
@settings(max_examples=6, deadline=None)
def test_no_false_negatives_survive_shard_merge(seed, n_shards, kind):
    """The no-false-negative guarantee (vs exact ground truth) holds for a
    hierarchy assembled by merging independently built shard states."""
    from repro.core import hierarchy as hh
    from repro.core import sketch as sk
    from repro.streams import ngram_hh_workload, zipf_hh_workload

    if kind == "zipf":
        wl = zipf_hh_workload(phi=0.004, n_occurrences=20_000,
                              n_edges=3_000, seed=seed)
    else:
        wl = ngram_hh_workload(vocab_size=256, n=2, phi=0.004,
                               n_sequences=16, seq_len=128, seed=seed)
    base = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (64, 64), 3)
    hspec = hh.HierarchySpec.from_spec(base)
    key = jax.random.PRNGKey(seed)
    items, freqs = wl.stream.items, wl.stream.freqs
    bounds = np.linspace(0, len(items), n_shards + 1).astype(int)
    merged = None
    for s, e in zip(bounds[:-1], bounds[1:]):
        part = hh.build_hierarchy(hspec, key, items[s:e], freqs[s:e])
        merged = part if merged is None else hh.merge(merged, part)
    got_i, got_e = hh.find_heavy_hitters(hspec, merged, wl.threshold,
                                         wl.candidates(base))
    exact = {tuple(r) for r in wl.exact_items.tolist()}
    got = {tuple(r) for r in got_i.tolist()}
    assert exact <= got, exact - got


def test_sharded_hierarchy_build_equals_single_device():
    """sharded_hierarchy_build over a real multi-device mesh is bit-exact
    vs build_hierarchy, across a few spec shapes (subprocess sweep)."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import hierarchy as hh, sketch as sk
        from repro.core.hashing import KeySchema

        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        rng = np.random.default_rng(0)
        for ranges, w, n_items in (((16, 16), 3, 4096), ((32, 8), 2, 2048)):
            schema = KeySchema(domains=(1 << 20, 1 << 20))
            base = sk.mod_sketch_spec(schema, [(0,), (1,)], ranges, w)
            hspec = hh.HierarchySpec.from_spec(base)
            key = jax.random.PRNGKey(w)
            items = rng.integers(0, 1 << 20, size=(n_items, 2),
                                 dtype=np.int64).astype(np.uint32)
            freqs = rng.integers(1, 9, size=n_items).astype(np.int32)
            state0 = hh.init_hierarchy(hspec, key)
            got = hh.sharded_hierarchy_build(
                hspec, state0, mesh, ("data",),
                jnp.asarray(items), jnp.asarray(freqs))
            want = hh.build_hierarchy(hspec, key, items, freqs)
            for g, t in zip(got.states, want.states):
                assert (np.asarray(g.table) == np.asarray(t.table)).all()
        print("sharded build parity OK")
    """))


# --------------------------------------------------------------------------
# Regression: every new sharded entry point refuses conservative mode
# --------------------------------------------------------------------------

def test_conservative_refuses_every_sharded_entry_point():
    from repro.core import distributed as dist
    from repro.core import hierarchy as hh
    from repro.core import sketch as sk
    from repro.core.hashing import KeySchema
    from repro.kernels import KernelSketch
    from repro.serving.engine import SketchTopKEndpoint
    from repro.serving.sharded_topk import ShardedTopKService

    schema = KeySchema(domains=(1 << 16, 1 << 16))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (8, 8), 2)
    hspec = hh.HierarchySpec.from_spec(spec)
    key = jax.random.PRNGKey(0)
    mesh = jax.make_mesh((1,), ("data",))

    with pytest.raises(ValueError, match="single-shard"):
        ShardedTopKService(spec, key, mesh, mode="conservative")
    with pytest.raises(ValueError, match="single-shard"):
        KernelSketch(spec, key, mode="conservative").sharded_update(
            mesh, ("data",), np.zeros((2, 2), np.uint32), np.ones(2))
    with pytest.raises(ValueError, match="single-shard"):
        hh.sharded_hierarchy_build(
            hspec, hh.init_hierarchy(hspec, key), mesh, ("data",),
            np.zeros((2, 2), np.uint32), np.ones(2, np.int32),
            mode="conservative")
    with pytest.raises(ValueError, match="single-shard"):
        dist.lazy_hierarchy_update(hspec, mesh, ("data",), (), (),
                                   np.zeros((2, 2), np.uint32),
                                   np.ones(2, np.int32),
                                   mode="conservative")
    with pytest.raises(ValueError, match="single-shard"):
        SketchTopKEndpoint(spec, key, mode="conservative").to_sharded(mesh)
    # the linear service stays linear: mode is pinned at construction
    svc = ShardedTopKService(spec, key, mesh)
    assert svc.mode == "linear"


def test_kernel_sketch_sharded_update_parity():
    """KernelSketch.sharded_update (jit-cached psum fold, power-of-two
    padding) is bit-exact vs the reference serial build across uneven
    streamed blocks; multi-device coverage rides on the subprocess tests."""
    from repro.core import sketch as sk
    from repro.core.hashing import KeySchema
    from repro.kernels import KernelSketch

    schema = KeySchema(domains=(1 << 20, 1 << 20))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (16, 16), 3)
    key = jax.random.PRNGKey(5)
    rng = np.random.default_rng(5)
    items = rng.integers(0, 1 << 20, size=(700, 2),
                         dtype=np.int64).astype(np.uint32)
    freqs = rng.integers(1, 9, size=700).astype(np.int32)
    mesh = jax.make_mesh((1,), ("data",))

    ks = KernelSketch(spec, key)
    for s, e in ((0, 300), (300, 700)):   # uneven blocks share one compile
        ks.sharded_update(mesh, ("data",), items[s:e], freqs[s:e])
    assert len(ks._sharded_folds) == 1
    want = sk.build_sketch(spec, key, items, freqs)
    np.testing.assert_array_equal(ks.table_view(), np.asarray(want.table))
