"""Fused single-launch hierarchy ingest: shared-family cascade + Pallas
kernel parity.

Covers the PR-5 acceptance surface: the shared per-group hash family
(level params = prefix slices of the finest draw), the mixed-radix index
cascade (one hash pass -> all level indices), bit-parity of the fused
multi-level Pallas kernel vs the per-level jnp reference on int32 and f32
tables (duplicate keys, non-tile-multiple level widths, zero-frequency pad
rows), the endpoint's fused-ingest path, and descent guarantees under the
shared params."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.kernels import KernelHierarchy, hier_update_pallas, make_hier_plan
from repro.serving.engine import SketchTopKEndpoint
from repro.streams import zipf_hh_workload


def _hier(ranges=(48, 90, 7), w=3,
          domains=(1 << 32, 256, 1000, 4096), part=((1, 2), (0,), (3,))):
    """A 3-level hierarchy with a joint group, a 2-chunk module, and level
    table sizes that are NOT tile multiples."""
    schema = KeySchema(domains=domains)
    base = sk.mod_sketch_spec(schema, [tuple(g) for g in part], ranges, w)
    return hh.HierarchySpec.from_spec(base)


def _stream(hspec, n, seed=0, dup=True):
    rng = np.random.default_rng(seed)
    items = np.stack(
        [rng.integers(0, d, n, dtype=np.uint64).astype(np.uint32)
         for d in hspec.base.schema.domains], axis=1)
    if dup:
        items[n // 10 : n // 4] = items[0]       # heavy duplication
    freqs = rng.integers(1, 1 << 12, n).astype(np.int32)
    return items, freqs


# --------------------------------------------------------------------------
# Shared family + cascade identities
# --------------------------------------------------------------------------

def test_level_params_are_prefix_slices():
    hspec = _hier()
    state = hh.init_hierarchy(hspec, jax.random.PRNGKey(3))
    assert hh.params_share_prefix(state)
    fine = state.states[-1].params
    for l, st in enumerate(state.states):
        nc = hspec.levels[l].schema.total_chunks
        np.testing.assert_array_equal(np.asarray(st.params.q),
                                      np.asarray(fine.q)[:, :nc])
        np.testing.assert_array_equal(np.asarray(st.params.r),
                                      np.asarray(fine.r)[:, : l + 1])
    # a fresh independent draw per level violates the invariant
    keys = jax.random.split(jax.random.PRNGKey(9), hspec.n_levels)
    indep = hh.HierarchyState(states=tuple(
        sk.init_state(s, k) for s, k in zip(hspec.levels, keys)))
    assert not hh.params_share_prefix(indep)


def test_hierarchy_indices_match_per_level_compute_indices():
    """The cascade (one hash pass + integer divisions) must equal every
    level's own compute_indices on its re-cut columns, bit for bit."""
    hspec = _hier()
    state = hh.init_hierarchy(hspec, jax.random.PRNGKey(1))
    items, _ = _stream(hspec, 257, seed=2)
    idxs = hh.hierarchy_indices(hspec, state.states[-1].params,
                                jnp.asarray(items))
    for lvl, (spec_l, st_l) in enumerate(zip(hspec.levels, state.states)):
        want = sk.compute_indices(spec_l, st_l.params,
                                  hspec.level_items(lvl, jnp.asarray(items)))
        np.testing.assert_array_equal(np.asarray(idxs[lvl]),
                                      np.asarray(want))


def test_cascade_update_equals_per_level_reference():
    """hh.update (cascade) and hh.update_jit are bit-identical to the
    per-level reference fold, for both the linear and conservative paths."""
    hspec = _hier()
    key = jax.random.PRNGKey(5)
    items, freqs = _stream(hspec, 400, seed=3)
    it, fr = jnp.asarray(items), jnp.asarray(freqs)

    ref = hh.update_reference(hspec, hh.init_hierarchy(hspec, key), it, fr)
    got = hh.update(hspec, hh.init_hierarchy(hspec, key), it, fr)
    got_jit = hh.update_jit(hspec, hh.init_hierarchy(hspec, key), it, fr)
    for a, b, c in zip(got.states, ref.states, got_jit.states):
        np.testing.assert_array_equal(np.asarray(a.table),
                                      np.asarray(b.table))
        np.testing.assert_array_equal(np.asarray(c.table),
                                      np.asarray(b.table))

    # conservative: same cascade for indices, per-level sequential folds
    cons = hh.update_conservative_jit(
        hspec, hh.init_hierarchy(hspec, key), it, fr)
    want = []
    st0 = hh.init_hierarchy(hspec, key)
    for lvl, (spec_l, st_l) in enumerate(zip(hspec.levels, st0.states)):
        want.append(sk.update_conservative(
            spec_l, st_l, hspec.level_items(lvl, it), fr))
    for a, b in zip(cons.states, want):
        np.testing.assert_array_equal(np.asarray(a.table),
                                      np.asarray(b.table))


# --------------------------------------------------------------------------
# Fused Pallas kernel parity
# --------------------------------------------------------------------------

def test_fused_kernel_bit_parity_int32():
    """Acceptance: one pallas_call over the concatenated padded tables is
    bit-identical to the per-level jnp reference on int32 tables, with
    duplicate keys and non-tile-multiple level widths."""
    hspec = _hier()
    key = jax.random.PRNGKey(7)
    items, freqs = _stream(hspec, 500, seed=0)
    kh = KernelHierarchy(hspec, key, tile_h=128, block_b=128, interpret=True)
    for lvl, pad in zip(hspec.levels, kh.hplan.level_pads):
        assert lvl.table_size % 128 != 0, "cases must exercise padding"
        assert pad % 128 == 0
    kh.update(items, freqs)

    ref = hh.update_reference(hspec, hh.init_hierarchy(hspec, key),
                              jnp.asarray(items), jnp.asarray(freqs))
    for a, b in zip(kh.state().states, ref.states):
        np.testing.assert_array_equal(np.asarray(a.table),
                                      np.asarray(b.table))


def test_fused_kernel_bit_parity_f32_integer_weights():
    """f32 tables: the one-hot contraction sums every cell's multiset of
    weights; with integer-valued f32 weights (< 2^24) all partial sums are
    exactly representable, so parity is bit-exact despite the different
    accumulation order."""
    hspec = _hier()
    key = jax.random.PRNGKey(11)
    rng = np.random.default_rng(4)
    items, _ = _stream(hspec, 300, seed=5)
    vals = rng.integers(1, 1 << 10, 300).astype(np.float32)
    kh = KernelHierarchy(hspec, key, tile_h=128, block_b=128,
                         dtype=jnp.float32, interpret=True)
    kh.update(items, vals)
    ref = hh.update_reference(hspec,
                              hh.init_hierarchy(hspec, key, dtype=jnp.float32),
                              jnp.asarray(items), jnp.asarray(vals))
    for a, b in zip(kh.state().states, ref.states):
        np.testing.assert_array_equal(np.asarray(a.table),
                                      np.asarray(b.table))


def test_fused_kernel_f32_random_weights_close():
    """Arbitrary float weights: tolerance-level parity (accumulation order
    differs between MXU contraction and scatter order)."""
    hspec = _hier(ranges=(32, 16, 5))
    key = jax.random.PRNGKey(13)
    rng = np.random.default_rng(6)
    items, _ = _stream(hspec, 256, seed=7)
    vals = rng.standard_normal(256).astype(np.float32)
    kh = KernelHierarchy(hspec, key, tile_h=128, block_b=256,
                         dtype=jnp.float32, interpret=True)
    kh.update(items, vals)
    ref = hh.update_reference(hspec,
                              hh.init_hierarchy(hspec, key, dtype=jnp.float32),
                              jnp.asarray(items), jnp.asarray(vals))
    for a, b in zip(kh.state().states, ref.states):
        np.testing.assert_allclose(np.asarray(a.table), np.asarray(b.table),
                                   rtol=1e-5, atol=1e-4)


def test_fused_kernel_zero_freq_pad_rows_neutral():
    """A block shorter than block_b is zero-padded; the pad rows hash to
    real cells but add frequency 0, so no table cell may change."""
    hspec = _hier()
    key = jax.random.PRNGKey(17)
    items, freqs = _stream(hspec, 131, seed=8)   # 131 % 128 != 0
    kh = KernelHierarchy(hspec, key, tile_h=128, block_b=128, interpret=True)
    kh.update(items, freqs)
    ref = hh.update_reference(hspec, hh.init_hierarchy(hspec, key),
                              jnp.asarray(items), jnp.asarray(freqs))
    for a, b in zip(kh.state().states, ref.states):
        np.testing.assert_array_equal(np.asarray(a.table),
                                      np.asarray(b.table))
    # explicit zero-frequency items are also no-ops through the kernel
    before = np.asarray(kh.table).copy()
    kh.update(items[:64], np.zeros(64, np.int32))
    np.testing.assert_array_equal(before, np.asarray(kh.table))


def test_fused_kernel_multi_block_matches_one_shot():
    """Streaming through several fixed-size blocks equals one reference
    fold of the whole stream (linearity + in-place donation)."""
    hspec = _hier(ranges=(16, 8, 6), w=2)
    key = jax.random.PRNGKey(19)
    items, freqs = _stream(hspec, 700, seed=9)
    kh = KernelHierarchy(hspec, key, tile_h=128, block_b=256, interpret=True)
    for s, e in ((0, 300), (300, 700)):
        kh.update(items[s:e], freqs[s:e])
    ref = hh.update_reference(hspec, hh.init_hierarchy(hspec, key),
                              jnp.asarray(items), jnp.asarray(freqs))
    for a, b in zip(kh.state().states, ref.states):
        np.testing.assert_array_equal(np.asarray(a.table),
                                      np.asarray(b.table))


def test_kernel_hierarchy_rejects_independent_params():
    """The fused kernel hashes with the finest params only; adopting a
    state whose levels were drawn independently must be refused loudly."""
    hspec = _hier()
    keys = jax.random.split(jax.random.PRNGKey(23), hspec.n_levels)
    indep = hh.HierarchyState(states=tuple(
        sk.init_state(s, k) for s, k in zip(hspec.levels, keys)))
    with pytest.raises(ValueError, match="shared per-group hash family"):
        KernelHierarchy.from_state(hspec, indep)


def test_fused_kernel_freq_guard():
    hspec = _hier()
    kh = KernelHierarchy(hspec, jax.random.PRNGKey(0), tile_h=128,
                         block_b=8, interpret=True)
    items, _ = _stream(hspec, 8, seed=1, dup=False)
    with pytest.raises(ValueError, match="negative"):
        kh.update(items, np.array([1, -1] * 4, np.int32))
    with pytest.raises(ValueError, match="2\\^24"):
        kh.update(items, np.full(8, 1 << 24, np.int64))
    assert np.asarray(kh.table).max() == 0


# --------------------------------------------------------------------------
# Endpoint + descent under the shared family
# --------------------------------------------------------------------------

def test_endpoint_fused_ingest_matches_reference_endpoint():
    """use_update_kernel=True must leave every observable identical: level
    tables bit-exact, same heavy_hitters and topk output."""
    wl = zipf_hh_workload(phi=0.004, n_occurrences=50_000, n_edges=5_000,
                          seed=2)
    spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (128, 128), 3)
    key = jax.random.PRNGKey(0)
    plain = SketchTopKEndpoint(spec, key)
    fused = SketchTopKEndpoint(spec, key, use_update_kernel=True)
    # uneven blocks exercise the kernel's internal padding
    edges = [0, 313, 1200, len(wl.stream.items)]
    for s, e in zip(edges[:-1], edges[1:]):
        plain.ingest(wl.stream.items[s:e], wl.stream.freqs[s:e])
        fused.ingest(wl.stream.items[s:e], wl.stream.freqs[s:e])
    assert fused.total == plain.total
    for a, b in zip(fused.state.states, plain.state.states):
        np.testing.assert_array_equal(np.asarray(a.table),
                                      np.asarray(b.table))
    pi, pe = plain.heavy_hitters(wl.threshold)
    fi, fe = fused.heavy_hitters(wl.threshold)
    np.testing.assert_array_equal(pi, fi)
    np.testing.assert_array_equal(pe, fe)
    ti, te = plain.topk(8)
    ui, ue = fused.topk(8)
    np.testing.assert_array_equal(ti, ui)
    np.testing.assert_array_equal(te, ue)
    # no false negatives through the fused path (exact ground truth)
    exact = {tuple(r) for r in wl.exact_items.tolist()}
    got = {tuple(r) for r in fi.tolist()}
    assert exact <= got, exact - got


def test_endpoint_fused_merge_and_to_sharded_roundtrip():
    """merge_from and to_sharded must work through the fused endpoint's
    state property (tables packed/unpacked losslessly)."""
    wl = zipf_hh_workload(phi=0.01, n_occurrences=10_000, n_edges=2_000,
                          seed=4)
    spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (32, 32), 2)
    key = jax.random.PRNGKey(0)
    half = len(wl.stream.items) // 2
    a = SketchTopKEndpoint(spec, key, use_update_kernel=True)
    b = SketchTopKEndpoint(spec, key, use_update_kernel=True)
    a.ingest(wl.stream.items[:half], wl.stream.freqs[:half])
    b.ingest(wl.stream.items[half:], wl.stream.freqs[half:])
    a.merge_from(b)
    whole = SketchTopKEndpoint(spec, key)
    whole.ingest(wl.stream.items, wl.stream.freqs)
    for x, y in zip(a.state.states, whole.state.states):
        np.testing.assert_array_equal(np.asarray(x.table),
                                      np.asarray(y.table))
    mesh = jax.make_mesh((1,), ("data",))
    svc = a.to_sharded(mesh)
    hi_a, _ = svc.heavy_hitters(wl.threshold)
    hi_w, _ = whole.heavy_hitters(wl.threshold)
    np.testing.assert_array_equal(hi_a, hi_w)


def test_conservative_endpoint_ignores_update_kernel_flag():
    """Conservative mode cannot take the fused linear kernel; the flag
    falls back to the jnp per-level folds (which still share the cascade's
    single hash pass) and the endpoint behaves identically."""
    hspec_spec = sk.mod_sketch_spec(KeySchema(domains=(1 << 16, 1 << 16)),
                                    [(0,), (1,)], (16, 16), 2)
    rng = np.random.default_rng(0)
    items = rng.integers(0, 1 << 12, size=(200, 2)).astype(np.uint32)
    freqs = rng.integers(1, 50, size=200).astype(np.int64)
    key = jax.random.PRNGKey(1)
    c1 = SketchTopKEndpoint(hspec_spec, key, mode="conservative")
    c2 = SketchTopKEndpoint(hspec_spec, key, mode="conservative",
                            use_update_kernel=True)
    assert c2._kh is None
    c1.ingest(items, freqs)
    c2.ingest(items, freqs)
    for a, b in zip(c1.state.states, c2.state.states):
        np.testing.assert_array_equal(np.asarray(a.table),
                                      np.asarray(b.table))


def test_cascade_entry_points_reject_independent_params():
    """Regression: hh.update/update_jit/update_conservative_jit derive
    coarse cells from the finest index, which is garbage for independently
    drawn per-level params -- they must refuse such states loudly instead
    of silently corrupting every coarse level (update_reference remains
    the escape hatch)."""
    hspec = _hier(ranges=(16, 8, 4), w=2)
    keys = jax.random.split(jax.random.PRNGKey(29), hspec.n_levels)
    indep = hh.HierarchyState(states=tuple(
        sk.init_state(s, k) for s, k in zip(hspec.levels, keys)))
    items, freqs = _stream(hspec, 64, seed=11, dup=False)
    it, fr = jnp.asarray(items), jnp.asarray(freqs)
    for fold in (hh.update, hh.update_jit, hh.update_conservative,
                 hh.update_conservative_jit):
        with pytest.raises(ValueError, match="shared per-group hash family"):
            fold(hspec, indep, it, fr)
    # update_reference still serves pre-cascade states
    hh.update_reference(hspec, indep, it, fr)


def test_endpoint_ingest_after_to_sharded_keeps_service_alive():
    """Regression: to_sharded must COPY the endpoint's tables -- the
    endpoint's donating ingest would otherwise delete buffers the promoted
    service still reads."""
    wl = zipf_hh_workload(phi=0.01, n_occurrences=8_000, n_edges=1_500,
                          seed=6)
    spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (32, 32), 2)
    ep = SketchTopKEndpoint(spec, jax.random.PRNGKey(0))
    half = len(wl.stream.items) // 2
    ep.ingest(wl.stream.items[:half], wl.stream.freqs[:half])
    mesh = jax.make_mesh((1,), ("data",))
    svc = ep.to_sharded(mesh)
    snapshot = [np.asarray(st.table).copy() for st in svc.state().states]
    # continued single-shard ingest donates the ENDPOINT's tables ...
    ep.ingest(wl.stream.items[half:], wl.stream.freqs[half:])
    # ... and the service must still serve from its own (copied) buffers
    for before, st in zip(snapshot, svc.state().states):
        np.testing.assert_array_equal(before, np.asarray(st.table))
    svc.topk(3)


def test_sharded_build_bit_exact_under_shared_params():
    """sharded_hierarchy_build (one shard_map, cascade fold + psum) on a
    single-device mesh is bit-exact vs the serial cascade build -- the
    multi-device sweep rides in tests/test_sharded_topk.py."""
    hspec = _hier(ranges=(16, 8, 4), w=2)
    key = jax.random.PRNGKey(2)
    items, freqs = _stream(hspec, 512, seed=10)
    mesh = jax.make_mesh((1,), ("data",))
    state0 = hh.init_hierarchy(hspec, key)
    got = hh.sharded_hierarchy_build(hspec, state0, mesh, ("data",),
                                     jnp.asarray(items),
                                     jnp.asarray(freqs.astype(np.int32)))
    want = hh.build_hierarchy(hspec, key, items, freqs)
    for g, w_ in zip(got.states, want.states):
        np.testing.assert_array_equal(np.asarray(g.table),
                                      np.asarray(w_.table))
