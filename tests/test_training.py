"""Optimizer, checkpoint/restart, fault tolerance, grad compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training import train_loop as tl
from repro.training.fault_tolerance import StragglerMonitor, Supervisor
from repro.training.grad_compression import (
    CompressionConfig,
    compress_decompress,
    compression_ratio,
    init_compression,
)


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def _numpy_adamw(params, grads, m, v, step, cfg):
    lr = float(opt.lr_schedule(cfg, jnp.int32(step)))
    m = cfg.beta1 * m + (1 - cfg.beta1) * grads
    v = cfg.beta2 * v + (1 - cfg.beta2) * grads**2
    mh = m / (1 - cfg.beta1**step)
    vh = v / (1 - cfg.beta2**step)
    return params - lr * (mh / (np.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * params), m, v


def test_adamw_matches_numpy_reference():
    cfg = opt.OptimizerConfig(lr=1e-2, clip_norm=1e9, warmup_steps=0,
                              total_steps=100, min_lr_frac=1.0)
    rng = np.random.default_rng(0)
    p_np = rng.standard_normal((8, 16)).astype(np.float32)
    params = {"w": jnp.asarray(p_np)}
    state = opt.init_state(cfg, params)
    m = np.zeros_like(p_np)
    v = np.zeros_like(p_np)
    for step in range(1, 4):
        g_np = rng.standard_normal((8, 16)).astype(np.float32)
        params, state, _ = opt.apply_updates(cfg, params, {"w": jnp.asarray(g_np)},
                                             state)
        p_np, m, v = _numpy_adamw(p_np, g_np, m, v, step, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), p_np,
                                   rtol=1e-5, atol=1e-6)


def test_adamw8bit_tracks_fp32():
    cfg32 = opt.OptimizerConfig(name="adamw", lr=1e-2, warmup_steps=0,
                                total_steps=50)
    cfg8 = opt.OptimizerConfig(name="adamw8bit", lr=1e-2, warmup_steps=0,
                               total_steps=50)
    rng = np.random.default_rng(1)
    w0 = rng.standard_normal((4, 256)).astype(np.float32)
    p32 = {"w": jnp.asarray(w0)}
    p8 = {"w": jnp.asarray(w0)}
    s32 = opt.init_state(cfg32, p32)
    s8 = opt.init_state(cfg8, p8)
    assert isinstance(s8["m"]["w"], opt.Moment8)
    # int8 state is ~4x smaller than fp32 m+v
    assert opt.state_bytes(s8) < 0.45 * opt.state_bytes(s32)
    for i in range(5):
        g = {"w": jnp.asarray(rng.standard_normal((4, 256)).astype(np.float32))}
        p32, s32, _ = opt.apply_updates(cfg32, p32, g, s32)
        p8, s8, _ = opt.apply_updates(cfg8, p8, g, s8)
    diff = float(jnp.max(jnp.abs(p32["w"] - p8["w"])))
    scale = float(jnp.max(jnp.abs(w0 - p32["w"])))
    assert diff < 0.25 * scale  # quantized path tracks fp32 updates


def test_lr_schedule_shape():
    cfg = opt.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_frac=0.1)
    lrs = [float(opt.lr_schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, abs=0.02)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=0.02)


def test_grad_clipping():
    cfg = opt.OptimizerConfig(clip_norm=1.0)
    big = {"w": jnp.full((10,), 100.0)}
    clipped, norm = opt.clip_by_global_norm(big, 1.0)
    assert float(norm) == pytest.approx(100.0 * np.sqrt(10), rel=1e-4)
    assert float(opt.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


# --------------------------------------------------------------------------
# checkpoint / restart
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_prune(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for step in (10, 20, 30, 40):
        ckpt.save(d, step, {"state": tree}, keep_last=2)
    assert ckpt.latest_step(d) == 40
    assert sorted(os.listdir(d)) == ["step_00000030", "step_00000040"]
    step, restored = ckpt.restore(d, {"state": tree})
    assert step == 40
    np.testing.assert_array_equal(np.asarray(restored["state"]["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_ignores_incomplete(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.ones(3)}
    ckpt.save(d, 1, {"state": tree})
    os.makedirs(os.path.join(d, "step_00000099"))  # no manifest: incomplete
    assert ckpt.latest_step(d) == 1


def test_async_writer(tmp_path):
    d = str(tmp_path / "ck")
    w = ckpt.AsyncWriter(d)
    w.submit(5, {"state": {"a": jnp.ones(3)}})
    w.wait()
    assert ckpt.latest_step(d) == 5


def test_supervisor_restarts_and_replays_exactly(tmp_path):
    """A mid-run crash must not change the final state (exactly-once)."""
    d = str(tmp_path / "ck")

    def make_step(fail_at):
        calls = {"n": 0}

        def step_fn(step, state):
            if step == fail_at and calls["n"] == 0:
                calls["n"] += 1
                raise RuntimeError("injected node failure")
            return {"x": state["x"] + step}
        return step_fn

    sup = Supervisor(d, save_every=2, max_restarts=2, async_save=False)
    final_step, state = sup.run({"x": jnp.zeros(())}, make_step(fail_at=5),
                                0, 8)
    assert sup.restarts == 1
    # reference: uninterrupted run
    want = 0.0
    for s in range(8):
        want += s
    assert float(state["x"]) == want


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    def always_fail(step, state):
        raise RuntimeError("dead host")
    sup = Supervisor(str(tmp_path / "ck"), save_every=100, max_restarts=2,
                     async_save=False)
    with pytest.raises(RuntimeError, match="max_restarts"):
        sup.run({"x": jnp.zeros(())}, always_fail, 0, 5)


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(threshold=2.0, ewma=0.0)
    for step in range(5):
        rep = mon.record(step, {0: 1.0, 1: 1.1, 2: 0.9, 3: 5.0})
    assert rep.stragglers == [3]


# --------------------------------------------------------------------------
# sketch-based gradient compression
# --------------------------------------------------------------------------

def test_compression_error_feedback_converges():
    """EF + top-k on a heavy-tailed gradient (the feature's contract):
    repeated compression transmits the heavy mass, residual stays bounded."""
    cfg = CompressionConfig(enabled=True, width=5, ratio=4.0, min_size=256)
    rng = np.random.default_rng(0)
    dense = rng.standard_normal((32, 32)).astype(np.float32) * 0.05
    heavy_idx = rng.choice(1024, size=20, replace=False)
    dense.reshape(-1)[heavy_idx] += rng.choice([-5.0, 5.0], size=20).astype(np.float32)
    g = {"w": jnp.asarray(dense)}
    state = init_compression(cfg, g, jax.random.PRNGKey(0))
    acc = np.zeros((32, 32), np.float32)
    resid_norms = []
    for i in range(30):
        est, state, met = compress_decompress(cfg, g, state)
        acc += np.asarray(est["w"])
        resid_norms.append(float(np.linalg.norm(np.asarray(state.residual["w"]))))
    rel = np.linalg.norm(acc / 30 - dense) / np.linalg.norm(dense)
    assert rel < 0.25, rel                       # heavy mass transmitted
    g_norm = float(np.linalg.norm(dense))
    # residual = sub-threshold light mass; with a constant test gradient it
    # accumulates at most linearly (EF recycles it once it crosses the
    # selection threshold) -- no exponential blowup
    assert resid_norms[-1] < 3.0 * g_norm, resid_norms[-1]
    light_norm = float(np.linalg.norm(
        np.where(np.abs(dense) < 1.0, dense, 0.0)))
    assert resid_norms[-1] <= 40 * light_norm


def test_compression_ratio_reported():
    cfg = CompressionConfig(enabled=True, width=3, ratio=8.0, min_size=256)
    params = {"w": jnp.zeros((64, 64)), "b": jnp.zeros((8,))}
    r = compression_ratio(cfg, params)
    assert 4.0 < r < 16.0


def test_small_leaves_pass_through():
    cfg = CompressionConfig(enabled=True, min_size=1 << 20)
    g = {"w": jnp.ones((8, 8))}
    state = init_compression(cfg, g, jax.random.PRNGKey(0))
    est, state, _ = compress_decompress(cfg, g, state)
    np.testing.assert_array_equal(np.asarray(est["w"]), np.ones((8, 8)))


# --------------------------------------------------------------------------
# train loop integration
# --------------------------------------------------------------------------

def test_train_loop_descends_and_sketch_counts_exact(tmp_path):
    cfg = get_reduced("gemma-7b")
    tcfg = tl.TrainConfig(optimizer=opt.OptimizerConfig(lr=2e-3,
                                                        total_steps=40))
    state, hist = tl.train(cfg, tcfg, num_steps=12, batch=4, seq=32,
                           key=jax.random.PRNGKey(0))
    assert hist["loss"][-1] < hist["loss"][0]
    # in-step sketch total == #bigram occurrences seen
    tbl = np.asarray(state["sketch_table"])
    per_row = tbl.sum(axis=1)
    assert (per_row == 12 * 4 * 31).all()


def test_microbatching_matches_single_batch_loss():
    cfg = get_reduced("starcoder2-7b")
    base = tl.TrainConfig(optimizer=opt.OptimizerConfig(lr=0.0, clip_norm=1e9,
                                                        weight_decay=0.0),
                          sketch_enabled=False)
    import dataclasses
    micro = dataclasses.replace(base, microbatches=2)
    state0 = tl.init_train_state(cfg, base, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab_size)}
    _, m1 = tl.make_train_step(cfg, base)(state0, batch)
    _, m2 = tl.make_train_step(cfg, micro)(state0, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=5e-3)


# --------------------------------------------------------------------------
# gradient compression: budget, exact-k, jittability, descent, DP
# --------------------------------------------------------------------------

def test_compression_budget_never_exceeded():
    """Regression grid for the floor split: prod(ranges) <= h for every
    (shape, ratio, width, beta) combination.  The old round()-based split
    overshot the cell budget by up to ~2x for small tables, silently
    reporting a better compression ratio than it delivered."""
    import itertools
    from repro.training.grad_compression import _leaf_spec

    grid = itertools.product(
        [(32, 32), (128, 64), (7, 13), (4096,), (3, 5, 64), (2, 100_000)],
        [2.0, 8.0, 64.0],
        [1, 3, 5],
        [0.25, 1.0, 4.0],
    )
    for shape, ratio, width, beta in grid:
        cfg = CompressionConfig(ratio=ratio, width=width,
                                beta_rows_cols=beta)
        spec = _leaf_spec(cfg, shape)
        n = int(np.prod(shape))
        h = max(64, int(n / (ratio * width)))
        assert int(np.prod(spec.ranges)) <= h, \
            (shape, ratio, width, beta, spec.ranges, h)
        assert all(r >= 2 for r in spec.ranges)


def test_compression_selects_exactly_k():
    """Tie-heavy gradient: dozens of coordinates share the k-th magnitude.
    top_k index selection must return exactly plan.k coordinates -- the old
    ``|est| >= thresh`` mask shipped every tied coordinate, blowing the
    second-round budget."""
    from repro.training import grad_compression as gc

    cfg = CompressionConfig(enabled=True, width=5, ratio=4.0, min_size=256,
                            k=8)
    g_np = np.zeros((32, 32), np.float32)
    g_np.reshape(-1)[:64] = 3.0          # 64-way tie, k = 8
    g = {"w": jnp.asarray(g_np)}
    state = init_compression(cfg, g, jax.random.PRNGKey(0))
    comp = state.compressors["w"]
    assert comp.plan.k == 8
    est, state, _ = compress_decompress(cfg, g, state)
    nnz = int(np.sum(np.asarray(est["w"]) != 0))
    assert nnz == 8, nnz
    # shipped values are the exact gradient entries (second round)
    sent = np.asarray(est["w"]).reshape(-1)
    np.testing.assert_array_equal(np.unique(sent[sent != 0]), [3.0])


def test_compression_jittable_with_cached_state():
    """compress_decompress traces under jit with the state as a pytree
    argument: specs/coords/descent geometry are frozen in the state at
    init (LeafCompressor aux data), not rebuilt per call."""
    cfg = CompressionConfig(enabled=True, width=3, ratio=4.0, min_size=256)
    rng = np.random.default_rng(7)
    g = {"w": jnp.asarray(rng.standard_normal((32, 32)).astype(np.float32))}
    state = init_compression(cfg, g, jax.random.PRNGKey(1))
    jitted = jax.jit(compress_decompress, static_argnums=0)
    est_j, state_j, met_j = jitted(cfg, g, state)
    est_e, state_e, met_e = compress_decompress(cfg, g, state)
    np.testing.assert_allclose(np.asarray(est_j["w"]),
                               np.asarray(est_e["w"]))
    np.testing.assert_allclose(np.asarray(state_j.residual["w"]),
                               np.asarray(state_e.residual["w"]))
    # second call hits the jit cache (same treedef/aux): no retrace error
    jitted(cfg, est_j, state_j)


def test_compression_descent_matches_dense_dequery():
    """Beam descent (k << rows) finds the same above-noise-floor top-k
    coordinates as an exhaustive dense dequery of every coordinate.

    Uses a row-resolving split (beta_rows_cols skews the budget until
    ranges[0] == rows): that is the regime where _leaf_plan enables row
    pruning.  Tail slots at the noise floor may differ -- descent only
    scans beam rows, so which near-zero coordinate fills the last slots
    is arbitrary in both paths -- but every estimate above half the
    planted magnitude must be selected identically.
    """
    from repro.core import countsketch as cs
    from repro.training import grad_compression as gc

    cfg = CompressionConfig(enabled=True, width=5, ratio=2.0, min_size=256,
                            beta_rows_cols=256.0, k=24)
    rows, cols = 1024, 64
    rng = np.random.default_rng(8)
    g_np = rng.standard_normal((rows, cols)).astype(np.float32) * 0.01
    hot_rows = rng.choice(rows, 12, replace=False)
    hot_cols = rng.integers(0, cols, 12)
    hot = hot_rows * cols + hot_cols
    g_np.reshape(-1)[hot] += rng.choice([-8.0, 8.0], 12).astype(np.float32)
    g = {"w": jnp.asarray(g_np)}
    state = init_compression(cfg, g, jax.random.PRNGKey(2))
    comp = state.compressors["w"]
    plan = comp.plan
    assert plan.hspec.levels[-1].ranges[0] == rows  # row-resolving level 0
    assert plan.beam < plan.rows                    # actually pruning rows

    vals = jnp.asarray(g_np.reshape(-1))
    tables = tuple(jnp.zeros((s.width, s.table_size), jnp.float32)
                   for s in plan.hspec.levels)
    tables = cs.hier_fold_tables(plan.hspec, comp.params, tables,
                                 comp.coords, vals)
    descent = set(np.asarray(
        gc._descend_topk(plan, comp.params, tables)).tolist())

    hstate = cs.CountSketchHierarchy(comp.params, tables)
    dense = np.asarray(cs.hier_query(plan.hspec, hstate, 1, comp.coords))
    dense_top = set(np.argsort(-np.abs(dense))[: plan.k].tolist())
    floor = 4.0   # half the planted magnitude: separates heavy from noise
    assert {c for c in descent if abs(dense[c]) > floor} == \
           {c for c in dense_top if abs(dense[c]) > floor}
    assert set(hot.tolist()) <= descent   # every heavy coordinate found
    assert set(hot.tolist()) <= dense_top


def test_compression_bytes_ratio_accounting():
    """compression_ratio reports BYTES shipped: f32 tables of every level
    + the 8k-byte second round, against the leaf's own dtype."""
    from repro.training.grad_compression import _leaf_plan

    cfg = CompressionConfig(enabled=True, width=3, ratio=8.0, min_size=256)
    params = {"w": jnp.zeros((64, 64)), "b": jnp.zeros((8,))}
    plan = _leaf_plan(cfg, (64, 64))
    table_bytes = 4 * sum(s.width * s.table_size
                          for s in plan.hspec.levels)
    expect = (64 * 64 * 4) / (table_bytes + 8 * plan.k)
    assert compression_ratio(cfg, params) == pytest.approx(expect)
    # bf16 leaves ship half the raw bytes -> half the ratio
    params16 = {"w": jnp.zeros((64, 64), jnp.bfloat16)}
    assert compression_ratio(cfg, params16) == pytest.approx(expect / 2)


def test_compression_dp_tables_allreduce():
    """2-device pmap with axis_name: tables (not gradients) cross the DP
    axis; replicas stay bit-identical and identical per-device batches
    reproduce the single-device result."""
    import subprocess, sys, textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.training import grad_compression as gc

        cfg1 = gc.CompressionConfig(enabled=True, width=5, ratio=4.0,
                                    min_size=256)
        cfg2 = gc.CompressionConfig(enabled=True, width=5, ratio=4.0,
                                    min_size=256, axis_name="dp")
        rng = np.random.default_rng(0)
        g_np = rng.standard_normal((32, 32)).astype(np.float32)
        grads = {"w": jnp.asarray(g_np), "b": jnp.asarray(
            rng.standard_normal(8).astype(np.float32))}
        state = gc.init_compression(cfg1, grads, jax.random.PRNGKey(0))

        out1, st1, _ = gc.compress_decompress(cfg1, grads, state)

        step = jax.pmap(lambda g, s: gc.compress_decompress(cfg2, g, s),
                        axis_name="dp")
        g2 = jax.tree.map(lambda x: jnp.stack([x, x]), grads)
        s2 = jax.tree.map(lambda x: jnp.stack([x, x]), state)
        out2, st2, _ = step(g2, s2)

        w = np.asarray(out2["w"])
        assert np.array_equal(w[0], w[1]), "replicas diverged"
        np.testing.assert_allclose(w[0], np.asarray(out1["w"]),
                                   rtol=1e-6, atol=1e-6)
        # passthrough leaves are pmean'd too
        b = np.asarray(out2["b"])
        np.testing.assert_allclose(b[0], np.asarray(grads["b"]),
                                   rtol=1e-6)
        # different per-device grads: selection still agrees (merged
        # tables are identical), replicas remain bit-identical
        gA = jax.tree.map(
            lambda x: jnp.stack([x, jnp.zeros_like(x)]), grads)
        outA, stA, _ = step(gA, s2)
        wA = np.asarray(outA["w"])
        assert np.array_equal(wA[0], wA[1]), "replicas diverged (mixed)"
        print("DP OK")
    """
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"stderr:\\n{out.stderr[-4000:]}"
    assert "DP OK" in out.stdout
