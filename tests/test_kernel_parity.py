"""Pallas kernels vs the core/sketch.py reference path (not just the jnp
oracles in kernels/ref.py): same params, same stream => same table, same
estimates, across the paper's three spec families, both table dtypes, and
table widths that are not a multiple of the kernel tile."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.kernels.ops import KernelSketch

_SCHEMA = KeySchema(domains=(1 << 32, 1 << 32))


def _spec_cases():
    # (name, spec, tile_h): every table_size is deliberately NOT a multiple
    # of its tile so the padding path is always exercised
    return [
        ("count-min", sk.count_min_spec(_SCHEMA, 1000, 3), 256),
        ("equal", sk.equal_sketch_spec(_SCHEMA, 1100, 2), 512),
        ("mod", sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (48, 90), 4), 512),
        ("mod-joint", sk.mod_sketch_spec(
            KeySchema(domains=(256,) * 4), [(0, 2), (1, 3)], (36, 45), 3), 256),
    ]


def _stream_for(spec, rng, b):
    items = np.stack(
        [rng.integers(0, d, b, dtype=np.uint64).astype(np.uint32)
         for d in spec.schema.domains], axis=1)
    freqs = rng.integers(1, 1 << 12, size=(b,)).astype(np.int32)
    return items, freqs


@pytest.mark.parametrize("name,spec,tile_h", _spec_cases())
def test_update_and_query_parity_int32(name, spec, tile_h):
    rng = np.random.default_rng(abs(hash(name)) % 2**32)
    assert spec.table_size % tile_h != 0, "case must exercise padding"
    ks = KernelSketch(spec, jax.random.PRNGKey(7), tile_h=tile_h,
                      block_b=128, interpret=True)
    items, freqs = _stream_for(spec, rng, 500)
    ks.update(items, freqs)

    core = sk.SketchState(
        params=ks.params,
        table=jnp.zeros((spec.width, spec.table_size), jnp.int32))
    core = sk.update_jit(spec, core, jnp.asarray(items), jnp.asarray(freqs))

    np.testing.assert_array_equal(np.asarray(ks.state().table),
                                  np.asarray(core.table))
    q = items[rng.choice(len(items), 97, replace=False)]
    np.testing.assert_array_equal(
        ks.query(q), np.asarray(sk.query_jit(spec, core, jnp.asarray(q))))


@pytest.mark.slow
@pytest.mark.parametrize("name,spec,tile_h", _spec_cases())
def test_update_parity_float32(name, spec, tile_h):
    """f32 tables (gradient sketches): one MXU contraction, tolerance-based
    because float accumulation order differs between the paths."""
    rng = np.random.default_rng(abs(hash(name + "f32")) % 2**32)
    ks = KernelSketch(spec, jax.random.PRNGKey(9), tile_h=tile_h,
                      block_b=128, dtype=jnp.float32, interpret=True)
    items, _ = _stream_for(spec, rng, 500)
    vals = rng.standard_normal(500).astype(np.float32)
    ks.update(items, vals)

    core = sk.SketchState(
        params=ks.params,
        table=jnp.zeros((spec.width, spec.table_size), jnp.float32))
    core = sk.update_jit(spec, core, jnp.asarray(items), jnp.asarray(vals))

    np.testing.assert_allclose(np.asarray(ks.state().table),
                               np.asarray(core.table), rtol=1e-5, atol=1e-4)


def test_block_padding_is_neutral():
    """Stream length not a multiple of block_b: zero-padded tail items must
    not change any estimate (they hash somewhere but add freq 0)."""
    spec = sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (100, 41), 2)
    rng = np.random.default_rng(0)
    items, freqs = _stream_for(spec, rng, 131)  # 131 % 128 != 0
    ks = KernelSketch(spec, jax.random.PRNGKey(3), tile_h=128, block_b=128,
                      interpret=True)
    ks.update(items, freqs)
    core = sk.SketchState(
        params=ks.params,
        table=jnp.zeros((spec.width, spec.table_size), jnp.int32))
    core = sk.update_jit(spec, core, jnp.asarray(items), jnp.asarray(freqs))
    np.testing.assert_array_equal(np.asarray(ks.state().table),
                                  np.asarray(core.table))
