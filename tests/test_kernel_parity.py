"""Pallas kernels vs the core/sketch.py reference path (not just the jnp
oracles in kernels/ref.py): same params, same stream => same table, same
estimates, across the paper's three spec families, both table dtypes, and
table widths that are not a multiple of the kernel tile."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.kernels.ops import KernelSketch

_SCHEMA = KeySchema(domains=(1 << 32, 1 << 32))


def _spec_cases():
    # (name, spec, tile_h): every table_size is deliberately NOT a multiple
    # of its tile so the padding path is always exercised
    return [
        ("count-min", sk.count_min_spec(_SCHEMA, 1000, 3), 256),
        ("equal", sk.equal_sketch_spec(_SCHEMA, 1100, 2), 512),
        ("mod", sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (48, 90), 4), 512),
        ("mod-joint", sk.mod_sketch_spec(
            KeySchema(domains=(256,) * 4), [(0, 2), (1, 3)], (36, 45), 3), 256),
    ]


def _stream_for(spec, rng, b):
    items = np.stack(
        [rng.integers(0, d, b, dtype=np.uint64).astype(np.uint32)
         for d in spec.schema.domains], axis=1)
    freqs = rng.integers(1, 1 << 12, size=(b,)).astype(np.int32)
    return items, freqs


@pytest.mark.parametrize("name,spec,tile_h", _spec_cases())
def test_update_and_query_parity_int32(name, spec, tile_h):
    rng = np.random.default_rng(abs(hash(name)) % 2**32)
    assert spec.table_size % tile_h != 0, "case must exercise padding"
    ks = KernelSketch(spec, jax.random.PRNGKey(7), tile_h=tile_h,
                      block_b=128, interpret=True)
    items, freqs = _stream_for(spec, rng, 500)
    ks.update(items, freqs)

    core = sk.SketchState(
        params=ks.params,
        table=jnp.zeros((spec.width, spec.table_size), jnp.int32))
    core = sk.update_jit(spec, core, jnp.asarray(items), jnp.asarray(freqs))

    np.testing.assert_array_equal(np.asarray(ks.state().table),
                                  np.asarray(core.table))
    q = items[rng.choice(len(items), 97, replace=False)]
    np.testing.assert_array_equal(
        ks.query(q), np.asarray(sk.query_jit(spec, core, jnp.asarray(q))))


@pytest.mark.slow
@pytest.mark.parametrize("name,spec,tile_h", _spec_cases())
def test_update_parity_float32(name, spec, tile_h):
    """f32 tables (gradient sketches): one MXU contraction, tolerance-based
    because float accumulation order differs between the paths."""
    rng = np.random.default_rng(abs(hash(name + "f32")) % 2**32)
    ks = KernelSketch(spec, jax.random.PRNGKey(9), tile_h=tile_h,
                      block_b=128, dtype=jnp.float32, interpret=True)
    items, _ = _stream_for(spec, rng, 500)
    vals = rng.standard_normal(500).astype(np.float32)
    ks.update(items, vals)

    core = sk.SketchState(
        params=ks.params,
        table=jnp.zeros((spec.width, spec.table_size), jnp.float32))
    core = sk.update_jit(spec, core, jnp.asarray(items), jnp.asarray(vals))

    np.testing.assert_allclose(np.asarray(ks.state().table),
                               np.asarray(core.table), rtol=1e-5, atol=1e-4)


def _conservative_reference(spec, params, items, freqs, dtype):
    core = sk.SketchState(
        params=params,
        table=jnp.zeros((spec.width, spec.table_size), dtype))
    return sk.update_conservative(spec, core, jnp.asarray(items),
                                  jnp.asarray(freqs))


@pytest.mark.parametrize("name,spec,tile_h", _spec_cases())
def test_conservative_parity_int32(name, spec, tile_h):
    """Acceptance: conservative Pallas kernel bit-exact vs
    core.sketch.update_conservative, with duplicate keys inside one block
    (the sequential-dependence case) and non-tile-multiple widths."""
    rng = np.random.default_rng(abs(hash(name + "cons")) % 2**32)
    assert spec.table_size % tile_h != 0, "case must exercise padding"
    ks = KernelSketch(spec, jax.random.PRNGKey(7), tile_h=tile_h,
                      block_b=128, interpret=True, mode="conservative")
    items, freqs = _stream_for(spec, rng, 500)
    items[40:90] = items[0]       # heavy duplication inside block 0
    items[130:140] = items[129]   # ... and across the block-1 boundary
    ks.update(items, freqs)

    core = _conservative_reference(spec, ks.params, items, freqs, jnp.int32)
    np.testing.assert_array_equal(ks.table_view(), np.asarray(core.table))
    q = items[rng.choice(len(items), 97, replace=False)]
    np.testing.assert_array_equal(
        ks.query(q), np.asarray(sk.query_jit(spec, core, jnp.asarray(q))))


def test_conservative_parity_float32_bit_exact():
    """No MXU contraction in the conservative kernel => f32 is bit-exact
    too (gather/min/add/max in reference order), unlike the linear kernel's
    tolerance-based f32 parity."""
    spec = sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (48, 90), 4)
    rng = np.random.default_rng(1)
    items, _ = _stream_for(spec, rng, 300)
    items[50:80] = items[49]
    vals = (rng.standard_normal(300).astype(np.float32) ** 2)  # >= 0
    ks = KernelSketch(spec, jax.random.PRNGKey(9), tile_h=512, block_b=128,
                      dtype=jnp.float32, interpret=True, mode="conservative")
    ks.update(items, vals)
    core = _conservative_reference(spec, ks.params, items, vals, jnp.float32)
    np.testing.assert_array_equal(ks.table_view(), np.asarray(core.table))


def test_conservative_chunked_b_variant_matches():
    """Small VMEM budget => chunk_b < B (chunked-B grid); same result."""
    from repro.kernels.hashes import make_plan
    from repro.kernels.sketch_update import padded_table_size
    from repro.kernels.sketch_update_conservative import (
        conservative_chunk_b,
        sketch_update_conservative_pallas,
    )

    spec = sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (100, 41), 2)
    plan = make_plan(spec)
    params = sk.init_params(spec, jax.random.PRNGKey(3))
    rng = np.random.default_rng(2)
    items, freqs = _stream_for(spec, rng, 256)
    items[10:60] = items[9]
    chunks = spec.schema.module_chunks(jnp.asarray(items))
    h_pad = padded_table_size(spec.table_size, 128)
    t0 = jnp.zeros((spec.width, h_pad), jnp.int32)

    table_bytes = 2 * spec.width * h_pad * 4
    tight = table_bytes + 4 * 64 * (chunks.shape[1] * 4 + 4)
    chunk = conservative_chunk_b(256, chunks.shape[1], spec.width, h_pad, 4,
                                 vmem_limit_bytes=tight)
    assert 1 <= chunk < 256, chunk
    got = sketch_update_conservative_pallas(
        plan, t0, chunks, jnp.asarray(freqs), params.q, params.r,
        chunk_b=chunk, interpret=True)
    full = sketch_update_conservative_pallas(
        plan, t0, chunks, jnp.asarray(freqs), params.q, params.r,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(full))
    # table alone over budget => no chunk fits; wrapper takes reference path
    assert conservative_chunk_b(256, chunks.shape[1], spec.width, h_pad, 4,
                                vmem_limit_bytes=table_bytes - 1) is None
    # regression: non-power-of-two blocks must get a chunk that divides b
    # (the old halving loop returned e.g. 62 for b=1000 and crashed the
    # kernel's divisibility check), and a budget that fits the table but
    # not even one item's inputs must fall back to the reference path
    for b in (1000, 288, 7):
        ch = conservative_chunk_b(b, chunks.shape[1], spec.width, h_pad, 4,
                                  vmem_limit_bytes=tight)
        assert ch is not None and b % ch == 0, (b, ch)
    assert conservative_chunk_b(256, chunks.shape[1], spec.width, h_pad, 4,
                                vmem_limit_bytes=table_bytes + 1) is None


def test_conservative_vmem_fallback_reference_path(monkeypatch):
    """When the table working set exceeds VMEM the wrapper must route to
    core.sketch.update_conservative, bit-for-bit."""
    import repro.kernels.ops as ops_mod

    spec = sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (48, 90), 4)
    rng = np.random.default_rng(5)
    items, freqs = _stream_for(spec, rng, 200)
    items[20:50] = items[19]
    monkeypatch.setattr(ops_mod, "conservative_chunk_b",
                        lambda *a, **k: None)
    ks = KernelSketch(spec, jax.random.PRNGKey(7), tile_h=512, block_b=128,
                      interpret=True, mode="conservative")
    ks.update(items, freqs)
    core = _conservative_reference(spec, ks.params, items, freqs, jnp.int32)
    np.testing.assert_array_equal(ks.table_view(), np.asarray(core.table))


def test_freq_guard_rejects_negative_and_large_magnitude():
    """Regression: the old guard only checked max >= 2^24, so negative and
    large-magnitude-negative frequencies slipped into the 12-bit limb
    split.  Int tables must reject both; f32 tables keep negatives
    (gradient sketches)."""
    spec = sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (100, 41), 2)
    rng = np.random.default_rng(0)
    items, _ = _stream_for(spec, rng, 8)

    ks = KernelSketch(spec, jax.random.PRNGKey(3), tile_h=128, block_b=8,
                      interpret=True)
    with pytest.raises(ValueError, match="negative"):
        ks.update(items, np.array([1, -1, 1, 1, 1, 1, 1, 1], np.int32))
    with pytest.raises(ValueError, match="2\\^24"):
        ks.update(items, np.full(8, -(1 << 30), np.int64))
    with pytest.raises(ValueError, match="2\\^24"):
        ks.update(items, np.full(8, 1 << 24, np.int64))
    assert ks.table_view().max() == 0  # nothing leaked into the table

    # f32 linear: negatives allowed (turnstile / gradient values)
    ksf = KernelSketch(spec, jax.random.PRNGKey(3), tile_h=128, block_b=8,
                       dtype=jnp.float32, interpret=True)
    ksf.update(items, np.array([0.5, -0.5] * 4, np.float32))

    # conservative rejects negatives on any dtype (silent no-op otherwise)
    for dtype in (jnp.int32, jnp.float32):
        ksc = KernelSketch(spec, jax.random.PRNGKey(3), tile_h=128, block_b=8,
                           dtype=dtype, interpret=True, mode="conservative")
        with pytest.raises(ValueError, match="non-negative"):
            ksc.update(items, np.array([1, -2] * 4, np.int32))

    # ... but has no limb split, so f >= 2^24 stays valid and bit-exact
    ksc = KernelSketch(spec, jax.random.PRNGKey(3), tile_h=128, block_b=8,
                       interpret=True, mode="conservative")
    big = np.full(8, 1 << 25, np.int64)
    ksc.update(items, big)
    core = _conservative_reference(spec, ksc.params, items, big, jnp.int32)
    np.testing.assert_array_equal(ksc.table_view(), np.asarray(core.table))
    # values past the int32 table range would wrap negative in the cast and
    # silently no-op: rejected instead
    with pytest.raises(ValueError, match="table range"):
        ksc.update(items, np.full(8, 1 << 31, np.int64))

    # NaN weights would poison every touched f32 cell (query would then
    # UNDERestimate); the guard must catch them, not just f < 0
    ksf32c = KernelSketch(spec, jax.random.PRNGKey(3), tile_h=128, block_b=8,
                          dtype=jnp.float32, interpret=True,
                          mode="conservative")
    nan_f = np.array([1.0, 1.0, np.nan, 1.0, 1.0, 1.0, 1.0, 1.0], np.float32)
    with pytest.raises(ValueError, match="non-negative"):
        ksf32c.update(items, nan_f)


def test_block_padding_is_neutral():
    """Stream length not a multiple of block_b: zero-padded tail items must
    not change any estimate (they hash somewhere but add freq 0)."""
    spec = sk.mod_sketch_spec(_SCHEMA, [(0,), (1,)], (100, 41), 2)
    rng = np.random.default_rng(0)
    items, freqs = _stream_for(spec, rng, 131)  # 131 % 128 != 0
    ks = KernelSketch(spec, jax.random.PRNGKey(3), tile_h=128, block_b=128,
                      interpret=True)
    ks.update(items, freqs)
    core = sk.SketchState(
        params=ks.params,
        table=jnp.zeros((spec.width, spec.table_size), jnp.int32))
    core = sk.update_jit(spec, core, jnp.asarray(items), jnp.asarray(freqs))
    np.testing.assert_array_equal(np.asarray(ks.state().table),
                                  np.asarray(core.table))
