"""Thm 6 / Table I: Bell numbers and partition enumeration."""
import pytest

from repro.core.partition import all_partitions, bell_number, canonical

# paper Table I, verbatim
TABLE_I = {1: 1, 2: 2, 3: 5, 4: 15, 5: 52, 6: 203, 7: 877, 8: 4140,
           9: 21147, 10: 115975, 11: 678570}


def test_bell_numbers_match_table_1():
    for n, t in TABLE_I.items():
        assert bell_number(n) == t


def test_bell_grows_faster_than_2n():
    """Paper: for n > 4, T(n) > 2^n and diverges from it."""
    for n in range(5, 12):
        assert bell_number(n) > 2 ** n


def test_enumeration_count_matches_bell():
    for n in range(1, 7):
        parts = list(all_partitions(range(n)))
        assert len(parts) == bell_number(n)
        assert len(set(parts)) == len(parts)          # no duplicates
        for p in parts:
            flat = sorted(m for g in p for m in g)
            assert flat == list(range(n))             # exact cover


def test_canonical_ordering():
    assert canonical([[2, 0], [1]]) == ((0, 2), (1,))
    assert canonical([(1,), (0, 2)]) == ((0, 2), (1,))
