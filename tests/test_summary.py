"""Space-saving summary unit tests (core/summary.py)."""
import numpy as np
import pytest

from repro.core.summary import SpaceSaving


def _rows(*vals):
    return np.asarray(vals, dtype=np.uint32).reshape(-1, 1)


def test_late_heavy_value_evicts_lightest():
    s = SpaceSaving(capacity=3, n_cols=1)
    s.offer(_rows(1, 2, 3), np.array([5, 1, 4]))
    s.offer(_rows(9), np.array([100]))
    got = set(s.values()[:, 0].tolist())
    assert got == {1, 3, 9}           # 2 (count 1) evicted
    # inherited floor keeps the overestimate property
    assert s.counts()[(9,)] == 101


def test_counts_only_overestimate_and_wm_bound():
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 50, size=2000).astype(np.uint32).reshape(-1, 1)
    freqs = rng.integers(1, 10, size=2000)
    s = SpaceSaving(capacity=16, n_cols=1)
    for i in range(0, 2000, 100):
        s.offer(vals[i : i + 100], freqs[i : i + 100])
    true = {}
    for v, f in zip(vals[:, 0].tolist(), freqs.tolist()):
        true[v] = true.get(v, 0) + int(f)
    w = sum(true.values())
    for row, c in s.counts().items():
        assert c >= true.get(row[0], 0)               # overestimate only
        assert c - true.get(row[0], 0) <= w / 16 + 1  # inherited error bound


def test_fractional_weights_admit():
    """Regression: int64-truncated totals dropped every sub-1.0 weight, so
    f32 gradient streams never populated the candidate pools."""
    s = SpaceSaving(capacity=4, n_cols=1)
    s.offer(_rows(1, 2, 3), np.array([0.5, 0.9, 0.4], np.float32))
    assert len(s) == 3
    assert s.counts()[(2,)] == pytest.approx(0.9)
    # zero-weight pad rows still stay out
    s.offer(_rows(7), np.array([0.0]))
    assert (7,) not in s.counts()


def test_merge_absent_rows_get_min_count_floor():
    """Regression: merge must substitute a full side's min count for absent
    rows (the mergeable-summaries rule) -- contributing 0 instead broke
    count(v) >= true(v) for rows evicted on one shard, so a globally heavy
    value could be out-ranked by light survivors after merge_from."""
    a = SpaceSaving(capacity=2, n_cols=1)
    b = SpaceSaving(capacity=2, n_cols=1)
    # v=7 (weight 10 per shard) is evicted on both shards by weight-12 rows
    a.offer(_rows(7), np.array([10]))
    a.offer(_rows(1, 2), np.array([12, 12]))
    b.offer(_rows(7), np.array([10]))
    b.offer(_rows(3, 4), np.array([12, 12]))
    m_a = min(a.counts().values())
    m_b = min(b.counts().values())
    a.merge_from(b)
    # every retained count includes the other side's floor, so it still
    # upper-bounds the true weight of ANY row, including evicted v=7
    for row, c in a.counts().items():
        assert c >= m_a + m_b >= 20  # true(7) = 20 stays dominated
    # under-capacity sides add no floor (absent there means truly unseen)
    c2 = SpaceSaving(capacity=4, n_cols=1)
    c2.offer(_rows(1), np.array([12]))
    d = SpaceSaving(capacity=3, n_cols=1)
    d.offer(_rows(8, 9), np.array([5, 6]))
    d.merge_from(c2)
    assert d.counts()[(8,)] == 5 and d.counts()[(1,)] == 12
    e = SpaceSaving(capacity=1, n_cols=1)
    e.offer(_rows(5), np.array([9]))
    d2 = SpaceSaving(capacity=3, n_cols=1)
    d2.offer(_rows(8, 9), np.array([5, 6]))
    d2.merge_from(e)  # e is full with min 9: rows absent from e get +9
    assert d2.counts()[(8,)] == 5 + 9 and d2.counts()[(9,)] == 6 + 9
    assert d2.counts()[(5,)] == 9  # d2 under capacity: no floor from d2


def test_merge_keeps_heavy_from_both_shards():
    a = SpaceSaving(capacity=3, n_cols=1)
    b = SpaceSaving(capacity=3, n_cols=1)
    a.offer(_rows(1, 2, 3), np.array([50, 1, 2]))
    b.offer(_rows(4, 5, 2), np.array([60, 1, 1]))
    a.merge_from(b)
    got = set(a.values()[:, 0].tolist())
    assert {1, 4} <= got and len(a) == 3
    # eviction after a merge still works (heap rebuilt over merged counts)
    a.offer(_rows(8), np.array([500]))
    assert 8 in set(a.values()[:, 0].tolist())
    with pytest.raises(ValueError, match="widths"):
        a.merge_from(SpaceSaving(capacity=3, n_cols=2))


def test_multiway_fold_overestimates_and_admits_heavy():
    """SpaceSaving.fold across shards (the sharded serving candidate-pool
    sync): counts keep upper-bounding true weights and any value past the
    W/m admission bound survives, however the stream was split."""
    rng = np.random.default_rng(7)
    n = 400
    vals = rng.integers(0, 60, size=n).astype(np.uint32).reshape(-1, 1)
    freqs = rng.integers(1, 6, size=n).astype(np.int64)
    # one globally heavy value spread evenly across shards
    vals[::8] = 99
    freqs[::8] = 10
    true = {}
    for v, f in zip(vals[:, 0].tolist(), freqs.tolist()):
        true[v] = true.get(v, 0) + int(f)
    w_total = sum(true.values())
    m = 16
    assert true[99] > w_total / m  # past the admission bound

    for n_shards in (2, 4):
        bounds = np.linspace(0, n, n_shards + 1).astype(int)
        shards = []
        for s, e in zip(bounds[:-1], bounds[1:]):
            p = SpaceSaving(capacity=m, n_cols=1)
            p.offer(vals[s:e], freqs[s:e])
            shards.append(p)
        folded = SpaceSaving.fold(shards)
        assert len(folded) <= m
        assert (99,) in folded.counts()                # admitted
        for row, c in folded.counts().items():
            assert c >= true.get(row[0], 0)            # overestimate only
        # fold == iterative merge_from (same cascade)
        it = SpaceSaving(capacity=m, n_cols=1)
        for p in shards:
            it.merge_from(p)
        assert folded.counts() == it.counts()


def test_fold_min_count_floor_accumulates_across_shards():
    """Rows absent from a full shard inherit that shard's min-count floor,
    and the floors add up across a multi-way fold -- so a value evicted on
    every shard still cannot out-rank the retained overestimates."""
    shards = []
    for base in (0, 10, 20):
        p = SpaceSaving(capacity=2, n_cols=1)
        p.offer(_rows(7), np.array([4]))               # evicted below
        p.offer(_rows(base + 1, base + 2), np.array([6, 5]))
        shards.append(p)
    floors = [min(p.counts().values()) for p in shards]
    folded = SpaceSaving.fold(shards)
    # every retained count >= the sum of the other shards' floors + its own
    # observed mass; in particular >= true(7) = 12 for any retained row
    for row, c in folded.counts().items():
        assert c >= sum(floors) - max(floors) + 5
    # under-capacity folds are exact unions: no floors, no truncation
    a = SpaceSaving(capacity=8, n_cols=1)
    b = SpaceSaving(capacity=8, n_cols=1)
    a.offer(_rows(1, 2), np.array([3, 4]))
    b.offer(_rows(2, 3), np.array([5, 6]))
    u = SpaceSaving.fold([a, b])
    assert u.counts() == {(1,): 3, (2,): 9, (3,): 6}


def test_fold_validation():
    with pytest.raises(ValueError, match="at least one"):
        SpaceSaving.fold([])
    with pytest.raises(ValueError, match="widths"):
        SpaceSaving.fold([SpaceSaving(capacity=2, n_cols=1),
                          SpaceSaving(capacity=2, n_cols=2)])


def test_lazy_heap_stays_bounded():
    """Regression: repeated increments of resident rows pushed one stale
    heap entry each and nothing ever drained them under capacity."""
    s = SpaceSaving(capacity=8, n_cols=1)
    hot = _rows(1, 2, 3)
    for _ in range(200):
        s.offer(hot, np.array([1, 1, 1]))
    assert len(s._heap) <= 4 * s.capacity
    assert s.counts()[(1,)] == 200
    # eviction still finds the true minimum after compactions
    s.offer(_rows(4, 5, 6, 7, 8), np.ones(5))
    s.offer(_rows(9), np.array([50]))
    assert 9 in set(s.values()[:, 0].tolist())
    assert {1, 2, 3} <= set(s.values()[:, 0].tolist())


def test_validation():
    with pytest.raises(ValueError):
        SpaceSaving(capacity=0, n_cols=1)
    s = SpaceSaving(capacity=2, n_cols=2)
    with pytest.raises(ValueError, match="\\[N, 2\\]"):
        s.offer(np.zeros((3, 1), np.uint32))
