"""benchmarks/diff_bench.py: warn-only trajectory diff + first-run seeding.

Runs the module as a subprocess exactly like the CI perf-trajectory step
does, against synthetic BENCH_*.json artifacts in a tmp dir.  The
contract: exit code 0 ALWAYS; regressions/disappearances surface as
``::warning::`` lines; a missing/empty/unparseable prior is "no prior",
and ``--seed-baseline`` turns that into a copied baseline so a freshly
added artifact (BENCH_MIGRATE.json) starts its trajectory immediately.
"""
import json
import os
import subprocess
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _artifact(rows):
    return {"results": [
        {"name": n, "us_per_call": us, "derived": {}, "raw": ""}
        for n, us in rows]}


def _write(path, payload):
    with open(path, "w") as f:
        if isinstance(payload, str):
            f.write(payload)
        else:
            json.dump(payload, f)


def _diff(*argv):
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.diff_bench", *argv],
        capture_output=True, text=True, cwd=_ROOT, timeout=120)
    return out


def test_regression_and_disappearance_warn_but_exit_zero(tmp_path):
    old = tmp_path / "OLD.json"
    new = tmp_path / "NEW.json"
    _write(old, _artifact([("a", 100.0), ("b", 100.0), ("gone", 50.0)]))
    _write(new, _artifact([("a", 500.0), ("b", 101.0)]))
    out = _diff(str(old), str(new))
    assert out.returncode == 0
    assert "::warning::bench regression a:" in out.stdout
    assert "::warning::bench row disappeared: gone" in out.stdout
    assert "regression b" not in out.stdout
    assert "1 regression(s)" in out.stdout


def test_improvement_reported_not_warned(tmp_path):
    old = tmp_path / "OLD.json"
    new = tmp_path / "NEW.json"
    _write(old, _artifact([("a", 500.0)]))
    _write(new, _artifact([("a", 100.0)]))
    out = _diff(str(old), str(new))
    assert out.returncode == 0
    assert "bench improvement a" in out.stdout
    assert "::warning::" not in out.stdout


def test_missing_prior_is_first_run(tmp_path):
    new = tmp_path / "NEW.json"
    _write(new, _artifact([("a", 100.0)]))
    out = _diff(str(tmp_path / "ABSENT.json"), str(new))
    assert out.returncode == 0
    assert "no prior" in out.stdout
    assert "::warning::" not in out.stdout


def test_empty_and_unparseable_prior_treated_as_no_prior(tmp_path):
    new = tmp_path / "NEW.json"
    _write(new, _artifact([("a", 100.0)]))
    # empty trajectory: an artifact with zero usable rows (all errored)
    empty = tmp_path / "EMPTY.json"
    _write(empty, _artifact([("a", -1.0)]))
    out = _diff(str(empty), str(new))
    assert out.returncode == 0
    assert "no usable rows" in out.stdout
    assert "::warning::" not in out.stdout
    # unparseable trajectory: truncated write from a killed CI box
    broken = tmp_path / "BROKEN.json"
    _write(broken, '{"results": [{"name": "a",')
    out = _diff(str(broken), str(new))
    assert out.returncode == 0
    assert "could not parse prior" in out.stdout


def test_seed_baseline_creates_trajectory(tmp_path):
    new = tmp_path / "BENCH_MIGRATE.json"
    _write(new, _artifact([("migrate/accuracy_retuned", 100.0)]))
    old = tmp_path / "bench-baseline" / "BENCH_MIGRATE.json"
    out = _diff(str(old), str(new), "--seed-baseline")
    assert out.returncode == 0
    assert "no prior" in out.stdout and "seeded baseline" in out.stdout
    assert json.load(open(old)) == json.load(open(new))
    # second run: the seeded baseline diffs cleanly against itself
    out2 = _diff(str(old), str(new), "--seed-baseline")
    assert out2.returncode == 0
    assert "0 regression(s), 0 improvement(s)" in out2.stdout


def test_seed_baseline_noop_without_flag(tmp_path):
    new = tmp_path / "NEW.json"
    _write(new, _artifact([("a", 100.0)]))
    old = tmp_path / "OLD.json"
    out = _diff(str(old), str(new))
    assert out.returncode == 0
    assert not old.exists()
