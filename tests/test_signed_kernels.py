"""Signed (Count-Sketch) mode of the kernel stack vs the jnp core.

Everything here is a bit-exactness or mode-contract test: the Pallas signed
update/query kernels (flat and fused-hierarchy), the separable signed
candidate grid, the sharded psum fold, and the ops-layer mode matrix
(merge rules, dtype guards).  Statistical properties of the estimator live
in tests/test_fcm_countsketch.py.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import countsketch as cs
from repro.core import distributed as dist
from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.core.hierarchy import HierarchySpec
from repro.kernels import ops
from repro.kernels.hashes import make_plan
from repro.kernels.hier_query import (
    hier_candidate_query_signed,
    hier_candidate_query_signed_ref,
)
from repro.kernels.hier_update import (
    hier_update_signed_pallas,
    hier_update_signed_ref,
    make_hier_plan,
)
from repro.kernels.sketch_query import sketch_query_signed_pallas
from repro.kernels.sketch_update import (
    padded_table_size,
    sketch_update_signed_pallas,
)


def _spec(w=5):
    schema = KeySchema(domains=(1 << 32, 1 << 20, 256))
    return sk.SketchSpec(schema, ((0,), (1, 2)), (32, 16), w)


def _stream(n=512, seed=0):
    rng = np.random.default_rng(seed)
    items = np.stack([
        rng.integers(0, 1 << 32, size=n, dtype=np.uint64).astype(np.uint32),
        rng.integers(0, 1 << 20, size=n, dtype=np.uint32),
        rng.integers(0, 256, size=n, dtype=np.uint32),
    ], axis=-1)
    freqs = rng.integers(-1000, 1000, size=n).astype(np.int32)
    return items, freqs


def _jnp_state(spec, params, dtype=jnp.int32):
    return cs.CountSketchState(
        params, jnp.zeros((spec.width, spec.table_size), dtype))


def test_flat_signed_update_kernel_bit_exact():
    """Pallas signed fold == jnp scatter reference on int32 tables, with
    negative (turnstile) weights in the stream."""
    spec = _spec()
    params = cs.init_params(spec, jax.random.key(0))
    items, freqs = _stream()
    plan = make_plan(spec)
    h_pad = padded_table_size(spec.table_size, 128)
    table = jnp.zeros((spec.width, h_pad), jnp.int32)
    chunks = jnp.asarray(spec.schema.module_chunks_np(items))
    out = sketch_update_signed_pallas(
        plan, table, chunks, jnp.asarray(freqs), params.base.q,
        params.base.r, params.sign_q, params.sign_r, tile_h=128,
        interpret=True)
    ref = cs.update(spec, _jnp_state(spec, params), jnp.asarray(items),
                    jnp.asarray(freqs))
    np.testing.assert_array_equal(
        np.asarray(out)[:, : spec.table_size], np.asarray(ref.table))


def test_flat_signed_query_kernel_bit_exact():
    spec = _spec()
    params = cs.init_params(spec, jax.random.key(1))
    items, freqs = _stream(seed=1)
    st = cs.update(spec, _jnp_state(spec, params), jnp.asarray(items),
                   jnp.asarray(freqs))
    h_pad = padded_table_size(spec.table_size, 128)
    table = jnp.pad(st.table, ((0, 0), (0, h_pad - spec.table_size)))
    plan = make_plan(spec)
    q_items = items[:100]
    chunks = jnp.asarray(spec.schema.module_chunks_np(q_items))
    rows = sketch_query_signed_pallas(
        plan, table, chunks, params.base.q, params.base.r, params.sign_q,
        params.sign_r, tile_h=128, interpret=True)
    ref_rows, ref_med = cs.query_rows(spec, st, jnp.asarray(q_items))
    np.testing.assert_array_equal(np.asarray(rows),
                                  np.asarray(ref_rows).astype(np.int32))
    np.testing.assert_allclose(
        np.median(np.asarray(rows).astype(np.float32), axis=0),
        np.asarray(ref_med))


def test_hier_signed_update_kernel_bit_exact():
    """Fused one-launch hierarchy fold == per-level jnp oracle == cascade."""
    spec = _spec()
    hspec = HierarchySpec.from_spec(spec)
    params = cs.init_params(spec, jax.random.key(2))
    items, freqs = _stream(n=256, seed=2)
    chunks = jnp.asarray(spec.schema.module_chunks_np(items))

    hplan = make_hier_plan(hspec, tile_h=128)
    table = jnp.zeros((spec.width, hplan.padded_cols), jnp.int32)
    out = hier_update_signed_pallas(
        hplan, table, chunks, jnp.asarray(freqs), params.base.q,
        params.base.r, params.sign_q, params.sign_r, interpret=True)
    ref = hier_update_signed_ref(
        hplan, jnp.zeros_like(table), chunks, jnp.asarray(freqs),
        params.base.q, params.base.r, params.sign_q, params.sign_r)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    hier0 = cs.CountSketchHierarchy(
        params, tuple(jnp.zeros((s.width, s.table_size), jnp.int32)
                      for s in hspec.levels))
    casc = cs.hier_update(hspec, hier0, jnp.asarray(items),
                          jnp.asarray(freqs))
    oracle = cs.hier_update_reference(hspec, hier0, jnp.asarray(items),
                                      jnp.asarray(freqs))
    for lvl, (a, b) in enumerate(zip(casc.tables, oracle.tables)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"level {lvl}")
    for lvl in range(hspec.n_levels):
        got = np.asarray(out)[:, hplan.level_offsets[lvl]:
                              hplan.level_offsets[lvl]
                              + hspec.levels[lvl].table_size]
        np.testing.assert_array_equal(got, np.asarray(casc.tables[lvl]),
                                      err_msg=f"fused level {lvl}")


def test_signed_candidate_grid_kernel_bit_exact():
    """Signed candidate-grid kernel == jnp ref == direct flat queries."""
    spec = _spec(w=3)
    hspec = HierarchySpec.from_spec(spec)
    params = cs.init_params(spec, jax.random.key(3))
    items, freqs = _stream(n=256, seed=3)
    hier = cs.CountSketchHierarchy(
        params, tuple(jnp.zeros((s.width, s.table_size), jnp.int32)
                      for s in hspec.levels))
    hier = cs.hier_update(hspec, hier, jnp.asarray(items),
                          jnp.asarray(freqs))

    prefixes = np.unique(items[:, :1], axis=0)[:24]
    values = np.unique(items[:, 1:], axis=0)[:16]
    pp, cp, sp, sc = cs.candidate_signed_partials(
        hspec, params, 1, jnp.asarray(prefixes), jnp.asarray(values))
    ker = hier_candidate_query_signed(hier.tables[1], pp, cp, sp, sc,
                                      tile_h=128, interpret=True)
    ref = hier_candidate_query_signed_ref(hier.tables[1], pp, cp, sp, sc)
    np.testing.assert_array_equal(np.asarray(ker).astype(np.float32),
                                  np.asarray(ref))

    grid = np.asarray(jnp.median(ref, axis=0))
    full = np.concatenate([
        np.repeat(prefixes, len(values), axis=0),
        np.tile(values, (len(prefixes), 1)),
    ], axis=1)
    flat = np.asarray(cs.hier_query(hspec, hier, 1, jnp.asarray(full)))
    np.testing.assert_allclose(grid.reshape(-1), flat)


def test_candidate_estimates_kernel_matches_ref_with_chunking():
    spec = _spec(w=3)
    hspec = HierarchySpec.from_spec(spec)
    params = cs.init_params(spec, jax.random.key(4))
    items, freqs = _stream(n=256, seed=4)
    hier = cs.CountSketchHierarchy(
        params, tuple(jnp.zeros((s.width, s.table_size), jnp.int32)
                      for s in hspec.levels))
    hier = cs.hier_update(hspec, hier, jnp.asarray(items),
                          jnp.asarray(freqs))
    prefixes = np.unique(items[:, :1], axis=0)[:17]  # odd: forces pad chunk
    values = np.unique(items[:, 1:], axis=0)[:8]
    a = cs.candidate_estimates(hspec, hier, 1, prefixes, values,
                               use_kernel=True, interpret=True, tile_h=128,
                               max_batch=40)
    b = cs.candidate_estimates(hspec, hier, 1, prefixes, values,
                               use_kernel=False)
    np.testing.assert_array_equal(a, b)


def test_ops_signed_sketch_matches_core():
    spec = _spec()
    items, freqs = _stream(seed=5)
    ks = ops.KernelSketch(spec, jax.random.key(5), mode="signed",
                          dtype=jnp.int32, interpret=True)
    ks.update(items[:300], freqs[:300])
    ks.update(items[300:], freqs[300:])
    ref = cs.update(spec, _jnp_state(spec, ks.cs_params),
                    jnp.asarray(items), jnp.asarray(freqs))
    np.testing.assert_array_equal(np.asarray(ks.cs_state().table),
                                  np.asarray(ref.table))
    qi = items[:64]
    np.testing.assert_allclose(
        ks.query(qi), np.asarray(cs.query(spec, ref, jnp.asarray(qi))))


def test_ops_signed_hierarchy_matches_core():
    spec = _spec()
    hspec = HierarchySpec.from_spec(spec)
    items, freqs = _stream(n=300, seed=6)
    kh = ops.KernelHierarchy(hspec, jax.random.key(6), mode="signed",
                             dtype=jnp.int32, interpret=True, tile_h=128,
                             block_b=128)
    kh.update(items, freqs)
    hier = cs.CountSketchHierarchy(
        kh.cs_params, tuple(jnp.zeros((s.width, s.table_size), jnp.int32)
                            for s in hspec.levels))
    hier = cs.hier_update(hspec, hier, jnp.asarray(items),
                          jnp.asarray(freqs))
    for lvl, (a, b) in enumerate(zip(kh.cs_state().tables, hier.tables)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"level {lvl}")


def test_sharded_signed_build_bit_exact():
    spec = _spec()
    params = cs.init_params(spec, jax.random.key(7))
    items, freqs = _stream(n=256, seed=7)
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    delta = dist.sharded_signed_build(
        spec, params, mesh, ("d",), jnp.asarray(items), jnp.asarray(freqs),
        table_dtype=jnp.int32)
    ref = cs.update(spec, _jnp_state(spec, params), jnp.asarray(items),
                    jnp.asarray(freqs))
    np.testing.assert_array_equal(np.asarray(delta), np.asarray(ref.table))


def test_mode_matrix_contracts():
    spec = _spec()
    items, freqs = _stream(n=64, seed=8)

    # signed merge requires identical params incl. the sign draw
    a = ops.KernelSketch(spec, jax.random.key(8), mode="signed",
                         interpret=True)
    b = ops.KernelSketch(spec, jax.random.key(9), mode="signed",
                         interpret=True)
    with pytest.raises(ValueError):
        a.merge(b)

    # signed x linear cannot merge
    c = ops.KernelSketch(spec, jax.random.key(8), mode="linear",
                         interpret=True)
    with pytest.raises(ValueError):
        a.merge(c)

    # conservative still refused by every distributed surface
    with pytest.raises(ValueError):
        dist.require_linear("conservative", "test")
    dist.require_linear("signed", "test")   # signed is linear: allowed
    dist.require_linear("linear", "test")

    # hierarchy refuses conservative mode outright
    hspec = HierarchySpec.from_spec(spec)
    with pytest.raises(ValueError):
        ops.KernelHierarchy(hspec, jax.random.key(0), mode="conservative")

    # state() is the linear-mode surface; signed exposes cs_state()
    with pytest.raises(ValueError):
        a.state()
    assert a.cs_state().table.shape == (spec.width, spec.table_size)

    # |f| >= 2^24 exceeds the two-limb exactness bound on int tables
    with pytest.raises(ValueError):
        ops.check_signed_kernel_freqs(
            np.array([1 << 24], np.int64), jnp.int32)
    ops.check_signed_kernel_freqs(np.array([-(1 << 23)], np.int64),
                                  jnp.int32)
