"""Model zoo: per-arch smoke tests + oracle checks for attention/SSD/MoE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced, shape_applicable
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# assigned-architecture smoke tests (deliverable f)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    params = tfm.init_params(cfg, KEY)
    b, s = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    embeds = None
    if cfg.frontend:
        embeds = jax.random.normal(jax.random.PRNGKey(2),
                                   (b, cfg.frontend_len, cfg.d_model),
                                   cfg.activation_dtype)
    logits, aux = tfm.forward(cfg, params, tokens, embeds=embeds)
    exp_len = s + (cfg.frontend_len if (cfg.frontend and not cfg.n_enc_layers) else 0)
    assert logits.shape == (b, exp_len, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf in logits"
    # one gradient step
    loss, grads = jax.value_and_grad(
        lambda p: tfm.loss_fn(cfg, p, tokens, embeds=embeds)[0])(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_parameter_budget(arch):
    """Full configs match their nameplate sizes (sanity on 6*N*D inputs)."""
    cfg = get_config(arch)
    n = cfg.param_count()["total"]
    nameplate = {
        "mamba2-130m": 0.13e9, "internvl2-26b": 20e9, "command-r-35b": 35e9,
        "gemma2-9b": 9e9, "starcoder2-7b": 7e9, "gemma-7b": 8.5e9,
        "mixtral-8x22b": 141e9, "dbrx-132b": 132e9,
        "jamba-1.5-large-398b": 398e9, "seamless-m4t-medium": 1.2e9,
    }[arch]
    assert 0.4 * nameplate <= n <= 2.1 * nameplate, f"{arch}: {n:,}"


def test_long_500k_applicability():
    subq = [a for a in ARCHS if shape_applicable(get_config(a), "long_500k")]
    assert sorted(subq) == ["jamba-1.5-large-398b", "mamba2-130m"]


# --------------------------------------------------------------------------
# attention oracles
# --------------------------------------------------------------------------

def _naive_gqa(cfg, p, x, window=0):
    """Reference: explicit per-head loop attention with causal mask."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    pos = jnp.arange(s)
    q = attn.apply_rope(q, pos, cfg.rope_theta)
    k = attn.apply_rope(k, pos, cfg.rope_theta)
    rep = cfg.n_heads // cfg.n_kv_heads
    outs = []
    for h in range(cfg.n_heads):
        qh = q[:, :, h, :].astype(jnp.float32)
        kh = k[:, :, h // rep, :].astype(jnp.float32)
        vh = v[:, :, h // rep, :].astype(jnp.float32)
        logits = qh @ kh.transpose(0, 2, 1) / np.sqrt(hd)
        i, j = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
        mask = j <= i
        if window:
            mask = mask & (j > i - window)
        logits = jnp.where(mask[None], logits, -1e30)
        outs.append(jax.nn.softmax(logits, -1) @ vh)
    o = jnp.stack(outs, axis=2).astype(x.dtype)
    return o.reshape(b, s, -1) @ p["wo"]


@pytest.mark.parametrize("n_kv,window", [(4, 0), (2, 0), (1, 0), (4, 8)])
def test_gqa_attention_matches_naive(n_kv, window):
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=n_kv, d_ff=128, vocab_size=64,
                      dtype="float32")
    p = attn.make_attn_params(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 24, 64), jnp.float32)
    got = attn.self_attention(cfg, p, x, jnp.arange(24), window)
    want = _naive_gqa(cfg, p, x, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_blockwise_attention_equals_dense():
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64,
                      dtype="float32", attn_chunk=16, attn_chunk_threshold=8)
    p = attn.make_attn_params(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 64), jnp.float32)
    got = attn.self_attention(cfg, p, x, jnp.arange(64), 0)  # blockwise path
    cfg2 = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64,
                       dtype="float32", attn_chunk_threshold=10_000)
    want = attn.self_attention(cfg2, p, x, jnp.arange(64), 0)  # dense path
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_attn_softcap_bounds_logit_influence():
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", attn_softcap=5.0)
    p = attn.make_attn_params(cfg, KEY)
    x = 100.0 * jax.random.normal(jax.random.PRNGKey(5), (1, 8, 32), jnp.float32)
    out = attn.self_attention(cfg, p, x, jnp.arange(8), 0)
    assert bool(jnp.isfinite(out).all())


# --------------------------------------------------------------------------
# Mamba2 / SSD oracle
# --------------------------------------------------------------------------

def _naive_ssm_scan(x, dtv, bmat, cmat, a, d_skip):
    """Token-by-token linear recurrence (the definitionally-correct SSM)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    hstate = np.zeros((b, h, n, p), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    for t in range(s):
        decay = np.exp(dtv[:, t] * a)                        # [B,H]
        hstate = hstate * decay[:, :, None, None] + np.einsum(
            "bn,bh,bhp->bhnp", bmat[:, t], dtv[:, t], x[:, t])
        ys[:, t] = np.einsum("bn,bhnp->bhp", cmat[:, t], hstate) \
            + d_skip[None, :, None] * x[:, t]
    return ys, hstate


def test_ssd_chunked_matches_sequential_recurrence():
    cfg = get_reduced("mamba2-130m")
    rng = np.random.default_rng(0)
    b, s = 2, 40  # not a multiple of chunk (16): exercises padding
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x = rng.standard_normal((b, s, h, p)).astype(np.float32)
    dtv = rng.uniform(0.01, 0.5, (b, s, h)).astype(np.float32)
    bmat = rng.standard_normal((b, s, n)).astype(np.float32)
    cmat = rng.standard_normal((b, s, n)).astype(np.float32)
    a = -rng.uniform(0.5, 2.0, (h,)).astype(np.float32)
    d_skip = rng.standard_normal((h,)).astype(np.float32)
    y, hf = ssm_mod._ssd_chunk_scan(cfg, jnp.asarray(x), jnp.asarray(dtv),
                                    jnp.asarray(bmat), jnp.asarray(cmat),
                                    jnp.asarray(a), jnp.asarray(d_skip),
                                    jnp.zeros((b, h, n, p), jnp.float32))
    y_ref, h_ref = _naive_ssm_scan(x, dtv, bmat, cmat, a, d_skip)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hf), h_ref, rtol=2e-3, atol=2e-3)


def test_ssm_decode_matches_forward():
    cfg = get_reduced("mamba2-130m")
    p = ssm_mod.make_ssm_params(cfg, KEY)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model),
                          jnp.float32)
    full = ssm_mod.ssm_forward(cfg, p, u)
    cache = ssm_mod.init_ssm_cache(cfg, 2)
    outs = []
    for t in range(12):
        o, cache = ssm_mod.ssm_decode(cfg, p, cache, u[:, t : t + 1])
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=3e-2, atol=3e-2)


# --------------------------------------------------------------------------
# MoE oracle
# --------------------------------------------------------------------------

def test_moe_dropless_matches_dense_mixture():
    cfg = get_reduced("mixtral-8x22b")
    p = moe_mod.make_moe_params(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model),
                          jnp.float32).astype(cfg.activation_dtype)
    got, aux = moe_mod.apply_moe(cfg, p, x)
    # dense oracle: every token through its top-k experts explicitly
    xt = x.reshape(-1, cfg.d_model)
    gates = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"], -1)
    wts, exps = jax.lax.top_k(gates, cfg.top_k)
    wts = wts / wts.sum(-1, keepdims=True)
    want = np.zeros(xt.shape, np.float32)
    for ti in range(xt.shape[0]):
        acc = np.zeros((cfg.d_model,), np.float32)
        for kk in range(cfg.top_k):
            e = int(exps[ti, kk])
            h = jax.nn.silu(xt[ti] @ p["w_gate"][e]) * (xt[ti] @ p["w_in"][e])
            acc += float(wts[ti, kk]) * np.asarray(
                (h @ p["w_out"][e]).astype(jnp.float32))
        want[ti] = acc
    np.testing.assert_allclose(np.asarray(got.reshape(-1, cfg.d_model),),
                               want, rtol=5e-2, atol=5e-2)
    assert float(aux["dropped_frac"]) == 0.0


def test_moe_capacity_drops_under_imbalance():
    cfg = get_reduced("mixtral-8x22b")
    import dataclasses
    cfg = dataclasses.replace(cfg, capacity_factor=1.0)
    p = moe_mod.make_moe_params(cfg, KEY)
    # big T so the capacity path (not dropless) is taken
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 512, cfg.d_model),
                          jnp.float32).astype(cfg.activation_dtype)
    _, aux = moe_mod.apply_moe(cfg, p, x)
    assert 0.0 <= float(aux["dropped_frac"]) < 0.8


# --------------------------------------------------------------------------
# decode equivalence across families (integration)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gemma2-9b", "command-r-35b",
                                  "seamless-m4t-medium", "mamba2-130m"])
def test_prefill_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    params = tfm.init_params(cfg, KEY)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s + 3), 0,
                                cfg.vocab_size)
    embeds = None
    if cfg.frontend:
        embeds = jax.random.normal(jax.random.PRNGKey(2),
                                   (b, cfg.frontend_len, cfg.d_model),
                                   cfg.activation_dtype)
    full, _ = tfm.forward(cfg, params, tokens, embeds=embeds)
    n_prefix = 0 if (cfg.n_enc_layers or not cfg.frontend) else cfg.frontend_len
    last, cache = tfm.prefill(cfg, params, tokens[:, :s], embeds=embeds,
                              max_len=n_prefix + s + 8)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full[:, n_prefix + s - 1]),
                               rtol=1e-2, atol=2e-2)
    pos = n_prefix + s
    for t in range(2):
        lg, cache = tfm.decode_step(cfg, params, cache,
                                    tokens[:, s + t : s + t + 1],
                                    jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, n_prefix + s + t]),
                                   rtol=2e-2, atol=5e-2)
        pos += 1
