"""Windowed heavy-hitter benchmarks (core/window.py + windowed serving).

Emitted as the common CSV rows and archived by CI as BENCH_WINDOW.json
(run via ``python -m benchmarks.run --only window``):

  * ``window/ingest_eN`` -- ingest + epoch-advance throughput of the
    windowed service as the ring grows (N = 4/8/16 epochs).  The ingest
    fold itself is epoch-count independent (one cascade fold into the head
    slot + the running window sum); what the sweep watches is the advance
    cost (one subtract) and any per-ring overhead creeping in.
  * ``window/query_eN`` -- merged-window topk latency, incremental running
    sum vs lazy O(N)-slot resum, same ring sizes.
  * ``window/accuracy_MODE`` -- live ARE / heavy-hitter F1 / F2 error of
    tumbling vs decay vs landmark over a DRIFTING stream (key popularity
    re-permuted every few epochs, streams.dstream.drifting_batches).  The
    windowed modes track the drift; landmark keeps averaging over dead
    heavy sets and degrades -- the number BENCH_WINDOW.json exists to
    prove.

CPU/interpret numbers: orchestration + jnp scatter costs, not kernel
speed (docs/benchmarks.md, "interpret-mode caveat").
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import sketch as sk
from repro.serving.windowed_topk import WindowedTopKService
from repro.streams import DStreamHarness, drifting_batches, zipf_hh_workload

_EPOCHS_SWEEP = (4, 8, 16)
_BLOCKS_PER_EPOCH = 2


def _workload():
    wl = zipf_hh_workload(n_occurrences=200_000, n_edges=20_000, seed=0)
    spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (256, 256), 4)
    return wl, spec


def window_ingest_throughput() -> None:
    wl, spec = _workload()
    items, freqs = wl.stream.items, wl.stream.freqs
    for n_epochs in _EPOCHS_SWEEP:
        svc = WindowedTopKService(spec, jax.random.PRNGKey(0),
                                  n_epochs=n_epochs)
        n_blocks = n_epochs * _BLOCKS_PER_EPOCH
        edges = np.linspace(0, len(items), n_blocks + 1).astype(int)
        # warmup: compile the fold + advance paths
        svc.ingest(items[: edges[1]], freqs[: edges[1]])
        svc.advance()
        t0 = time.perf_counter()
        for b, (s, e) in enumerate(zip(edges[:-1], edges[1:])):
            if b and b % _BLOCKS_PER_EPOCH == 0:
                svc.advance()
            svc.ingest(items[s:e], freqs[s:e])
        jax.block_until_ready(svc.state().states[-1].table)
        dt = time.perf_counter() - t0
        rows_per_s = len(items) / max(dt, 1e-9)
        emit(f"window/ingest_e{n_epochs}", dt * 1e6 / n_blocks,
             f"epochs={n_epochs};blocks={n_blocks};"
             f"rows_per_s={rows_per_s:.3e}")


def window_query_latency() -> None:
    wl, spec = _workload()
    items, freqs = wl.stream.items, wl.stream.freqs
    for n_epochs in _EPOCHS_SWEEP:
        svcs = {
            "inc": WindowedTopKService(spec, jax.random.PRNGKey(0),
                                       n_epochs=n_epochs, incremental=True),
            "lazy": WindowedTopKService(spec, jax.random.PRNGKey(0),
                                        n_epochs=n_epochs, incremental=False),
        }
        n_blocks = n_epochs * _BLOCKS_PER_EPOCH
        edges = np.linspace(0, len(items), n_blocks + 1).astype(int)
        for svc in svcs.values():
            for b, (s, e) in enumerate(zip(edges[:-1], edges[1:])):
                if b and b % _BLOCKS_PER_EPOCH == 0:
                    svc.advance()
                svc.ingest(items[s:e], freqs[s:e])
        for tag, svc in svcs.items():
            svc.topk(16)                       # warmup/compile
            t0 = time.perf_counter()
            reps = 3
            for _ in range(reps):
                top_items, top_est = svc.topk(16)
            dt = (time.perf_counter() - t0) / reps
            emit(f"window/query_e{n_epochs}_{tag}", dt * 1e6,
                 f"epochs={n_epochs};merge={tag};k=16;"
                 f"top1={int(top_est[0]) if len(top_est) else 0}")


def window_mode_accuracy() -> None:
    """Drifting stream: the accuracy case for windowing over since-boot.

    Two scores per mode.  ``are``/``recall``/``f2_rel_err`` measure the
    sketch against the mode's OWN exact semantics (how well the tables
    approximate what they claim to hold -- sketch error proper).
    ``recent_topk_recall`` measures the mode's top-k against the exact
    top-k of the LAST ``n_epochs`` epochs -- the "what is heavy right
    now" question real traffic asks.  Under drift the windowed modes
    track it and landmark keeps voting for dead heavy sets."""
    from repro.streams.dstream import ExactWindowCounter

    spec = sk.mod_sketch_spec(
        sk.KeySchema(domains=(1 << 20, 1 << 20)), [(0,), (1,)], (32, 32), 4)
    n_epochs, n_batches, k = 4, 24, 32
    for mode, decay in (("tumbling", 1.0), ("decay", 0.5),
                        ("landmark", 1.0)):
        svc = WindowedTopKService(spec, jax.random.PRNGKey(0),
                                  n_epochs=n_epochs, window_mode=mode,
                                  decay=decay)
        harness = DStreamHarness(svc, k=k, phi=0.01)
        recent = ExactWindowCounter(n_epochs, mode="tumbling")
        recent_recalls = []
        t0 = time.perf_counter()
        clock = 0
        for batch in drifting_batches(
                (1 << 20, 1 << 20), n_batches, rows_per_batch=4_000,
                batches_per_epoch=2, drift_every=3, n_keys=2_000, seed=0):
            while clock < batch.t:
                recent.advance()
                clock += 1
            recent.ingest(batch.items, batch.freqs)
            harness.step(batch)
            truth = recent.window_counts()
            exact_top = {kk for kk, _ in sorted(
                truth.items(), key=lambda kv: (-kv[1], kv[0]))[:k]}
            got_items, _ = svc.topk(k)
            got_top = {tuple(r) for r in got_items.tolist()}
            recent_recalls.append(
                len(exact_top & got_top) / max(len(exact_top), 1))
        dt = time.perf_counter() - t0
        # steady-state accuracy: average over the post-warmup half
        tail = harness.reports[len(harness.reports) // 2:]
        are = float(np.mean([r.are_topk for r in tail]))
        recall = float(np.mean([r.recall for r in tail]))
        f2_err = float(np.mean([r.f2_rel_err for r in tail]))
        recent_recall = float(np.mean(recent_recalls[len(recent_recalls) // 2:]))
        emit(f"window/accuracy_{mode}", dt * 1e6 / n_batches,
             f"mode={mode};decay={decay};are={are:.4f};recall={recall:.3f};"
             f"recent_topk_recall={recent_recall:.3f};"
             f"f2_rel_err={f2_err:.4f};epochs={n_epochs};batches={n_batches}")


ALL = [window_ingest_throughput, window_query_latency, window_mode_accuracy]
