"""Gradient-compression benchmarks -> BENCH_GRAD.json.

Run via ``python -m benchmarks.run --only grad_compression``:

  * ``grad/descent_vs_dense`` -- the headline latency pair: top-k
    selection on a row-resolving leaf (beta_rows_cols skews the budget
    so level 0 gets one cell per row) via beam descent
    (training.grad_compression._descend_topk: level-0 row ranking ->
    beam * cols signed candidate grid) vs the dense dequery baseline
    (finest-level median of every coordinate -- the [w, N]
    materialization the descent replaces).  Both paths are jitted and
    produce identical above-noise selections (tests/test_training.py::
    test_compression_descent_matches_dense_dequery).
  * ``grad/relerr_ratio_*`` -- per-step relative error of one
    compress -> decompress round trip at increasing compression ratios,
    with the bytes-accurate ratio (tables + 8k second round) alongside
    the nominal config ratio.
  * ``grad/allreduce_bytes`` -- bytes crossing the DP axis per step:
    dense gradient all-reduce (4N) vs the sketch protocol's table
    all-reduce + k exact values (4 * sum_L w*h_L + 8k), timed over the
    full compress_decompress step for context.

CPU/interpret numbers: orchestration + jnp scatter costs, not kernel
speed (docs/benchmarks.md, "interpret-mode caveat").
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import countsketch as cs
from repro.training import grad_compression as gc

_KEY = jax.random.PRNGKey(0)


def _planted_grad(rng, shape, n_hot: int, mag: float = 8.0) -> np.ndarray:
    g = rng.standard_normal(shape).astype(np.float32) * 0.01
    n = g.size
    hot = rng.choice(n, n_hot, replace=False)
    g.reshape(-1)[hot] += rng.choice([-mag, mag], n_hot).astype(np.float32)
    return g


def grad_compression_descent_vs_dense() -> None:
    # Row-resolving split: h = 1024*1024/(4*3) = 87381, beta=16 ->
    # ranges = (1024, 85); k=64 -> beam=128 scans 128*1024 candidates
    # instead of the dense baseline's 1024*1024.
    shape = (1024, 1024)
    cfg = gc.CompressionConfig(enabled=True, width=3, ratio=4.0,
                               min_size=256, beta_rows_cols=16.0, k=64)
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(_planted_grad(rng, shape, 32))}
    state = gc.init_compression(cfg, g, _KEY)
    comp = state.compressors["w"]
    plan = comp.plan
    assert plan.beam < plan.rows, "descent must actually prune rows"

    vals = g["w"].reshape(-1)
    tables = tuple(jnp.zeros((s.width, s.table_size), jnp.float32)
                   for s in plan.hspec.levels)
    tables = cs.hier_fold_tables(plan.hspec, comp.params, tables,
                                 comp.coords, vals)

    descend = jax.jit(lambda t: gc._descend_topk(plan, comp.params, t))

    def dense_topk(t):
        hstate = cs.CountSketchHierarchy(comp.params, t)
        est = cs.hier_query(plan.hspec, hstate, 1, comp.coords)
        return jax.lax.top_k(jnp.abs(est), plan.k)[1]

    dense = jax.jit(dense_topk)

    us_descent, sel_d = timed(lambda: jax.block_until_ready(descend(tables)))
    us_dense, sel_n = timed(lambda: jax.block_until_ready(dense(tables)))
    scanned = plan.beam * plan.cols + plan.rows
    emit("grad/descent_vs_dense", us_descent,
         f"dense_us={us_dense:.1f};speedup={us_dense / us_descent:.2f};"
         f"beam={plan.beam};rows={plan.rows};k={plan.k};"
         f"scanned={scanned};n={plan.rows * plan.cols}")


def grad_compression_relerr_vs_ratio() -> None:
    shape = (256, 256)
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(_planted_grad(rng, shape, 24))}
    for ratio in (4.0, 16.0, 64.0):
        cfg = gc.CompressionConfig(enabled=True, width=3, ratio=ratio,
                                   min_size=256)
        state = gc.init_compression(cfg, g, _KEY)
        t0 = time.perf_counter()
        _, _, metrics = jax.block_until_ready(
            gc.compress_decompress(cfg, g, state))
        us = (time.perf_counter() - t0) * 1e6
        emit(f"grad/relerr_ratio_{int(ratio)}", us,
             f"rel_err={float(metrics['compress_rel_err']):.4f};"
             f"nominal_ratio={ratio};"
             f"bytes_ratio={gc.compression_ratio(cfg, g):.2f}")


def grad_compression_allreduce_bytes() -> None:
    shape = (512, 512)
    cfg = gc.CompressionConfig(enabled=True, width=3, ratio=16.0,
                               min_size=256)
    rng = np.random.default_rng(2)
    g = {"w": jnp.asarray(_planted_grad(rng, shape, 24))}
    state = gc.init_compression(cfg, g, _KEY)
    plan = state.compressors["w"].plan
    grad_bytes = 4 * plan.rows * plan.cols
    table_bytes = 4 * sum(s.width * s.table_size for s in plan.hspec.levels)
    wire_bytes = table_bytes + 8 * plan.k

    step = jax.jit(gc.compress_decompress, static_argnums=0)
    us, _ = timed(lambda: jax.block_until_ready(step(cfg, g, state)))
    emit("grad/allreduce_bytes", us,
         f"grad_allreduce_bytes={grad_bytes};"
         f"table_allreduce_bytes={wire_bytes};"
         f"bytes_saved_x={grad_bytes / wire_bytes:.2f};k={plan.k}")


ALL = [grad_compression_descent_vs_dense, grad_compression_relerr_vs_ratio,
       grad_compression_allreduce_bytes]
