"""Heavy-hitter subsystem benchmark.

    PYTHONPATH=src python benchmarks/hh_bench.py

Measures, on the zipf edge workload:

  * hierarchy build cost vs the flat base sketch (the per-level overhead),
  * find_heavy_hitters descent vs brute force (query every distinct key at
    the leaf level) -- the pruning win grows with the candidate universe,
  * the Pallas candidate kernel vs the jnp gather reference on one descent
    level (interpret mode on CPU; on TPU set interpret=False for real
    numbers).

Emits the common CSV rows (name, us_per_call, derived).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import hierarchy as hh
from repro.core import sketch as sk
from repro.streams import zipf_hh_workload


def main() -> None:
    key = jax.random.PRNGKey(0)
    wl = zipf_hh_workload(n_occurrences=200_000, n_edges=20_000, seed=0)
    stream = wl.stream
    base = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (512, 512), 4)
    hspec = hh.HierarchySpec.from_spec(base)
    cands = wl.candidates(base)

    us, state = timed(hh.build_hierarchy, hspec, key, stream.items,
                      stream.freqs, repeat=1)
    emit("hh/build_hierarchy", us, f"levels={hspec.n_levels}")
    us_flat, flat = timed(sk.build_sketch, base, key, stream.items,
                          stream.freqs, repeat=1)
    emit("hh/build_flat_base", us_flat, f"overhead={us / max(us_flat, 1):.2f}x")

    us, (items, est) = timed(hh.find_heavy_hitters, hspec, state,
                             wl.threshold, cands, repeat=1)
    exact = {tuple(r) for r in wl.exact_items.tolist()}
    got = {tuple(r) for r in items.tolist()}
    emit("hh/descent", us,
         f"reported={len(got)};false_neg={len(exact - got)}")

    # brute force: query every distinct key against the flat sketch
    def brute():
        q = sk.query_jit(base, flat, jnp.asarray(stream.items))
        q = np.asarray(q)
        keep = q >= wl.threshold
        return stream.items[keep], q[keep]

    us_bf, (bf_items, _) = timed(brute, repeat=1)
    emit("hh/brute_force", us_bf,
         f"distinct={len(stream.items)};brute/descent={us_bf / max(us, 1):.2f}x")

    # kernel vs reference on one representative descent level.  NOTE: on CPU
    # the Pallas path runs in interpret mode (Python per grid step) and is
    # orders of magnitude slower than the jnp reference; the row exists to
    # track the TPU number (interpret=False), not to be read on CPU.
    prefixes = np.unique(stream.items[:, 0])[:64][:, None]
    values = np.unique(stream.items[:, 1])[:128][:, None]
    for use_kernel, name, rep in ((False, "hh/cand_query_ref", 3),
                                  (True, "hh/cand_query_pallas", 1)):
        us, grid = timed(hh.candidate_estimates, hspec, state, 1,
                         prefixes, values, use_kernel=use_kernel, repeat=rep)
        emit(name, us, f"grid={grid.shape[0]}x{grid.shape[1]}")


if __name__ == "__main__":
    main()
