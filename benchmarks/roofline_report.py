"""Roofline report: aggregate the dry-run cell JSONs into the SRoofline table.

Reads benchmarks/results/dryrun/*.json (produced by repro.launch.dryrun) and
emits one CSV row per cell plus markdown tables for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_cells(variant: str = "baseline") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{variant}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_rows(variant: str = "baseline") -> None:
    for c in load_cells(variant):
        emit(
            f"roofline_{c['arch']}_{c['shape']}_{c['mesh']}",
            c["compile_s"] * 1e6,
            f"bottleneck={c['bottleneck']};t_comp={c['t_compute_s']:.3e};"
            f"t_mem={c['t_memory_s']:.3e};t_coll={c['t_collective_s']:.3e};"
            f"useful={c['useful_flops_frac']:.3f};"
            f"roofline_frac={c['roofline_frac']:.4f}",
        )


def markdown_table(variant: str = "baseline", mesh: str = "pod16x16") -> str:
    rows = [c for c in load_cells(variant) if c["mesh"] == mesh]
    rows.sort(key=lambda c: (c["arch"], c["shape"]))
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
           "useful | roofline |",
           "|---|---|---|---|---|---|---|---|"]
    for c in rows:
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['t_compute_s']:.2e} | "
            f"{c['t_memory_s']:.2e} | {c['t_collective_s']:.2e} | "
            f"{c['bottleneck']} | {c['useful_flops_frac']:.2f} | "
            f"{c['roofline_frac']:.4f} |")
    return "\n".join(out)


ALL = [roofline_rows]

if __name__ == "__main__":
    print(markdown_table())
