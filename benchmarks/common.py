"""Shared benchmark machinery: streams, sketch evaluation, CSV emission."""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.range_opt import optimal_ranges_mod2
from repro.streams import (
    Stream,
    ipv4_stream,
    observed_error,
    reinterpret_modularity,
    zipf_graph_stream,
)

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def rows_as_records() -> List[dict]:
    """Emitted rows as JSON-ready records, ``derived`` parsed into k=v pairs
    (the BENCH_*.json artifact schema; see benchmarks/run.py)."""
    records = []
    for row in ROWS:
        name, us, derived = row.split(",", 2)
        parsed = {}
        for part in derived.split(";"):
            if "=" in part:
                k, v = part.split("=", 1)
                parsed[k] = v
        records.append({"name": name, "us_per_call": float(us),
                        "derived": parsed, "raw": derived})
    return records


@functools.lru_cache(maxsize=None)
def twitter_like() -> Stream:
    """Mild-skew graph stream, #targets ~ 3x #sources (Table III shape),
    heavy overload distinct/h like the paper's Twitter (~78x at h=1e6)."""
    return zipf_graph_stream(n_src=20_000, n_tgt=60_000, n_edges=400_000,
                             n_occurrences=2_000_000, s_src=0.7, s_tgt=0.7,
                             seed=0, name="twitter-like")


@functools.lru_cache(maxsize=None)
def ipv4_like(which: int = 1) -> Stream:
    """#sources ~ 10x #targets (CAIDA probing shape)."""
    return ipv4_stream(n_src_hosts=30_000, n_tgt_hosts=3_000, n_pairs=120_000,
                       n_occurrences=2_000_000, seed=which,
                       name=f"ipv4-{which}-like")


def sketch_error(spec: sk.SketchSpec, stream: Stream, key,
                 queries: Tuple[np.ndarray, np.ndarray]) -> float:
    state = sk.build_sketch(spec, key, stream.items, stream.freqs)
    qi, qf = queries
    est = np.asarray(sk.query_jit(spec, state, jnp.asarray(qi)))
    return observed_error(est, qf)


def standard_specs(stream: Stream, h: int, w: int, sample_frac: float = 0.02,
                   seed: int = 0) -> Dict[str, sk.SketchSpec]:
    rng = np.random.default_rng(seed)
    s_items, s_freqs = stream.sample(sample_frac, rng)
    a, b = optimal_ranges_mod2(s_items, s_freqs, h)
    return {
        "count-min": sk.count_min_spec(stream.schema, h, w),
        "equal-sketch": sk.equal_sketch_spec(stream.schema, h, w),
        "mod-sketch": sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (a, b), w),
    }


def timed(fn, *args, repeat: int = 3, **kw) -> Tuple[float, object]:
    out = fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return dt * 1e6, out
