"""Empirical check of the paper's probabilistic bounds (Thms 1 and 2)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, twitter_like
from repro.core import sketch as sk

KEY = jax.random.PRNGKey(0)


def bounds_check() -> None:
    stream = twitter_like()
    L = stream.total
    h, w = 4096, 4
    t0 = time.perf_counter()
    qi, qf = stream.random_k_queries(2000, np.random.default_rng(0))

    # Thm 1 (Count-Min): P[est > true + eps*L] <= (1/(h*eps))^w
    eps = 4.0 / h
    cm = sk.count_min_spec(stream.schema, h, w)
    st = sk.build_sketch(cm, KEY, stream.items, stream.freqs)
    est = np.asarray(sk.query_jit(cm, st, jnp.asarray(qi)))
    viol_cm = float(np.mean(est > qf + eps * L))
    bound_cm = (1.0 / (h * eps)) ** w

    # Thm 2 (MOD): est <= true + [L + O(*,x2)*b + O(x1,*)*a] * eps'
    a, b = 64, 64
    eps2 = 12.0 / (a * b)
    mod = sk.mod_sketch_spec(stream.schema, [(0,), (1,)], (a, b), w)
    st2 = sk.build_sketch(mod, KEY, stream.items, stream.freqs)
    est2 = np.asarray(sk.query_jit(mod, st2, jnp.asarray(qi)))
    from repro.streams.stats import exact_marginals
    o1 = exact_marginals(stream.items, stream.freqs, [0])
    o2 = exact_marginals(stream.items, stream.freqs, [1])
    # align marginals with the queried rows
    import numpy as _np
    packed = stream.items[:, 0].astype(_np.uint64) << _np.uint64(32) | stream.items[:, 1]
    qpacked = qi[:, 0].astype(_np.uint64) << _np.uint64(32) | qi[:, 1]
    idx = {int(k): i for i, k in enumerate(packed)}
    rows = _np.array([idx[int(k)] for k in qpacked])
    slack = (L + o2[rows] * b + o1[rows] * a) * eps2
    viol_mod = float(np.mean(est2 > qf + slack))
    bound_mod = (3.0 / (a * b * eps2)) ** w
    us = (time.perf_counter() - t0) * 1e6
    emit("bounds_thm1_thm2", us,
         f"thm1_viol={viol_cm:.4f}<=bound={bound_cm:.4f};"
         f"thm2_viol={viol_mod:.4f}<=bound={bound_mod:.4f};"
         f"holds={viol_cm <= bound_cm + 0.01 and viol_mod <= bound_mod + 0.01}")


ALL = [bounds_check]
