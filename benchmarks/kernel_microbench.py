"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp reference.

On this container the Pallas body runs interpreted (Python), so the wall
times below measure the REFERENCE path's throughput and validate kernel
equivalence at realistic shapes; the MXU-utilisation claims live in the
roofline analysis.  On TPU, set interpret=False and re-run.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import sketch as sk
from repro.core.hashing import KeySchema
from repro.kernels import ref
from repro.kernels.hashes import make_plan
from repro.kernels.sketch_update import padded_table_size, sketch_update_pallas
from repro.kernels.sketch_update_conservative import (
    conservative_chunk_b,
    sketch_update_conservative_pallas,
)

KEY = jax.random.PRNGKey(0)


def kernel_update_equivalence() -> None:
    rng = np.random.default_rng(0)
    schema = KeySchema(domains=(1 << 32, 1 << 32))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (1024, 1024), 5)
    plan = make_plan(spec)
    params = sk.init_params(spec, KEY)
    b = 4096
    items = rng.integers(0, 1 << 32, size=(b, 2), dtype=np.uint64).astype(np.uint32)
    freqs = rng.integers(1, 100, size=(b,)).astype(np.int32)
    chunks = schema.module_chunks(jnp.asarray(items))
    h_pad = padded_table_size(spec.table_size, 512)
    t0 = jnp.zeros((spec.width, h_pad), jnp.int32)

    us_ref, want = timed(lambda: jax.block_until_ready(
        ref.sketch_update_ref(plan, t0, chunks, jnp.asarray(freqs),
                              params.q, params.r)))
    t_int0 = time.perf_counter()
    got = sketch_update_pallas(plan, t0, chunks, jnp.asarray(freqs),
                               params.q, params.r, tile_h=512, interpret=True)
    t_int = time.perf_counter() - t_int0
    exact = bool((np.asarray(got) == np.asarray(want)).all())
    emit("kernel_update_ref_path", us_ref,
         f"items_per_s={b / (us_ref / 1e6):.3e};pallas_interpret_exact={exact};"
         f"interpret_s={t_int:.1f}")


def kernel_update_conservative() -> None:
    """Linear vs conservative update throughput on the same stream block.

    The conservative path is sequential in B (min-gather + max-scatter per
    item), so its throughput floor is structural, not incidental; this case
    records the linear-vs-conservative ratio alongside kernel/reference
    parity.  On this container both jnp references are the timed paths and
    the Pallas kernels run interpreted for the parity bit.
    """
    rng = np.random.default_rng(1)
    schema = KeySchema(domains=(1 << 32, 1 << 32))
    spec = sk.mod_sketch_spec(schema, [(0,), (1,)], (256, 256), 4)
    plan = make_plan(spec)
    params = sk.init_params(spec, KEY)
    b = 1024
    items = rng.integers(0, 1 << 32, size=(b, 2), dtype=np.uint64).astype(np.uint32)
    items[: b // 8] = items[0]  # duplicate-heavy head, the skewed-stream case
    freqs = rng.integers(1, 100, size=(b,)).astype(np.int32)
    chunks = schema.module_chunks(jnp.asarray(items))
    h_pad = padded_table_size(spec.table_size, 512)
    t0 = jnp.zeros((spec.width, h_pad), jnp.int32)

    us_lin, _ = timed(lambda: jax.block_until_ready(
        ref.sketch_update_ref(plan, t0, chunks, jnp.asarray(freqs),
                              params.q, params.r)))
    def cons_once():
        # fresh zero table per call: update_conservative_jit donates its
        # table arg, so a shared state0 would be consumed on the first call
        state0 = sk.SketchState(
            params=params,
            table=jnp.zeros((spec.width, spec.table_size), jnp.int32))
        return jax.block_until_ready(
            sk.update_conservative_jit(spec, state0, jnp.asarray(items),
                                       jnp.asarray(freqs)).table)

    us_cons, want = timed(cons_once)

    t_int0 = time.perf_counter()
    got = sketch_update_conservative_pallas(
        plan, t0, chunks, jnp.asarray(freqs), params.q, params.r,
        interpret=True)
    t_int = time.perf_counter() - t_int0
    exact = bool((np.asarray(got)[:, : spec.table_size]
                  == np.asarray(want)).all())
    chunk = conservative_chunk_b(b, chunks.shape[1], spec.width, h_pad, 4)
    emit("kernel_update_conservative", us_cons,
         f"items_per_s={b / (us_cons / 1e6):.3e};"
         f"linear_items_per_s={b / (us_lin / 1e6):.3e};"
         f"linear_vs_conservative={us_cons / us_lin:.2f}x;"
         f"chunk_b={chunk};pallas_interpret_exact={exact};"
         f"interpret_s={t_int:.1f}")


def kernel_vmem_budget() -> None:
    """Structural check: worst-case VMEM working set of the update kernel."""
    b, tile_h, c = 1024, 512, 4
    onehot = b * tile_h * 4
    chunks = b * c * 4
    freqs = 2 * b * 4
    tile = tile_h * 4
    total = onehot + chunks + freqs + tile
    emit("kernel_vmem_budget", 0.0,
         f"bytes={total};mb={total / 2**20:.2f};fits_16mb_vmem={total < 16 * 2**20}")


ALL = [kernel_update_equivalence, kernel_update_conservative,
       kernel_vmem_budget]
