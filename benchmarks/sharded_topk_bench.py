"""Sharded heavy-hitter serving benchmarks (serving/sharded_topk.py).

Two sweeps, both emitted as the common CSV rows and archived by CI as
BENCH_*.json (run via ``python -m benchmarks.run --only sharded``):

  * ingest throughput vs shard count -- the per-shard lazy fold scales the
    ingest path over the mesh's data axis; shard counts sweep the divisors
    of the available device count (force more CPU devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, as the CI bench
    job does),
  * sync cadence -- how much of the ingest wall time the psum sync point
    costs as the merge all-reduce is amortized over more blocks.

On a single-device run only the 1-shard rows are produced (the sweep
adapts rather than failing), which keeps the bench usable in any
container.  CPU numbers track the collective/orchestration overheads, not
kernel speed; re-run on hardware for real throughput.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import sketch as sk
from repro.serving.sharded_topk import ShardedTopKService
from repro.streams import zipf_hh_workload

_BLOCKS = 8


def _workload():
    wl = zipf_hh_workload(n_occurrences=200_000, n_edges=20_000, seed=0)
    spec = sk.mod_sketch_spec(wl.stream.schema, [(0,), (1,)], (256, 256), 4)
    return wl, spec


def _block_edges(n: int):
    return np.linspace(0, n, _BLOCKS + 1).astype(int)


def sharded_ingest_throughput() -> None:
    wl, spec = _workload()
    items, freqs = wl.stream.items, wl.stream.freqs
    counts = [c for c in (1, 2, 4, 8) if c <= jax.device_count()]
    edges = _block_edges(len(items))
    for c in counts:
        mesh = jax.make_mesh((c,), ("data",))
        svc = ShardedTopKService(spec, jax.random.PRNGKey(0), mesh,
                                 sync_every=None)
        # warmup: compile the per-shard fold for this shard count
        svc.ingest(items[: edges[1]], freqs[: edges[1]])
        svc.sync()
        t0 = time.perf_counter()
        for s, e in zip(edges[:-1], edges[1:]):
            svc.ingest(items[s:e], freqs[s:e])
        jax.block_until_ready(svc._local)
        dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        svc.sync()
        jax.block_until_ready(svc.state().states[0].table)
        dt_sync = time.perf_counter() - t0
        rows_per_s = len(items) / max(dt, 1e-9)
        emit(f"sharded/ingest_s{c}", dt * 1e6 / _BLOCKS,
             f"shards={c};rows_per_s={rows_per_s:.3e};"
             f"sync_us={dt_sync * 1e6:.1f}")


def sharded_sync_cadence() -> None:
    wl, spec = _workload()
    items, freqs = wl.stream.items, wl.stream.freqs
    c = max(c for c in (1, 2, 4, 8) if c <= jax.device_count())
    mesh = jax.make_mesh((c,), ("data",))
    edges = _block_edges(len(items))
    for cadence in (1, 4, _BLOCKS):
        svc = ShardedTopKService(spec, jax.random.PRNGKey(0), mesh,
                                 sync_every=cadence)
        svc.ingest(items[: edges[1]], freqs[: edges[1]])  # warmup/compile
        svc.sync()
        t0 = time.perf_counter()
        for s, e in zip(edges[:-1], edges[1:]):
            svc.ingest(items[s:e], freqs[s:e])
        svc.sync()
        jax.block_until_ready(svc.state().states[0].table)
        dt = time.perf_counter() - t0
        n_syncs = -(-_BLOCKS // cadence)
        emit(f"sharded/sync_every_{cadence}", dt * 1e6 / _BLOCKS,
             f"shards={c};syncs={n_syncs};wall_s={dt:.3f}")


def sharded_query_after_sync() -> None:
    """End-to-end: topk served from the merged tables (descent included)."""
    wl, spec = _workload()
    c = max(cc for cc in (1, 2, 4, 8) if cc <= jax.device_count())
    mesh = jax.make_mesh((c,), ("data",))
    svc = ShardedTopKService(spec, jax.random.PRNGKey(0), mesh)
    svc.ingest(wl.stream.items, wl.stream.freqs)
    t0 = time.perf_counter()
    items, est = svc.topk(16)
    dt = time.perf_counter() - t0
    exact = {tuple(r) for r in wl.exact_items[:16].tolist()}
    got = {tuple(r) for r in items.tolist()}
    emit("sharded/topk16", dt * 1e6,
         f"shards={c};hit16={len(exact & got)};est0={int(est[0])}")


ALL = [sharded_ingest_throughput, sharded_sync_cadence,
       sharded_query_after_sync]
