"""Benchmark driver: one function per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV (see benchmarks.common.emit) and
writes the same rows as a ``BENCH_*.json`` artifact (``--json-out``) so CI
can archive the perf trajectory run over run.
Usage: PYTHONPATH=src python -m benchmarks.run [--only substring]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run benchmarks whose name contains this substring")
    ap.add_argument("--json-out", default="BENCH_RESULTS.json",
                    help="path of the JSON artifact (BENCH_*.json pattern); "
                         "'' disables")
    args = ap.parse_args()

    import jax

    from benchmarks import bounds_check, common, grad_compression_bench, \
        hierarchy_ingest_bench, kernel_microbench, migrate_bench, \
        paper_figs, recovery_bench, roofline_report, serve_bench, \
        sharded_topk_bench, window_bench
    benches = (paper_figs.ALL + bounds_check.ALL + kernel_microbench.ALL
               + roofline_report.ALL + sharded_topk_bench.ALL
               + hierarchy_ingest_bench.ALL + window_bench.ALL
               + migrate_bench.ALL + serve_bench.ALL
               + grad_compression_bench.ALL + recovery_bench.ALL)
    print("name,us_per_call,derived")
    t_start = time.time()
    failures = []
    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:
            failures.append(fn.__name__)
            print(f"{fn.__name__},-1,ERROR", flush=True)
            traceback.print_exc()
        print(f"# {fn.__name__} finished in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr, flush=True)

    if args.json_out:
        artifact = {
            "started_unix": t_start,
            "wall_s": time.time() - t_start,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "only": args.only,
            "failures": failures,
            "results": common.rows_as_records(),
        }
        with open(args.json_out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# wrote {args.json_out} ({len(artifact['results'])} rows)",
              file=sys.stderr, flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
