"""Benchmark driver: one function per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV (see benchmarks.common.emit).
Usage: PYTHONPATH=src python -m benchmarks.run [--only substring]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run benchmarks whose name contains this substring")
    args = ap.parse_args()

    from benchmarks import bounds_check, kernel_microbench, paper_figs, roofline_report
    benches = (paper_figs.ALL + bounds_check.ALL + kernel_microbench.ALL
               + roofline_report.ALL)
    print("name,us_per_call,derived")
    failures = 0
    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:
            failures += 1
            print(f"{fn.__name__},-1,ERROR", flush=True)
            traceback.print_exc()
        print(f"# {fn.__name__} finished in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr, flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
